"""Batched serving: prefill a batch of prompts, decode with donated rolling
caches, repeat fully on-device (the autorun analogue) and compare
throughput — then serve a request stream through the continuous-batching
engine (paged KV cache, eviction/refill between ticks).

  PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x7b
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import flow as rflow
from repro.configs.base import FlowConfig, ShapeConfig
from repro.serving.engine import Engine, EngineConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=24)
    args = ap.parse_args()

    shape = ShapeConfig("serve", "decode", args.prompt_len + args.steps,
                        args.batch)
    cm = rflow.compile(args.arch, shape, FlowConfig(mode="folded"),
                       smoke=True)
    cfg = cm.cfg
    params = cm.init_params(jax.random.key(0))
    eng = Engine(cm, params, EngineConfig(temperature=0.0))

    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.n_patch_tokens:
        batch["patches"] = jnp.asarray(
            rng.randn(args.batch, cfg.n_patch_tokens, cfg.d_vision),
            jnp.float32)
    if cfg.n_encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.randn(args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)

    t0 = time.time()
    toks, _ = eng.generate(batch, args.steps)          # host-driven loop
    t_host = time.time() - t0
    t0 = time.time()
    toks2 = eng.generate_fori(batch, args.steps)       # one on-device program
    t_dev = time.time() - t0
    assert np.array_equal(np.asarray(toks), np.asarray(toks2)[:, :args.steps])
    tps = args.batch * args.steps
    print(f"host loop:      {tps / t_host:8.1f} tok/s")
    print(f"on-device loop: {tps / t_dev:8.1f} tok/s (incl. compile)")
    print("sample:", np.asarray(toks)[0].tolist())

    if cfg.attention is not None and not cfg.cross_attention:
        # continuous batching: 2x oversubscribed request stream through the
        # paged KV pool — finished sequences evicted, queue refills slots
        from repro.serving import EngineConfig as ECfg, synthetic_requests
        # fixed prompt lengths + an exact prompt bucket: on TPU the
        # flash-attention prefill masks by iota, so the engine (correctly)
        # refuses left-padded buckets there
        total = args.prompt_len + args.steps
        # block size must divide every prompt-bucket rung (EngineConfig
        # validates); fall back through the pow2 ladder until one fits
        block = next(b for b in (16, 8, 4, 2, 1)
                     if args.prompt_len % b == 0 and total % b == 0)
        eng2 = Engine(cm, params, ECfg(
            max_batch=args.batch,
            max_seq_len=total,
            prompt_buckets=(args.prompt_len, total),
            block_size=block))
        reqs = synthetic_requests(2 * args.batch, cfg.vocab_size,
                                  prompt_len=args.prompt_len,
                                  max_new_tokens=args.steps,
                                  vary_lens=False)
        report = eng2.run(reqs)
        print(report.describe())


if __name__ == "__main__":
    main()
