"""Quickstart: the compilation flow end to end through the public API.

``repro.flow.compile`` is the one front door: frozen model (config) in,
compiled model out.  The returned ``CompiledModel`` owns the ExecutionPlan,
the jitted train/prefill/decode/generate callables and the flow report;
kernel backends resolve per op through the KernelRegistry (``backend="auto"``
→ Pallas on TPU, reference on CPU).

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import flow
from repro.configs.base import FlowConfig, ShapeConfig
from repro.optim.adamw import AdamW


def main():
    shape = ShapeConfig("quickstart", "train", 32, 4)

    # --- the flow: one call — graph -> passes -> plan -> compiled model ----
    cm = flow.compile("llama3.2-1b", shape, smoke=True)
    print(cm.describe())
    n_ops = sum(len(b.ops) for b in cm.plan.graph.blocks)
    fused = [op.op for b in cm.plan.graph.blocks for op in b.ops
             if op.attrs.get("act") or op.op == "glu_matmul"]
    print(f"micro-ops after LF fusion: {n_ops}; "
          f"fused kernels: {sorted(set(fused))}")

    # --- base configuration (the paper's unoptimized kernels) --------------
    base = flow.compile("llama3.2-1b", shape, FlowConfig().base(), smoke=True)
    print(f"base flow: mode={base.plan.stream.mode} "
          f"precision={base.flow.precision} "
          f"folded={any(u.folded for u in base.plan.units)}")

    # --- one training step --------------------------------------------------
    cfg = cm.cfg
    params = cm.init_params(jax.random.key(0))
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 32)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 32)),
                                   jnp.int32)}
    opt = AdamW(lr=1e-3)
    step = cm.train_step(opt)
    params, _, metrics = step(params, opt.init(params), batch)
    print(f"train step: loss={float(metrics['loss']):.4f} "
          f"acc={float(metrics['acc']):.3f}")

    # --- batched generation (prefill -> rolling-cache decode) ---------------
    toks, _ = cm.generate(params, {"tokens": batch["tokens"][:, :16]},
                          steps=8)
    print(f"generated: {np.asarray(toks)[0].tolist()}")
    print(cm.describe(stats=True).splitlines()[-1])   # per-stage compile stats


if __name__ == "__main__":
    main()
