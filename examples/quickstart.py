"""Quickstart: the compilation flow end to end on one small model.

Builds the graph for llama3.2-1b (reduced config), shows what each pass did
(fusion rewrites, folding groups, tile selection), runs one training step and
generates a few tokens.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.configs.base import FlowConfig, ShapeConfig
from repro.core import lowering
from repro.core.plan import build_plan
from repro.models.lm import build_graph
from repro.serving.engine import Engine, EngineConfig


def main():
    cfg = get_smoke("llama3.2-1b")
    shape = ShapeConfig("quickstart", "train", 32, 4)

    # --- the flow: graph -> passes -> plan ---------------------------------
    raw = build_graph(cfg)
    n_ops_before = sum(len(b.ops) for b in raw.blocks)
    plan = build_plan(cfg, FlowConfig(mode="folded"), shape)
    n_ops_after = sum(len(b.ops) for b in plan.graph.blocks)
    print(plan.describe())
    print(f"LF fusion: {n_ops_before} micro-ops -> {n_ops_after}")
    fused = [op.op for b in plan.graph.blocks for op in b.ops
             if op.attrs.get("act") or op.op == "glu_matmul"]
    print(f"fused kernels: {sorted(set(fused))}")

    # --- base configuration (the paper's unoptimized kernels) --------------
    base = build_plan(cfg, FlowConfig().base(), shape)
    print(f"base flow: mode={base.stream.mode} precision="
          f"{base.flow.precision} folded={any(u.folded for u in base.units)}")

    # --- one training step ---------------------------------------------------
    params = lowering.init_params(plan, jax.random.key(0))
    loss_fn = lowering.make_loss_fn(plan)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 32)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 32)),
                                   jnp.int32)}
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(loss_fn, has_aux=True))(params, batch)
    print(f"train step: loss={float(loss):.4f} "
          f"acc={float(metrics['acc']):.3f}")

    # --- batched generation (prefill -> rolling-cache decode) ---------------
    eng = Engine(plan, params, EngineConfig(temperature=0.0))
    toks, _ = eng.generate({"tokens": batch["tokens"][:, :16]}, steps=8)
    print(f"generated: {np.asarray(toks)[0].tolist()}")


if __name__ == "__main__":
    main()
