"""The paper's own networks through the flow: LeNet-5 (pipelined mode),
MobileNetV1 and ResNet-34 (folded mode), base vs optimized configuration —
the Table III/IV story at CPU-runnable scale.

  PYTHONPATH=src python examples/paper_cnns.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import flow as rflow
from repro.configs import get_config, get_smoke
from repro.configs.base import FlowConfig, ShapeConfig

SERVE = ShapeConfig("serve", "prefill", 64, 8)


def bench(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e3)


def main():
    for name, cfg, B in [("lenet5", get_config("lenet5"), 16),
                         ("mobilenetv1", get_smoke("mobilenetv1"), 2),
                         ("resnet34", get_smoke("resnet34"), 2)]:
        rng = np.random.RandomState(0)
        batch = {"images": jnp.asarray(
            rng.randn(B, cfg.image_size, cfg.image_size, cfg.image_channels),
            jnp.float32)}
        rows = []
        # precision held at fp32 for the CPU wall-time comparison (bf16 is
        # emulated on the CPU backend; OF targets the TPU MXU)
        for label, flow in [("base", FlowConfig().base()),
                            ("optimized", FlowConfig(precision="fp32"))]:
            cm = rflow.compile(cfg, SERVE, flow)
            params = cm.init_params(jax.random.key(0))
            f = lambda p, b: cm.prefill(p, b)[0]  # noqa: E731 — jitted stage
            ms = bench(f, params, batch)
            n_ops = sum(len(b.ops) for b in cm.plan.graph.blocks)
            rows.append((label, cm.plan.stream.mode, flow.precision, n_ops,
                         ms))
        print(f"\n{name} (batch {B}, {cfg.image_size}px):")
        for label, mode, prec, n_ops, ms in rows:
            print(f"  {label:10s} mode={mode:9s} prec={prec} "
                  f"micro-ops={n_ops:4d}  {ms:8.2f} ms  "
                  f"({B / ms * 1e3:8.1f} fps)")
        print(f"  speedup: {rows[0][-1] / rows[1][-1]:.2f}x "
              f"(paper's FPGA gap: 9.4x-846x from generated-hardware "
              f"quality; on CPU XLA fuses the base program too — the TPU "
              f"gap lives in the kernel path, see EXPERIMENTS.md)")


if __name__ == "__main__":
    main()
