"""End-to-end training driver: train a small LM for a few hundred steps with
checkpointing and (optional) fault injection, on synthetic data with
learnable structure.  The loss should drop well below the unigram entropy.

  PYTHONPATH=src python examples/train_lm.py --steps 300
  PYTHONPATH=src python examples/train_lm.py --steps 100 --fail-at 40  # recovery demo
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro import flow as rflow
from repro.configs.base import FlowConfig, ShapeConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim.adamw import AdamW
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--compress", action="store_true")
    args = ap.parse_args()

    shape = ShapeConfig("example", "train", args.seq, args.batch)
    cm = rflow.compile(args.arch, shape, FlowConfig(mode="folded"),
                       smoke=True)
    cfg = cm.cfg
    print(cm.describe())

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                                  global_batch=args.batch))
    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    tr = Trainer(
        cm,
        AdamW(lr=3e-3, warmup_steps=20, total_steps=args.steps,
              compress="int8_ef" if args.compress else None),
        TrainerConfig(steps=args.steps, ckpt_dir=ckpt_dir, ckpt_every=50,
                      log_every=max(1, args.steps // 25),
                      fail_at_step=args.fail_at))
    _, _, hist = tr.fit(data, jax.random.key(0))
    for s, l in hist:
        print(f"step {s:5d}  loss {l:.4f}")
    if args.fail_at is not None:
        print(f"(recovered from the injected failure at step {args.fail_at}; "
              f"restarts={tr._restarts})")
    print(f"checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()
