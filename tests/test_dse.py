"""Design-space explorer tests: determinism, budget rules, compile-in-the-
loop validation, and the tiling `_fit` regression (even-division rule 2)."""
import dataclasses

import pytest

from repro.configs import get_config, get_smoke
from repro.configs.base import FlowConfig, ShapeConfig, TuningConfig
from repro.core import dse
from repro.core.estimator import estimate_footprint, estimate_step_seconds
from repro.core.passes import tiling

SERVE = ShapeConfig("bench", "prefill", 64, 8)
SMOKE_TRAIN = ShapeConfig("smoke", "train", 16, 2)


# ---------------------------------------------------------------------------
# tiling._fit regression (satellite: non-dividing tile bug)
# ---------------------------------------------------------------------------

def test_fit_falls_back_to_divisor():
    # the reported bug: _fit(192, 512, 128) returned 128, which does not
    # divide 192 (rule 2 violation); now the largest divisor <= target wins
    assert tiling._fit(192, 512, 128) == 192
    assert 192 % tiling._fit(192, 100, 128) == 0
    for n, target in [(192, 512), (384, 256), (1536, 512), (130, 512),
                      (96 * 7, 512)]:
        got = tiling._fit(n, target, 128)
        assert n % got == 0, (n, target, got)
        assert got <= max(target, 1) or n <= 128


def test_fit_prefers_aligned_divisors():
    assert tiling._fit(1024, 512, 128) == 512
    assert tiling._fit(4096, 2048, 128) == 2048
    assert tiling._fit(64, 512, 128) == 64          # n < align: kernel pads


def test_matmul_tile_divides_odd_dims():
    bm, bk, bn = tiling.select_matmul_tile(192, 192, 192, vmem=24 * 2 ** 20)
    assert 192 % bm == 0 and 192 % bk == 0 and 192 % bn == 0


# ---------------------------------------------------------------------------
# explorer
# ---------------------------------------------------------------------------

def test_explore_deterministic():
    cfg = get_smoke("llama3.2-1b")
    r1 = dse.explore(cfg, SMOKE_TRAIN, use_cache=False)
    r2 = dse.explore(cfg, SMOKE_TRAIN, use_cache=False)
    assert r1 is not r2                    # genuinely recomputed
    assert r1.best.flow == r2.best.flow
    assert [c.knobs for c in r1.candidates] == [c.knobs for c in r2.candidates]
    assert r1.plan.describe() == r2.plan.describe()


def test_explore_result_cached_across_calls():
    """Identical (cfg, shape, flow) searches are served from the process
    cache — --autotune across serve/train/dryrun pays once."""
    cfg = get_smoke("llama3.2-1b")
    dse.clear_explore_cache()
    calls = []

    def validator(flow):
        calls.append(flow)
        return dse.compile_candidate(cfg, SMOKE_TRAIN, flow)

    r1 = dse.explore(cfg, SMOKE_TRAIN, validator=validator, top_k=1)
    n = len(calls)
    assert n >= 1
    r2 = dse.explore(cfg, SMOKE_TRAIN, validator=validator, top_k=1)
    assert r2 is r1                        # cache hit: no recompute
    assert len(calls) == n                 # ...and no re-validation
    assert dse.explore_cache_stats()["hits"] == 1


def test_explore_cache_keys_on_backend():
    """The fingerprint includes the flow (kernel_backend included): a
    different backend policy is a different search."""
    import dataclasses as dc
    cfg = get_smoke("llama3.2-1b")
    dse.clear_explore_cache()
    f_auto = FlowConfig(mode="folded")
    f_ref = dc.replace(f_auto, kernel_backend="reference")
    r1 = dse.explore(cfg, SMOKE_TRAIN, f_auto)
    r2 = dse.explore(cfg, SMOKE_TRAIN, f_ref)
    assert r1 is not r2
    assert dse.explore_cache_stats() == {"hits": 0, "misses": 2,
                                         "evictions": 0}
    assert dse.explore(cfg, SMOKE_TRAIN, f_auto) is r1


def test_kernel_backend_is_a_tunable_dimension():
    """The KernelSelectPass exposes the registry's backend policy to the
    explorer (ISSUE acceptance: DSE searches over kernel selection)."""
    cfg = get_smoke("llama3.2-1b")
    space = dse.tunable_space(cfg, FlowConfig(mode="folded"), SMOKE_TRAIN)
    assert space["kernel_backend"] == ("auto", "reference")
    flows = dse.enumerate_candidates(
        cfg, SMOKE_TRAIN, FlowConfig(mode="folded"),
        space={"kernel_backend": ("auto", "reference")})
    assert {f.kernel_backend for f, _ in flows} == {"auto", "reference"}


def test_explore_fits_budget_cnns_and_lm():
    """Acceptance: the chosen plan's estimator-predicted footprint fits the
    device budget for the paper's three CNNs and an LM config."""
    for cfg in (get_config("lenet5"), get_config("mobilenetv1"),
                get_config("resnet34"), get_smoke("llama3.2-1b")):
        r = dse.explore(cfg, SERVE)
        assert r.best.fits, cfg.name
        assert r.best.footprint_bytes < r.budget_bytes
        # the chosen flow's plan reports stats through the Pass interface
        assert set(r.plan.pass_stats) >= {"fusion", "folding", "tiling"}


@pytest.mark.parametrize("arch,smoke,shape", [
    ("lenet5", False, SERVE),
    ("mobilenetv1", True, SERVE),
    ("resnet34", True, SERVE),
    ("llama3.2-1b", True, SMOKE_TRAIN),
])
def test_explore_validated_compile_in_the_loop(arch, smoke, shape):
    """Top-k candidates are compiled (lower+compile+memory_analysis) and the
    chosen one measurably fits the budget — the paper's place-&-route
    confirmation, in seconds."""
    cfg = get_smoke(arch) if smoke else get_config(arch)
    r = dse.explore(cfg, shape, validator=dse.compile_validator(cfg, shape),
                    top_k=1)
    assert len(r.validated) >= 1
    assert r.validated[0]["per_device_bytes"] > 0
    assert r.validated[0]["fits"]
    assert r.best.flow == r.plan.flow


def test_budget_is_a_config_knob():
    """dse.HBM_BYTES is only a default: the budget comes from
    FlowConfig.tuning and a tiny budget flips the fit verdicts."""
    cfg = get_smoke("llama3.2-1b")
    tight = FlowConfig(mode="folded",
                       tuning=TuningConfig(hbm_bytes=1024))
    r = dse.explore(cfg, SMOKE_TRAIN, tight)
    assert r.budget_bytes == 1024
    assert not r.best.fits                       # nothing fits 1 KiB...
    assert r.best.footprint_bytes == min(c.footprint_bytes
                                         for c in r.candidates)
    roomy = dse.explore(cfg, SMOKE_TRAIN)
    assert roomy.budget_bytes == dse.HBM_BYTES
    assert roomy.best.fits


def test_estimator_monotonic_knobs():
    """Rule sanity: memory savers shrink the predicted footprint; disabled
    passes inflate the predicted step time."""
    cfg, shape = get_smoke("llama3.2-1b"), SMOKE_TRAIN
    f = FlowConfig(mode="folded")
    fp1 = estimate_footprint(cfg, shape, f)["total"]
    fp2 = estimate_footprint(
        cfg, shape, dataclasses.replace(f, microbatches=4))["total"]
    assert fp2 < fp1
    fp3 = estimate_footprint(
        cfg, shape, dataclasses.replace(f, remat="nested"))["total"]
    assert fp3 < estimate_footprint(
        cfg, shape, dataclasses.replace(f, remat="none"))["total"]
    st_on = estimate_step_seconds(cfg, shape, f)["step_s"]
    st_off = estimate_step_seconds(cfg, shape, f.base())["step_s"]
    assert st_off > st_on


def test_enumeration_respects_cap():
    cfg = get_smoke("llama3.2-1b")
    capped = FlowConfig(mode="folded",
                        tuning=TuningConfig(max_candidates=7))
    flows = dse.enumerate_candidates(cfg, SMOKE_TRAIN, capped)
    assert len(flows) == 7


def test_autotune_train_cell_budget_arg():
    """autotune_train_cell derives its budget from FlowConfig.tuning (no
    hard-coded HBM_BYTES)."""
    import inspect
    sig = inspect.signature(dse.autotune_train_cell)
    assert "hbm_bytes" in sig.parameters
