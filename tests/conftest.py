import os
import sys

# NOTE: deliberately NO --xla_force_host_platform_device_count here — smoke
# tests and benches must see 1 device.  Multi-device tests run in
# subprocesses with their own XLA_FLAGS (see test_distributed.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import FlowConfig, ShapeConfig

SMOKE_SHAPE = ShapeConfig("smoke", "train", 16, 2)


def smoke_batch(cfg, B=2, S=16, seed=0, with_labels=True):
    rng = np.random.RandomState(seed)
    if cfg.family == "cnn":
        out = {"images": jnp.asarray(
            rng.randn(B, cfg.image_size, cfg.image_size, cfg.image_channels),
            jnp.float32)}
        if with_labels:
            out["labels"] = jnp.asarray(rng.randint(0, cfg.vocab_size, B),
                                        jnp.int32)
        return out
    out = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)),
                                 jnp.int32)}
    if with_labels:
        out["labels"] = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)),
                                    jnp.int32)
    if cfg.n_patch_tokens:
        out["patches"] = jnp.asarray(
            rng.randn(B, cfg.n_patch_tokens, cfg.d_vision), jnp.float32)
    if cfg.n_encoder_layers:
        out["frames"] = jnp.asarray(
            rng.randn(B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return out


def relerr(a, b):
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    return float(jnp.max(jnp.abs(a - b))) / (float(jnp.max(jnp.abs(b))) + 1e-9)


@pytest.fixture
def rng():
    return np.random.RandomState(0)
