"""Serving bookkeeping invariants: block pool, scheduler, engine config.

These run without a model — the scheduler and allocator are pure host-side
policy, which is exactly why they get their own exhaustive checks."""
import numpy as np
import pytest

from repro.serving.engine import EngineConfig
from repro.serving.kvcache import (BlockPool, TRASH_BLOCK, blocks_for_tokens)
from repro.serving.scheduler import (Request, Scheduler, bucket_for,
                                     synthetic_requests)


# ---------------------------------------------------------------------------
# block pool
# ---------------------------------------------------------------------------

def test_pool_never_hands_out_trash_block():
    pool = BlockPool(8)
    got = pool.allocate(7)
    assert TRASH_BLOCK not in got
    assert sorted(got) == list(range(1, 8))


def test_pool_exhaustion_and_release():
    pool = BlockPool(5)
    a = pool.allocate(2)
    b = pool.allocate(2)
    assert not pool.can_allocate(1)
    with pytest.raises(RuntimeError):
        pool.allocate(1)
    pool.release(a)
    assert pool.can_allocate(2)
    c = pool.allocate(2)
    assert set(c) == set(a)                 # freed blocks are reused
    assert pool.used_blocks == 4 and pool.free_blocks == 0
    pool.release(b)
    pool.release(c)
    assert pool.used_blocks == 0


def test_pool_double_free_rejected():
    pool = BlockPool(4)
    a = pool.allocate(1)
    pool.release(a)
    with pytest.raises(ValueError):
        pool.release(a)
    with pytest.raises(ValueError):
        pool.release([TRASH_BLOCK])


def test_pool_refcounts_share_and_release():
    pool = BlockPool(4)
    (b,) = pool.allocate(1)
    pool.incref(b)                          # a second chain references b
    assert pool.refcount(b) == 2
    pool.decref(b)
    assert pool.refcount(b) == 1 and pool.used_blocks == 1
    pool.decref(b)                          # last reference -> free
    assert pool.refcount(b) == 0 and pool.free_blocks == 3
    with pytest.raises(ValueError):
        pool.decref(b)                      # double free
    with pytest.raises(ValueError):
        pool.incref(b)                      # free blocks can't be referenced
    with pytest.raises(ValueError):
        pool.incref(TRASH_BLOCK)
    pool.check_invariants()


def test_pool_cached_blocks_park_and_revive():
    """An indexed (mark_cached) block parks on the LRU list at refcount 0 —
    still counted allocatable — and revives through incref."""
    pool = BlockPool(4)
    a, b = pool.allocate(2)
    pool.mark_cached(a)
    pool.release([a, b])
    assert pool.cached_blocks == 1 and pool.free_blocks == 3
    pool.incref(a)                          # revive off the LRU list
    assert pool.refcount(a) == 1 and pool.cached_blocks == 0
    pool.decref(a)                          # parks again (still tagged)
    assert pool.cached_blocks == 1
    pool.check_invariants()


def test_pool_lru_reclaim_order_and_callback():
    """Allocation pressure reclaims parked blocks oldest-first, firing the
    eviction callback; the free list is always preferred."""
    seen = []
    pool = BlockPool(4, on_cache_evict=seen.append)
    a, b, c = pool.allocate(3)
    for x in (a, b, c):
        pool.mark_cached(x)
    pool.release([b])                       # parked order: b, then a
    pool.release([a])
    pool.release([c])                       # order: b, a, c
    got = pool.allocate(3)                  # no free blocks -> all reclaims
    assert got == [b, a, c]                 # LRU order
    assert seen == [b, a, c]
    assert pool.n_cache_evictions == 3
    assert not pool.is_cached(b)            # reclaim drops the tag
    pool.check_invariants()


def test_blocks_for_tokens():
    assert blocks_for_tokens(1, 8) == 1
    assert blocks_for_tokens(8, 8) == 1
    assert blocks_for_tokens(9, 8) == 2
    assert blocks_for_tokens(0, 8) == 1     # empty chains still own a block


def test_bucket_for():
    assert bucket_for(1, (2, 4, 8)) == 2
    assert bucket_for(3, (2, 4, 8)) == 4
    assert bucket_for(8, (2, 4, 8)) == 8
    with pytest.raises(ValueError):
        bucket_for(9, (2, 4, 8))


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def _sched(n_slots=2, blocks=9, bs=4, max_seq=16):
    return Scheduler(n_slots, bs, BlockPool(blocks), max_seq_len=max_seq)


def test_admission_is_fifo_and_slot_bound():
    s = _sched(n_slots=2)
    for i in range(4):
        s.submit(Request(i, np.arange(1, 5), max_new_tokens=2))
    adm = s.admissions()
    assert [a.request.rid for a in adm] == [0, 1]      # FIFO, 2 slots
    assert s.admissions() == []                        # slots full
    assert s.high_water == 2
    assert len(s.queue) == 2


def test_admission_control_blocks_on_pool_budget():
    # 9-block pool => 8 allocatable; each request needs 2 (prompt 4 + new 2,
    # block 4) => only 4 fit even though slots are plentiful
    s = _sched(n_slots=8, blocks=9)
    for i in range(6):
        s.submit(Request(i, np.arange(1, 5), max_new_tokens=2))
    adm = s.admissions()
    assert len(adm) == 4
    assert s.pool.free_blocks == 8                     # reserved, not allocated


def test_eviction_frees_slot_and_counts_refills():
    s = _sched(n_slots=1)
    s.submit(Request("a", np.arange(1, 4), max_new_tokens=2))
    s.submit(Request("b", np.arange(1, 4), max_new_tokens=1))
    (adm,) = s.admissions()
    assert adm.request.rid == "a" and s.n_refills == 0
    s.record_token(adm.slot, 7, first=True)
    s.record_token(adm.slot, 8)
    assert s.finished() == [adm.slot]
    res = s.evict(adm.slot)
    assert res.rid == "a" and res.tokens == [7, 8]
    assert res.finish_reason == "length"
    (adm2,) = s.admissions()                           # refill the freed slot
    assert adm2.request.rid == "b" and s.n_refills == 1
    s.record_token(adm2.slot, 9, first=True)
    assert s.finished() == [adm2.slot]
    s.evict(adm2.slot)
    assert not s.has_work()
    assert s.n_admitted == 2 and s.n_evicted == 2


def test_stop_token_finishes_early():
    s = _sched()
    s.submit(Request("a", np.arange(1, 4), max_new_tokens=8, stop_token=42))
    (adm,) = s.admissions()
    s.record_token(adm.slot, 5, first=True)
    assert s.finished() == []
    s.record_token(adm.slot, 42)
    assert s.finished() == [adm.slot]
    assert s.evict(adm.slot).finish_reason == "stop"


def test_oversized_request_rejected_at_submit():
    s = _sched(max_seq=16)
    with pytest.raises(ValueError):
        s.submit(Request("big", np.arange(1, 14), max_new_tokens=8))


def test_synthetic_requests_deterministic():
    a = synthetic_requests(4, 99, prompt_len=8, seed=3)
    b = synthetic_requests(4, 99, prompt_len=8, seed=3)
    assert [r.prompt.tolist() for r in a] == [r.prompt.tolist() for r in b]
    assert all(r.prompt_len <= 8 for r in a)


# ---------------------------------------------------------------------------
# engine config validation
# ---------------------------------------------------------------------------

def test_engine_config_defaults_ladders():
    e = EngineConfig(max_batch=8, max_seq_len=48)
    assert e.batch_buckets == (1, 2, 4, 8)
    assert e.prompt_buckets[-1] == 48
    assert e.blocks_per_slot * e.block_size >= 48


def test_engine_config_validation():
    with pytest.raises(ValueError):
        EngineConfig(max_batch=0)
    with pytest.raises(ValueError):
        EngineConfig(block_size=0)
    with pytest.raises(ValueError):
        EngineConfig(max_batch=4, batch_buckets=(1, 2))   # must end at max
    with pytest.raises(ValueError):
        EngineConfig(max_seq_len=32, prompt_buckets=(16, 64))  # overflows
    with pytest.raises(ValueError):
        EngineConfig(temperature=-1.0)
    # a partial prompt ladder is padded up to the envelope
    e = EngineConfig(max_seq_len=64, prompt_buckets=(16,))
    assert e.prompt_buckets == (16, 64)


def test_engine_config_block_size_divides_every_prompt_bucket():
    """Regression: block_size must divide every prompt-bucket rung, not just
    fit the envelope — the paged pool packs prompts block-by-block and the
    prefix index hashes block-aligned runs."""
    with pytest.raises(ValueError, match="divide every prompt bucket"):
        EngineConfig(max_seq_len=64, block_size=8, prompt_buckets=(12, 64))
    with pytest.raises(ValueError, match="divide every prompt bucket"):
        # the default pow2 ladder itself can't satisfy a non-pow2 block
        EngineConfig(max_seq_len=64, block_size=12)
    with pytest.raises(ValueError, match="divide every prompt bucket"):
        # max_seq_len is the final rung: it must be whole blocks too
        EngineConfig(max_seq_len=100, block_size=16)
    e = EngineConfig(max_seq_len=64, block_size=16)
    assert all(b % 16 == 0 for b in e.prompt_buckets)
    # the default ladder starts at the block size, never below it
    assert e.prompt_buckets[0] == 16
