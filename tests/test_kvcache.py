"""Serving bookkeeping invariants: block pool, scheduler, engine config.

These run without a model — the scheduler and allocator are pure host-side
policy, which is exactly why they get their own exhaustive checks."""
import numpy as np
import pytest

from repro.serving.engine import EngineConfig
from repro.serving.kvcache import (BlockPool, TRASH_BLOCK, blocks_for_tokens)
from repro.serving.scheduler import (Request, Scheduler, bucket_for,
                                     synthetic_requests)


# ---------------------------------------------------------------------------
# block pool
# ---------------------------------------------------------------------------

def test_pool_never_hands_out_trash_block():
    pool = BlockPool(8)
    got = pool.allocate(7)
    assert TRASH_BLOCK not in got
    assert sorted(got) == list(range(1, 8))


def test_pool_exhaustion_and_release():
    pool = BlockPool(5)
    a = pool.allocate(2)
    b = pool.allocate(2)
    assert not pool.can_allocate(1)
    with pytest.raises(RuntimeError):
        pool.allocate(1)
    pool.release(a)
    assert pool.can_allocate(2)
    c = pool.allocate(2)
    assert set(c) == set(a)                 # freed blocks are reused
    assert pool.used_blocks == 4 and pool.free_blocks == 0
    pool.release(b)
    pool.release(c)
    assert pool.used_blocks == 0


def test_pool_double_free_rejected():
    pool = BlockPool(4)
    a = pool.allocate(1)
    pool.release(a)
    with pytest.raises(ValueError):
        pool.release(a)
    with pytest.raises(ValueError):
        pool.release([TRASH_BLOCK])


def test_blocks_for_tokens():
    assert blocks_for_tokens(1, 8) == 1
    assert blocks_for_tokens(8, 8) == 1
    assert blocks_for_tokens(9, 8) == 2
    assert blocks_for_tokens(0, 8) == 1     # empty chains still own a block


def test_bucket_for():
    assert bucket_for(1, (2, 4, 8)) == 2
    assert bucket_for(3, (2, 4, 8)) == 4
    assert bucket_for(8, (2, 4, 8)) == 8
    with pytest.raises(ValueError):
        bucket_for(9, (2, 4, 8))


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def _sched(n_slots=2, blocks=9, bs=4, max_seq=16):
    return Scheduler(n_slots, bs, BlockPool(blocks), max_seq_len=max_seq)


def test_admission_is_fifo_and_slot_bound():
    s = _sched(n_slots=2)
    for i in range(4):
        s.submit(Request(i, np.arange(1, 5), max_new_tokens=2))
    adm = s.admissions()
    assert [a.request.rid for a in adm] == [0, 1]      # FIFO, 2 slots
    assert s.admissions() == []                        # slots full
    assert s.high_water == 2
    assert len(s.queue) == 2


def test_admission_control_blocks_on_pool_budget():
    # 9-block pool => 8 allocatable; each request needs 2 (prompt 4 + new 2,
    # block 4) => only 4 fit even though slots are plentiful
    s = _sched(n_slots=8, blocks=9)
    for i in range(6):
        s.submit(Request(i, np.arange(1, 5), max_new_tokens=2))
    adm = s.admissions()
    assert len(adm) == 4
    assert s.pool.free_blocks == 8                     # reserved, not allocated


def test_eviction_frees_slot_and_counts_refills():
    s = _sched(n_slots=1)
    s.submit(Request("a", np.arange(1, 4), max_new_tokens=2))
    s.submit(Request("b", np.arange(1, 4), max_new_tokens=1))
    (adm,) = s.admissions()
    assert adm.request.rid == "a" and s.n_refills == 0
    s.record_token(adm.slot, 7, first=True)
    s.record_token(adm.slot, 8)
    assert s.finished() == [adm.slot]
    res = s.evict(adm.slot)
    assert res.rid == "a" and res.tokens == [7, 8]
    assert res.finish_reason == "length"
    (adm2,) = s.admissions()                           # refill the freed slot
    assert adm2.request.rid == "b" and s.n_refills == 1
    s.record_token(adm2.slot, 9, first=True)
    assert s.finished() == [adm2.slot]
    s.evict(adm2.slot)
    assert not s.has_work()
    assert s.n_admitted == 2 and s.n_evicted == 2


def test_stop_token_finishes_early():
    s = _sched()
    s.submit(Request("a", np.arange(1, 4), max_new_tokens=8, stop_token=42))
    (adm,) = s.admissions()
    s.record_token(adm.slot, 5, first=True)
    assert s.finished() == []
    s.record_token(adm.slot, 42)
    assert s.finished() == [adm.slot]
    assert s.evict(adm.slot).finish_reason == "stop"


def test_oversized_request_rejected_at_submit():
    s = _sched(max_seq=16)
    with pytest.raises(ValueError):
        s.submit(Request("big", np.arange(1, 14), max_new_tokens=8))


def test_synthetic_requests_deterministic():
    a = synthetic_requests(4, 99, prompt_len=8, seed=3)
    b = synthetic_requests(4, 99, prompt_len=8, seed=3)
    assert [r.prompt.tolist() for r in a] == [r.prompt.tolist() for r in b]
    assert all(r.prompt_len <= 8 for r in a)


# ---------------------------------------------------------------------------
# engine config validation
# ---------------------------------------------------------------------------

def test_engine_config_defaults_ladders():
    e = EngineConfig(max_batch=8, max_seq_len=48)
    assert e.batch_buckets == (1, 2, 4, 8)
    assert e.prompt_buckets[-1] == 48
    assert e.blocks_per_slot * e.block_size >= 48


def test_engine_config_validation():
    with pytest.raises(ValueError):
        EngineConfig(max_batch=0)
    with pytest.raises(ValueError):
        EngineConfig(block_size=0)
    with pytest.raises(ValueError):
        EngineConfig(max_batch=4, batch_buckets=(1, 2))   # must end at max
    with pytest.raises(ValueError):
        EngineConfig(max_seq_len=32, prompt_buckets=(16, 64))  # overflows
    with pytest.raises(ValueError):
        EngineConfig(temperature=-1.0)
    # a partial prompt ladder is padded up to the envelope
    e = EngineConfig(max_seq_len=64, prompt_buckets=(16,))
    assert e.prompt_buckets == (16, 64)
