"""Speculative decoding: exactness gates, rollback accounting, drafters.

The whole feature is gated on being *exact*:

* greedy speculative output (tokens AND sampled-step logits) is
  byte-identical to the 1-token-per-tick host loop, for every drafter and
  combined with chunked prefill / prefix caching,
* sampled speculative output is drafter-invariant — the per-request
  counter-mode rng streams make the token at commit index t of request
  serial s a pure function of (seed, s, t), so the null drafter and the
  n-gram drafter produce the same bytes,
* an oracle drafter (replaying a previous run's outputs) accepts
  everything: the accept-all path must reproduce the same bytes with fewer
  host syncs — the rng-stream parity gate,
* rejected drafts roll back through the ledger: no pool leak, COW forks
  that served only rejected tokens are undone.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.engine import Engine, EngineConfig
from repro.serving.kvcache import BlockLedger
from repro.serving.scheduler import (Request, shared_prefix_requests,
                                     synthetic_requests)
from repro.serving.speculation import (NGramDrafter, NullDrafter,
                                       SpeculationConfig, sample_targets)

from test_serving import _assert_results_identical, _serve_cm


def _run(reqs, *, capture=True, **kw):
    cm, params = _serve_cm()
    ekw = dict(max_batch=4, max_seq_len=64, block_size=8,
               capture_logits=capture)
    ekw.update(kw)
    eng = Engine(cm, params, EngineConfig(**ekw))
    return eng, eng.run(reqs)


def _reqs(n=6, prompt_len=12, max_new=16, seed=3):
    cm, _ = _serve_cm()
    return synthetic_requests(n, cm.cfg.vocab_size, prompt_len=prompt_len,
                              max_new_tokens=max_new, seed=seed)


# ---------------------------------------------------------------------------
# config + drafter units
# ---------------------------------------------------------------------------

def test_speculation_config_parse():
    assert SpeculationConfig.parse("off") is None
    assert SpeculationConfig.parse("") is None
    sp = SpeculationConfig.parse("ngram:6")
    assert (sp.kind, sp.draft_k) == ("ngram", 6)
    assert SpeculationConfig.parse("null").draft_k == 4
    sp = SpeculationConfig.parse("draft:gpt2:2")
    assert (sp.kind, sp.draft_cfg, sp.draft_k) == ("draft", "gpt2", 2)
    assert sp.describe() == "draft:gpt2:2"
    with pytest.raises(ValueError):
        SpeculationConfig.parse("draft:4")
    with pytest.raises(ValueError):
        SpeculationConfig.parse("ngram:4:9")


def test_speculation_config_invariants():
    with pytest.raises(ValueError, match="drafter kind"):
        EngineConfig(speculation="bogus:4", max_seq_len=64, block_size=8)
    with pytest.raises(ValueError, match="draft_k"):
        EngineConfig(speculation=SpeculationConfig(draft_k=0),
                     max_seq_len=64, block_size=8)
    with pytest.raises(ValueError, match="mutually exclusive"):
        EngineConfig(speculation="ngram:4", fori_seg=4,
                     max_seq_len=64, block_size=8)
    e = EngineConfig(speculation="ngram:4", max_seq_len=64, block_size=8)
    assert isinstance(e.speculation, SpeculationConfig)
    assert e.tick_buckets == (1, 5)
    assert EngineConfig(max_seq_len=64, block_size=8).tick_buckets == (1,)


def test_ngram_drafter_prompt_lookup():
    d = NGramDrafter(max_n=3, min_n=1)
    h = np.asarray([5, 1, 2, 3, 9, 7, 1, 2, 3], np.int32)
    # trailing 3-gram [1,2,3] recurs at position 1; continuation is 9, 7, 1
    np.testing.assert_array_equal(d.propose(h, 3), [9, 7, 1])
    # a continuation that runs off the history end extends by re-lookup
    # over the drafted tokens: a period-1 cycle drafts all k
    np.testing.assert_array_equal(
        d.propose(np.asarray([4, 4], np.int32), 5), [4, 4, 4, 4, 4])
    # period-2 cycle likewise continues the alternation
    np.testing.assert_array_equal(
        d.propose(np.asarray([6, 2, 6, 2, 6], np.int32), 4), [2, 6, 2, 6])
    assert d.propose(np.asarray([8], np.int32), 4).size == 0
    assert NullDrafter().propose(h, 4).size == 0
    with pytest.raises(ValueError):
        NGramDrafter(max_n=2, min_n=3)


def test_sample_targets_is_counter_mode():
    """Each (serial, commit-index) cell draws with its own folded key —
    independent of the tick's column packing."""
    rng = np.random.RandomState(0)
    lg = jnp.asarray(rng.randn(2, 3, 17), jnp.float32)
    key = jax.random.key(9)
    out = np.asarray(sample_targets(lg, key, jnp.asarray([4, 7]),
                                    jnp.asarray([0, 5]), 0.7))
    for i, (serial, t0) in enumerate([(4, 0), (7, 5)]):
        rk = jax.random.fold_in(key, serial)
        for c in range(3):
            want = jax.random.categorical(jax.random.fold_in(rk, t0 + c),
                                          lg[i, c] / 0.7)
            assert out[i, c] == int(want)
    # the same cell sampled in a different packing yields the same token
    shifted = np.asarray(sample_targets(lg[:, 1:], key, jnp.asarray([4, 7]),
                                        jnp.asarray([1, 6]), 0.7))
    np.testing.assert_array_equal(out[:, 1:], shifted)


# ---------------------------------------------------------------------------
# exactness gates
# ---------------------------------------------------------------------------

def test_greedy_ngram_matches_host_loop_byte_identical():
    reqs = _reqs()
    _, base = _run(reqs)
    eng, spec = _run(reqs, speculation="ngram:4")
    _assert_results_identical(base, spec)
    m = spec.metrics
    assert m["speculation"] and m["spec_drafter"] == "ngram:4"
    assert m["spec_tokens_drafted"] > 0
    # the pool never leaks under partial acceptance
    assert eng.last_cache.pool.used_blocks == 0
    eng.last_cache.ledger.check()


@pytest.mark.parametrize("extra", [
    {"prefix_cache": True},
    {"prefix_cache": True, "chunked_prefill": True, "chunk_size": 4,
     "chunk_buckets": (1, 4)},
])
def test_greedy_shared_prefix_with_cache_combos_byte_identical(extra):
    """Speculation composed with prefix caching and chunked prefill (the
    COW-heavy shared-prefix workload) stays byte-identical to the plain
    host loop with the same toggles."""
    cm, _ = _serve_cm()
    reqs = shared_prefix_requests(6, cm.cfg.vocab_size, prefix_len=24,
                                  tail_len=8, max_new_tokens=16, seed=11)
    _, base = _run(reqs, **extra)
    eng, spec = _run(reqs, speculation="ngram:4", **extra)
    _assert_results_identical(base, spec)
    assert spec.metrics["spec_tokens_accepted"] > 0
    assert eng.last_cache.pool.used_blocks == 0
    eng.last_cache.ledger.check()


def test_sampled_output_is_drafter_invariant():
    """temperature > 0: the null drafter (no speculation ever accepted) and
    the n-gram drafter must emit identical bytes — the rejection-sampling
    identity plus per-request rng streams."""
    reqs = _reqs(seed=5)
    _, null = _run(reqs, capture=False, speculation="null:4",
                   temperature=0.8, seed=13)
    _, ngram = _run(reqs, capture=False, speculation="ngram:4",
                    temperature=0.8, seed=13)
    assert null.metrics["spec_tokens_drafted"] == 0
    for rid, a in null.by_id.items():
        assert a.tokens == ngram.by_id[rid].tokens, rid


class OracleDrafter:
    """Replays a previous run's exact outputs: every draft is accepted."""
    kind = "oracle"

    def __init__(self, report, requests):
        by_id = report.by_id
        self.streams = [(np.asarray(r.prompt, np.int32),
                         np.asarray(by_id[r.rid].tokens, np.int32))
                        for r in requests]

    def propose(self, history, k):
        h = np.asarray(history, np.int32)
        for p, t in self.streams:
            if h.size >= p.size and np.array_equal(h[:p.size], p):
                done = h.size - p.size
                return t[done:done + k]
        return np.empty(0, np.int32)


def test_oracle_accept_all_greedy_fewer_syncs_same_bytes():
    reqs = _reqs(max_new=20)
    _, base = _run(reqs, capture=False)
    cm, params = _serve_cm()
    eng = Engine(cm, params, EngineConfig(max_batch=4, max_seq_len=64,
                                          block_size=8, speculation="ngram:4"))
    eng.drafter_override = OracleDrafter(base, reqs)
    spec = eng.run(reqs)
    m = spec.metrics
    assert m["spec_acceptance_rate"] == 1.0
    assert m["spec_rollback_tokens"] == 0
    assert m["host_syncs"] < base.metrics["host_syncs"]
    for rid, a in base.by_id.items():
        assert a.tokens == spec.by_id[rid].tokens, rid


def test_oracle_accept_all_sampled_rng_stream_parity():
    """The accept-all path consumes the SAME rng stream positions as the
    one-token path: an oracle replay of a sampled null-drafter run must
    reproduce its bytes exactly while committing many tokens per tick."""
    reqs = _reqs(max_new=20, seed=8)
    _, null = _run(reqs, capture=False, speculation="null:4",
                   temperature=0.7, seed=21)
    cm, params = _serve_cm()
    eng = Engine(cm, params, EngineConfig(max_batch=4, max_seq_len=64,
                                          block_size=8, speculation="ngram:4",
                                          temperature=0.7, seed=21))
    eng.drafter_override = OracleDrafter(null, reqs)
    spec = eng.run(reqs)
    assert spec.metrics["spec_acceptance_rate"] == 1.0
    for rid, a in null.by_id.items():
        assert a.tokens == spec.by_id[rid].tokens, rid


# ---------------------------------------------------------------------------
# controls, counters, drafters-through-the-engine
# ---------------------------------------------------------------------------

def test_per_request_speculate_toggle_and_counters():
    reqs = _reqs(n=4, max_new=12)
    off = [Request(rid=r.rid, prompt=r.prompt,
                   max_new_tokens=r.max_new_tokens, speculate=False)
           for r in reqs]
    eng, rep_off = _run(off, capture=False, speculation="ngram:4")
    assert rep_off.metrics["spec_tokens_drafted"] == 0
    _, rep_on = _run(reqs, capture=False, speculation="ngram:4")
    m = rep_on.metrics
    assert m["spec_tokens_drafted"] == \
        sum(r.tokens_drafted for r in rep_on.results)
    assert m["spec_tokens_accepted"] == \
        sum(r.tokens_accepted for r in rep_on.results)
    for r in rep_on.results:
        assert 0 <= r.tokens_accepted <= r.tokens_drafted
        if r.tokens_drafted:
            assert r.acceptance_rate == r.tokens_accepted / r.tokens_drafted
    assert "speculation: ngram:4" in rep_on.describe()
    assert "spec=ngram:4" in eng.describe()


def test_draft_model_drafter_end_to_end_greedy_parity():
    """The small-model drafter (here: the same smoke config drafting for
    itself) runs the full compile-propose-verify path and stays exact."""
    reqs = _reqs(n=3, max_new=10)
    _, base = _run(reqs)
    _, spec = _run(reqs, speculation="draft:llama3.2-1b:2")
    _assert_results_identical(base, spec)
    assert spec.metrics["spec_drafter"] == "draft:llama3.2-1b:2"
    assert spec.metrics["spec_tokens_drafted"] > 0


# ---------------------------------------------------------------------------
# ledger rollback accounting
# ---------------------------------------------------------------------------

def test_ledger_spec_rollback_undoes_fork_and_restores_spare():
    """A COW fork that served only rejected speculative writes is undone:
    the chain repoints at the shared original and the charged spare comes
    back; a fork that holds a committed token stays."""
    led = BlockLedger(20, 3, 4, 4, prefix_cache=True)
    p = np.arange(1, 8, dtype=np.int32)           # 7 tokens: 1.75 blocks
    led.admit(0, p, 11)
    led.register_prompt(0)
    led.release(0)                  # full + partial tail blocks indexed
    for slot in (1, 2):             # two hits share the parked partial
        m = led.match_and_lock(p)
        assert m is not None and m.covered == 6 and m.needs_cow_spare
        led.admit(slot, p, 11, match=m)
    assert led.needs_fork(1)

    led.spec_begin(1)
    ci, old, new = led.fork(1)
    led.note_write(1, 2)
    assert led.spec_commit(1, 0) == 2             # reject everything
    assert led.chains[1][ci] == old               # chain repointed back
    assert led.spares[1] == new                   # charged spare restored
    assert led.spec_fork_undos == 1
    assert led.spec_rollback_tokens == 2
    assert led.lens[1] == 6
    led.check()

    led.spec_begin(1)                             # partial acceptance
    ci2, _, new2 = led.fork(1)
    led.note_write(1, 2)
    assert led.spec_commit(1, 1) == 1
    assert led.chains[1][ci2] == new2             # committed K/V: fork stays
    assert led.spares[1] is None
    assert led.spec_fork_undos == 1
    assert led.lens[1] == 7
    led.check()

    led.release(1)
    led.release(2)
    led.check()
    assert led.pool.used_blocks == 0


def test_spec_window_protocol_errors():
    led = BlockLedger(20, 2, 4, 4, prefix_cache=False)
    with pytest.raises(RuntimeError, match="empty"):
        led.spec_begin(0)
    led.admit(0, np.asarray([1, 2, 3], np.int32), 6)
    led.spec_begin(0)
    with pytest.raises(RuntimeError, match="already"):
        led.spec_begin(0)
    led.note_write(0, 2)
    with pytest.raises(ValueError, match="outside"):
        led.spec_commit(0, 3)
    with pytest.raises(RuntimeError, match="no open"):
        led.spec_commit(1, 0)
