"""Multi-device tests (subprocess with xla_force_host_platform_device_count):
sharded train step parity, pipeline (CH) parity, dry-run on a small mesh,
elastic checkpoint resharding."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def run_sub(body: str, ndev: int = 8, timeout: int = 900) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
        import sys
        sys.path.insert(0, {repr(os.path.join(ROOT, 'src'))})
        sys.path.insert(0, {repr(ROOT)})
    """) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout)
    assert r.returncode == 0, r.stdout[-3000:] + "\n" + r.stderr[-3000:]
    return r.stdout


def test_sharded_train_step_matches_single_device():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke
        from repro.configs.base import FlowConfig, ShapeConfig
        from repro.core import lowering
        from repro.core.plan import build_plan
        from repro.distributed.sharding import ShardingRules
        cfg = get_smoke("llama3.2-1b")
        shape = ShapeConfig("s", "train", 16, 4)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = ShardingRules(mesh, dp=("data",))
        flow = FlowConfig(mode="folded", precision="fp32")
        plan_s = build_plan(cfg, flow, shape, mesh_axes=("data", "model"),
                            rules=rules)
        plan_1 = build_plan(cfg, flow, shape)
        params = lowering.init_params(plan_1, jax.random.key(0))
        rng = np.random.RandomState(0)
        batch = {"tokens": jnp.asarray(rng.randint(0, 256, (4, 16)), jnp.int32),
                 "labels": jnp.asarray(rng.randint(0, 256, (4, 16)), jnp.int32)}
        l1, _ = lowering.make_loss_fn(plan_1)(params, batch)
        with mesh:
            psh = rules.params_shardings(plan_s)
            sp = jax.tree.map(jax.device_put, params, psh)
            sb = {k: jax.device_put(v, s) for (k, v), s in
                  zip(batch.items(), rules.batch_sharding(
                      {k: v for k, v in batch.items()}).values())}
            l2, _ = jax.jit(lowering.make_loss_fn(plan_s))(sp, sb)
        err = abs(float(l1) - float(l2)) / (abs(float(l1)) + 1e-9)
        assert err < 2e-5, (float(l1), float(l2))
        print("PARITY OK", float(l1), float(l2))
    """)
    assert "PARITY OK" in out


def test_pipeline_loss_matches_folded():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke
        from repro.configs.base import FlowConfig, ShapeConfig
        from repro.core import lowering
        from repro.core.plan import build_plan
        from repro.distributed.pipeline_parallel import make_pipeline_loss
        cfg = get_smoke("llama3.2-1b")   # 3 layers -> pad to 4 for 2 stages
        import dataclasses
        cfg = dataclasses.replace(cfg, n_layers=4)
        shape = ShapeConfig("s", "train", 16, 4)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        flow = FlowConfig(mode="folded", precision="fp32", remat="none",
                          pp_axis="pod",
                          mesh_split=(("pod", 2), ("data", 2), ("model", 2)))
        plan = build_plan(cfg, flow, shape, mesh_axes=tuple(mesh.axis_names))
        # the ShardingPass assigned the pipeline stages on the plan
        sp = plan.sharding
        assert sp is not None and sp.pp_axis == "pod" and sp.n_stages == 2
        assert sp.stage_of_layer == (0, 0, 1, 1), sp.stage_of_layer
        params = lowering.init_params(plan, jax.random.key(0))
        rng = np.random.RandomState(0)
        batch = {"tokens": jnp.asarray(rng.randint(0, 256, (4, 16)), jnp.int32),
                 "labels": jnp.asarray(rng.randint(0, 256, (4, 16)), jnp.int32)}
        base, _ = lowering.make_loss_fn(plan)(params, batch)
        pipe_loss = make_pipeline_loss(plan, mesh, n_microbatches=2)
        with mesh:
            lp = jax.jit(pipe_loss)(params, batch)
        err = abs(float(base) - float(lp)) / (abs(float(base)) + 1e-9)
        assert err < 2e-4, (float(base), float(lp))
        # gradients flow through ppermute
        g = jax.jit(jax.grad(pipe_loss))(params, batch)
        gn = sum(float(jnp.sum(jnp.square(x))) for x in jax.tree.leaves(g))
        assert gn > 0
        print("PIPE OK", float(base), float(lp), gn)
    """, ndev=8, timeout=1200)
    assert "PIPE OK" in out


def test_moe_shard_map_parity():
    """The manual shard_map MoE (EP + expert-TP) must match single-device CE
    exactly; only the aux load-balance term differs (per-shard means — the
    GShard semantics)."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke
        from repro.configs.base import FlowConfig, ShapeConfig
        from repro.core import lowering
        from repro.core.plan import build_plan
        from repro.distributed.sharding import ShardingRules
        for arch in ("mixtral-8x7b", "deepseek-moe-16b"):
            cfg = get_smoke(arch)
            shape = ShapeConfig("s", "train", 16, 4)
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            rules = ShardingRules(mesh, dp=("data",))
            flow = FlowConfig(mode="folded", precision="fp32")
            plan_s = build_plan(cfg, flow, shape, mesh_axes=("data", "model"),
                                rules=rules)
            plan_1 = build_plan(cfg, flow, shape)
            params = lowering.init_params(plan_1, jax.random.key(0))
            rng = np.random.RandomState(0)
            batch = {"tokens": jnp.asarray(rng.randint(0, 256, (4, 16)), jnp.int32),
                     "labels": jnp.asarray(rng.randint(0, 256, (4, 16)), jnp.int32)}
            _, m1 = lowering.make_loss_fn(plan_1)(params, batch)
            with mesh:
                psh = rules.params_shardings(plan_s)
                sp = jax.tree.map(jax.device_put, params, psh)
                _, m2 = jax.jit(lowering.make_loss_fn(plan_s))(sp, batch)
            err = abs(float(m1["loss"]) - float(m2["loss"]))
            err /= abs(float(m1["loss"])) + 1e-9
            assert err < 1e-5, (arch, float(m1["loss"]), float(m2["loss"]))
        print("MOE PARITY OK")
    """, timeout=1200)
    assert "MOE PARITY OK" in out


def test_dryrun_cell_small_mesh():
    out = run_sub("""
        import jax
        from repro.launch.dryrun import run_cell
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        r = run_cell("llama3.2-1b", "decode_32k", mesh=mesh)
        assert r["memory"]["per_device_bytes"] > 0
        assert r["hlo"]["collective_bytes"] >= 0
        print("DRYRUN OK", r["compile_s"])
    """)
    assert "DRYRUN OK" in out


def test_elastic_checkpoint_reshard():
    """Save sharded on a (2,4) mesh, restore onto (4,2) — elastic scaling."""
    out = run_sub("""
        import jax, jax.numpy as jnp, tempfile, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train import checkpoint as ckpt
        m1 = jax.make_mesh((2, 4), ("data", "model"))
        m2 = jax.make_mesh((4, 2), ("data", "model"))
        x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        xs = jax.device_put(x, NamedSharding(m1, P("data", "model")))
        d = tempfile.mkdtemp()
        ckpt.save(d, 1, {"x": xs})
        out = ckpt.restore(d, 1, {"x": xs},
                           {"x": NamedSharding(m2, P("model", "data"))})
        np.testing.assert_array_equal(np.asarray(out["x"]), np.asarray(x))
        assert out["x"].sharding.spec == P("model", "data")
        print("ELASTIC OK")
    """)
    assert "ELASTIC OK" in out


def test_compile_mesh_dict_acceptance():
    """ISSUE acceptance: compile(..., mesh={'data': 2, 'model': 2}) on 4
    forced host devices records the sharding decisions on the plan, and
    dse.explore over the same setup enumerates >= 2 distinct mesh
    factorizations and returns a candidate that compiles and runs."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import flow as rflow
        from repro.configs import get_smoke
        from repro.configs.base import ShapeConfig
        from repro.core import dse
        from repro.distributed.meshspec import MeshSpec
        cfg = get_smoke("llama3.2-1b")
        shape = ShapeConfig("s", "prefill", 16, 4)
        cm = rflow.compile(cfg, shape, mesh={"data": 2, "model": 2})
        d = cm.plan.describe()
        assert "sharding: mesh={data:2,model:2} dp=data:2 tp=model:2" in d, d
        assert cm.plan.sharding.param_specs
        params = cm.init_params(jax.random.key(0))
        rng = np.random.RandomState(0)
        batch = {"tokens": jnp.asarray(rng.randint(0, 256, (4, 16)), jnp.int32)}
        logits, _, _ = cm.prefill(params, batch)
        assert logits.shape[0] == 4

        # the DSE searches the factorizations of the 4 local devices...
        r = dse.explore(cfg, shape, devices=4,
                        validator=dse.compile_validator(cfg, shape))
        splits = {c.flow.mesh_split for c in r.candidates}
        assert len(splits) >= 2, splits
        assert r.best.flow.mesh_split is not None
        # ...and the winner compiles and runs on its own mesh
        best_cm = rflow.compile(cfg, shape, r.best.flow,
                                mesh=MeshSpec.of(r.best.flow.mesh_split))
        lg, _, _ = best_cm.prefill(best_cm.init_params(jax.random.key(0)),
                                   batch)
        assert bool(jnp.all(jnp.isfinite(lg.astype(jnp.float32))))
        print("MESH DSE OK", sorted(splits), r.best.flow.mesh_split)
    """, ndev=4, timeout=1200)
    assert "MESH DSE OK" in out


def test_measure_validation_on_mesh():
    """validate='measure': the DSE ranks top-k survivors by measured step
    time of the actual sharded executable."""
    out = run_sub("""
        import jax
        from repro import flow as rflow
        from repro.configs import get_smoke
        from repro.configs.base import ShapeConfig
        cfg = get_smoke("llama3.2-1b")
        shape = ShapeConfig("s", "prefill", 16, 4)
        cm = rflow.compile(cfg, shape, mesh={"data": 2, "model": 2},
                           autotune=True, validate="measure")
        er = cm.explore_result
        assert er is not None and er.validated
        assert all(v["measured_step_s"] > 0 for v in er.validated)
        assert cm.plan.sharding is not None
        print("MEASURE OK", len(er.validated))
    """, ndev=4, timeout=1200)
    assert "MEASURE OK" in out


def test_multipod_mesh_axes():
    out = run_sub("""
        from repro.launch.mesh import make_production_mesh
        # only 8 host devices: build the small analogue directly
        import jax
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        assert tuple(mesh.axis_names) == ("pod", "data", "model")
        print("MESH OK")
    """)
    assert "MESH OK" in out
