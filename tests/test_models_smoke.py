"""REQUIRED smoke tests: every assigned architecture instantiates a reduced
config and runs one forward/train step on CPU, asserting output shapes and
no NaNs — plus prefill→decode consistency per arch."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, CNNS, get_smoke
from repro.configs.base import FlowConfig, ShapeConfig
from repro.core import lowering
from repro.core.plan import build_plan

from conftest import SMOKE_SHAPE, relerr, smoke_batch

FLOW = FlowConfig(mode="folded")


def _plan(arch, **kw):
    return build_plan(get_smoke(arch), FlowConfig(mode="folded", **kw),
                      SMOKE_SHAPE)


@pytest.mark.parametrize("arch", ARCHS + CNNS)
def test_train_step_smoke(arch):
    cfg = get_smoke(arch)
    plan = _plan(arch)
    params = lowering.init_params(plan, jax.random.key(0))
    loss_fn = lowering.make_loss_fn(plan)
    batch = smoke_batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, batch)
    assert jnp.isfinite(loss), arch
    gleaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
               for g in gleaves), arch
    # shapes: grads match params
    assert jax.tree.structure(grads) == jax.tree.structure(params)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes(arch):
    cfg = get_smoke(arch)
    plan = _plan(arch)
    params = lowering.init_params(plan, jax.random.key(0))
    apply = lowering.make_apply(plan)
    B, S = 2, 16
    batch = smoke_batch(cfg, B, S, with_labels=False)
    logits, state, _ = apply(params, batch, mode="prefill")
    assert logits.shape == (B, 1, cfg.padded_vocab)     # last-position logits
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """Decode with cached state == full prefill of S+1 tokens (fp32)."""
    import numpy as np
    cfg = get_smoke(arch)
    plan = build_plan(cfg, FlowConfig(mode="folded", precision="fp32"),
                      SMOKE_SHAPE)
    params = lowering.init_params(plan, jax.random.key(1))
    apply = lowering.make_apply(plan)
    B, S = 2, 12
    rng = np.random.RandomState(0)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S + 1)), jnp.int32)
    extras = smoke_batch(cfg, B, S, with_labels=False)
    extras.pop("tokens")
    lg_p, st, _ = apply(params, {"tokens": toks[:, :S], **extras},
                        mode="prefill")
    lg_d, _, _ = apply(params, {"tokens": toks[:, S:S + 1]}, state=st,
                       cache_index=jnp.int32(S), mode="decode")
    lg_ref, _, _ = apply(params, {"tokens": toks, **extras}, mode="prefill")
    assert relerr(lg_d, lg_ref) < 2e-4, arch


def test_multi_step_decode_rolling_window():
    """Decode past the window: rolling cache must equal full recompute."""
    import numpy as np
    cfg = get_smoke("mixtral-8x7b")        # window = 16
    plan = build_plan(cfg, FlowConfig(mode="folded", precision="fp32"),
                      SMOKE_SHAPE)
    params = lowering.init_params(plan, jax.random.key(2))
    apply = lowering.make_apply(plan)
    B, S, extra = 1, 12, 8                 # crosses the 16-token window
    rng = np.random.RandomState(3)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S + extra)),
                       jnp.int32)
    _, st, _ = apply(params, {"tokens": toks[:, :S]}, mode="prefill")
    for t in range(extra):
        lg_d, st, _ = apply(params, {"tokens": toks[:, S + t:S + t + 1]},
                            state=st, cache_index=jnp.int32(S + t),
                            mode="decode")
    lg_ref, _, _ = apply(params, {"tokens": toks}, mode="prefill")
    assert relerr(lg_d, lg_ref) < 2e-4


@pytest.mark.parametrize("arch", ["llama3.2-1b", "rwkv6-7b"])
def test_pallas_backend_matches_reference(arch):
    cfg = get_smoke(arch)
    batch = smoke_batch(cfg, with_labels=False)
    p_ref = build_plan(cfg, FlowConfig(mode="folded", precision="fp32"),
                       SMOKE_SHAPE)
    p_pal = build_plan(cfg, FlowConfig(mode="folded", precision="fp32",
                                       kernel_backend="pallas_interpret"),
                       SMOKE_SHAPE)
    params = lowering.init_params(p_ref, jax.random.key(0))
    y1, _, _ = lowering.make_apply(p_ref)(params, batch, mode="prefill")
    y2, _, _ = lowering.make_apply(p_pal)(params, batch, mode="prefill")
    assert relerr(y1, y2) < 1e-5
