"""KernelRegistry coverage: every op resolves under every backend policy,
auto-resolution is platform-aware, the plan records the resolution, and the
Pallas implementations dispatched through the registry agree numerically
with the reference path (CPU interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import FlowConfig
from repro.core.ops_impl import OPS
from repro.core.plan import _build_plan
from repro.kernels import ref
from repro.kernels.registry import REGISTRY, canon_backend, plan_kernel

from conftest import SMOKE_SHAPE, relerr

R = np.random.RandomState(11)


# ---------------------------------------------------------------------------
# resolution
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["auto", "ref", "pallas_interpret"])
def test_every_op_resolves(backend):
    """Acceptance: every op in core/ops_impl.OPS resolves under auto, ref
    and pallas_interpret, and the resolved implementation is callable."""
    for op in OPS:
        resolved = REGISTRY.resolve(op, backend)
        assert resolved in ("ref", "pallas", "pallas_interpret"), (op, backend)
        impl = REGISTRY.get(op, resolved)
        assert callable(impl.fn), (op, backend)


def test_auto_is_platform_aware():
    accel = set(REGISTRY.accelerated_ops())
    assert {"matmul", "glu_matmul", "attention", "decode_attention",
            "conv2d", "rg_lru"} <= accel
    for op in accel:
        assert REGISTRY.resolve(op, "auto", platform="tpu") == "pallas"
        assert REGISTRY.resolve(op, "auto", platform="cpu") == "ref"
    # ops with no Pallas implementation stay on the reference path everywhere
    assert REGISTRY.resolve("norm", "auto", platform="tpu") == "ref"
    assert REGISTRY.resolve("norm", "pallas") == "ref"


def test_backend_aliases_and_unknown():
    assert canon_backend("reference") == "ref"
    assert canon_backend("ref") == "ref"
    with pytest.raises(ValueError):
        canon_backend("cuda")
    with pytest.raises(ValueError):
        REGISTRY.resolve("matmul", "cuda")


def test_plan_records_resolution_and_describe():
    plan = _build_plan(get_smoke("llama3.2-1b"), FlowConfig(mode="folded"),
                       SMOKE_SHAPE)
    assert set(OPS) <= set(plan.kernels)
    assert "kernels: backend=auto" in plan.describe()
    assert plan.pass_stats["kernels"]["applied"]


def test_plan_kernel_dispatch_respects_capabilities():
    cfg = get_smoke("llama3.2-1b")
    p_int = _build_plan(cfg, FlowConfig(mode="folded",
                                        kernel_backend="pallas_interpret"),
                        SMOKE_SHAPE)
    p_ref = _build_plan(cfg, FlowConfig(mode="folded",
                                        kernel_backend="reference"),
                        SMOKE_SHAPE)
    x2, w2 = jnp.zeros((4, 8)), jnp.zeros((8, 16))
    kern = plan_kernel(p_int, "matmul", x=x2, w=w2)
    assert kern is not None and kern[1] is True        # interpret flag
    # capability predicate: 1-D activations fall back to the reference path
    assert plan_kernel(p_int, "matmul", x=jnp.zeros((8,)), w=w2) is None
    # grouped conv has no Pallas implementation path
    assert plan_kernel(p_int, "conv2d", groups=4) is None
    assert plan_kernel(p_int, "conv2d", groups=1) is not None
    # a reference-pinned plan never dispatches to Pallas
    assert plan_kernel(p_ref, "matmul", x=x2, w=w2) is None


# ---------------------------------------------------------------------------
# Pallas-vs-reference numerical agreement through the registry (CPU interpret)
# ---------------------------------------------------------------------------

def test_registry_matmul_matches_reference():
    fn = REGISTRY.get("matmul", "pallas_interpret").fn
    x = jnp.asarray(R.randn(32, 48), jnp.float32)
    w = jnp.asarray(R.randn(48, 64), jnp.float32)
    b = jnp.asarray(R.randn(64), jnp.float32)
    y = fn(x, w, bias=b, act="gelu", tile=(16, 16, 32), interpret=True)
    assert relerr(y, ref.matmul_fused_ref(x, w, bias=b, act="gelu")) < 1e-5


def test_registry_attention_matches_reference():
    fn = REGISTRY.get("attention", "pallas_interpret").fn
    q = jnp.asarray(R.randn(2, 32, 4, 16), jnp.float32)
    k = jnp.asarray(R.randn(2, 32, 2, 16), jnp.float32)
    v = jnp.asarray(R.randn(2, 32, 2, 16), jnp.float32)
    y = fn(q, k, v, causal=True, tile=(16, 16), interpret=True)
    assert relerr(y, ref.flash_attention_ref(q, k, v, causal=True)) < 1e-5


def test_registry_conv_matches_reference():
    fn = REGISTRY.get("conv2d", "pallas_interpret").fn
    x = jnp.asarray(R.randn(2, 12, 12, 4), jnp.float32)
    w = jnp.asarray(R.randn(3, 3, 4, 8), jnp.float32)
    y = fn(x, w, stride=1, padding="SAME", act="relu", tile=(4, 8),
           interpret=True)
    r = ref.conv2d_fused_ref(x, w, stride=1, padding="SAME", act="relu")
    assert relerr(y, r) < 1e-5


def test_backend_pins_apply_to_same_numerics():
    """End-to-end: auto (→ ref on CPU), reference and pallas_interpret plans
    produce the same prefill logits (fp32)."""
    from repro.core import lowering
    from conftest import smoke_batch
    cfg = get_smoke("llama3.2-1b")
    batch = smoke_batch(cfg, with_labels=False)
    outs = []
    for backend in ("auto", "reference", "pallas_interpret"):
        plan = _build_plan(cfg, FlowConfig(mode="folded", precision="fp32",
                                           kernel_backend=backend),
                           SMOKE_SHAPE)
        params = lowering.init_params(plan, jax.random.key(0))
        y, _, _ = lowering._make_apply(plan)(params, batch, mode="prefill")
        outs.append(y)
    assert relerr(outs[0], outs[1]) == 0.0       # auto == reference on CPU
    assert relerr(outs[0], outs[2]) < 1e-5       # interpret agrees
