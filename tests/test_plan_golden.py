"""Golden plan snapshots: the pass pipeline's output for the paper's three
CNNs plus an LM config, base vs optimized flows.  These pin the plan-level
behaviour of the whole pipeline (units, tiles, mode) — any pass change that
shifts them must update the goldens deliberately."""
import pytest

from repro.configs import get_config, get_smoke
from repro.configs.base import FlowConfig, ShapeConfig
from repro.core.plan import build_plan

SERVE = ShapeConfig("bench", "prefill", 64, 8)
SMOKE_TRAIN = ShapeConfig("smoke", "train", 16, 2)

BASE_TILES = ("{'matmul': (128, 128, 128), 'attention': (128, 128), "
              "'decode_attention': 512, 'conv2d': (8, 128), "
              "'wkv_chunk': 16, 'ce_chunk': 256}")

# KernelRegistry resolution on the CPU test platform: "auto" resolves every
# accelerable op to the reference backend (Pallas is chosen on TPU only)
KERNELS = ("  kernels: backend=auto attention=ref conv2d=ref "
           "copy_block=ref decode_attention=ref glu_matmul=ref matmul=ref "
           "paged_decode_attention=ref rg_lru=ref")

GOLDEN = {
    ("lenet5", "opt"): f"""\
plan[lenet5 x bench] mode=pipelined
  passes: fuse=True fold=True tiles=True cw=True prec=bf16
  units: 3 (0 folded: )
  tiles: {{'matmul': (64, 120, 84), 'conv2d': (8, 128), 'wkv_chunk': 32, 'ce_chunk': 256}}
{KERNELS}""",
    ("lenet5", "base"): f"""\
plan[lenet5 x bench] mode=folded
  passes: fuse=False fold=False tiles=False cw=False prec=fp32
  units: 3 (0 folded: )
  tiles: {BASE_TILES}
{KERNELS}""",
    ("mobilenetv1", "opt"): f"""\
plan[mobilenetv1 x bench] mode=pipelined
  passes: fuse=True fold=True tiles=True cw=True prec=bf16
  units: 15 (0 folded: )
  tiles: {{'matmul': (64, 1024, 512), 'conv2d': (8, 128), 'wkv_chunk': 32, 'ce_chunk': 256}}
{KERNELS}""",
    ("mobilenetv1", "base"): f"""\
plan[mobilenetv1 x bench] mode=folded
  passes: fuse=False fold=False tiles=False cw=False prec=fp32
  units: 15 (0 folded: )
  tiles: {BASE_TILES}
{KERNELS}""",
    ("resnet34", "opt"): f"""\
plan[resnet34 x bench] mode=pipelined
  passes: fuse=True fold=True tiles=True cw=True prec=bf16
  units: 18 (0 folded: )
  tiles: {{'matmul': (64, 512, 512), 'conv2d': (8, 128), 'wkv_chunk': 32, 'ce_chunk': 256}}
{KERNELS}""",
    ("resnet34", "base"): f"""\
plan[resnet34 x bench] mode=folded
  passes: fuse=False fold=False tiles=False cw=False prec=fp32
  units: 18 (0 folded: )
  tiles: {BASE_TILES}
{KERNELS}""",
}


@pytest.mark.parametrize("arch,variant", sorted(GOLDEN))
def test_cnn_plan_golden(arch, variant):
    flow = FlowConfig(mode="auto") if variant == "opt" else FlowConfig().base()
    plan = build_plan(get_config(arch), flow, SERVE)
    assert plan.describe() == GOLDEN[(arch, variant)]


def test_lm_plan_golden():
    plan = build_plan(get_smoke("llama3.2-1b"), FlowConfig(mode="folded"),
                      SMOKE_TRAIN)
    assert plan.describe() == f"""\
plan[llama3.2-1b x smoke] mode=folded
  passes: fuse=True fold=True tiles=True cw=True prec=bf16
  units: 3 (1 folded: 3x1)
  tiles: {{'matmul': (16, 64, 192), 'attention': (16, 16), 'decode_attention': 512, 'conv2d': (8, 128), 'wkv_chunk': 32, 'ce_chunk': 256}}
{KERNELS}"""


def test_lm_plan_golden_sharded():
    """The ShardingPass's decisions are part of the plan snapshot: the mesh
    factorization, axis roles, and param-spec census appear as the plan's
    sharding line."""
    plan = build_plan(
        get_smoke("llama3.2-1b"),
        FlowConfig(mode="folded", mesh_split=(("data", 2), ("model", 2))),
        SMOKE_TRAIN)
    assert plan.describe() == f"""\
plan[llama3.2-1b x smoke] mode=folded
  passes: fuse=True fold=True tiles=True cw=True prec=bf16
  units: 3 (1 folded: 3x1)
  tiles: {{'matmul': (16, 64, 192), 'attention': (16, 16), 'decode_attention': 512, 'conv2d': (8, 128), 'wkv_chunk': 32, 'ce_chunk': 256}}
  sharding: mesh={{data:2,model:2}} dp=data:2 tp=model:2 pp=- params[tp=7 fsdp=4 repl=0]
{KERNELS}"""


def test_describe_is_deterministic():
    args = (get_config("resnet34"), FlowConfig(mode="auto"), SERVE)
    assert build_plan(*args).describe(stats=True) == \
        build_plan(*args).describe(stats=True)


@pytest.mark.parametrize("arch,variant", sorted(GOLDEN))
def test_old_and_new_entry_points_identical(arch, variant):
    """Byte-identical plans through the deprecated build_plan shim and the
    repro.flow.compile facade (same golden snapshot)."""
    from repro import flow as rflow
    fl = FlowConfig(mode="auto") if variant == "opt" else FlowConfig().base()
    old = build_plan(get_config(arch), fl, SERVE)
    new = rflow.compile(get_config(arch), SERVE, fl)
    assert old.describe(stats=True) == new.plan.describe(stats=True)
    assert new.plan.describe() == GOLDEN[(arch, variant)]
