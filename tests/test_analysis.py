"""Static verifier tests (repro.analysis).

Three layers:
* a negative case per diagnostic code — each mutation makes its code fire
  exactly once (the codes are the machine interface, so they are pinned);
* a golden sweep — every shipped config verifies clean at its default
  check shape;
* the DSE wiring — static pruning strictly reduces compiled candidates
  while the winning plan stays byte-identical.
"""
import dataclasses
from types import SimpleNamespace

import pytest

from repro import flow as rflow
from repro.analysis import (DIAGNOSTIC_CODES, PlanVerificationError,
                            verify_engine_config, verify_pipeline,
                            verify_plan)
from repro.analysis.checkers import static_flow_diagnostics
from repro.configs import ARCHS, CNNS, get_config, get_smoke
from repro.configs.base import FlowConfig, ShapeConfig
from repro.core.passmanager import PassManager
from repro.core.passes import default_passes
from repro.core.passes.fusion import FusionPass
from repro.core.plan import _build_plan
from repro.kernels.registry import REGISTRY, KernelContract
from repro.serving.engine import EngineConfig

DECODE = ShapeConfig("an_decode", "decode", 128, 4)
CNN_SHAPE = ShapeConfig("an_cnn", "prefill", 64, 8)


@pytest.fixture(scope="module")
def lm_plan():
    return _build_plan(get_smoke("llama3.2-1b"), FlowConfig(), DECODE)


@pytest.fixture(scope="module")
def cnn_plan():
    return _build_plan(get_config("lenet5"), FlowConfig(), CNN_SHAPE)


def _mutated(plan, **over):
    p = dataclasses.replace(plan)
    for k, v in over.items():
        setattr(p, k, v)
    return p


def _fires_once(result, code):
    codes = list(result.codes())
    assert codes.count(code) == 1, (code, result.describe())
    return [d for d in result.diagnostics if d.code == code][0]


# ---------------------------------------------------------------------------
# negative cases — cross-pass contracts (X)
# ---------------------------------------------------------------------------

def test_x001_units_must_partition_blocks(lm_plan):
    res = verify_plan(_mutated(lm_plan, units=lm_plan.units[:-1]))
    d = _fires_once(res, "X001")
    assert d.severity == "error" and not res.ok


def test_x002_tile_must_divide_problem_dim(lm_plan):
    tiles = dict(lm_plan.tiles)
    bm, bk, bn = tiles["matmul"]
    tiles["matmul"] = (3, bk, bn)       # decode m = max(1, 8) = 8; 8 % 3 != 0
    res = verify_plan(_mutated(lm_plan, tiles=tiles))
    _fires_once(res, "X002")


def test_x003_stream_stage_bounds(lm_plan):
    bad = dataclasses.replace(lm_plan.stream, stage_boundaries=(5, 2))
    res = verify_plan(_mutated(lm_plan, stream=bad))
    _fires_once(res, "X003")


def test_x004_shard_axes_must_divide(lm_plan):
    from repro.analysis.checkers import _iter_param_shapes
    key, shape = next(iter(_iter_param_shapes(lm_plan)))
    sp = SimpleNamespace(axis_sizes={"data": 7},
                         param_specs={key: (("data",),) +
                                      (None,) * (len(shape) - 1)})
    assert shape[0] % 7 != 0            # param dims are powers of two here
    res = verify_plan(_mutated(lm_plan, sharding=sp))
    _fires_once(res, "X004")


def test_x005_unknown_mesh_axis(lm_plan):
    from repro.analysis.checkers import _iter_param_shapes
    key, _ = next(iter(_iter_param_shapes(lm_plan)))
    sp = SimpleNamespace(axis_sizes={}, param_specs={key: ("ghost",)})
    res = verify_plan(_mutated(lm_plan, sharding=sp))
    _fires_once(res, "X005")


def test_x006_unknown_op_in_kernel_table(lm_plan):
    res = verify_plan(_mutated(lm_plan,
                               kernels={**lm_plan.kernels, "bogus_op": "ref"}))
    _fires_once(res, "X006")


def test_x007_invalid_graph(lm_plan):
    class _BadGraph:
        def __init__(self, blocks):
            self.blocks = blocks

        def validate(self):
            raise AssertionError("op reads undefined value %x0")

    res = verify_plan(_mutated(lm_plan, graph=_BadGraph(lm_plan.graph.blocks)))
    _fires_once(res, "X007")


def test_x008_unconsumed_tile_key(lm_plan):
    res = verify_plan(_mutated(lm_plan,
                               tiles={**lm_plan.tiles, "mystery": (8, 8)}))
    _fires_once(res, "X008")


# ---------------------------------------------------------------------------
# negative cases — pipeline ordering (P)
# ---------------------------------------------------------------------------

def test_p101_reader_before_writer():
    res = verify_pipeline(PassManager([FusionPass()]))  # reads graph unwritten
    _fires_once(res, "P101")


def test_p102_required_artifact_never_written():
    passes = [p for p in default_passes() if p.name != "tiling"]
    res = verify_pipeline(PassManager(passes))
    d = _fires_once(res, "P102")
    assert d.op == "tiles"


def test_default_pipeline_orders_clean():
    assert verify_pipeline(PassManager.default_pipeline()).ok


# ---------------------------------------------------------------------------
# negative cases — kernel contracts (K)
# ---------------------------------------------------------------------------

def test_k201_backend_without_impl(lm_plan):
    res = verify_plan(_mutated(lm_plan,
                               kernels={**lm_plan.kernels, "norm": "pallas"}))
    _fires_once(res, "K201")


def test_k202_workingset_exceeds_vmem_budget(lm_plan):
    plan = _mutated(
        lm_plan,
        kernels={**lm_plan.kernels, "matmul": "pallas"},
        flow=dataclasses.replace(lm_plan.flow, vmem_budget_bytes=64))
    res = verify_plan(plan)
    _fires_once(res, "K202")


def test_k203_donation_unsafe_kernel(lm_plan):
    REGISTRY.register("unsafe_probe_op", "pallas", lambda: None,
                      contract=KernelContract(donation_safe=False))
    try:
        assert lm_plan.cache.donate_state
        res = verify_plan(_mutated(
            lm_plan,
            kernels={**lm_plan.kernels, "unsafe_probe_op": "pallas"}))
        _fires_once(res, "K203")
    finally:
        del REGISTRY._impls[("unsafe_probe_op", "pallas")]


def test_k204_static_capability_fallback_warns():
    # whisper's decoder cross-attends: the flash kernel statically rejects
    # those ops, so a pallas resolution silently falls back at dispatch
    plan = _build_plan(get_smoke("whisper-small"), FlowConfig(),
                       ShapeConfig("an_wsp", "prefill", 32, 2))
    plan = _mutated(plan, kernels={**plan.kernels, "attention": "pallas"})
    res = verify_plan(plan)
    d = _fires_once(res, "K204")
    assert d.severity == "warning"
    assert res.ok                       # warnings do not fail verification
    assert "cross-attention" in d.message


def test_k205_pool_smaller_than_one_slot(lm_plan):
    ecfg = EngineConfig(max_seq_len=64, block_size=16, num_blocks=3)
    res = verify_engine_config(lm_plan, ecfg)   # blocks_per_slot=4, need 5
    _fires_once(res, "K205")


# ---------------------------------------------------------------------------
# negative cases — serving invariants (S)
# ---------------------------------------------------------------------------

def _ecfg(**kw):
    return EngineConfig(**kw)


def test_s301_block_must_divide_prompt_buckets(lm_plan):
    ecfg = _ecfg()
    ecfg.prompt_buckets = (ecfg.block_size + 1, ecfg.max_seq_len)
    res = verify_engine_config(lm_plan, ecfg)
    _fires_once(res, "S301")


def test_s302_chunk_ladder_needs_rung_one(lm_plan):
    ecfg = _ecfg(chunk_size=4, chunk_buckets=(1, 4))
    ecfg.chunk_buckets = (2, 4)
    res = verify_engine_config(lm_plan, ecfg)
    _fires_once(res, "S302")


def test_s303_fori_seg_one_invalid(lm_plan):
    ecfg = _ecfg()
    ecfg.fori_seg = 1
    res = verify_engine_config(lm_plan, ecfg)
    _fires_once(res, "S303")


def test_s304_batch_ladder_must_end_at_max_batch(lm_plan):
    ecfg = _ecfg()
    ecfg.batch_buckets = (ecfg.max_batch + 1,)
    res = verify_engine_config(lm_plan, ecfg)
    _fires_once(res, "S304")


def test_s305_prompt_buckets_exceed_envelope(lm_plan):
    ecfg = _ecfg()
    ecfg.prompt_buckets = (ecfg.max_seq_len * 2,)
    res = verify_engine_config(lm_plan, ecfg)
    _fires_once(res, "S305")


def test_s306_chunk_size_out_of_range(lm_plan):
    ecfg = _ecfg(chunk_size=4, chunk_buckets=(1, 4))
    ecfg.chunk_size = ecfg.max_seq_len * 2
    ecfg.chunk_buckets = (1, ecfg.chunk_size)
    res = verify_engine_config(lm_plan, ecfg)
    _fires_once(res, "S306")


def test_s307_speculation_fori_seg_clash(lm_plan):
    ecfg = _ecfg(speculation="ngram:4")
    ecfg.fori_seg = 4           # S307: host decides acceptance every tick
    res = verify_engine_config(lm_plan, ecfg)
    _fires_once(res, "S307")


# ---------------------------------------------------------------------------
# negative cases — mesh-split divisibility (M, warnings)
# ---------------------------------------------------------------------------

def test_m401_batch_not_divisible_by_dp(lm_plan):
    plan = _mutated(lm_plan, flow=dataclasses.replace(
        lm_plan.flow, mesh_split=(("data", 3),)))   # batch 4 % 3 != 0
    res = verify_plan(plan)
    d = _fires_once(res, "M401")
    assert d.severity == "warning" and res.ok


def test_m402_tp_idles_for_cnn(cnn_plan):
    plan = _mutated(cnn_plan, flow=dataclasses.replace(
        cnn_plan.flow, mesh_split=(("model", 2),)))
    res = verify_plan(plan)
    _fires_once(res, "M402")


def test_m403_pp_outside_lm_train(lm_plan):
    plan = _mutated(lm_plan, flow=dataclasses.replace(
        lm_plan.flow, pp_axis="pod", mesh_split=(("pod", 2),)))
    res = verify_plan(plan)
    _fires_once(res, "M403")


# ---------------------------------------------------------------------------
# negative cases — flow-knob screen (F)
# ---------------------------------------------------------------------------

def test_f501_bogus_flow_knob(lm_plan):
    diags = static_flow_diagnostics(
        lm_plan.cfg, lm_plan.shape,
        dataclasses.replace(lm_plan.flow, kernel_backend="bogus"))
    assert [d.code for d in diags] == ["F501"]


def test_f501_bogus_tile_override_key(lm_plan):
    diags = static_flow_diagnostics(
        lm_plan.cfg, lm_plan.shape,
        dataclasses.replace(lm_plan.flow,
                            tile_overrides=(("bogus_kernel", (8, 128)),)))
    assert [d.code for d in diags] == ["F501"]


# ---------------------------------------------------------------------------
# negative cases — persistent autotune store (T)
# ---------------------------------------------------------------------------

def test_t601_stale_tunedb_record_warns_and_remeasures(tmp_path):
    """A persisted winner whose knobs no longer apply to FlowConfig is
    surfaced as a T601 warning and the search falls back to measuring."""
    import warnings as _warnings
    from repro import tunedb
    from repro.configs import get_smoke
    from repro.configs.base import FlowConfig, ShapeConfig
    from repro.core import dse

    cfg = get_smoke("llama3.2-1b")
    shape = ShapeConfig("t601", "decode", 64, 4)
    flow = FlowConfig(mode="folded")
    path = str(tmp_path / "tune.jsonl")
    db = tunedb.TuneDB(path)
    key = dse._explore_db_key(cfg, shape, flow, 1, None, None, "compile",
                              dse._platform_key())
    db.put(tunedb.TuneRecord.make(
        "explore", key,
        {"best_knobs": (("no_such_flow_field", 1),), "validated": []}))

    def validator(f):
        return {"per_device_bytes": 1000}

    dse.clear_explore_cache()
    with _warnings.catch_warnings(record=True) as w:
        _warnings.simplefilter("always")
        r = dse.explore(cfg, shape, flow, validator=validator,
                        use_cache=False, db=db)
    assert any("[T601]" in str(x.message) for x in w)
    assert r.tunedb_status == "cold" and r.n_measured >= 1


def test_every_code_has_a_negative_case():
    """The table above must stay in lockstep with DIAGNOSTIC_CODES."""
    import inspect
    import sys
    src = inspect.getsource(sys.modules[__name__])
    for code in DIAGNOSTIC_CODES:
        assert f'"{code}"' in src or f"_{code.lower()}_" in src, code


# ---------------------------------------------------------------------------
# golden sweep — every shipped config verifies clean
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ARCHS + CNNS)
def test_shipped_configs_verify_clean(name):
    from repro.launch.check import check_config
    summary, diags = check_config(name)
    assert summary.startswith("ok"), (name, summary, diags)
    assert diags == []


def test_compile_verify_records_result():
    cm = rflow.compile("llama3.2-1b", DECODE, smoke=True, verify=True)
    assert cm.plan.verification is not None and cm.plan.verification.ok
    assert "verify: ok" in cm.plan.describe()


def test_compile_verify_gates_before_jit():
    flow = FlowConfig(kernel_backend="pallas", vmem_budget_bytes=1)
    with pytest.raises(PlanVerificationError) as ei:
        rflow.compile("llama3.2-1b", DECODE, flow, smoke=True, verify=True)
    assert "K202" in str(ei.value)
    assert not ei.value.result.ok


def test_unverified_describe_has_no_verify_line(lm_plan):
    assert "verify:" not in lm_plan.describe()


# ---------------------------------------------------------------------------
# DSE static pruning
# ---------------------------------------------------------------------------

def test_dse_static_pruning_skips_compiles_keeps_winner():
    from repro.core import dse
    cfg = get_smoke("llama3.2-1b")
    flow0 = FlowConfig(mode="folded")
    calls = []

    def validator(flow):
        calls.append(flow)
        return {"per_device_bytes": 1}

    er = dse.explore(cfg, DECODE, flow0, validator=validator,
                     space={"kernel_backend": ("auto", "bogus")},
                     use_cache=False)
    assert er.n_enumerated == 2
    assert er.n_static_pruned == 1          # 'bogus' never built nor compiled
    assert "static_pruned=1" in er.describe()
    n_bad = len(calls)
    calls.clear()

    er2 = dse.explore(cfg, DECODE, flow0, validator=validator,
                      space={"kernel_backend": ("auto",)}, use_cache=False)
    assert er2.n_static_pruned == 0
    assert len(calls) == n_bad == 1         # pruning saved the extra compile
    assert er.best.flow == er2.best.flow
    assert er.plan.describe() == er2.plan.describe()


def test_dse_all_candidates_statically_invalid_raises():
    from repro.core import dse
    cfg = get_smoke("llama3.2-1b")
    with pytest.raises(ValueError, match="static flow screen"):
        dse.explore(cfg, DECODE, FlowConfig(mode="folded"),
                    space={"kernel_backend": ("bogus",)}, use_cache=False)
