"""Long-context decode behaviour at small scale: the three sub-quadratic
archs decode far past their window/state horizon with bounded caches, and
rolling/recurrent state stays exact vs teacher-forced recompute."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke, LONG_CONTEXT_OK
from repro.configs.base import FlowConfig, ShapeConfig
from repro.core import lowering
from repro.core.plan import build_plan

from conftest import relerr

SHAPE = ShapeConfig("long", "train", 16, 2)


def _decode_many(arch, S=10, extra=24):
    """Prefill S tokens, decode `extra` more (past the window), compare the
    final logits against a full teacher-forced prefill."""
    cfg = get_smoke(arch)
    plan = build_plan(cfg, FlowConfig(mode="folded", precision="fp32"),
                      SHAPE)
    params = lowering.init_params(plan, jax.random.key(3))
    apply = lowering.make_apply(plan)
    rng = np.random.RandomState(7)
    B = 2
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S + extra)),
                       jnp.int32)
    _, st, _ = apply(params, {"tokens": toks[:, :S]}, mode="prefill")
    lg = None
    for t in range(extra):
        lg, st, _ = apply(params, {"tokens": toks[:, S + t:S + t + 1]},
                          state=st, cache_index=jnp.int32(S + t),
                          mode="decode")
    ref, _, _ = apply(params, {"tokens": toks}, mode="prefill")
    return lg, ref, cfg, st


@pytest.mark.parametrize("arch", list(LONG_CONTEXT_OK))
def test_decode_past_window_matches_recompute(arch):
    lg, ref, cfg, _ = _decode_many(arch)
    assert relerr(lg, ref) < 5e-4, arch


@pytest.mark.parametrize("arch", list(LONG_CONTEXT_OK))
def test_state_is_bounded(arch):
    """The decode state must not grow with generated length (the long_500k
    feasibility property): cache length ≤ min(window, shape seq_len)."""
    cfg = get_smoke(arch)
    plan = build_plan(cfg, FlowConfig(mode="folded"), SHAPE)
    state = lowering.init_state(plan, batch_size=2, abstract=True)
    w = cfg.attention.window if cfg.attention else 0
    for unit_state in state.values():
        for key, leaf in unit_state.items():
            sub = leaf if isinstance(leaf, dict) else {"": leaf}
            for s in jax.tree.leaves(sub):
                for d in s.shape:
                    assert d <= max(plan.cache_len, cfg.d_ff,
                                    cfg.padded_vocab), (arch, key, s.shape)
        # attention caches specifically bounded by the window
        if cfg.attention and cfg.attention.window:
            for key, leaf in unit_state.items():
                if isinstance(leaf, dict) and "k" in leaf:
                    assert leaf["k"].shape[-3] <= min(SHAPE.seq_len,
                                                      cfg.attention.window)


def test_rglru_conv_state_across_window():
    """RG-LRU temporal-conv state must carry exactly across many decode
    steps (width-4 causal conv: the last 3 inputs)."""
    lg, ref, cfg, st = _decode_many("recurrentgemma-2b", S=6, extra=30)
    assert relerr(lg, ref) < 5e-4


def test_long_shape_registry():
    from repro.configs import cells
    longs = [(a, s) for a, s, r in cells(include_skipped=True)
             if s == "long_500k" and r]
    assert sorted(a for a, _ in longs) == sorted(LONG_CONTEXT_OK)
