"""Property-based serving-state invariants (hypothesis).

Block aliasing is the easiest place to corrupt serving state, so the
refcounting allocator + prefix index get hammered with random interleavings
of admit / decode / finish / evict against the *real* host-side ledger
(:class:`repro.serving.kvcache.BlockLedger` — the exact object the engine
mirrors onto device state), checking after every step:

* no double-free (the pool raises; conservation would also catch it),
* ``free + cached + live == pool size - 1`` (trash excluded) and every
  live refcount equals the number of chain/spare references,
* no slot's chain references a freed block,
* speculative windows (fork -> write -> partial-acceptance rollback via
  ``spec_begin``/``spec_commit``) conserve blocks and never double-free —
  undone COW forks repoint to still-valid originals,
* the trash block is never allocated, referenced, cached or chained,
* LRU eviction only ever reclaims unreferenced (parked) blocks,
* prefix matches never cover the whole prompt (the last token is always
  recomputed for its logits) and only ever return locked, live blocks.

The suite is deterministic (``derandomize=True``) so CI failures reproduce;
run it with ``--hypothesis-show-statistics`` to see example counts.
"""
import os

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serving.kvcache import (BlockLedger, TRASH_BLOCK,  # noqa: E402
                                   blocks_for_tokens)
from repro.serving.prefix import block_hashes  # noqa: E402

pytestmark = pytest.mark.slow

# fixed-seed profile for CI: 500+ deterministic examples per property
settings.register_profile(
    "serving-ci", settings(max_examples=500, derandomize=True, deadline=None))
settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "serving-ci"))

BS = 4                 # block size
BPS = 6                # blocks per slot -> 24-token capacity
SLOTS = 3
MAX_NEW = 3


def _prompt(seed: int, length: int) -> np.ndarray:
    # a 2-token alphabet makes identical prefixes (and hence index hits,
    # shared partial tails and COW forks) common instead of vanishing
    rng = np.random.RandomState(seed)
    return rng.randint(0, 2, length).astype(np.int32)


class Harness:
    """Drives a BlockLedger through the engine's host-side discipline:
    admit (match -> charge -> seed), decode ticks (fork-before-write,
    catch-up then generate), finish/evict — without any device state."""

    def __init__(self, num_blocks: int, prefix_cache: bool):
        self.led = BlockLedger(num_blocks, SLOTS, BS, BPS,
                               prefix_cache=prefix_cache)
        self.prefix_cache = prefix_cache
        # per live slot: target total tokens (prompt + generated budget)
        self.target = [0] * SLOTS
        self.prompt_len = [0] * SLOTS
        self.forks_seen = 0

    # -- engine-loop mirror --------------------------------------------------
    def admit(self, seed: int, length: int, max_new: int) -> bool:
        free = [s for s in range(SLOTS) if not self.led.chains[s]]
        if not free:
            return False
        slot = free[0]
        prompt = _prompt(seed, length)
        budget = length + max_new
        if budget > BPS * BS:
            return False
        match = self.led.match_and_lock(prompt) if self.prefix_cache else None
        need = self.led.fresh_blocks_needed(budget, match)
        if need > self.led.pool.free_blocks:
            if match is not None:
                self.led.unlock(match)
            return False
        self.led.admit(slot, prompt, budget, match=match)
        self.target[slot] = budget
        self.prompt_len[slot] = length
        if match is None:
            # cold path: the prefill scatter makes the whole prompt resident
            self.led.register_prompt(slot)
        return True

    def tick(self) -> None:
        """One decode tick over every live slot: COW forks first (decode
        never writes a block with refcount > 1), then the write."""
        for s in range(SLOTS):
            if not self.led.chains[s]:
                continue
            if self.led.lens[s] >= self.target[s] - 1:
                continue               # budget reached; waiting for evict
            if self.led.needs_fork(s):
                ci, old, new = self.led.fork(s)
                assert old != new and new != TRASH_BLOCK
                self.forks_seen += 1
            ci = self.led.lens[s] // BS
            blk = self.led.chains[s][ci]
            assert self.led.pool.refcount(blk) == 1 or not self.prefix_cache, \
                "decode would write a shared block"
            self.led.note_write(s)
            if self.led.lens[s] == self.prompt_len[s]:
                # catch-up complete: the prompt is fully resident
                self.led.register_prompt(s)

    def spec_tick(self, j: int, commit_sel: int) -> None:
        """One speculative verify window over every live slot: open the
        window, fork-before-write, write up to ``j`` speculative tokens,
        then commit a prefix chosen by ``commit_sel`` and roll the rest
        back — the draft->verify->rollback discipline.  Windows opened over
        a catch-up position write into COW-shared blocks, so full rejection
        exercises the fork-undo path (chain repointed at the original,
        spare restored).  Prompt registration happens only from *committed*
        length — never on a write that might roll back."""
        for s in range(SLOTS):
            if not self.led.chains[s]:
                continue
            fed = min(j, self.target[s] - 1 - self.led.lens[s])
            if fed < 1:
                continue
            self.led.spec_begin(s)
            for _ in range(fed):
                if self.led.needs_fork(s):
                    ci, old, new = self.led.fork(s)
                    assert old != new and new != TRASH_BLOCK
                    self.forks_seen += 1
                self.led.note_write(s)
            self.led.spec_commit(s, commit_sel % (fed + 1))
            if not self.led._registered[s] \
                    and self.led.lens[s] >= self.prompt_len[s]:
                self.led.register_prompt(s)

    def finish(self, which: int) -> None:
        live = [s for s in range(SLOTS) if self.led.chains[s]]
        if not live:
            return
        slot = live[which % len(live)]
        self.led.release(slot)
        self.target[slot] = self.prompt_len[slot] = 0

    def step(self, op) -> None:
        kind = op[0]
        if kind == 0:
            self.admit(seed=op[1], length=op[2], max_new=op[3])
        elif kind == 1:
            self.tick()
        elif kind == 2:
            self.finish(op[1])
        else:
            self.spec_tick(op[1], op[2])
        self.led.check()


OPS = st.one_of(
    st.tuples(st.just(0), st.integers(0, 7), st.integers(1, 20),
              st.integers(1, MAX_NEW)),
    st.tuples(st.just(1)),
    st.tuples(st.just(2), st.integers(0, SLOTS - 1)),
    st.tuples(st.just(3), st.integers(1, MAX_NEW), st.integers(0, 10)),
)
SCRIPTS = st.lists(OPS, min_size=1, max_size=40)
POOLS = st.integers(8, 1 + SLOTS * BPS)


@given(script=SCRIPTS, num_blocks=POOLS)
def test_interleavings_preserve_invariants_prefix_on(script, num_blocks):
    """The headline property: random admit/decode/finish interleavings with
    prefix caching + COW sharing never break conservation, refcounts, chain
    validity or the trash block."""
    h = Harness(num_blocks, prefix_cache=True)
    for op in script:
        h.step(op)
    # drain: everything releases cleanly and nothing leaks
    for s in range(SLOTS):
        if h.led.chains[s]:
            h.led.release(s)
    h.led.check()
    assert h.led.pool.used_blocks == 0


@given(script=SCRIPTS, num_blocks=POOLS)
def test_interleavings_preserve_invariants_prefix_off(script, num_blocks):
    """Same machine with sharing disabled: the refcounting pool must degrade
    to the plain free-list allocator (refcounts all 1, nothing cached)."""
    h = Harness(num_blocks, prefix_cache=False)
    for op in script:
        h.step(op)
        assert h.led.pool.cached_blocks == 0
        assert all(h.led.pool.refcount(b) == 1
                   for chain in h.led.chains for b in chain)
    assert h.forks_seen == 0


@given(script=SCRIPTS)
def test_trash_block_never_allocated_or_refcounted(script):
    h = Harness(1 + SLOTS * BPS, prefix_cache=True)
    for op in script:
        h.step(op)
        assert h.led.pool.refcount(TRASH_BLOCK) == 0
        assert not h.led.pool.is_cached(TRASH_BLOCK)
        for chain in h.led.chains:
            assert TRASH_BLOCK not in chain


@given(script=SCRIPTS, num_blocks=st.integers(8, 14))
def test_lru_eviction_only_reclaims_unreferenced(script, num_blocks):
    """Under a deliberately tight pool, cached blocks are reclaimed — but
    only ever blocks no chain or spare references, and their index entries
    are dropped at reclaim time (led.check() verifies no index entry ever
    points at a free block afterwards)."""
    h = Harness(num_blocks, prefix_cache=True)
    n_reclaims = [0]

    def hook(b):
        assert h.led.pool.refcount(b) == 0, "reclaimed a referenced block"
        assert all(b not in chain for chain in h.led.chains), \
            "reclaimed a chained block"
        assert b not in h.led.spares, "reclaimed a COW spare"
        n_reclaims[0] += 1
        h.led._on_reclaim(b)     # the ledger's own hook: drop index entries

    h.led.pool.on_cache_evict = hook
    for op in script:
        h.step(op)
    h.led.check()


@given(seed=st.integers(0, 50), length=st.integers(2, BPS * BS - MAX_NEW))
def test_match_never_covers_whole_prompt(seed, length):
    """After a cold request is served and evicted, re-matching its exact
    prompt hits — but always leaves >= 1 token to recompute (its logits
    seed sampling), and every matched block is locked (refcount 1)."""
    h = Harness(1 + SLOTS * BPS, prefix_cache=True)
    assert h.admit(seed, length, MAX_NEW)
    for _ in range(MAX_NEW + length):
        h.tick()
    h.finish(0)
    prompt = _prompt(seed, length)
    match = h.led.match_and_lock(prompt)
    assert match is not None, "identical prompt must hit after eviction"
    assert match.covered == length - 1
    assert match.covered_raw == length
    assert match.needs_cow_spare
    for b in match.blocks:
        assert h.led.pool.refcount(b) == 1
    h.led.unlock(match)
    h.led.check()


@given(seed=st.integers(0, 20), cut=st.integers(1, 15))
def test_block_hash_chain_is_prefix_sensitive(seed, cut):
    """Chained hashes: equal digests imply equal *prefixes* — perturbing any
    earlier token changes every later digest."""
    prompt = _prompt(seed, 16)
    other = prompt.copy()
    other[cut % prompt.size] ^= 1
    ha = block_hashes(prompt, BS)
    hb = block_hashes(other, BS)
    flip_block = (cut % prompt.size) // BS
    for i, ((da, ea), (db, eb)) in enumerate(zip(ha, hb)):
        assert ea == eb
        if i < flip_block:
            assert da == db
        else:
            assert da != db


def test_blocks_for_tokens_matches_charge():
    led = BlockLedger(20, 2, BS, BPS, prefix_cache=False)
    for budget in range(1, BPS * BS + 1):
        assert led.fresh_blocks_needed(budget, None) == \
            blocks_for_tokens(budget, BS)
