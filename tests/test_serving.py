"""Serving-engine integration: batched generation, host-free decode loop.
The engine consumes a repro.flow.CompiledModel (the public API)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import flow as rflow
from repro.configs.base import FlowConfig
from repro.serving.engine import Engine, EngineConfig

from conftest import SMOKE_SHAPE, smoke_batch


def _engine(arch="llama3.2-1b"):
    cm = rflow.compile(arch, SMOKE_SHAPE,
                       FlowConfig(mode="folded", precision="fp32"),
                       smoke=True)
    params = cm.init_params(jax.random.key(0))
    return cm.cfg, cm, Engine(cm, params, EngineConfig(temperature=0.0))


def test_generate_shapes_and_determinism():
    cfg, plan, eng = _engine()
    batch = smoke_batch(cfg, B=2, S=8, with_labels=False)
    toks1, _ = eng.generate(batch, steps=5)
    toks2, _ = eng.generate(batch, steps=5)
    assert toks1.shape == (2, 5)
    np.testing.assert_array_equal(np.asarray(toks1), np.asarray(toks2))
    assert int(jnp.max(toks1)) < cfg.padded_vocab


def test_generate_fori_matches_python_loop():
    """The fully on-device (autorun-analogue) loop == the host loop."""
    cfg, plan, eng = _engine()
    batch = smoke_batch(cfg, B=2, S=8, with_labels=False)
    t_host, _ = eng.generate(batch, steps=6)
    t_dev = eng.generate_fori(batch, steps=6)
    np.testing.assert_array_equal(np.asarray(t_host), np.asarray(t_dev))


def test_generate_matches_teacher_forcing():
    """Greedy generation must equal argmax of a teacher-forced forward over
    the generated prefix (cache correctness across many steps)."""
    cfg, cm, eng = _engine()
    batch = smoke_batch(cfg, B=1, S=6, with_labels=False)
    toks, _ = eng.generate(batch, steps=4)
    full = jnp.concatenate([batch["tokens"], toks[:, :3]], axis=1)
    logits, _, _ = cm.apply(eng.params, {"tokens": full}, mode="prefill")
    want = jnp.argmax(logits[:, -1], -1)
    np.testing.assert_array_equal(np.asarray(toks[:, 3]), np.asarray(want))


@pytest.mark.parametrize("arch", ["rwkv6-7b", "recurrentgemma-2b",
                                  "whisper-small"])
def test_generate_stateful_archs(arch):
    cfg, plan, eng = _engine(arch)
    batch = smoke_batch(cfg, B=2, S=8, with_labels=False)
    toks, _ = eng.generate(batch, steps=4)
    assert toks.shape == (2, 4)
    assert int(jnp.max(toks)) < cfg.padded_vocab


def test_temperature_sampling_runs():
    cfg, cm, _ = _engine()
    params = cm.init_params(jax.random.key(0))
    eng = Engine(cm, params, EngineConfig(temperature=0.8, seed=1))
    batch = smoke_batch(cfg, B=2, S=8, with_labels=False)
    toks, _ = eng.generate(batch, steps=4)
    assert toks.shape == (2, 4)
