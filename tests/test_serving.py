"""Serving-engine integration: batched generation, host-free decode loop,
continuous batching over the paged KV cache, engine-level autotune.
The engine consumes a repro.flow.CompiledModel (the public API)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import flow as rflow
from repro.configs.base import FlowConfig, ShapeConfig
from repro.serving.engine import Engine, EngineConfig
from repro.serving.scheduler import (Request, shared_prefix_requests,
                                     synthetic_requests)

from conftest import SMOKE_SHAPE, smoke_batch


def _engine(arch="llama3.2-1b"):
    cm = rflow.compile(arch, SMOKE_SHAPE,
                       FlowConfig(mode="folded", precision="fp32"),
                       smoke=True)
    params = cm.init_params(jax.random.key(0))
    return cm.cfg, cm, Engine(cm, params, EngineConfig(temperature=0.0))


SERVE_SHAPE = ShapeConfig("serve", "decode", 64, 4)


@functools.lru_cache(maxsize=1)
def _serve_cm():
    """One compiled decode cell shared by the serving-loop tests."""
    cm = rflow.compile("llama3.2-1b", SERVE_SHAPE,
                       FlowConfig(mode="folded", precision="fp32"),
                       smoke=True)
    params = cm.init_params(jax.random.key(0))
    return cm, params


def test_generate_shapes_and_determinism():
    cfg, plan, eng = _engine()
    batch = smoke_batch(cfg, B=2, S=8, with_labels=False)
    toks1, _ = eng.generate(batch, steps=5)
    toks2, _ = eng.generate(batch, steps=5)
    assert toks1.shape == (2, 5)
    np.testing.assert_array_equal(np.asarray(toks1), np.asarray(toks2))
    assert int(jnp.max(toks1)) < cfg.padded_vocab


def test_generate_fori_matches_python_loop():
    """The fully on-device (autorun-analogue) loop == the host loop."""
    cfg, plan, eng = _engine()
    batch = smoke_batch(cfg, B=2, S=8, with_labels=False)
    t_host, _ = eng.generate(batch, steps=6)
    t_dev = eng.generate_fori(batch, steps=6)
    np.testing.assert_array_equal(np.asarray(t_host), np.asarray(t_dev))


def test_generate_matches_teacher_forcing():
    """Greedy generation must equal argmax of a teacher-forced forward over
    the generated prefix (cache correctness across many steps)."""
    cfg, cm, eng = _engine()
    batch = smoke_batch(cfg, B=1, S=6, with_labels=False)
    toks, _ = eng.generate(batch, steps=4)
    full = jnp.concatenate([batch["tokens"], toks[:, :3]], axis=1)
    logits, _, _ = cm.apply(eng.params, {"tokens": full}, mode="prefill")
    want = jnp.argmax(logits[:, -1], -1)
    np.testing.assert_array_equal(np.asarray(toks[:, 3]), np.asarray(want))


@pytest.mark.parametrize("arch", ["rwkv6-7b", "recurrentgemma-2b",
                                  "whisper-small"])
def test_generate_stateful_archs(arch):
    cfg, plan, eng = _engine(arch)
    batch = smoke_batch(cfg, B=2, S=8, with_labels=False)
    toks, _ = eng.generate(batch, steps=4)
    assert toks.shape == (2, 4)
    assert int(jnp.max(toks)) < cfg.padded_vocab


def test_temperature_sampling_runs():
    cfg, cm, _ = _engine()
    params = cm.init_params(jax.random.key(0))
    eng = Engine(cm, params, EngineConfig(temperature=0.8, seed=1))
    batch = smoke_batch(cfg, B=2, S=8, with_labels=False)
    toks, _ = eng.generate(batch, steps=4)
    assert toks.shape == (2, 4)


# ---------------------------------------------------------------------------
# continuous batching over the paged KV cache
# ---------------------------------------------------------------------------

def test_run_continuous_batching_16_requests():
    """The acceptance loop: 16 concurrent requests through 4 slots finish
    with multiple eviction/refill cycles and coherent metrics."""
    cm, params = _serve_cm()
    eng = Engine(cm, params, EngineConfig(max_batch=4, max_seq_len=64,
                                          block_size=8))
    reqs = synthetic_requests(16, cm.cfg.vocab_size, prompt_len=8,
                              max_new_tokens=4, seed=1)
    report = eng.run(reqs)
    assert len(report.results) == 16
    assert all(r.n_generated == 4 for r in report.results)
    assert all(r.finish_reason == "length" for r in report.results)
    m = report.metrics
    assert m["evictions"] == 16 and m["admissions"] == 16
    assert m["refills"] >= 2                 # >= 2 eviction/refill cycles
    assert m["generated_tokens"] == 64
    assert m["tokens_per_s"] > 0
    assert m["p95_latency_s"] >= m["p50_latency_s"] > 0
    assert m["peak_used_blocks"] <= eng.new_cache().num_blocks - 1
    # metrics surface through describe()
    d = eng.describe()
    assert "serving[16 req]" in d and "refills=" in d and "kv-pool" in d


def test_run_is_deterministic():
    cm, params = _serve_cm()
    reqs = synthetic_requests(6, cm.cfg.vocab_size, prompt_len=6,
                              max_new_tokens=3, seed=2)
    ecfg = EngineConfig(max_batch=2, max_seq_len=64, block_size=8)
    r1 = Engine(cm, params, ecfg).run(reqs)
    r2 = Engine(cm, params, ecfg).run(reqs)
    assert [r.tokens for r in r1.results] == [r.tokens for r in r2.results]


def test_paged_decode_matches_rolling_tokens():
    """Continuous-batching generation over the paged pool reproduces the
    rolling-cache generate() token-for-token (same seeds, greedy)."""
    cm, params = _serve_cm()
    rng = np.random.RandomState(5)
    prompts = rng.randint(0, cm.cfg.vocab_size, (2, 8)).astype(np.int32)
    toks_roll, _ = cm.generate(params, {"tokens": jnp.asarray(prompts)},
                               steps=6)
    eng = Engine(cm, params,
                 EngineConfig(max_batch=2, max_seq_len=64, block_size=8,
                              prompt_buckets=(8, 64)))
    rep = eng.run([Request("a", prompts[0], max_new_tokens=6),
                   Request("b", prompts[1], max_new_tokens=6)])
    paged = np.stack([rep.by_id["a"].tokens, rep.by_id["b"].tokens])
    np.testing.assert_array_equal(np.asarray(toks_roll), paged)


def test_paged_decode_logits_byte_identical_to_rolling():
    """One decode tick, same cache contents: the paged lookup path (gather
    through block tables) must produce *byte-identical* logits to the
    rolling cache — the ref fallback mirrors _sdpa operation-for-operation
    and the pool capacity is sized so the gathered length matches."""
    from repro.serving.kvcache import PagedKVCache
    cm, params = _serve_cm()
    B, S = 2, 8
    rng = np.random.RandomState(7)
    toks = rng.randint(0, cm.cfg.vocab_size, (B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks)}
    # rolling: prefill then one decode step at position S
    logits_p, rstate, _ = cm.prefill(params, batch)
    nxt = jnp.argmax(logits_p[:, -1], -1).astype(jnp.int32)[:, None]
    lg_roll, _, _ = cm.decode(params, {"tokens": nxt}, rstate, jnp.int32(S))
    # paged: pack the same prefill into a pool whose per-slot capacity
    # equals the rolling cache length (64 = 8 blocks x 8), decode same token
    _, pstate, _ = cm.prefill(params, batch)
    cache = PagedKVCache(cm.plan, B, block_size=8, blocks_per_slot=8)
    for i in range(B):
        cache.admit(i, S, S + 8, pstate, i, 0)
    lg_paged, _, _ = cm.decode(
        params, {"tokens": nxt,
                 "positions": jnp.full((B, 1), S, jnp.int32)},
        cache.state, jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(lg_roll), np.asarray(lg_paged))


def test_paged_decode_matches_rolling_with_bucketed_prompts():
    """Left-padded bucketed prefill: requests of different lengths batched
    into one prompt bucket still reproduce their individual rolling-path
    generations exactly."""
    cm, params = _serve_cm()
    rng = np.random.RandomState(11)
    p_long = rng.randint(0, cm.cfg.vocab_size, 8).astype(np.int32)
    p_short = rng.randint(0, cm.cfg.vocab_size, 5).astype(np.int32)
    want_long, _ = cm.generate(params, {"tokens": jnp.asarray(p_long[None])},
                               steps=5)
    want_short, _ = cm.generate(params,
                                {"tokens": jnp.asarray(p_short[None])},
                                steps=5)
    eng = Engine(cm, params,
                 EngineConfig(max_batch=2, max_seq_len=64, block_size=8))
    rep = eng.run([Request("long", p_long, max_new_tokens=5),
                   Request("short", p_short, max_new_tokens=5)])
    np.testing.assert_array_equal(np.asarray(want_long)[0],
                                  rep.by_id["long"].tokens)
    np.testing.assert_array_equal(np.asarray(want_short)[0],
                                  rep.by_id["short"].tokens)


def test_paged_pool_memory_scales_with_live_tokens():
    """The point of paging: pool bytes are set by the block budget, not by
    max_seq_len x slots.  A pool provisioned for half the envelope is ~half
    the rolling cache's footprint and still serves (admission control queues
    the rest)."""
    cm, params = _serve_cm()
    full = EngineConfig(max_batch=4, max_seq_len=64, block_size=8)
    half_blocks = 1 + (full.blocks_per_slot * 4) // 2
    half = EngineConfig(max_batch=4, max_seq_len=64, block_size=8,
                        num_blocks=half_blocks)
    eng_full = Engine(cm, params, full)
    eng_half = Engine(cm, params, half)
    bytes_full = eng_full.new_cache().pool_bytes()
    bytes_half = eng_half.new_cache().pool_bytes()
    assert bytes_half < 0.6 * bytes_full
    reqs = synthetic_requests(6, cm.cfg.vocab_size, prompt_len=8,
                              max_new_tokens=3, seed=4)
    rep = eng_half.run(reqs)
    assert len(rep.results) == 6
    assert rep.metrics["peak_used_blocks"] < half_blocks


def test_slice_merge_roundtrip():
    from repro.serving.kvcache import merge_state, slice_state
    cm, params = _serve_cm()
    eng = Engine(cm, params, EngineConfig(max_batch=4, max_seq_len=64,
                                          block_size=8))
    cache = eng.new_cache()
    part = slice_state(cache.state, cache.slot_axes, 2)
    back = merge_state(cache.state, part, cache.slot_axes, 2)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), cache.state, back)


def test_run_raises_on_unservable_request():
    """A request whose block budget exceeds the whole pool fails loudly
    instead of spinning in the admission loop."""
    cm, params = _serve_cm()
    eng = Engine(cm, params, EngineConfig(max_batch=2, max_seq_len=64,
                                          block_size=8, num_blocks=3))
    with pytest.raises(RuntimeError, match="never free enough blocks"):
        eng.run([Request("x", np.arange(1, 30, dtype=np.int32),
                         max_new_tokens=8)])


def test_run_rejects_padded_prompts_for_recurrent_models():
    """Hybrid models (recurrences mix across positions without reading the
    positions array) must refuse left-padded bucketed prefill instead of
    silently corrupting the recurrent state; exact-bucket prompts serve."""
    cm = rflow.compile("recurrentgemma-2b", SERVE_SHAPE,
                       FlowConfig(mode="folded", precision="fp32"),
                       smoke=True)
    params = cm.init_params(jax.random.key(0))
    eng = Engine(cm, params,
                 EngineConfig(max_batch=2, max_seq_len=64, block_size=8,
                              prompt_buckets=(8, 64)))
    with pytest.raises(ValueError, match="recurrent temporal-mixing"):
        eng.run([Request("padded", np.arange(1, 6, dtype=np.int32),
                         max_new_tokens=2)])
    rep = eng.run([Request("exact", np.arange(1, 9, dtype=np.int32),
                           max_new_tokens=2)])
    assert rep.by_id["exact"].n_generated == 2


def test_run_rejects_stateless_families():
    cm = rflow.compile("lenet5", ShapeConfig("s", "prefill", 8, 2),
                       FlowConfig(mode="folded", precision="fp32"))
    params = cm.init_params(jax.random.key(0))
    eng = Engine(cm, params, EngineConfig(max_batch=2, max_seq_len=16))
    with pytest.raises(ValueError):
        eng.run([Request("x", np.arange(1, 4), max_new_tokens=2)])


# ---------------------------------------------------------------------------
# prefix caching: parity under sharing + adversarial scheduler scenarios
# ---------------------------------------------------------------------------

def _run_pair(reqs, *, capture=False, **ecfg_kw):
    """The same request batch served cold (prefix_cache=False) and with the
    prefix cache on; returns both reports."""
    cm, params = _serve_cm()
    kw = dict(max_batch=2, max_seq_len=64, block_size=8,
              capture_logits=capture)
    kw.update(ecfg_kw)
    off = Engine(cm, params, EngineConfig(prefix_cache=False, **kw)).run(reqs)
    on = Engine(cm, params, EngineConfig(prefix_cache=True, **kw)).run(reqs)
    return off, on


def _assert_results_identical(off, on):
    """Per-request tokens AND the logits each token was sampled from must be
    byte-identical between the cold and prefix-cached runs.  Matched by
    request id — eviction order may differ (a cache hit samples its first
    token one tick later than a same-wave cold admission)."""
    assert set(off.by_id) == set(on.by_id)
    for rid, a in off.by_id.items():
        b = on.by_id[rid]
        assert a.tokens == b.tokens, f"request {rid} diverged"
        assert len(a.logits) == len(b.logits) > 0
        for la, lb in zip(a.logits, b.logits):
            np.testing.assert_array_equal(la, lb)


def test_prefix_hit_with_cow_fork_matches_cold_byte_identical():
    """A request served via prefix-cache hits — including two simultaneous
    requests forking the same shared partial tail block mid-block — produces
    byte-identical logits to the cold path.  (a, b) warm the cache; (c, d)
    admit together, both seed the full + partial blocks, and the first
    decode write forks the shared partial (COW)."""
    cm, params = _serve_cm()
    rng = np.random.RandomState(21)
    p = rng.randint(0, cm.cfg.vocab_size, 12).astype(np.int32)  # 1.5 blocks
    reqs = lambda: [Request(x, p, max_new_tokens=4) for x in "abcd"]
    off, on = _run_pair(reqs(), capture=True, prompt_buckets=(16, 64))
    _assert_results_identical(off, on)
    m = on.metrics
    assert m["prefix_hits"] >= 2            # c and d seed from the cache
    assert m["cow_forks"] >= 1              # shared partial block forked
    assert m["prefill_tokens_computed"] < off.metrics["prefill_tokens_computed"]


def test_prefix_entirely_cached_prompt_zero_block_prefill():
    """A prompt that is entirely a cached prefix: block-aligned, fully
    matched — the request allocates only generation-budget blocks, joins no
    prefill batch (zero-block prefill; one catch-up decode recomputes the
    last token's logits), and still matches the cold path byte-for-byte."""
    cm, params = _serve_cm()
    rng = np.random.RandomState(22)
    p = rng.randint(0, cm.cfg.vocab_size, 16).astype(np.int32)  # 2 full blocks
    # max_batch=1 serializes: 'a' warms + evicts, then 'b' admits alone
    off, on = _run_pair(
        [Request("a", p, max_new_tokens=3), Request("b", p, max_new_tokens=3)],
        capture=True, max_batch=1, prompt_buckets=(16, 64))
    _assert_results_identical(off, on)
    m = on.metrics
    assert m["prefix_hits"] == 1
    assert m["prefill_batches"] == 1        # 'b' never joined a prefill batch
    assert m["catchup_tokens"] == 1         # only the recomputed last token
    assert m["prefix_cached_tokens"] == 15  # covered caps at prompt_len - 1


def test_prefix_parity_shared_prefix_batch():
    """Mixed workload parity: a shared system prompt with distinct tails
    (the hit path re-enters mid-block at a non-block-aligned position) —
    tokens and sampled-step logits byte-identical to the cold run."""
    cm, params = _serve_cm()
    reqs = lambda: shared_prefix_requests(6, cm.cfg.vocab_size, prefix_len=24,
                                          tail_len=6, max_new_tokens=3,
                                          seed=31)
    off, on = _run_pair(reqs(), capture=True, max_batch=2)
    _assert_results_identical(off, on)
    assert on.metrics["prefix_hits"] >= 4
    assert on.metrics["prefix_hit_rate"] > 0.3


def test_prefix_admission_under_nearly_full_pool():
    """Adversarial: a pool too small for two cold requests still admits a
    cache hit (it is charged only for uncovered blocks) — and refuses to
    double-book blocks when eviction pressure races admission in the same
    tick (the matched blocks are locked at decision time)."""
    cm, params = _serve_cm()
    rng = np.random.RandomState(23)
    p = rng.randint(0, cm.cfg.vocab_size, 16).astype(np.int32)
    # 6 allocatable blocks: a cold 16+8 request needs 3; two cold ones need
    # 6 -> the pool fits them only serially.  With the prefix cache, 'b'
    # charges 1 fresh block + 1 COW spare and shares the other two.
    ecfg = EngineConfig(max_batch=2, max_seq_len=64, block_size=8,
                        num_blocks=7, prefix_cache=True,
                        prompt_buckets=(16, 64))
    eng = Engine(cm, params, ecfg)
    rep = eng.run([Request("a", p, max_new_tokens=8),
                   Request("b", p, max_new_tokens=8),
                   Request("c", p, max_new_tokens=8)])
    assert len(rep.results) == 3
    assert all(r.n_generated == 8 for r in rep.results)
    assert rep.metrics["prefix_hits"] >= 1
    assert rep.metrics["peak_used_blocks"] <= 6


def test_prefix_eviction_racing_admission_same_tick():
    """Adversarial: allocation pressure in the same tick as a cache-hit
    admission must reclaim only unlocked cached blocks — the run completes
    with every request byte-identical to its cold serve."""
    cm, params = _serve_cm()
    rng = np.random.RandomState(24)
    shared = rng.randint(0, cm.cfg.vocab_size, 16).astype(np.int32)
    fresh = [rng.randint(0, cm.cfg.vocab_size, 16).astype(np.int32)
             for _ in range(3)]
    reqs = lambda: [Request("s0", shared, max_new_tokens=3),
                    Request("f0", fresh[0], max_new_tokens=3),
                    Request("s1", shared, max_new_tokens=3),
                    Request("f1", fresh[1], max_new_tokens=3),
                    Request("f2", fresh[2], max_new_tokens=3),
                    Request("s2", shared, max_new_tokens=3)]
    # 8 allocatable blocks, each request needs <= 3: cached blocks from
    # finished requests must be reclaimed to admit the fresh prompts while
    # 's*' hits lock theirs
    off, on = _run_pair(reqs(), capture=True, num_blocks=9,
                        prompt_buckets=(16, 64))
    _assert_results_identical(off, on)
    assert on.metrics["prefix_hits"] >= 1
    assert on.metrics["prefix_cache_evictions"] >= 1


def test_prefix_hit_never_blocks_an_admittable_request():
    """Regression: when the match-inclusive charge (locked blocks leave the
    allocatable count, + a COW spare) exceeds the pool but the *cold* charge
    fits, the scheduler must drop the match and admit cold — a cache hit
    must never make a servable request unadmittable."""
    cm, params = _serve_cm()
    rng = np.random.RandomState(25)
    p = rng.randint(0, cm.cfg.vocab_size, 16).astype(np.int32)
    # 6 allocatable blocks; prompt 16 + 32 new = 48 tok = exactly 6 blocks.
    # After 'a' serves, its 2 prompt blocks are indexed; a naive hit charge
    # for 'b' is 6-2+1=5 fresh vs 4 unlocked-free -> must fall back to cold
    reqs = [Request("a", p, max_new_tokens=32),
            Request("b", p, max_new_tokens=32)]
    ecfg = dict(max_batch=2, max_seq_len=64, block_size=8, num_blocks=7,
                prompt_buckets=(16, 64))
    off = Engine(cm, params, EngineConfig(prefix_cache=False, **ecfg)).run(reqs)
    on = Engine(cm, params, EngineConfig(prefix_cache=True, **ecfg)).run(reqs)
    assert [len(r.tokens) for r in on.results] == [32, 32]
    for rid in "ab":
        assert off.by_id[rid].tokens == on.by_id[rid].tokens


def test_prefix_marginal_match_treated_as_miss():
    """A match covering less than prefix_cache_min_ratio of the prompt is a
    miss: the request takes the batched prefill instead of a long
    one-token-per-tick catch-up tail."""
    cm, params = _serve_cm()
    rng = np.random.RandomState(26)
    head = rng.randint(0, cm.cfg.vocab_size, 8).astype(np.int32)
    long_tail = rng.randint(0, cm.cfg.vocab_size, 24).astype(np.int32)
    ecfg = dict(max_batch=1, max_seq_len=64, block_size=8,
                prompt_buckets=(8, 32, 64))
    # 'warm' indexes the 8-token head; 'probe' shares only that one block
    # of its 32-token prompt (25% < the 0.5 default) -> cold prefill
    eng = Engine(cm, params, EngineConfig(prefix_cache=True, **ecfg))
    rep = eng.run([Request("warm", head, max_new_tokens=2),
                   Request("probe", np.concatenate([head, long_tail]),
                           max_new_tokens=2)])
    assert rep.metrics["prefix_hits"] == 0
    assert rep.metrics["catchup_tokens"] == 0
    # the same probe with the threshold off takes the marginal hit
    eng2 = Engine(cm, params, EngineConfig(prefix_cache=True,
                                           prefix_cache_min_ratio=0.0,
                                           **ecfg))
    rep2 = eng2.run([Request("warm", head, max_new_tokens=2),
                     Request("probe", np.concatenate([head, long_tail]),
                             max_new_tokens=2)])
    assert rep2.metrics["prefix_hits"] == 1
    assert rep2.metrics["catchup_tokens"] == 24
    assert rep.by_id["probe"].tokens == rep2.by_id["probe"].tokens


@pytest.mark.slow
def test_shared_prefix_replay_acceptance():
    """The acceptance loop: 16 requests with a common system prompt served
    through the prefix cache compute < 50% of the prefill tokens of the
    no-cache run, with byte-identical per-request logits."""
    cm, params = _serve_cm()
    reqs = lambda: shared_prefix_requests(16, cm.cfg.vocab_size,
                                          prefix_len=24, tail_len=8,
                                          max_new_tokens=4, seed=7)
    off, on = _run_pair(reqs(), capture=True, max_batch=4)
    _assert_results_identical(off, on)
    m = on.metrics
    assert m["prefill_tokens_computed"] < \
        0.5 * off.metrics["prefill_tokens_computed"]
    assert m["prefix_hits"] >= 12
    d = Engine(cm, params, EngineConfig(max_batch=4, max_seq_len=64,
                                        block_size=8, prefix_cache=True))
    d.run(reqs())
    assert "prefix-cache:" in d.describe() and "hit_rate=" in d.describe()


# ---------------------------------------------------------------------------
# engine-level autotune
# ---------------------------------------------------------------------------

def test_autotune_deterministic_on_host():
    """Same profile, fresh caches: the compile-validated search must pick
    the same flow every time (forced host devices, no wall-clock in the
    ranking)."""
    from repro.serving.autotune import ServingProfile, autotune_decode
    prof = ServingProfile(name="det", batch_buckets=(2,), max_seq_len=32,
                          block_sizes=(8,))
    kw = dict(profile=prof, smoke=True, validate="compile",
              tune_blocks=False, use_cache=False)
    a = autotune_decode("llama3.2-1b", **kw)
    b = autotune_decode("llama3.2-1b", **kw)
    assert a.flow_for(2) == b.flow_for(2)
    assert a.per_bucket[2].best.knob_str() == b.per_bucket[2].best.knob_str()


def test_autotune_measure_returns_pinnable_flow():
    """validate="measure" ranks survivors by measured step time and the
    Engine pins the winner (the acceptance path)."""
    from repro.serving.autotune import ServingProfile, autotune_decode
    prof = ServingProfile(name="pin", batch_buckets=(2,), max_seq_len=32,
                          block_sizes=(8, 16))
    at = autotune_decode("llama3.2-1b", profile=prof, smoke=True,
                         validate="measure", iters=1)
    er = at.per_bucket[2]
    assert er.validated and any("measured_step_s" in v for v in er.validated)
    assert at.block_size in (8, 16)
    eng = at.engine()
    assert eng.plan.flow == at.flow_for(2)
    rep = eng.run(synthetic_requests(3, at.cfg.vocab_size, prompt_len=6,
                                     max_new_tokens=2, seed=0))
    assert len(rep.results) == 3
    assert "serving-autotune[" in at.describe()


# ---------------------------------------------------------------------------
# multi-device serving (runs under XLA_FLAGS=--xla_force_host_platform_
# device_count=N; skipped on a single-device host)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >= 2 (forced) host devices")
def test_run_multidevice_scheduler():
    """Continuous batching with the decode cell compiled onto a dp mesh:
    the scheduler's bucketed ticks ride the sharded executable."""
    cm = rflow.compile("llama3.2-1b", SERVE_SHAPE,
                       FlowConfig(mode="folded", precision="fp32"),
                       mesh={"data": 2}, smoke=True)
    params = cm.init_params(jax.random.key(0))
    eng = Engine(cm, params,
                 EngineConfig(max_batch=2, max_seq_len=64, block_size=8,
                              batch_buckets=(2,)))
    reqs = synthetic_requests(5, cm.cfg.vocab_size, prompt_len=8,
                              max_new_tokens=3, seed=3)
    rep = eng.run(reqs)
    assert len(rep.results) == 5
    assert rep.metrics["refills"] >= 1
    assert all(r.n_generated == 3 for r in rep.results)
