"""PassManager subsystem tests: the uniform Pass protocol, per-pass stats and
timing instrumentation, pluggability, and build_plan equivalence."""
import pytest

from repro.configs import get_config, get_smoke
from repro.configs.base import FlowConfig, SHAPES, ShapeConfig
from repro.core.passmanager import Pass, PassManager, PlanContext
from repro.core.plan import build_plan

from conftest import SMOKE_SHAPE

SERVE = ShapeConfig("bench", "prefill", 64, 8)

EXPECTED_PASSES = ["graph", "fusion", "streaming", "folding", "sharding",
                   "tiling", "precision", "caching", "kernels"]


def test_default_pipeline_order():
    pm = PassManager.default_pipeline()
    assert [p.name for p in pm.passes] == EXPECTED_PASSES


def test_build_plan_is_thin_wrapper():
    """build_plan == default_pipeline().run for the same inputs."""
    cfg, flow = get_smoke("llama3.2-1b"), FlowConfig(mode="folded")
    p1 = build_plan(cfg, flow, SMOKE_SHAPE)
    p2 = PassManager.default_pipeline().run(cfg, flow, SMOKE_SHAPE)
    assert p1.describe(stats=True) == p2.describe(stats=True)
    assert [u.indices for u in p1.units] == [u.indices for u in p2.units]
    assert p1.tiles == p2.tiles


def test_every_pass_reports_stats_and_timing():
    plan = build_plan(get_smoke("llama3.2-1b"), FlowConfig(mode="folded"),
                      SMOKE_SHAPE)
    assert list(plan.pass_stats) == EXPECTED_PASSES
    for name, st in plan.pass_stats.items():
        if name == "sharding":        # no mesh on this cell: records a skip
            assert not st["applied"]
            continue
        assert st["applied"], name
        assert plan.pass_timings_ms[name] >= 0
    assert len(plan.trace) == len(EXPECTED_PASSES)


def test_sharding_pass_applies_with_mesh_split():
    plan = build_plan(
        get_smoke("llama3.2-1b"),
        FlowConfig(mode="folded", mesh_split=(("data", 2), ("model", 2))),
        SMOKE_SHAPE)
    st = plan.pass_stats["sharding"]
    assert st["applied"] and st["dp"] == 2 and st["tp"] == 2
    assert plan.sharding is not None
    assert plan.sharding.mesh.size == 4
    assert plan.pass_timings_ms["sharding"] >= 0


def test_skipped_pass_recorded():
    plan = build_plan(get_smoke("llama3.2-1b"),
                      FlowConfig(fuse_epilogues=False, mode="folded"),
                      SMOKE_SHAPE)
    assert plan.pass_stats["fusion"] == {"applied": False}
    assert "fusion" not in plan.pass_timings_ms
    assert "skip fusion" in plan.trace


def test_fusion_stats_count_rewrites():
    plan = build_plan(get_smoke("llama3.2-1b"), FlowConfig(mode="folded"),
                      SMOKE_SHAPE)
    st = plan.pass_stats["fusion"]
    assert st["ops_removed"] == st["ops_before"] - st["ops_after"] > 0
    assert st["epilogues"]["glu"] > 0          # swiglu FFNs fused


def test_replaced_pass_plugs_in():
    """A custom pass swapped into the pipeline drives the plan artifact."""
    class FixedTiles(Pass):
        name = "tiling"
        paper = "test"

        def run(self, ctx: PlanContext) -> None:
            ctx.artifacts["tiles"] = {"matmul": (8, 8, 8)}
            ctx.stats[self.name] = {"applied": True, "fixed": True}

    pm = PassManager.default_pipeline().replaced(FixedTiles())
    plan = pm.run(get_smoke("llama3.2-1b"), FlowConfig(mode="folded"),
                  SMOKE_SHAPE)
    assert plan.tiles == {"matmul": (8, 8, 8)}
    assert plan.pass_stats["tiling"] == {"applied": True, "fixed": True}


def test_duplicate_pass_names_rejected():
    pm = PassManager.default_pipeline()
    with pytest.raises(ValueError):
        PassManager(pm.passes + [pm.passes[-1]])


def test_incomplete_pipeline_rejected():
    pm = PassManager.default_pipeline()
    with pytest.raises(ValueError, match="tiles"):
        PassManager([p for p in pm.passes if p.name != "tiling"]).run(
            get_smoke("llama3.2-1b"), FlowConfig(mode="folded"), SMOKE_SHAPE)


def test_tunable_space_train_vs_serve():
    pm = PassManager.default_pipeline()
    cfg, flow = get_config("llama3.2-1b"), FlowConfig()
    train = pm.tunable_space(cfg, flow, SHAPES["train_4k"])
    serve = pm.tunable_space(cfg, flow, SERVE)
    for key in ("fuse_epilogues", "fold_layers", "tile_select",
                "cached_writes", "precision", "vmem_budget_bytes"):
        assert key in train and key in serve
    for key in ("microbatches", "remat", "scan_unroll", "ce_chunk"):
        assert key in train and key not in serve
    # a currently-off pass still exposes its knob (the explorer can enable it)
    off = pm.tunable_space(cfg, FlowConfig(fuse_epilogues=False),
                           SHAPES["train_4k"])
    assert off["fuse_epilogues"] == (True, False)


def test_graph_pass_isolates_caller_graph():
    """A caller-provided graph must not be mutated by fusion (deepcopy)."""
    from repro.models.lm import build_graph
    cfg = get_smoke("llama3.2-1b")
    g = build_graph(cfg)
    ops_before = sum(len(b.ops) for b in g.blocks)
    build_plan(cfg, FlowConfig(mode="folded"), SMOKE_SHAPE, graph=g)
    assert sum(len(b.ops) for b in g.blocks) == ops_before
