"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.passes import tiling
from repro.kernels import ops, ref
from conftest import relerr

SET = dict(max_examples=20, deadline=None)


# ---------------------------------------------------------------------------
# LU/LT invariants: the three factor rules of the paper (§IV-J)
# ---------------------------------------------------------------------------

@given(m=st.integers(8, 8192), k=st.integers(128, 16384),
       n=st.integers(128, 16384), vmem=st.sampled_from(
           [8 * 2 ** 20, 24 * 2 ** 20, 64 * 2 ** 20]))
@settings(**SET)
def test_matmul_tile_rules(m, k, n, vmem):
    bm, bk, bn = tiling.select_matmul_tile(m, k, n, vmem=vmem)
    # rule 2: even division — OR a 128-aligned tile (the kernel pads the
    # problem to the tile grid; alignment beats divisibility on the MXU)
    assert m % bm == 0 or bm % 128 == 0
    assert k % bk == 0 or bk % 128 == 0
    assert n % bn == 0 or bn % 128 == 0
    # rule 3: fits the budget (unless the minimum tile itself exceeds it)
    ws = (bm * bk + bk * bn) * 2 + bm * bn * 6
    min_ws = (128 * 128 * 2) * 2 + 128 * 128 * 6
    assert ws <= max(vmem, min_ws * 16)
    # rule 1 (alignment): MXU-aligned when the dim allows it
    if n % 128 == 0:
        assert bn % 128 == 0


@given(sq=st.integers(1, 512).map(lambda x: x * 128),
       dh=st.sampled_from([64, 128, 256]))
@settings(**SET)
def test_attention_tile_rules(sq, dh):
    bq, bk = tiling.select_attention_tile(sq, sq, dh, vmem=24 * 2 ** 20)
    assert sq % bq == 0 and sq % bk == 0
    ws = (bq + 2 * bk) * dh * 2 + bq * bk * 4 + bq * dh * 4
    assert ws <= 24 * 2 ** 20 or (bq == 128 and bk == 128)


# ---------------------------------------------------------------------------
# Recurrence kernels: chunked == sequential oracle
# ---------------------------------------------------------------------------

@given(s=st.integers(2, 33), h=st.sampled_from([1, 2]),
       dk=st.sampled_from([4, 8]), chunk=st.sampled_from([2, 4, 16]),
       seed=st.integers(0, 10), parallel=st.booleans())
@settings(**SET)
def test_wkv_chunked_matches_sequential(s, h, dk, chunk, seed, parallel):
    from repro.core.ops_impl import _wkv_chunked
    rng = np.random.RandomState(seed)
    B, dv = 2, dk
    r = jnp.asarray(rng.randn(B, s, h, dk), jnp.float32) * 0.5
    k = jnp.asarray(rng.randn(B, s, h, dk), jnp.float32) * 0.5
    v = jnp.asarray(rng.randn(B, s, h, dv), jnp.float32) * 0.5
    logw = -jnp.exp(jnp.asarray(rng.randn(B, s, h, dk), jnp.float32))
    u = jnp.asarray(rng.randn(h, dk), jnp.float32)
    y, fin = _wkv_chunked(r, k, v, logw, u, chunk, parallel=parallel)
    # sequential oracle
    S0 = jnp.zeros((B, h, dk, dv))
    ys = []
    for t in range(s):
        rt, kt, vt, wt = r[:, t], k[:, t], v[:, t], jnp.exp(logw[:, t])
        bonus = jnp.einsum("bhk,bhk,bhv->bhv", rt, u * kt, vt)
        ys.append(jnp.einsum("bhk,bhkv->bhv", rt, S0) + bonus)
        S0 = wt[..., None] * S0 + kt[..., None] * vt[..., None, :]
    yref = jnp.stack(ys, 1)
    assert relerr(y, yref) < 1e-4
    assert relerr(fin, S0) < 1e-4


@given(s=st.integers(1, 24), w=st.sampled_from([4, 8]),
       seed=st.integers(0, 5))
@settings(**SET)
def test_rglru_scan_matches_loop(s, w, seed):
    """associative_scan recurrence == explicit python loop."""
    rng = np.random.RandomState(seed)
    a = jnp.asarray(rng.rand(2, s, w) * 0.9, jnp.float32)
    b = jnp.asarray(rng.randn(2, s, w), jnp.float32)
    def comb(u, v):
        (a1, b1), (a2, b2) = u, v
        return a2 * a1, a2 * b1 + b2
    _, h = jax.lax.associative_scan(comb, (a, b), axis=1)
    ref_h = []
    cur = jnp.zeros((2, w))
    for t in range(s):
        cur = a[:, t] * cur + b[:, t]
        ref_h.append(cur)
    assert relerr(h, jnp.stack(ref_h, 1)) < 1e-5


# ---------------------------------------------------------------------------
# MoE dispatch invariants
# ---------------------------------------------------------------------------

@given(s=st.integers(2, 40), e=st.sampled_from([4, 8]),
       k=st.sampled_from([1, 2]), seed=st.integers(0, 20))
@settings(**SET)
def test_moe_positions_unique_and_causal(s, e, k, seed):
    """Per-(sequence, expert) slot positions are unique, dense from 0, and
    appending a token never changes earlier positions (causality)."""
    from repro.core import ops_impl
    rng = np.random.RandomState(seed)
    fe = jnp.asarray(rng.randint(0, e, (1, s * k)), jnp.int32)

    def positions(row):
        order = jnp.argsort(row, stable=True)
        se = row[order]
        starts = jnp.searchsorted(se, jnp.arange(e))
        ps = jnp.arange(row.shape[0]) - starts[se]
        return jnp.zeros_like(row).at[order].set(ps.astype(jnp.int32))

    pos = positions(fe[0])
    for ex in range(e):
        mask = np.asarray(fe[0]) == ex
        got = sorted(np.asarray(pos)[mask].tolist())
        assert got == list(range(mask.sum()))
        # token order preserved (causal cumsum semantics)
        assert (np.diff(np.asarray(pos)[mask]) > 0).all()
    # causality: prefix positions unchanged
    if s > 3:
        pos_prefix = positions(fe[0, : (s - 1) * k])
        np.testing.assert_array_equal(np.asarray(pos)[: (s - 1) * k],
                                      np.asarray(pos_prefix))


# ---------------------------------------------------------------------------
# Attention kernel: masking invariants under random windows/offsets
# ---------------------------------------------------------------------------

@given(sq=st.sampled_from([32, 64]), win=st.sampled_from([None, 8, 16]),
       off=st.sampled_from([0, 32]), seed=st.integers(0, 10))
@settings(max_examples=12, deadline=None)
def test_flash_matches_ref_random(sq, win, off, seed):
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(1, sq, 2, 16), jnp.float32)
    kv = jnp.asarray(rng.randn(1, sq + off, 1, 16), jnp.float32)
    y = ops.flash_attention(q, kv, kv, causal=True, window=win, q_offset=off,
                            tile=(16, 16), interpret=True)
    r = ref.flash_attention_ref(q, kv, kv, causal=True, window=win,
                                q_offset=off)
    assert relerr(y, r) < 1e-4


# ---------------------------------------------------------------------------
# Optimizer: compression error feedback is lossless in expectation
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 20))
@settings(**SET)
def test_int8_error_feedback_accumulates(seed):
    from repro.optim.adamw import AdamW
    rng = np.random.RandomState(seed)
    g = {"w": jnp.asarray(rng.randn(32, 32), jnp.float32)}
    opt = AdamW(compress="int8_ef")
    err = {"w": jnp.zeros((32, 32))}
    total_deq = jnp.zeros((32, 32))
    for _ in range(30):
        deq, err = opt.compress_grads(g, err)
        total_deq = total_deq + deq["w"]
    # sum of dequantized grads + residual error == sum of true grads
    assert relerr(total_deq + err["w"], 30.0 * g["w"]) < 1e-3
