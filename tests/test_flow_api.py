"""repro.flow public-API tests: the compile() facade, CompiledModel surface,
autotune caching, deprecation shims, and Engine/Trainer integration."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import flow as rflow
from repro.configs import get_smoke
from repro.configs.base import FlowConfig, ShapeConfig
from repro.optim.adamw import AdamW

from conftest import SMOKE_SHAPE, smoke_batch

DECODE = ShapeConfig("api", "decode", 24, 2)


def test_compile_accepts_names_and_configs():
    cm1 = rflow.compile("llama3.2-1b", SMOKE_SHAPE, smoke=True)
    cm2 = rflow.compile(get_smoke("llama3.2-1b"), SMOKE_SHAPE)
    assert cm1.plan.describe() == cm2.plan.describe()
    cm3 = rflow.compile("lenet5", "train_4k")       # str shape-cell name
    assert cm3.shape.name == "train_4k"
    with pytest.raises(KeyError):
        rflow.compile("llama3.2-1b", "no_such_shape", smoke=True)


def test_compiled_model_owns_the_flow_surface():
    cm = rflow.compile("llama3.2-1b", SMOKE_SHAPE, smoke=True)
    assert cm.plan.units and cm.plan.tiles and cm.plan.kernels
    assert "kernels: backend=auto" in cm.describe()
    params = cm.init_params(jax.random.key(0))
    batch = smoke_batch(cm.cfg)
    logits, state, _ = cm.prefill(params, {"tokens": batch["tokens"]})
    assert logits.shape[0] == batch["tokens"].shape[0]
    # per-stage compile stats recorded on first invocation
    assert "prefill" in cm.stats["stages"]
    assert cm.stats["stages"]["prefill"]["first_call_s"] >= 0
    assert "stages: " in cm.describe(stats=True)


def test_backend_kwarg_overrides_flow():
    cm = rflow.compile("llama3.2-1b", SMOKE_SHAPE, smoke=True,
                       backend="reference")
    assert cm.flow.kernel_backend == "reference"
    assert all(b == "ref" for b in cm.plan.kernels.values())
    # default backend="auto" keeps a flow-specified backend
    cm2 = rflow.compile("llama3.2-1b", SMOKE_SHAPE, smoke=True,
                        flow=FlowConfig(mode="folded",
                                        kernel_backend="pallas_interpret"))
    assert cm2.flow.kernel_backend == "pallas_interpret"


def test_train_step_and_generate_roundtrip():
    cm = rflow.compile("llama3.2-1b", SMOKE_SHAPE, smoke=True)
    params = cm.init_params(jax.random.key(0))
    opt = AdamW(lr=1e-3)
    step = cm.train_step(opt)
    batch = smoke_batch(cm.cfg)
    params, _, metrics = step(params, opt.init(params), batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    toks, state = cm.generate(params, {"tokens": batch["tokens"][:, :8]},
                              steps=4)
    assert toks.shape == (2, 4)
    toks2 = cm.generate_fori(params, {"tokens": batch["tokens"][:, :8]},
                             steps=4)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks2))


def test_engine_is_a_thin_consumer():
    from repro.serving.engine import Engine, EngineConfig
    cm = rflow.compile("llama3.2-1b", DECODE,
                       FlowConfig(mode="folded", precision="fp32"),
                       smoke=True)
    params = cm.init_params(jax.random.key(0))
    eng = Engine(cm, params, EngineConfig(temperature=0.0))
    assert eng.compiled is cm and eng.plan is cm.plan
    batch = smoke_batch(cm.cfg, B=2, S=8, with_labels=False)
    t1, _ = eng.generate(batch, steps=4)
    t2, _ = cm.generate(params, batch, steps=4)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    # legacy plan-based construction still works (shim path)
    eng2 = Engine(cm.plan, params)
    t3, _ = eng2.generate(batch, steps=4)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t3))


def test_trainer_accepts_compiled_model():
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.train.trainer import Trainer, TrainerConfig
    cm = rflow.compile("llama3.2-1b", SMOKE_SHAPE, smoke=True)
    data = SyntheticLM(DataConfig(vocab_size=cm.cfg.vocab_size, seq_len=16,
                                  global_batch=4))
    tr = Trainer(cm, AdamW(lr=3e-3, warmup_steps=2, total_steps=8),
                 TrainerConfig(steps=8, log_every=2))
    _, _, hist = tr.fit(data, jax.random.key(0))
    assert len(hist) >= 2


def test_autotune_keeps_pinned_backend():
    """An explicitly pinned backend is a constraint the explorer must not
    override: the kernel_backend dimension collapses to the pinned value."""
    from repro.core import dse
    cfg = get_smoke("llama3.2-1b")
    space = dse.tunable_space(
        cfg, FlowConfig(mode="folded", kernel_backend="reference"),
        SMOKE_SHAPE)
    assert space["kernel_backend"] == ("reference",)
    dse.clear_explore_cache()
    cm = rflow.compile(cfg, SMOKE_SHAPE, backend="reference", autotune=True)
    assert cm.flow.kernel_backend == "reference"
    assert all(b == "ref" for b in cm.plan.kernels.values())


def test_autotune_uses_the_explorer_cache():
    from repro.core import dse
    dse.clear_explore_cache()
    cm1 = rflow.compile("llama3.2-1b", SMOKE_SHAPE, smoke=True, autotune=True)
    assert cm1.explore_result is not None
    assert "dse: best=" in cm1.describe()
    cm2 = rflow.compile("llama3.2-1b", SMOKE_SHAPE, smoke=True, autotune=True)
    assert cm2.explore_result is cm1.explore_result   # cache hit
    assert dse.explore_cache_stats()["hits"] == 1


def test_deprecation_shims_warn_once():
    import repro.core.plan as plan_mod
    from repro.core.plan import build_plan
    cfg = get_smoke("llama3.2-1b")
    plan_mod._DEPRECATION_WARNED = False
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        build_plan(cfg, FlowConfig(mode="folded"), SMOKE_SHAPE)
        dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
        assert len(dep) == 1
        assert "repro.flow.compile" in str(dep[0].message)
        # further legacy calls in the same process: silent
        plan = build_plan(cfg, FlowConfig(mode="folded"), SMOKE_SHAPE)
        from repro.core import lowering
        lowering.make_apply(plan)
        dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
        assert len(dep) == 1


def test_facade_is_the_only_path_in_launch_serving_examples():
    """Acceptance guard: no direct build_plan/make_apply wiring outside
    repro/flow, the core, and the shims."""
    import os
    import re
    root = os.path.join(os.path.dirname(__file__), "..")
    offenders = []
    targets = []
    for sub in ("src/repro/launch", "src/repro/serving", "examples"):
        d = os.path.join(root, sub)
        targets += [os.path.join(d, f) for f in os.listdir(d)
                    if f.endswith(".py")]
    pat = re.compile(r"\bbuild_plan\s*\(|\blowering\.make_apply\s*\(|"
                     r"\bmake_apply\s*\(")
    for path in targets:
        with open(path) as f:
            src = f.read()
        if pat.search(src):
            offenders.append(os.path.relpath(path, root))
    assert not offenders, offenders
