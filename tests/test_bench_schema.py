"""Freeze the RunReport.metrics / BENCH_serving.json key schemas.

Benchmark consumers (CI artifact diffs, the README tables, downstream
plotting) key on these names; a silent rename between PRs corrupts every
comparison.  Any intentional schema change must update this test in the
same PR — that is the point.
"""
import os
import sys

import jax
import pytest

from repro import flow as rflow
from repro.configs.base import FlowConfig, ShapeConfig
from repro.serving.engine import Engine, EngineConfig
from repro.serving.scheduler import synthetic_requests

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))


# the flat RunReport.metrics keys, exactly as every consumer sees them
RUN_REPORT_KEYS = (
    "n_requests", "generated_tokens", "wall_s", "tokens_per_s",
    "p50_latency_s", "p95_latency_s", "p50_ttft_s", "p95_ttft_s",
    "decode_ticks", "prefill_batches",
    "chunk_size", "chunked_prefill", "fori_seg", "fori_segments",
    "host_syncs", "host_syncs_per_token",
    "admissions", "evictions", "refills",
    "pool_blocks", "block_size", "peak_used_blocks", "peak_live_tokens",
    "pool_bytes",
    "prefix_cache", "prefix_hits", "prefix_misses", "prefix_cached_tokens",
    "prefix_cache_evictions", "cow_forks", "prompt_tokens_total",
    "prefill_tokens_computed", "catchup_tokens", "prefix_hit_rate",
    "speculation", "spec_drafter", "spec_draft_k", "spec_ticks",
    "spec_tokens_drafted", "spec_tokens_accepted", "spec_acceptance_rate",
    "spec_rollback_tokens", "spec_fork_undos",
)

# the per-row metric columns of every BENCH_serving.json table
BENCH_ROW_METRIC_KEYS = (
    "tokens_per_s", "p50_latency_s", "p95_latency_s",
    "p50_ttft_s", "p95_ttft_s", "evictions", "refills",
    "prefix_hit_rate", "prefill_tokens_computed", "catchup_tokens",
    "host_syncs", "host_syncs_per_token", "fori_segments")


@pytest.fixture(scope="module")
def report():
    cm = rflow.compile("llama3.2-1b", ShapeConfig("serve", "decode", 64, 4),
                       FlowConfig(mode="folded", precision="fp32"),
                       smoke=True)
    params = cm.init_params(jax.random.key(0))
    eng = Engine(cm, params, EngineConfig(max_batch=4, max_seq_len=64))
    reqs = synthetic_requests(4, cm.cfg.vocab_size, prompt_len=8,
                              max_new_tokens=4)
    return eng.run(reqs)


def test_run_report_metric_keys_frozen(report):
    assert tuple(report.metrics.keys()) == RUN_REPORT_KEYS


def test_run_report_metric_types(report):
    m = report.metrics
    ints = ("n_requests", "generated_tokens", "decode_ticks",
            "prefill_batches", "host_syncs", "admissions", "evictions",
            "refills", "pool_blocks", "block_size", "peak_used_blocks",
            "peak_live_tokens", "prefix_hits", "spec_tokens_drafted")
    for k in ints:
        assert isinstance(m[k], int), (k, type(m[k]))
    floats = ("wall_s", "tokens_per_s", "p50_latency_s", "p95_latency_s",
              "host_syncs_per_token", "prefix_hit_rate",
              "spec_acceptance_rate")
    for k in floats:
        assert isinstance(m[k], float), (k, type(m[k]))
    assert isinstance(m["prefix_cache"], bool)
    assert isinstance(m["chunked_prefill"], bool)
    assert isinstance(m["speculation"], bool)


def test_bench_serving_row_schema_frozen(report):
    import paper_tables
    assert tuple(paper_tables._SERVING_METRIC_KEYS) == BENCH_ROW_METRIC_KEYS
    row = paper_tables._serving_row("x", 4, report.metrics)
    assert tuple(row.keys()) == ("name", "concurrency") + \
        BENCH_ROW_METRIC_KEYS


def test_bench_rows_derivable_from_registry_snapshot(report):
    # BENCH_serving.json rows come from report.metrics, which is assembled
    # from the registry snapshot — every row key must resolve through it
    assert report.registry is not None
    for k in BENCH_ROW_METRIC_KEYS:
        assert k in report.metrics, k
