"""End-to-end behaviour tests for the paper's system (the compilation flow)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, CNNS, get_smoke, cells, SHAPES
from repro.configs.base import FlowConfig, ShapeConfig
from repro.core import lowering
from repro.core.plan import build_plan
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim.adamw import AdamW
from repro.serving.engine import Engine, EngineConfig
from repro.train.trainer import Trainer, TrainerConfig

from conftest import SMOKE_SHAPE


def test_flow_plans_for_every_arch_and_shape_kind():
    """The compilation flow must produce a plan for every assigned arch in
    every shape kind (train/prefill/decode) without error."""
    for arch in ARCHS + CNNS:
        cfg = get_smoke(arch)
        for sname in ("train_4k", "prefill_32k", "decode_32k"):
            plan = build_plan(cfg, FlowConfig(), SHAPES[sname])
            assert plan.units and plan.tiles


def test_cell_table_counts():
    """The assignment's 40 cells: 33 runnable + 7 documented skips."""
    all_cells = cells(include_skipped=True)
    assert len(all_cells) == 40
    runnable = [c for c in all_cells if c[2]]
    assert len(runnable) == 33
    skipped = [(a, s) for a, s, r in all_cells if not r]
    assert all(s == "long_500k" for _, s in skipped)


def test_base_flow_is_the_papers_base():
    base = FlowConfig().base()
    assert not base.fuse_epilogues and not base.fold_layers
    assert not base.cached_writes and not base.tile_select
    assert base.precision == "fp32"


def test_fusion_reduces_op_count_everywhere():
    for arch in ARCHS:
        cfg = get_smoke(arch)
        p_base = build_plan(cfg, FlowConfig(fuse_epilogues=False),
                            SMOKE_SHAPE)
        p_opt = build_plan(cfg, FlowConfig(fuse_epilogues=True), SMOKE_SHAPE)
        n0 = sum(len(b.ops) for b in p_base.graph.blocks)
        n1 = sum(len(b.ops) for b in p_opt.graph.blocks)
        if arch == "rwkv6-7b":
            # rwkv layers are composite time/channel-mix ops: nothing for the
            # peephole fuser to rewrite (noted in DESIGN.md)
            assert n1 <= n0
        else:
            assert n1 < n0, arch


def test_train_then_serve_roundtrip(tmp_path):
    """Train a small LM, checkpoint, restore into a serving engine, and check
    the generations match the trained params' argmax (system-level wiring)."""
    from repro.train import checkpoint as ckpt_lib
    cfg = get_smoke("llama3.2-1b")
    plan = build_plan(cfg, FlowConfig(mode="folded"), SMOKE_SHAPE)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                  global_batch=8))
    tr = Trainer(plan, AdamW(lr=3e-3, warmup_steps=5, total_steps=40),
                 TrainerConfig(steps=40, ckpt_dir=str(tmp_path),
                               ckpt_every=20, log_every=10))
    params, opt_state, hist = tr.fit(data, jax.random.key(0))
    assert hist[-1][1] < hist[0][1]

    step = ckpt_lib.latest_step(str(tmp_path))
    restored = ckpt_lib.restore(str(tmp_path), step,
                                {"params": params, "opt": opt_state})
    eng = Engine(plan, restored["params"], EngineConfig(temperature=0.0))
    prompt = {"tokens": jnp.asarray(data.get(99)["tokens"][:2, :8])}
    toks, _ = eng.generate(prompt, steps=4)
    assert toks.shape == (2, 4)
    # the trained model should have learned the deterministic transition
    # (next = prev*31+7 mod V) for at least some steps
    assert int(jnp.max(toks)) < cfg.vocab_size


def test_serving_batch_order_invariance():
    """Per-sequence MoE dispatch: a sequence's output must not depend on the
    other requests in the batch (a serving invariant)."""
    cfg = get_smoke("mixtral-8x7b")
    plan = build_plan(cfg, FlowConfig(mode="folded", precision="fp32"),
                      SMOKE_SHAPE)
    params = lowering.init_params(plan, jax.random.key(0))
    apply = lowering.make_apply(plan)
    rng = np.random.RandomState(0)
    a = jnp.asarray(rng.randint(0, cfg.vocab_size, (1, 12)), jnp.int32)
    b = jnp.asarray(rng.randint(0, cfg.vocab_size, (1, 12)), jnp.int32)
    la, _, _ = apply(params, {"tokens": jnp.concatenate([a, b])},
                     mode="prefill")
    lb, _, _ = apply(params, {"tokens": jnp.concatenate([b, a])},
                     mode="prefill")
    np.testing.assert_allclose(np.asarray(la[0]), np.asarray(lb[1]),
                               rtol=1e-5, atol=1e-5)
