"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from conftest import relerr

R = np.random.RandomState(7)


def _tol(dt):
    return 2e-2 if dt == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("shape", [(16, 64, 32), (100, 130, 70),
                                   (256, 256, 256), (8, 512, 128)])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_matmul_bias_act(shape, dt):
    M, K, N = shape
    x = jnp.asarray(R.randn(M, K), dt)
    w = jnp.asarray(R.randn(K, N), dt)
    b = jnp.asarray(R.randn(N), dt)
    y = ops.matmul_fused(x, w, bias=b, act="gelu", tile=(32, 64, 32),
                         interpret=True)
    r = ref.matmul_fused_ref(x, w, bias=b, act="gelu")
    assert relerr(y, r) < _tol(dt)


@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_matmul_glu(dt):
    x = jnp.asarray(R.randn(64, 96), dt)
    w = jnp.asarray(R.randn(96, 48), dt)
    w2 = jnp.asarray(R.randn(96, 48), dt)
    y = ops.matmul_fused(x, w, w2=w2, act="silu", tile=(32, 32, 32),
                         interpret=True)
    r = ref.matmul_fused_ref(x, w, w2=w2, act="silu")
    assert relerr(y, r) < _tol(dt)


def test_matmul_base_no_cached_writes():
    """CW off: accumulate through the output block — still correct (fp32)."""
    x = jnp.asarray(R.randn(64, 256), jnp.float32)
    w = jnp.asarray(R.randn(256, 64), jnp.float32)
    y = ops.matmul_fused(x, w, tile=(32, 64, 32), vmem_accum=False,
                         interpret=True)
    assert relerr(y, ref.matmul_fused_ref(x, w)) < 1e-5


def test_matmul_leading_dims():
    x = jnp.asarray(R.randn(2, 10, 48), jnp.float32)
    w = jnp.asarray(R.randn(48, 32), jnp.float32)
    y = ops.matmul_fused(x, w, tile=(8, 16, 32), interpret=True)
    assert y.shape == (2, 10, 32)
    assert relerr(y, ref.matmul_fused_ref(x, w)) < 1e-5


@pytest.mark.parametrize("spec", [
    (2, 64, 64, 4, 4, 32, True, None, 0),
    (1, 48, 48, 4, 2, 16, True, 16, 0),
    (2, 32, 96, 6, 2, 32, True, None, 64),     # CP shard: q offset
    (1, 100, 100, 2, 1, 64, False, None, 0),   # bidirectional, ragged len
    (2, 128, 128, 8, 8, 64, True, 32, 0),
])
def test_flash_attention(spec):
    B, Sq, Skv, H, KV, D, causal, win, off = spec
    q = jnp.asarray(R.randn(B, Sq, H, D), jnp.float32)
    k = jnp.asarray(R.randn(B, Skv, KV, D), jnp.float32)
    v = jnp.asarray(R.randn(B, Skv, KV, D), jnp.float32)
    y = ops.flash_attention(q, k, v, causal=causal, window=win, q_offset=off,
                            tile=(32, 32), interpret=True)
    r = ref.flash_attention_ref(q, k, v, causal=causal, window=win,
                                q_offset=off)
    assert relerr(y, r) < 1e-5


@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dt):
    q = jnp.asarray(R.randn(2, 64, 4, 32), dt)
    k = jnp.asarray(R.randn(2, 64, 2, 32), dt)
    v = jnp.asarray(R.randn(2, 64, 2, 32), dt)
    y = ops.flash_attention(q, k, v, tile=(32, 32), interpret=True)
    assert relerr(y, ref.flash_attention_ref(q, k, v)) < _tol(dt)


@pytest.mark.parametrize("spec", [(2, 64, 4, 2, 32, None),
                                  (1, 96, 8, 1, 64, 32),
                                  (3, 40, 4, 4, 16, None)])
def test_decode_attention_rolling(spec):
    B, C, H, KV, D, win = spec
    fill = C // 2
    kc = jnp.asarray(R.randn(B, C, KV, D), jnp.float32)
    vc = jnp.asarray(R.randn(B, C, KV, D), jnp.float32)
    pos = jnp.where(jnp.arange(C)[None] < fill, jnp.arange(C)[None], -1)
    pos = jnp.broadcast_to(pos, (B, C)).astype(jnp.int32)
    q = jnp.asarray(R.randn(B, 1, H, D), jnp.float32)
    qpos = jnp.full((B, 1), fill, jnp.int32)
    y = ops.decode_attention(q, kc, vc, pos, qpos, window=win, tile=32,
                             interpret=True)
    r = ref.decode_attention_ref(q, kc, vc, pos, qpos, window=win)
    assert relerr(y, r) < 1e-5


@pytest.mark.parametrize("spec", [(2, 4, 2, 32, 4, 5, None),
                                  (3, 8, 4, 16, 8, 3, 12),
                                  (1, 4, 1, 64, 16, 2, None)])
def test_paged_decode_attention(spec):
    """The serving subsystem's block-table gather kernel (scalar-prefetch
    index_map) vs the registered ref fallback, heterogeneous row lengths."""
    B, H, KV, D, bs, nblk, win = spec
    NB = 1 + B * nblk
    q = jnp.asarray(R.randn(B, 1, H, D), jnp.float32)
    kp = jnp.asarray(R.randn(NB, bs, KV, D), jnp.float32)
    vp = jnp.asarray(R.randn(NB, bs, KV, D), jnp.float32)
    bt = jnp.asarray(1 + R.permutation(B * nblk).reshape(B, nblk), jnp.int32)
    lens = jnp.asarray([(7 * (b + 1)) % (nblk * bs) for b in range(B)],
                       jnp.int32)
    y = ops.paged_decode_attention(q, kp, vp, bt, lens, window=win,
                                   interpret=True)
    r = ref.paged_decode_attention_ref(q, kp, vp, bt, lens, window=win,
                                       compute_dtype=jnp.float32)
    assert relerr(y, r) < 1e-5


@pytest.mark.parametrize("lead", [None, 3])
def test_copy_block_matches_ref(lead):
    """The prefix-cache COW fork: pallas (scalar-prefetch index_map, pool
    aliased in place) vs the ref fallback, flat and folded pool layouts —
    only the destination block changes, byte-for-byte."""
    NB, bs, KV, D = 6, 4, 2, 16
    shape = (NB, bs, KV, D) if lead is None else (lead, NB, bs, KV, D)
    pool = jnp.asarray(R.randn(*shape), jnp.float32)
    src, dst = 2, 5
    y = ops.copy_block(pool, src, dst, interpret=True)
    r = ref.copy_block_ref(pool, src, dst)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(r))
    got = np.asarray(y)
    want = np.asarray(pool).copy()
    want[..., dst, :, :, :] = want[..., src, :, :, :]
    np.testing.assert_array_equal(got, want)
    # dynamic (traced) indices under jit: the ledger calls it both ways
    yj = jax.jit(lambda p, s, d: ref.copy_block_ref(p, s, d))(
        pool, jnp.int32(src), jnp.int32(dst))
    np.testing.assert_array_equal(np.asarray(yj), want)


@pytest.mark.parametrize("spec", [(2, 16, 64), (1, 33, 130), (3, 8, 256)])
def test_lru_scan(spec):
    from repro.kernels.lru_scan import lru_scan, lru_scan_ref
    B, S, W = spec
    a = jnp.asarray(R.rand(B, S, W) * 0.9, jnp.float32)
    b = jnp.asarray(R.randn(B, S, W), jnp.float32)
    y = lru_scan(a, b, block_w=128, interpret=True)
    assert relerr(y, lru_scan_ref(a, b)) < 1e-5


@pytest.mark.parametrize("tile", [(4, 8), (3, 128), (16, 4), (5, 8)])
@pytest.mark.parametrize("stride,pad", [(1, "SAME"), (2, "SAME"),
                                        (2, "VALID")])
def test_conv2d_tile_tuple_regression(tile, stride, pad):
    """Regression for the dropped tile component: the wrapper used to keep
    only tile[1] (channel block) and discard tile[0] (row block).  Both
    components must now reach the kernel and stay correct for any pair,
    including row blocks that don't divide H_out (divisor fallback)."""
    N, H, W, CI, CO = 2, 12, 12, 6, 16
    x = jnp.asarray(R.randn(N, H, W, CI), jnp.float32)
    w = jnp.asarray(R.randn(3, 3, CI, CO), jnp.float32)
    y = ops.conv2d_fused(x, w, stride=stride, padding=pad, act="relu",
                         tile=tile, interpret=True)
    r = ref.conv2d_fused_ref(x, w, stride=stride, padding=pad, act="relu")
    assert relerr(y, r) < 1e-5


def test_conv2d_tile_tuple_forwards_both_components(monkeypatch):
    """The ops-layer wrapper must consume the full (block_h, block_c) tuple
    the tiling pass selected, not just the channel half."""
    from repro.kernels import conv2d as _cv
    captured = {}
    orig = _cv.conv2d_fused

    def spy(x, w, **kw):
        captured.update(kw)
        return orig(x, w, **kw)

    monkeypatch.setattr(_cv, "conv2d_fused", spy)
    x = jnp.asarray(R.randn(1, 8, 8, 4), jnp.float32)
    w = jnp.asarray(R.randn(3, 3, 4, 8), jnp.float32)
    ops.conv2d_fused(x, w, tile=(4, 8), interpret=True)
    assert captured["block_h"] == 4 and captured["block_c"] == 8
    ops.conv2d_fused(x, w, tile=64, interpret=True)     # bare int: block_c
    assert captured["block_h"] is None and captured["block_c"] == 64


@pytest.mark.parametrize("spec", [
    (2, 16, 16, 3, 8, 3, 1, "SAME", True),
    (1, 17, 17, 4, 16, 5, 2, "SAME", False),
    (2, 12, 12, 8, 8, 1, 1, "VALID", True),    # the MobileNet 1x1 workhorse
    (1, 16, 16, 3, 6, 3, 2, "VALID", False),
])
def test_conv2d(spec):
    N, H, W, CI, CO, k, s, pad, bn = spec
    x = jnp.asarray(R.randn(N, H, W, CI), jnp.float32)
    w = jnp.asarray(R.randn(k, k, CI, CO), jnp.float32)
    bnp = tuple(jnp.asarray(R.rand(CO) + 0.5, jnp.float32)
                for _ in range(4)) if bn else None
    y = ops.conv2d_fused(x, w, stride=s, padding=pad, bn=bnp, act="relu",
                         interpret=True)
    r = ref.conv2d_fused_ref(x, w, stride=s, padding=pad, bn=bnp, act="relu")
    assert relerr(y, r) < 1e-5
