"""HLO-parser unit tests: dot FLOPs, collective bytes, trip multiplication —
against a real compiled module so the format stays honest."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.hlo_analysis import analyze_hlo, parse_hlo, _shape_bytes


def test_shape_bytes():
    assert _shape_bytes("bf16[4,8]{1,0}") == 64
    assert _shape_bytes("f32[2,2]") == 16
    assert _shape_bytes("(f32[2,2], bf16[4]{0})") == 16 + 8
    assert _shape_bytes("pred[]") == 0 or _shape_bytes("pred[]") == 1


def test_scan_trip_multiplication():
    """A scanned matmul must be counted trip_count times."""
    L, M, K = 12, 32, 64

    def f(h, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, h, ws)
        return jnp.sum(c)

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((L, K, K), jnp.float32)).compile()
    res = analyze_hlo(c.as_text())
    expect = 2.0 * M * K * K * L
    assert res["flops_hlo"] == pytest.approx(expect, rel=0.05), res
    assert L in res["while_trips"]


def test_unrolled_matches_scanned():
    M, K, L = 16, 32, 4

    def scanned(h, ws):
        def body(c, w):
            return c @ w, None
        return jnp.sum(jax.lax.scan(body, h, ws)[0])

    def unrolled(h, ws):
        for i in range(L):
            h = h @ ws[i]
        return jnp.sum(h)

    a1 = analyze_hlo(jax.jit(scanned).lower(
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((L, K, K), jnp.float32)).compile().as_text())
    a2 = analyze_hlo(jax.jit(unrolled).lower(
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((L, K, K), jnp.float32)).compile().as_text())
    assert a1["flops_hlo"] == pytest.approx(a2["flops_hlo"], rel=0.05)
