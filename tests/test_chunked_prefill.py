"""Chunked prefill + host-free decode segments: exactness gates.

Every perf path added by the chunked/overlapped serving work must be
byte-identical (token-for-token, and logit-for-logit where captured) to the
plain one-token-per-tick host loop it replaces:

* chunked prompt catch-up ((B, k) cells through the paged pool),
* chunk ticks landing mid-COW-fork under the prefix cache,
* left-padded bucketed prefill on the positional flash kernel,
* ``fori_seg`` on-device decode segments (greedy AND sampled).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import flow as rflow
from repro.configs.base import FlowConfig
from repro.serving.engine import Engine, EngineConfig
from repro.serving.scheduler import Request, synthetic_requests

from test_serving import (SERVE_SHAPE, _assert_results_identical, _serve_cm)


def _run_vs_baseline(reqs, *, capture=True, base_kw=None, **fast_kw):
    """Serve the same batch through the plain host loop and through the
    perf-path config; returns (baseline, fast) reports."""
    cm, params = _serve_cm()
    kw = dict(max_batch=2, max_seq_len=64, block_size=8,
              capture_logits=capture)
    kw.update(base_kw or {})
    base = Engine(cm, params, EngineConfig(**kw)).run(reqs)
    fast = Engine(cm, params, EngineConfig(**kw, **fast_kw)).run(reqs)
    return base, fast


# ---------------------------------------------------------------------------
# chunked prompt catch-up
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 4, 8])
def test_chunked_prefill_matches_cold_byte_identical(k):
    """Cold prompts drained k tokens per tick through the (B, k) catch-up
    cell produce byte-identical tokens and sampled-step logits to the
    batched-prefill baseline; the chunked run never joins a prefill batch."""
    cm, params = _serve_cm()
    reqs = synthetic_requests(6, cm.cfg.vocab_size, prompt_len=12,
                              max_new_tokens=4, seed=41)
    base, fast = _run_vs_baseline(reqs, chunked_prefill=True, chunk_size=k)
    _assert_results_identical(base, fast)
    assert base.metrics["prefill_batches"] >= 1
    assert fast.metrics["prefill_batches"] == 0
    assert fast.metrics["catchup_tokens"] >= sum(r.prompt_len for r in reqs)
    assert fast.metrics["chunk_size"] == k and fast.metrics["chunked_prefill"]


def test_chunk_tick_mid_cow_fork_matches_host_loop():
    """A COW fork triggered *inside a multi-token chunk tick* stays
    byte-identical to the one-token-per-tick host loop (the exactness
    gate).  'a'/'b' warm the index with a 1.5-block prompt and finish
    together, so the hits 'c'/'d' admit in the same wave and share the
    indexed partial block; the long cold prompts keep k=4 chunk ticks
    running, so the hit's first write — mid-block, into a block the index
    and the sibling hit still hold — forks during a multi-token tick.
    Tokens must also match the batched-prefill cold run (whose logit bits
    may legitimately differ: a (B, 48) prefill cell and (B, 4) catch-up
    cells round their matmuls differently)."""
    cm, params = _serve_cm()
    rng = np.random.RandomState(42)
    p = rng.randint(0, cm.cfg.vocab_size, 12).astype(np.int32)
    long1, long2 = (rng.randint(0, cm.cfg.vocab_size, 48).astype(np.int32)
                    for _ in range(2))
    reqs = [Request("a", p, max_new_tokens=2),
            Request("b", p, max_new_tokens=2),
            Request("l1", long1, max_new_tokens=8),
            Request("l2", long2, max_new_tokens=8),
            Request("c", p, max_new_tokens=4),
            Request("d", p, max_new_tokens=4)]
    kw = dict(max_batch=4, max_seq_len=64, block_size=8,
              capture_logits=True, prompt_buckets=(16, 48, 64),
              prefix_cache=True, chunked_prefill=True)
    host = Engine(cm, params, EngineConfig(**kw, chunk_size=1)).run(reqs)
    fast = Engine(cm, params, EngineConfig(**kw, chunk_size=4)).run(reqs)
    _assert_results_identical(host, fast)
    cold = Engine(cm, params, EngineConfig(
        max_batch=4, max_seq_len=64, block_size=8,
        prompt_buckets=(16, 48, 64))).run(reqs)
    assert {r.rid: r.tokens for r in cold.results} == \
        {r.rid: r.tokens for r in fast.results}
    m = fast.metrics
    assert m["prefix_hits"] >= 2
    assert m["cow_forks"] >= 1          # hit forked its block mid-chunk-tick
    assert m["prefill_batches"] == 0 and m["catchup_tokens"] > 0


def test_chunked_prefill_mixed_lengths_with_decode_interleave():
    """Chunk ticks interleave catch-up rows with rows already decoding —
    staggered admissions (1 slot free at a time) still match the baseline."""
    cm, params = _serve_cm()
    reqs = synthetic_requests(5, cm.cfg.vocab_size, prompt_len=10,
                              max_new_tokens=6, seed=43)
    base, fast = _run_vs_baseline(
        reqs, base_kw=dict(max_batch=2), chunked_prefill=True, chunk_size=4)
    _assert_results_identical(base, fast)
    assert fast.metrics["refills"] >= 1  # admissions landed mid-decode


def test_chunked_prefill_rejected_for_recurrent_models():
    """Recurrent temporal-mixing state advances one token per tick; the
    engine must refuse chunked prefill rather than corrupt it."""
    cm = rflow.compile("recurrentgemma-2b", SERVE_SHAPE,
                       FlowConfig(mode="folded", precision="fp32"),
                       smoke=True)
    params = cm.init_params(jax.random.key(0))
    eng = Engine(cm, params,
                 EngineConfig(max_batch=2, max_seq_len=64, block_size=8,
                              chunk_size=4, chunked_prefill=True))
    with pytest.raises(ValueError, match="chunked prefill"):
        eng.run([Request("x", np.arange(1, 9, dtype=np.int32),
                         max_new_tokens=2)])


def test_engine_config_validates_chunk_knobs():
    with pytest.raises(ValueError, match="chunk_size"):
        EngineConfig(max_seq_len=64, chunk_size=0)
    with pytest.raises(ValueError, match="chunk_size"):
        EngineConfig(max_seq_len=64, chunk_size=128)
    with pytest.raises(ValueError, match="fori_seg"):
        EngineConfig(fori_seg=1)
    with pytest.raises(ValueError, match="rung 1"):
        EngineConfig(chunk_size=4, chunk_buckets=(2, 4))
    with pytest.raises(ValueError, match="end at chunk_size"):
        EngineConfig(chunk_size=4, chunk_buckets=(1, 2))
    assert EngineConfig(chunk_size=4).chunk_buckets == (1, 4)
    assert EngineConfig(chunk_size=4,
                        chunk_buckets=(4, 1, 2)).chunk_buckets == (1, 2, 4)
    assert EngineConfig().chunk_buckets == (1,)


# ---------------------------------------------------------------------------
# host-free decode segments
# ---------------------------------------------------------------------------

def test_fori_segments_match_host_loop_greedy():
    """Steady-state decode run as one on-device fori segment: same tokens
    as the per-tick host loop, strictly fewer host syncs per token."""
    cm, params = _serve_cm()
    reqs = synthetic_requests(4, cm.cfg.vocab_size, prompt_len=8,
                              max_new_tokens=8, seed=44, vary_lens=False)
    base, fast = _run_vs_baseline(reqs, capture=False,
                                  base_kw=dict(max_batch=4), fori_seg=4)
    assert {r.rid: r.tokens for r in base.results} == \
        {r.rid: r.tokens for r in fast.results}
    assert fast.metrics["fori_segments"] >= 1
    assert fast.metrics["host_syncs_per_token"] < \
        base.metrics["host_syncs_per_token"]


def test_fori_segments_match_host_loop_sampled():
    """The segment loop splits the sampling rng exactly like the host tick,
    so even temperature > 0 streams are byte-identical."""
    cm, params = _serve_cm()
    reqs = synthetic_requests(3, cm.cfg.vocab_size, prompt_len=8,
                              max_new_tokens=6, seed=45, vary_lens=False)
    base, fast = _run_vs_baseline(
        reqs, capture=False,
        base_kw=dict(max_batch=4, temperature=0.8, seed=9), fori_seg=3)
    assert {r.rid: r.tokens for r in base.results} == \
        {r.rid: r.tokens for r in fast.results}
    assert fast.metrics["fori_segments"] >= 1


def test_chunk_and_fori_compose():
    """Both perf paths on at once (chunked catch-up feeding host-free
    segments) still reproduce the plain loop, and the report surfaces the
    new counters."""
    cm, params = _serve_cm()
    reqs = synthetic_requests(6, cm.cfg.vocab_size, prompt_len=12,
                              max_new_tokens=8, seed=46)
    base, fast = _run_vs_baseline(reqs, capture=False,
                                  base_kw=dict(max_batch=2),
                                  chunked_prefill=True, chunk_size=4,
                                  fori_seg=4)
    assert {r.rid: r.tokens for r in base.results} == \
        {r.rid: r.tokens for r in fast.results}
    m = fast.metrics
    assert m["fori_segments"] >= 1 and m["catchup_tokens"] > 0
    assert m["p95_ttft_s"] >= m["p50_ttft_s"] > 0
    d = fast.describe()
    assert "host_syncs/tok=" in d and "fori_segments=" in d


# ---------------------------------------------------------------------------
# kernel-level exactness: positional flash mask, (B, k) paged catch-up
# ---------------------------------------------------------------------------

def test_flash_positional_mask_matches_ref_and_pad_invariant():
    """Left-padded positions on the Pallas flash kernel (interpret mode)
    match the reference mask, and a padded row equals its unpadded serve."""
    from repro.kernels.attention import flash_attention
    from repro.kernels.ref import flash_attention_ref
    B, S, H, D = 2, 16, 2, 16
    rng = np.random.RandomState(50)
    q, k, v = (jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
               for _ in range(3))
    pos = np.full((B, S), -1, np.int32)
    for b, pad in enumerate((0, 5)):
        pos[b, pad:] = np.arange(S - pad)
    pos = jnp.asarray(pos)
    out = flash_attention(q, k, v, positions=pos, interpret=True)
    ref = flash_attention_ref(q, k, v, positions=pos)
    # padded query rows are garbage-and-discarded by contract: compare the
    # real rows only
    for b, pad in enumerate((0, 5)):
        np.testing.assert_allclose(np.asarray(out)[b, pad:],
                                   np.asarray(ref)[b, pad:],
                                   rtol=2e-5, atol=2e-5)
    # pad invariance: the 11 real tokens of row 1 behave as an 11-long batch
    solo = flash_attention_ref(q[1:, 5:], k[1:, 5:], v[1:, 5:])
    np.testing.assert_allclose(np.asarray(out)[1, 5:], np.asarray(solo)[0],
                               rtol=2e-5, atol=2e-5)


def test_paged_chunk_kernel_matches_ref():
    """The (B, k) multi-query pool lookup (interpret mode) matches the
    reference for full and padded chunks."""
    from repro.kernels.decode_attention import paged_decode_attention
    from repro.kernels.ref import paged_decode_attention_ref
    B, Sq, H, KV, D, bs, nblk = 2, 4, 4, 2, 16, 8, 4
    NB = 1 + B * nblk
    rng = np.random.RandomState(51)
    q = jnp.asarray(rng.randn(B, Sq, H, D), jnp.float32)
    kp = jnp.asarray(rng.randn(NB, bs, KV, D), jnp.float32)
    vp = jnp.asarray(rng.randn(NB, bs, KV, D), jnp.float32)
    bt = jnp.asarray(1 + np.arange(B * nblk).reshape(B, nblk), jnp.int32)
    lens = jnp.asarray([10, 7], jnp.int32)
    qpos = lens[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None, :]
    out = paged_decode_attention(q, kp, vp, bt, lens, qpos=qpos,
                                 interpret=True)
    ref = paged_decode_attention_ref(q, kp, vp, bt, lens, qpos=qpos,
                                     compute_dtype=jnp.float32)
    assert out.shape == (B, Sq, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    # a ragged chunk: row 1 only carries 2 real tokens; its real rows must
    # be untouched by the padding rows' presence
    qp2 = qpos.at[1, 2:].set(-1)
    out2 = paged_decode_attention(q, kp, vp, bt, lens, qpos=qp2,
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(out2)[1, :2],
                               np.asarray(ref)[1, :2], rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(out2)[0], np.asarray(ref)[0],
                               rtol=2e-5, atol=2e-5)


def test_kops_flash_attention_threads_positions():
    """Regression: the kernel-registry wrapper must forward ``positions``
    to the flash backend instead of silently dropping it."""
    from repro.kernels import ops as kops
    from repro.kernels.ref import flash_attention_ref
    B, S, H, D = 1, 8, 2, 16
    rng = np.random.RandomState(52)
    q, k, v = (jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
               for _ in range(3))
    pos = jnp.asarray(np.concatenate(
        [np.full(3, -1, np.int32), np.arange(5, dtype=np.int32)])[None])
    got = kops.flash_attention(q, k, v, positions=pos, interpret=True)
    want = flash_attention_ref(q, k, v, positions=pos)
    np.testing.assert_allclose(np.asarray(got)[:, 3:],
                               np.asarray(want)[:, 3:],
                               rtol=2e-5, atol=2e-5)
    # and it must differ from the positions-free call (the old bug made
    # them identical)
    nopos = kops.flash_attention(q, k, v, interpret=True)
    assert not np.allclose(np.asarray(got)[:, 3:], np.asarray(nopos)[:, 3:])


# ---------------------------------------------------------------------------
# autotune: chunk width + segment length are part of the pinnable outcome
# ---------------------------------------------------------------------------

def test_autotune_tunes_chunk_and_fori():
    from repro.serving.autotune import ServingProfile, autotune_decode
    prof = ServingProfile(name="chunk", batch_buckets=(2,), max_seq_len=32,
                          block_sizes=(8,), chunk_sizes=(1, 4),
                          fori_segs=(0, 4))
    at = autotune_decode("llama3.2-1b", profile=prof, smoke=True,
                         validate="none", iters=1, tune_fori=True)
    assert set(at.chunk_times_us) == {1, 4}
    assert at.chunk_size in (1, 4) and at.fori_seg in (0, 4)
    assert set(at.fori_times_s) == {"0", "4"}
    ec = at.engine_config()
    assert ec.chunk_size == at.chunk_size
    assert ec.chunked_prefill == (at.chunk_size > 1)
    assert ec.fori_seg == at.fori_seg
    d = at.describe()
    assert "chunk_us_per_tok:" in d and "fori_replay_s:" in d


def test_serving_profile_validates_chunk_candidates():
    from repro.serving.autotune import ServingProfile
    with pytest.raises(ValueError, match="chunk sizes"):
        ServingProfile(max_seq_len=32, block_sizes=(8,), chunk_sizes=(0,))
    with pytest.raises(ValueError, match="chunk sizes"):
        ServingProfile(max_seq_len=32, block_sizes=(8,), chunk_sizes=(64,))
    with pytest.raises(ValueError, match="fori segment"):
        ServingProfile(max_seq_len=32, block_sizes=(8,), fori_segs=(1,))
