"""repro.obs: tracer span semantics, ring-buffer bounding, Chrome export
schema, metrics registry typing, engine tick timelines, and the exactness
gates (byte-identical outputs traced vs untraced, <2% disabled overhead)."""
import json
import math
import time

import jax
import pytest

from repro import flow as rflow
from repro.configs.base import FlowConfig, ShapeConfig
from repro.launch.obs import summarize
from repro.obs import Counter, Gauge, Histogram, MetricsRegistry, Tracer
from repro.obs.trace import load_trace
from repro.serving.engine import Engine, EngineConfig
from repro.serving.scheduler import synthetic_requests


# ---------------------------------------------------------------------------
# tracer unit tests
# ---------------------------------------------------------------------------

def test_span_nesting_and_attributes():
    clock = iter(float(i) for i in range(100))
    tr = Tracer(enabled=True, clock=lambda: next(clock))
    with tr.span("outer", cat="a", x=1) as outer:
        with tr.span("inner", cat="b") as inner:
            inner.set(y=2)
        outer.set(z=3)
    evs = tr.events()
    assert [e["name"] for e in evs] == ["inner", "outer"]  # inner ends first
    inner_ev, outer_ev = evs
    assert inner_ev["depth"] == 1 and outer_ev["depth"] == 0
    assert outer_ev["args"] == {"x": 1, "z": 3}
    assert inner_ev["args"] == {"y": 2}
    # deterministic clock: outer spans [t=1, t=4), inner [t=2, t=3)
    assert outer_ev["dur"] == pytest.approx(3e6)
    assert inner_ev["dur"] == pytest.approx(1e6)
    assert inner_ev["ts"] >= outer_ev["ts"]


def test_span_end_idempotent_and_kwargs():
    tr = Tracer(enabled=True)
    sp = tr.span("s", k=1)
    sp.end(done=True)
    sp.end(done=False)       # second end is a no-op
    (ev,) = tr.events()
    assert ev["args"] == {"k": 1, "done": True}


def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    with tr.span("a"):
        pass
    sp = tr.span("b")
    sp.end()
    assert len(tr) == 0
    # span() returns the shared no-op instance on the disabled path
    assert tr.span("c") is tr.span("d")


def test_timed_measures_even_when_disabled():
    tr = Tracer(enabled=False)
    sp = tr.timed("work")
    time.sleep(0.002)
    sp.end()
    assert sp.elapsed_s > 0
    assert len(tr) == 0      # measured, not recorded


def test_ring_buffer_bounds_and_drop_count():
    tr = Tracer(enabled=True, max_events=8)
    for i in range(20):
        tr.span(f"s{i}").end()
    assert len(tr) == 8
    assert tr.n_dropped == 12
    assert [e["name"] for e in tr.events()] == [f"s{i}" for i in range(12, 20)]
    tr.clear()
    assert len(tr) == 0 and tr.n_dropped == 0


def test_decorator_form():
    tr = Tracer(enabled=True)

    @tr.trace()
    def work(a, b):
        return a + b

    assert work(2, 3) == 5
    (ev,) = tr.events()
    assert ev["name"].endswith("work") and ev["cat"] == "fn"


def test_chrome_export_schema(tmp_path):
    tr = Tracer(enabled=True)
    with tr.span("outer", cat="phase", phase="decode"):
        tr.span("inner", cat="sub").end()
    path = str(tmp_path / "t.trace.json")
    doc = tr.to_chrome(path)
    assert set(doc) == {"traceEvents", "displayTimeUnit", "otherData"}
    for ev in doc["traceEvents"]:
        # the fields Perfetto / chrome://tracing require on "X" events
        for field in ("name", "cat", "ph", "ts", "dur", "pid", "tid"):
            assert field in ev, f"event missing {field}"
        assert ev["ph"] == "X"
        assert ev["ts"] >= 0 and ev["dur"] >= 0
    # round-trips through the loader, and the file is valid JSON
    assert load_trace(path) == doc["traceEvents"]
    with open(path) as f:
        json.load(f)


def test_jsonl_export_roundtrip(tmp_path):
    tr = Tracer(enabled=True)
    for i in range(3):
        tr.span(f"s{i}").end()
    path = str(tmp_path / "t.jsonl")
    tr.to_jsonl(path)
    assert [e["name"] for e in load_trace(path)] == ["s0", "s1", "s2"]


# ---------------------------------------------------------------------------
# metrics registry unit tests
# ---------------------------------------------------------------------------

def test_registry_instruments_and_snapshot():
    reg = MetricsRegistry()
    reg.counter("a.count").inc()
    reg.counter("a.count").inc(4)
    reg.gauge("b.val").set(7)
    reg.gauge("b.val").set(3)
    h = reg.histogram("c.dist")
    for v in (0.1, 0.3, 0.2):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["a.count"] == 5
    assert snap["b.val"] == 3 and snap["b.val.peak"] == 7
    assert snap["c.dist.count"] == 3
    assert snap["c.dist.mean"] == pytest.approx(0.2)
    assert snap["c.dist.max"] == pytest.approx(0.3)
    # int gauges stay ints (describe() formats them with %d-style fields)
    assert isinstance(snap["b.val"], int)


def test_registry_type_conflicts_raise():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_counter_is_monotonic():
    c = Counter("c")
    with pytest.raises(ValueError):
        c.inc(-1)


def test_histogram_percentile_matches_legacy_formula():
    # the serving report always used nearest-rank:
    #   xs[min(len(xs)-1, ceil(p*len(xs))-1)] over the sorted samples
    for xs in ([0.5], [3.0, 1.0, 2.0], [float(i) for i in range(17)]):
        h = Histogram("h")
        for v in xs:
            h.observe(v)
        s = sorted(xs)
        for p in (0.5, 0.95, 0.99):
            want = s[min(len(s) - 1, int(math.ceil(p * len(s))) - 1)]
            assert h.percentile(p) == want
    assert Histogram("empty").percentile(0.95) == 0.0


def test_gauge_preserves_int_and_float():
    g = Gauge("g")
    g.set(4)
    assert isinstance(g.value, int)
    g.set(4.5)
    assert isinstance(g.value, float)


# ---------------------------------------------------------------------------
# engine integration: tick timeline + exactness gates
# ---------------------------------------------------------------------------

SERVE_SHAPE = ShapeConfig("serve", "decode", 64, 4)


@pytest.fixture(scope="module")
def served():
    cm = rflow.compile("llama3.2-1b", SERVE_SHAPE,
                       FlowConfig(mode="folded", precision="fp32"),
                       smoke=True)
    params = cm.init_params(jax.random.key(0))
    reqs = synthetic_requests(8, cm.cfg.vocab_size, prompt_len=8,
                              max_new_tokens=8)
    return cm, params, reqs


def _run(cm, params, reqs, **ecfg_kw):
    eng = Engine(cm, params, EngineConfig(max_batch=4, max_seq_len=64,
                                          **ecfg_kw))
    return eng, eng.run(reqs)


def test_traced_outputs_byte_identical(served):
    cm, params, reqs = served
    _, r_off = _run(cm, params, reqs)
    eng_on, r_on = _run(cm, params, reqs, trace=True)
    assert [r.tokens for r in r_off.results] == \
           [r.tokens for r in r_on.results]
    assert len(eng_on.tracer) > 0


def test_untraced_engine_records_nothing(served):
    cm, params, reqs = served
    eng, _ = _run(cm, params, reqs)
    assert len(eng.tracer) == 0


def test_tick_timeline_covers_wall_time(tmp_path, served):
    cm, params, reqs = served
    eng, report = _run(cm, params, reqs, trace=True)
    path = str(tmp_path / "run.trace.json")
    eng.tracer.to_chrome(path)
    s = summarize(load_trace(path))
    # phase spans (admit + decode/fori ticks) tile the run loop
    assert s["coverage"] >= 0.95
    phases = {name for name, _, _ in s["phases"]}
    assert "admit" in phases and phases & {"decode", "chunked-prefill",
                                           "spec-verify", "decode-fori"}
    # per-tick attributes: batch bucket, queue depth, pool occupancy,
    # host-sync count
    ticks = [e for e in eng.tracer.events() if e["cat"] == "phase"
             and e["args"].get("phase") != "admit"]
    assert ticks
    for ev in ticks:
        assert {"batch", "queue", "pool_live", "host_syncs"} <= \
            set(ev["args"])
    assert sum(1 for e in eng.tracer.events() if e["cat"] == "run") == 1


def test_trace_phases_chunked_and_spec(served):
    cm, params, reqs = served
    eng, _ = _run(cm, params, reqs, trace=True, prefix_cache=True,
                  chunk_size=4, chunked_prefill=True)
    phases = {e["args"].get("phase") for e in eng.tracer.events()
              if e["cat"] == "phase"}
    assert "chunked-prefill" in phases
    eng, _ = _run(cm, params, reqs, trace=True, speculation="ngram:3")
    phases = {e["args"].get("phase") for e in eng.tracer.events()
              if e["cat"] == "phase"}
    assert "spec-verify" in phases


def test_disabled_tracer_overhead_under_2pct(served):
    # the disabled hot path is one boolean check per span site; bound the
    # replay's total span cost by microbenchmarking that path and scaling
    # by the replay's span-site count, instead of racing two wall-clocks
    cm, params, reqs = served
    eng, report = _run(cm, params, reqs)
    wall = report.metrics["wall_s"]
    tr = eng.tracer
    n = 200_000
    t0 = time.perf_counter()
    for _ in range(n):
        tr.span("x")
    per_call = (time.perf_counter() - t0) / n
    # <= ~6 disabled span sites per tick (admit, tick, cow, evict, + ends)
    sites = 6 * (report.metrics["decode_ticks"]
                 + report.metrics["prefill_batches"] + 2)
    assert sites * per_call < 0.02 * wall


def test_injected_clock_is_deterministic(served):
    cm, params, reqs = served

    def fake_clock(state={"t": 0.0}):
        state["t"] += 0.5
        return state["t"]

    eng = Engine(cm, params, EngineConfig(max_batch=4, max_seq_len=64),
                 clock=fake_clock)
    m = eng.run(reqs).metrics
    # every timestamp came from the fake clock: wall and latencies are
    # exact multiples of the 0.5s step, nothing raced perf_counter
    assert m["wall_s"] % 0.5 == pytest.approx(0.0)
    assert m["p50_latency_s"] % 0.5 == pytest.approx(0.0)
    assert m["p50_ttft_s"] % 0.5 == pytest.approx(0.0)
    assert m["wall_s"] > 0


def test_run_report_carries_registry(served):
    cm, params, reqs = served
    _, report = _run(cm, params, reqs, prefix_cache=True)
    assert report.registry is not None
    snap = report.registry.snapshot()
    # dotted-name schema: the documented stable names exist
    for name in ("serving.requests", "serving.tokens.generated",
                 "serving.prefix.hits", "serving.sched.admissions",
                 "pool.blocks.live.peak", "pool.blocks.total",
                 "serving.spec.rollback_tokens"):
        assert name in snap, name
    # the flat report keys are a view over the snapshot
    m = report.metrics
    assert m["n_requests"] == snap["serving.requests"]
    assert m["prefix_hits"] == snap["serving.prefix.hits"]
    assert m["peak_used_blocks"] == snap["pool.blocks.live.peak"]


def test_summarize_cli(tmp_path, served, capsys):
    cm, params, reqs = served
    eng, _ = _run(cm, params, reqs, trace=True)
    path = str(tmp_path / "run.trace.json")
    eng.tracer.to_chrome(path)
    from repro.launch.obs import main
    assert main(["summarize", path]) == 0
    out = capsys.readouterr().out
    assert "phase" in out and "admit" in out and "coverage" in out


def test_kernel_dispatch_rejections_metric():
    from repro.kernels.registry import DISPATCH_REJECTIONS
    from repro.obs import METRICS
    before = METRICS.counter("kernels.dispatch.rejections").value
    n_before = sum(DISPATCH_REJECTIONS.values())
    cm = rflow.compile("llama3.2-1b", SERVE_SHAPE,
                       FlowConfig(mode="folded", precision="fp32",
                                  kernel_backend="pallas_interpret"),
                       smoke=True)
    params = cm.init_params(jax.random.key(0))
    cm.prefill(params, cm._measure_inputs(0))
    after = METRICS.counter("kernels.dispatch.rejections").value
    n_after = sum(DISPATCH_REJECTIONS.values())
    # the registry counter moves in lockstep with the legacy dict
    assert after - before == n_after - n_before
