"""Persistent autotune database tests (ISSUE 10): store round-trip and
atomicity, exact-hit / transfer warm starts through dse.explore, the
platform-fingerprint and _timed_runs-warmup bugfixes, the bounded explore
cache, and the serving microbench banking."""
import dataclasses
import json
import os
import threading

import pytest

from repro import tunedb
from repro.configs import get_smoke
from repro.configs.base import FlowConfig, ShapeConfig
from repro.core import dse

DECODE_B4 = ShapeConfig("db_decode4", "decode", 64, 4)
DECODE_B8 = ShapeConfig("db_decode8", "decode", 64, 8)


@pytest.fixture(autouse=True)
def _fresh_caches():
    dse.clear_explore_cache()
    tunedb.close_all()
    yield
    dse.clear_explore_cache()
    dse.set_explore_cache_limit(64)
    tunedb.close_all()


def _validator(calls):
    """Deterministic fake validator: every candidate fits; 'measured' time
    is a stable function of the knobs, so winner selection is exact."""
    def validate(flow):
        calls.append(flow)
        t = 0.001 + (0.0005 if flow.precision == "fp32" else 0.0) \
            + 0.0001 * flow.scan_unroll
        return {"per_device_bytes": 1000, "measured_step_s": t}
    return validate


# ---------------------------------------------------------------------------
# store semantics
# ---------------------------------------------------------------------------

def test_tuple_values_roundtrip_exactly():
    v = {"knobs": (("mesh_split", (("data", 2), ("model", 2))),
                   ("tile", (128, 256))),
         "nested": [1, (2, 3), {"k": (4,)}]}
    assert tunedb.decode_value(json.loads(
        tunedb.canonical_json(v))) == v


def test_record_roundtrip_and_last_wins(tmp_path):
    path = str(tmp_path / "tune.jsonl")
    db = tunedb.TuneDB(path)
    key = {"cfg": "a", "shape": 4}
    db.record("explore", key, {"best": 1})
    db.record("explore", key, {"best": 2})          # supersedes
    db.record("serving", {"cfg": "b"}, {"best": 3})
    assert len(db) == 2                             # index: last per fp
    re = tunedb.TuneDB(path)                        # fresh load from disk
    rec = re.lookup(key)
    assert rec is not None and rec.value == {"best": 2}
    assert [r.kind for r in re.records("serving")] == ["serving"]
    assert re.stats()["by_kind"] == {"explore": 1, "serving": 1}


def test_corrupt_and_truncated_lines_skipped_with_warning(tmp_path):
    path = str(tmp_path / "tune.jsonl")
    db = tunedb.TuneDB(path)
    db.record("explore", {"k": 1}, {"best": 1})
    db.record("explore", {"k": 2}, {"best": 2})
    with open(path, "a", encoding="utf-8") as f:
        f.write("not json at all\n")
        f.write('{"kind": "explore", "fingerprint": "abc", "ke')  # torn
    with pytest.warns(UserWarning, match="skipping corrupt record"):
        re = tunedb.TuneDB(path)
    assert len(re) == 2 and re.n_skipped == 2
    assert re.lookup({"k": 2}).value == {"best": 2}


def test_concurrent_writers_never_tear_records(tmp_path):
    path = str(tmp_path / "tune.jsonl")
    n_threads, n_each = 8, 25

    def writer(i):
        db = tunedb.TuneDB(path)                    # one handle per writer
        for j in range(n_each):
            db.record("serving", {"w": i, "j": j},
                      {"best": i * 1000 + j, "pad": "x" * 256})

    threads = [threading.Thread(target=writer, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    re = tunedb.TuneDB(path)                        # every line must parse
    assert re.n_skipped == 0
    assert len(re) == n_threads * n_each
    for i in range(n_threads):
        for j in range(n_each):
            assert re.lookup({"w": i, "j": j}).value["best"] == i * 1000 + j


def test_gc_compacts_and_drops_stale(tmp_path):
    path = str(tmp_path / "tune.jsonl")
    db = tunedb.TuneDB(path)
    for _ in range(3):
        db.record("explore", {"k": 1}, {"best": 1})  # 3 lines, 1 fingerprint
    db.put(dataclasses.replace(
        tunedb.TuneRecord.make("explore", {"k": 2}, {"best": 2}),
        code_version="pr0.0"))
    assert sum(1 for _ in open(path)) == 4
    out = db.gc()
    assert out == {"kept": 1, "dropped_stale": 1}
    assert sum(1 for _ in open(path)) == 1
    assert tunedb.TuneDB(path).lookup({"k": 1}).value == {"best": 1}


def test_stale_code_version_never_served(tmp_path):
    db = tunedb.TuneDB(str(tmp_path / "tune.jsonl"))
    db.put(dataclasses.replace(
        tunedb.TuneRecord.make("explore", {"k": 1}, {"best": 1}),
        code_version="pr0.0"))
    assert db.get(tunedb.fingerprint({"k": 1})) is None
    assert db.get(tunedb.fingerprint({"k": 1}), code_version=None) is not None


# ---------------------------------------------------------------------------
# dse.explore: exact hit and cross-config transfer
# ---------------------------------------------------------------------------

def test_explore_exact_hit_measures_nothing(tmp_path):
    """Round-trip acceptance: with a populated store, re-running the same
    search measures 0 candidates and returns the byte-identical winner."""
    cfg = get_smoke("llama3.2-1b")
    path = str(tmp_path / "tune.jsonl")
    calls = []
    kw = dict(validator=_validator(calls), rank_measured=True,
              use_cache=False, db=path)
    cold = dse.explore(cfg, DECODE_B4, **kw)
    assert cold.tunedb_status == "cold" and cold.n_measured > 0
    n_cold = len(calls)
    warm = dse.explore(cfg, DECODE_B4, **kw)
    assert warm.tunedb_status == "hit"
    assert warm.n_measured == 0 and len(calls) == n_cold   # zero validator
    assert warm.best.flow == cold.best.flow                # byte-identical
    assert warm.best.knobs == cold.best.knobs
    assert warm.validated == cold.validated                # replayed record


def test_explore_transfer_halves_measurements(tmp_path):
    """Bucket-transfer acceptance: a neighboring batch bucket's record
    re-anchors the ranking so >= 50% fewer candidates compile, and the
    winner matches the cold search of the same cell."""
    cfg = get_smoke("llama3.2-1b")
    path = str(tmp_path / "tune.jsonl")
    calls = []
    kw = dict(validator=_validator(calls), rank_measured=True,
              use_cache=False)
    baseline = dse.explore(cfg, DECODE_B8, **kw)           # no db: reference
    seed = dse.explore(cfg, DECODE_B4, **kw, db=path)      # seeds bucket 4
    assert seed.tunedb_status == "cold"
    warm = dse.explore(cfg, DECODE_B8, **kw, db=path)      # transfers 4 -> 8
    assert warm.tunedb_status == "transfer"
    assert warm.n_measured <= seed.n_measured // 2         # >= 50% fewer
    assert warm.n_measured >= 1
    assert warm.best.flow == baseline.best.flow            # same winner


def test_explore_writes_back_transfer_results(tmp_path):
    """A transferred search is itself banked: the third process over the
    same cell is an exact hit."""
    cfg = get_smoke("llama3.2-1b")
    path = str(tmp_path / "tune.jsonl")
    calls = []
    kw = dict(validator=_validator(calls), rank_measured=True,
              use_cache=False, db=path)
    dse.explore(cfg, DECODE_B4, **kw)
    assert dse.explore(cfg, DECODE_B8, **kw).tunedb_status == "transfer"
    again = dse.explore(cfg, DECODE_B8, **kw)
    assert again.tunedb_status == "hit" and again.n_measured == 0


def test_explore_db_defaults_from_flow_tuning(tmp_path):
    """FlowConfig.tuning.tune_db is the default store path."""
    cfg = get_smoke("llama3.2-1b")
    path = str(tmp_path / "tune.jsonl")
    flow = FlowConfig(mode="folded")
    flow = dataclasses.replace(
        flow, tuning=dataclasses.replace(flow.tuning, tune_db=path))
    calls = []
    kw = dict(validator=_validator(calls), rank_measured=True,
              use_cache=False)
    cold = dse.explore(cfg, DECODE_B4, flow, **kw)
    warm = dse.explore(cfg, DECODE_B4, flow, **kw)
    assert cold.tunedb_status == "cold" and warm.tunedb_status == "hit"
    assert os.path.exists(path)


# ---------------------------------------------------------------------------
# bugfix regressions
# ---------------------------------------------------------------------------

def test_explore_fingerprint_keys_on_platform(monkeypatch, tmp_path):
    """Regression: the in-process cache fingerprint and every persisted
    record must key on the jax backend/device *kind* — flipping platforms
    in one process (JAX_PLATFORMS, CPU<->TPU) must never serve results
    measured on the other one."""
    cfg = get_smoke("llama3.2-1b")
    path = str(tmp_path / "tune.jsonl")
    calls = []
    kw = dict(validator=_validator(calls), rank_measured=True,
              use_cache=True, db=path)
    monkeypatch.setattr(dse, "_platform_key", lambda: "cpu:host-A")
    r1 = dse.explore(cfg, DECODE_B4, **kw)
    monkeypatch.setattr(dse, "_platform_key", lambda: "tpu:TPU v5e")
    r2 = dse.explore(cfg, DECODE_B4, **kw)
    assert r2 is not r1                        # process cache: distinct entry
    assert r2.tunedb_status == "cold"          # persisted store: no hit
    assert dse.explore_cache_stats()["hits"] == 0
    # ...and the same platform still hits both layers
    r3 = dse.explore(cfg, DECODE_B4, **kw)
    assert r3 is r2
    fresh = dse.explore(cfg, DECODE_B4, validator=_validator(calls),
                        rank_measured=True, use_cache=False, db=path)
    assert fresh.tunedb_status == "hit"


def test_timed_runs_discard_warmup_compile_time(monkeypatch):
    """Regression: the first iteration (jit compile) must not land in the
    sample list — a compile-heavy candidate must win/lose on steady-state
    time."""
    from repro.obs.trace import Tracer
    from repro.serving import autotune

    state = {"t": 0.0, "calls": 0}

    def fake_clock():
        return state["t"]

    def fn():
        # first invocation pays 10s of "compile"; steady state is 1s
        state["t"] += 10.0 if state["calls"] == 0 else 1.0
        state["calls"] += 1

    monkeypatch.setattr(autotune, "TRACER", Tracer(clock=fake_clock))
    ts = autotune._timed_runs("t", fn, iters=3)
    assert state["calls"] == 4                  # 1 warmup + 3 samples
    assert ts == [1.0, 1.0, 1.0]                # compile time discarded


def test_explore_cache_lru_bounded_with_metrics():
    """Regression: _EXPLORE_CACHE is bounded (LRU) and publishes
    hits/misses/evictions."""
    from repro.obs import METRICS
    cfg = get_smoke("llama3.2-1b")
    dse.set_explore_cache_limit(2)
    ev0 = METRICS.counter("dse.cache.evictions").value
    shapes = [ShapeConfig(f"lru{i}", "decode", 64, 2 ** i) for i in range(3)]
    results = [dse.explore(cfg, s) for s in shapes]
    assert len(dse._EXPLORE_CACHE) == 2
    stats = dse.explore_cache_stats()
    assert stats["misses"] == 3 and stats["evictions"] == 1
    assert METRICS.counter("dse.cache.evictions").value == ev0 + 1
    # oldest evicted: shapes[0] recomputes, shapes[2] still cached
    assert dse.explore(cfg, shapes[2]) is results[2]
    assert dse.explore(cfg, shapes[0]) is not results[0]
    dse.clear_explore_cache()
    assert dse.explore_cache_stats() == {"hits": 0, "misses": 0,
                                         "evictions": 0}


def test_explore_cache_limit_zero_disables():
    cfg = get_smoke("llama3.2-1b")
    dse.set_explore_cache_limit(0)
    r1 = dse.explore(cfg, DECODE_B4)
    r2 = dse.explore(cfg, DECODE_B4)
    assert r1 is not r2 and len(dse._EXPLORE_CACHE) == 0


# ---------------------------------------------------------------------------
# serving microbench banking + the deterministic fake-clock winners
# ---------------------------------------------------------------------------

def test_tune_block_size_served_from_db(monkeypatch, tmp_path):
    from repro.serving import autotune
    cfg = get_smoke("llama3.2-1b")
    prof = autotune.ServingProfile(name="dbt", batch_buckets=(1, 2),
                                   max_seq_len=32, block_sizes=(8, 16))
    path = str(tmp_path / "tune.jsonl")
    b1, t1 = autotune.tune_block_size(cfg, prof, iters=2, db=path)

    def boom(*a, **kw):
        raise AssertionError("benched despite a banked record")

    monkeypatch.setattr(autotune, "_timed_runs", boom)
    b2, t2 = autotune.tune_block_size(cfg, prof, iters=2, db=path)
    assert (b2, t2) == (b1, t1)
    assert isinstance(b2, int) and all(isinstance(k, int) for k in t2)


def test_five_tuners_same_winners_on_fake_clock(monkeypatch, tmp_path):
    """The five tune_* microbenches pick deterministic winners on a fake
    clock where every span costs exactly one tick: ties everywhere, so each
    tuner's documented tie-break decides — stable across repeat runs."""
    from repro.obs.trace import Tracer
    from repro.serving import autotune

    cfg = get_smoke("llama3.2-1b")
    prof = autotune.ServingProfile(name="fake", batch_buckets=(2,),
                                   max_seq_len=32, block_sizes=(8, 16),
                                   chunk_sizes=(1, 2), fori_segs=(0, 4),
                                   spec_ks=(0, 2))
    at = autotune.autotune_decode(cfg, profile=prof, validate="none",
                                  tune_blocks=False, tune_chunks=False,
                                  use_cache=False)
    at.block_size = 8

    state = {"t": 0.0}
    monkeypatch.setattr(autotune, "TRACER",
                        Tracer(clock=lambda: state.__setitem__(
                            "t", state["t"] + 0.5) or state["t"]))

    winners = {}
    for _ in range(2):                          # identical on repeat
        run = {
            "block": autotune.tune_block_size(cfg, prof, iters=2)[0],
            "chunk": autotune.tune_chunk_size(cfg, prof, iters=2)[0],
            "fori": autotune.tune_fori_seg(at, iters=1)[0],
            "prefix": autotune.tune_prefix_cache(at, iters=1)[0],
            "spec": autotune.tune_speculation(at, iters=1)[0],
        }
        winners.setdefault("runs", []).append(run)
    a, b = winners["runs"]
    assert a == b
    # every span costs one tick -> ties -> each tuner's tie-break wins
    assert a["block"] == 16                     # larger block
    assert a["chunk"] == 2                      # larger chunk (per-token win)
    assert a["fori"] == 4                       # larger segment
    assert a["prefix"] is True                  # ties break toward on
    assert a["spec"] == "ngram:2"               # larger draft_k


def test_kernel_tiles_tile_invariant_off_tpu(tmp_path):
    """Off-TPU every op resolves to the tile-invariant reference kernels:
    tune_kernel_tiles returns no overrides (deterministic CPU CI) but still
    banks that outcome."""
    import jax
    from repro.serving import autotune
    if jax.default_backend() == "tpu":
        pytest.skip("CPU/GPU-only determinism check")
    cfg = get_smoke("llama3.2-1b")
    prof = autotune.ServingProfile(name="tiles", batch_buckets=(2,),
                                   max_seq_len=32, block_sizes=(8,))
    path = str(tmp_path / "tune.jsonl")
    ov, times = autotune.tune_kernel_tiles(cfg, prof, db=path)
    assert ov == () and times == {}
    assert tunedb.TuneDB(path).records("serving")


def test_tile_candidates_registered_for_attention_and_conv():
    from repro.kernels.registry import REGISTRY
    att = REGISTRY.get("attention", "pallas").contract
    cands = att.tile_candidates(get_smoke("llama3.2-1b"),
                                ShapeConfig("t", "prefill", 256, 2))
    assert cands and all(len(c) == 2 for c in cands)       # (bq, bkv)
    conv = REGISTRY.get("conv2d", "pallas").contract
    ccands = conv.tile_candidates(get_smoke("lenet5"),
                                  ShapeConfig("t", "prefill", 32, 2))
    assert ccands and all(len(c) == 2 for c in ccands)     # (bh, bc)


def test_tile_overrides_applied_by_tiling_pass():
    from repro.core.passes import tiling
    cfg = get_smoke("llama3.2-1b")
    flow = FlowConfig(mode="folded",
                      tile_overrides=(("attention", (128, 256)),
                                      ("wkv_chunk", 8)))
    tiles = tiling.run(cfg, ShapeConfig("t", "prefill", 256, 2), flow)
    assert tiles["attention"] == (128, 256)
    assert tiles["wkv_chunk"] == 8
    # an override for a key this cell does not produce is ignored
    flow2 = FlowConfig(mode="folded",
                       tile_overrides=(("attention", (64, 64)),))
    cnn = get_smoke("lenet5")
    tiles2 = tiling.run(cnn, ShapeConfig("t", "prefill", 32, 2), flow2)
    assert tiles2["attention"] == (64, 64) if "attention" in tiles2 else True


# ---------------------------------------------------------------------------
# the maintenance CLI
# ---------------------------------------------------------------------------

def test_launch_tune_cli_show_gc_export(tmp_path, capsys):
    from repro.launch import tune as cli
    path = str(tmp_path / "tune.jsonl")
    db = tunedb.TuneDB(path)
    db.record("explore", {"k": 1}, {"best": (("tile_select", True),)})
    db.put(dataclasses.replace(
        tunedb.TuneRecord.make("serving", {"k": 2}, {"best": 2}),
        code_version="pr0.0"))

    assert cli.main(["show", "--db", path, "-v"]) == 0
    out = capsys.readouterr().out
    assert "records" in out and "STALE" in out and "explore" in out

    exp = str(tmp_path / "dump.json")
    assert cli.main(["export", "--db", path, "--out", exp]) == 0
    doc = json.load(open(exp))
    assert len(doc["records"]) == 2
    assert doc["code_version"] == tunedb.CODE_VERSION

    assert cli.main(["gc", "--db", path]) == 0
    assert len(tunedb.TuneDB(path)) == 1       # stale record dropped
