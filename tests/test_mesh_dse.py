"""Mesh-aware DSE tests: dp/tp/pp factorizations as search dimensions,
divisibility rejection (uneven shards never survive pruning), determinism of
the chosen factorization, mesh-topology cache fingerprinting, and the
measured-time validation path (CompiledModel.measure).  Multi-device smoke
runs live in test_distributed.py (subprocess with forced host devices)."""
import dataclasses

import pytest

from repro import flow as rflow
from repro.configs import get_config, get_smoke
from repro.configs.base import FlowConfig, ShapeConfig, TuningConfig
from repro.core import dse
from repro.core.estimator import estimate_comm_bytes, estimate_step_seconds
from repro.core.passes.sharding import (enumerate_mesh_splits, split_roles,
                                        split_rejection_reason)
from repro.distributed.meshspec import MeshSpec

SMOKE_TRAIN = ShapeConfig("smoke", "train", 16, 4)
TINY_TRAIN = ShapeConfig("tiny", "train", 16, 2)


# ---------------------------------------------------------------------------
# MeshSpec + factorization enumeration
# ---------------------------------------------------------------------------

def test_meshspec_normalizes_every_spelling():
    s1 = MeshSpec.of({"data": 2, "model": 2})
    s2 = MeshSpec.of((("data", 2), ("model", 2)))
    s3 = MeshSpec.of(s1)
    assert s1 == s2 == s3
    assert s1.size == 4 and s1.names == ("data", "model")
    assert s1.describe() == "data:2,model:2"
    with pytest.raises(TypeError):
        MeshSpec.of(42)
    with pytest.raises(ValueError):
        MeshSpec((("data", 2), ("data", 2)))


def test_enumerate_mesh_splits_covers_factorizations():
    splits = enumerate_mesh_splits(4)
    assert splits[0] == (("data", 4), ("model", 1))   # pure DP first
    assert (("data", 2), ("model", 2)) in splits
    assert (("data", 1), ("model", 4)) in splits
    assert len(splits) == 3
    with_pp = enumerate_mesh_splits(8, pp_axis="pod")
    assert any(dict(s).get("pod") == 2 for s in with_pp)
    assert all(MeshSpec.of(s).size == 8 for s in with_pp)
    # the enumerator emits the flow's own axis names
    named = enumerate_mesh_splits(4, dp_axis="batch", tp_axis="mp",
                                  pp_axis="stage")
    assert all(set(dict(s)) <= {"batch", "mp", "stage"} for s in named)
    # no tp axis: everything lands on dp
    assert enumerate_mesh_splits(4, tp_axis=None) == ((("data", 4),),)


def test_split_roles_follow_flow_convention():
    flow = FlowConfig(mode="folded")
    dp, tp, pp = split_roles(flow, (("data", 2), ("model", 2)))
    assert (dp, tp, pp) == (("data",), "model", None)
    # size-1 tp degenerates; the axis then carries data parallelism
    dp, tp, pp = split_roles(flow, (("data", 4), ("model", 1)))
    assert (dp, tp, pp) == (("data", "model"), None, None)
    flow_pp = dataclasses.replace(flow, pp_axis="pod")
    dp, tp, pp = split_roles(flow_pp, (("pod", 2), ("data", 2), ("model", 2)))
    assert (dp, tp, pp) == (("data",), "model", "pod")


# ---------------------------------------------------------------------------
# divisibility rejection (the paper's even-division rule, across devices)
# ---------------------------------------------------------------------------

def test_split_rejection_rejects_uneven_shards():
    cfg = get_smoke("llama3.2-1b")          # d_ff=192, padded vocab 256
    assert split_rejection_reason(cfg, SMOKE_TRAIN, FlowConfig(mode="folded"),
                        (("data", 2), ("model", 2))) is None
    # batch 4 cannot shard over dp=8
    assert "batch" in split_rejection_reason(cfg, SMOKE_TRAIN, FlowConfig(mode="folded"),
                                   (("data", 8), ("model", 1)))
    # CNNs have no tp dimension
    assert "tp" in split_rejection_reason(get_config("lenet5"), SMOKE_TRAIN,
                                FlowConfig(mode="folded"),
                                (("data", 1), ("model", 2)))
    # pp needs an evenly divisible layer stack (smoke llama: 3 layers)
    flow_pp = FlowConfig(mode="folded", pp_axis="pod")
    assert "layers" in split_rejection_reason(cfg, SMOKE_TRAIN, flow_pp,
                                    (("pod", 2), ("data", 2), ("model", 1)))
    # tp is viable as soon as ANY tp-shardable dim divides (the solver
    # shards the first divisible role) — 4 heads divide even when d_ff/vocab
    # don't; tp=5 divides nothing
    assert split_rejection_reason(cfg, SMOKE_TRAIN, FlowConfig(mode="folded"),
                        (("data", 1), ("model", 4))) is None
    assert "divides none" in split_rejection_reason(
        cfg, SMOKE_TRAIN, FlowConfig(mode="folded"),
        (("data", 1), ("model", 5)))


def test_all_splits_rejected_falls_back_to_best_effort():
    """A CNN on 8 devices has no fully-even split (tp idles, batch 2 < dp);
    the screen must readmit everything instead of failing the search — the
    solver simply leaves unusable axes unsharded."""
    cfg = get_config("lenet5")
    r = dse.explore(cfg, TINY_TRAIN, devices=8, use_cache=False)
    assert r.best.flow.mesh_split is not None
    assert r.candidates and r.n_rejected == 0
    assert "sharding:" in r.plan.describe()


def test_uneven_shards_never_survive_pruning():
    """With batch 2 on 8 devices only dp<=2 splits are viable; every pruned
    candidate's split must shard the batch evenly."""
    cfg = get_smoke("llama3.2-1b")
    r = dse.explore(cfg, TINY_TRAIN, devices=8, use_cache=False)
    assert r.n_rejected > 0
    for c in r.candidates:
        split = c.flow.mesh_split
        assert split is not None
        dp_axes, _tp, _pp = split_roles(c.flow, split)
        sizes = dict(split)
        dp = 1
        for a in dp_axes:
            dp *= sizes.get(a, 1)
        assert TINY_TRAIN.global_batch % dp == 0, split
    assert r.best.flow.mesh_split is not None


# ---------------------------------------------------------------------------
# the explorer over mesh factorizations
# ---------------------------------------------------------------------------

def test_mesh_split_is_a_tunable_dimension():
    cfg = get_smoke("llama3.2-1b")
    flow = dataclasses.replace(
        FlowConfig(mode="folded"),
        tuning=TuningConfig(mesh_devices=4))
    space = dse.tunable_space(cfg, flow, SMOKE_TRAIN)
    assert len(space["mesh_split"]) == 3          # 4 = 4x1 | 2x2 | 1x4
    # an explicit mesh pins the dimension (like a pinned backend)
    pinned = dataclasses.replace(flow, mesh_split=(("data", 2), ("model", 2)))
    assert dse.tunable_space(cfg, pinned, SMOKE_TRAIN)["mesh_split"] == \
        ((("data", 2), ("model", 2)),)
    # single device: the mesh is not a dimension at all
    assert "mesh_split" not in dse.tunable_space(
        cfg, FlowConfig(mode="folded"), SMOKE_TRAIN)


def test_explore_mesh_choice_deterministic():
    cfg = get_smoke("llama3.2-1b")
    r1 = dse.explore(cfg, SMOKE_TRAIN, devices=4, use_cache=False)
    r2 = dse.explore(cfg, SMOKE_TRAIN, devices=4, use_cache=False)
    assert r1.best.flow.mesh_split == r2.best.flow.mesh_split
    assert r1.best.flow == r2.best.flow
    assert [c.knobs for c in r1.candidates] == [c.knobs for c in r2.candidates]
    assert r1.plan.describe() == r2.plan.describe()
    assert "sharding:" in r1.plan.describe()


def test_explore_cache_keys_on_mesh_topology():
    """Same device count, different topology => different fingerprint: a
    mesh change in-process must not return a stale plan."""
    cfg = get_smoke("llama3.2-1b")
    dse.clear_explore_cache()
    r1 = dse.explore(cfg, SMOKE_TRAIN, mesh={"data": 2, "model": 2})
    r2 = dse.explore(cfg, SMOKE_TRAIN, mesh={"data": 4, "model": 1})
    assert r1 is not r2
    assert dse.explore_cache_stats() == {"hits": 0, "misses": 2,
                                         "evictions": 0}
    assert dse.explore(cfg, SMOKE_TRAIN, mesh={"data": 2, "model": 2}) is r1
    assert dse.explore_cache_stats()["hits"] == 1
    # and an unmeshed search is yet another entry
    r3 = dse.explore(cfg, SMOKE_TRAIN)
    assert r3 is not r1 and r3 is not r2


def test_explore_with_pinned_mesh_records_sharding():
    cfg = get_smoke("llama3.2-1b")
    r = dse.explore(cfg, SMOKE_TRAIN, mesh={"data": 2, "model": 2},
                    use_cache=False)
    assert r.best.flow.mesh_split == (("data", 2), ("model", 2))
    sp = r.plan.sharding
    assert sp is not None and sp.dp_size == 2 and sp.tp_size == 2
    assert sp.param_specs                      # every param got a decision


# ---------------------------------------------------------------------------
# communication-cost term
# ---------------------------------------------------------------------------

def test_comm_cost_shapes_the_ranking():
    cfg = get_smoke("llama3.2-1b")
    flow = FlowConfig(mode="folded")
    assert estimate_comm_bytes(cfg, SMOKE_TRAIN, flow)["total"] == 0.0
    dp4 = dataclasses.replace(flow, mesh_split=(("data", 4), ("model", 1)))
    tp4 = dataclasses.replace(flow, mesh_split=(("data", 1), ("model", 4)))
    c_dp = estimate_comm_bytes(cfg, SMOKE_TRAIN, dp4)
    c_tp = estimate_comm_bytes(cfg, SMOKE_TRAIN, tp4)
    assert c_dp["all_gather"] > 0 and c_dp["reduce_scatter"] > 0
    assert c_dp["all_reduce"] == 0
    assert c_tp["all_reduce"] > 0 and c_tp["all_gather"] == 0
    st = estimate_step_seconds(cfg, SMOKE_TRAIN, dp4)
    assert st["comm_s"] > 0
    assert st["step_s"] >= st["comm_s"]
    # more data parallelism, more gathered bytes per device
    dp2 = dataclasses.replace(flow, mesh_split=(("data", 2), ("model", 2)))
    assert c_dp["all_gather"] > \
        estimate_comm_bytes(cfg, SMOKE_TRAIN, dp2)["all_gather"]


# ---------------------------------------------------------------------------
# measured-time validation (CompiledModel.measure / validate="measure")
# ---------------------------------------------------------------------------

def test_compiled_model_measure_smoke():
    cm = rflow.compile("llama3.2-1b", ShapeConfig("m", "prefill", 16, 2),
                       smoke=True)
    rec = cm.measure(iters=2)
    assert rec["stage"] == "prefill" and rec["iters"] == 2
    assert rec["measured_step_s"] > 0
    assert rec["mean_step_s"] >= rec["measured_step_s"]
    assert rec["per_device_bytes"] > 0
    assert cm.stats["measure"]["prefill"] is rec
    with pytest.raises(ValueError):
        cm.measure(stage="nope")


def test_explore_ranks_survivors_by_measured_time():
    cfg = get_smoke("llama3.2-1b")
    shape = ShapeConfig("m", "prefill", 16, 2)
    r = dse.explore(cfg, shape,
                    validator=dse.measure_validator(cfg, shape, iters=1),
                    top_k=2, rank_measured=True, use_cache=False)
    assert len(r.validated) == 2               # measured ranking sees all k
    assert all("measured_step_s" in v for v in r.validated)
    fitting = [v for v in r.validated if v["fits"]]
    assert fitting
    chosen = min(fitting, key=lambda v: v["measured_step_s"])
    assert r.best.knob_str() == chosen["knobs"]


def test_compile_validate_measure_end_to_end():
    from repro.core import dse as dse_mod
    dse_mod.clear_explore_cache()
    cm = rflow.compile("llama3.2-1b", ShapeConfig("m", "prefill", 16, 2),
                       smoke=True, autotune=True, validate="measure")
    assert cm.explore_result is not None
    assert all("measured_step_s" in v for v in cm.explore_result.validated)
    with pytest.raises(ValueError):
        rflow.compile("llama3.2-1b", ShapeConfig("m", "prefill", 16, 2),
                      smoke=True, validate="nope")


# ---------------------------------------------------------------------------
# shard_map compat unification guard (single helper in core/compat.py)
# ---------------------------------------------------------------------------

def test_shard_map_compat_is_single_sourced():
    import os
    import re
    root = os.path.join(os.path.dirname(__file__), "..", "src", "repro")
    defs = []
    for dirpath, _dirs, files in os.walk(root):
        for f in files:
            if not f.endswith(".py"):
                continue
            path = os.path.join(dirpath, f)
            with open(path) as fh:
                src = fh.read()
            if re.search(r"^def shard_map\(", src, re.M) or \
                    "jax.experimental.shard_map" in src:
                defs.append(os.path.relpath(path, root))
    assert defs == ["core/compat.py"], defs
    from repro.core import ops_impl
    from repro.distributed import pipeline_parallel
    for mod in (ops_impl, pipeline_parallel):
        import inspect
        assert "from repro.core.compat import shard_map" in \
            inspect.getsource(mod), mod.__name__
