"""Optimization-pass tests: fusion rewrites, folding plans, tile rules."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, get_smoke
from repro.configs.base import FlowConfig, SHAPES, ShapeConfig
from repro.core import lowering
from repro.core.graph import Block, Graph, ParamSpec as P
from repro.core.passes import folding, fusion, tiling
from repro.core.plan import build_plan

from conftest import SMOKE_SHAPE, relerr, smoke_batch


# ---------------------------------------------------------------------------
# LF — fusion
# ---------------------------------------------------------------------------

def _ffn_block():
    b = Block("l", "layer")
    b.add("g", "matmul", "h", params=[P("w1", (8, 16), ("d_model", "d_ff"))])
    b.add("ga", "act", "g", kind="silu")
    b.add("u", "matmul", "h", params=[P("w3", (8, 16), ("d_model", "d_ff"))])
    b.add("gu", "mul", "ga", "u")
    b.add("fo", "matmul", "gu", params=[P("w2", (16, 8), ("d_ff", "d_model"))])
    b.add("h", "add", "h", "fo")
    return b


def test_fusion_glu_and_residual():
    g = Graph("g", [_ffn_block()])
    fusion.run(g, fold_bn=True)
    ops = g.blocks[0].ops
    assert [o.op for o in ops] == ["glu_matmul", "matmul"]
    assert ops[0].attrs["act"] == "silu"
    assert ops[1].attrs.get("residual") is True
    assert ops[1].out == "h"


def test_fusion_bias_then_act():
    b = Block("l", "layer")
    b.add("y", "matmul", "h", params=[P("w", (8, 8), ("d_model", "d_model"))])
    b.add("y", "bias_add", "y", params=[P("b", (8,), ("d_model",), "zeros")])
    b.add("h", "act", "y", kind="gelu")
    g = Graph("g", [b])
    fusion.run(g, fold_bn=True)
    (op,) = g.blocks[0].ops
    assert op.op == "matmul" and op.attrs["bias"] and op.attrs["act"] == "gelu"
    assert len(op.params) == 2


def test_fusion_preserves_semantics():
    """Fused vs unfused lowering of a whole smoke model must agree."""
    cfg = get_smoke("llama3.2-1b")
    batch = smoke_batch(cfg, with_labels=False)
    f_on = build_plan(cfg, FlowConfig(fuse_epilogues=True, precision="fp32",
                                      mode="folded"), SMOKE_SHAPE)
    f_off = build_plan(cfg, FlowConfig(fuse_epilogues=False, precision="fp32",
                                       mode="folded"), SMOKE_SHAPE)
    params = lowering.init_params(f_on, jax.random.key(0))
    y1, _, _ = lowering.make_apply(f_on)(params, batch, mode="prefill")
    y2, _, _ = lowering.make_apply(f_off)(params, batch, mode="prefill")
    assert relerr(y1, y2) < 1e-5


def test_conv_bn_folding_inference_only():
    cfg = get_smoke("mobilenetv1")
    serve = build_plan(cfg, FlowConfig(), SHAPES["prefill_32k"])
    train = build_plan(cfg, FlowConfig(), SHAPES["train_4k"])
    has_bn_fused = any(op.attrs.get("bn") for b in serve.graph.blocks
                       for op in b.ops)
    train_bn_ops = any(op.op == "batchnorm" for b in train.graph.blocks
                       for op in b.ops)
    assert has_bn_fused and train_bn_ops


# ---------------------------------------------------------------------------
# PK — folding
# ---------------------------------------------------------------------------

def test_folding_full_configs():
    plan = build_plan(get_config("qwen1.5-4b"), FlowConfig(),
                      SHAPES["train_4k"])
    folded = [u for u in plan.units if u.folded]
    assert len(folded) == 1 and folded[0].reps == 40


def test_folding_recurrentgemma_superblock():
    plan = build_plan(get_config("recurrentgemma-2b"), FlowConfig(),
                      SHAPES["train_4k"])
    folded = [(u.reps, u.period) for u in plan.units if u.folded]
    assert (8, 3) in folded            # 8 x (rec, rec, attn)
    assert (2, 1) in folded            # the (rec, rec) tail


def test_base_flow_disables_folding():
    flow = FlowConfig().base()
    plan = build_plan(get_smoke("llama3.2-1b"), flow, SMOKE_SHAPE)
    assert not any(u.folded for u in plan.units)
    assert plan.flow.precision == "fp32"


def test_auto_mode_small_is_pipelined():
    plan = build_plan(get_smoke("llama3.2-1b"), FlowConfig(mode="auto"),
                      SMOKE_SHAPE)
    assert plan.stream.mode == "pipelined"
    assert not any(u.folded for u in plan.units)


def test_folded_equals_pipelined():
    """PK folding must not change the math — same params, same output."""
    cfg = get_smoke("llama3.2-1b")
    batch = smoke_batch(cfg, with_labels=False)
    pf = build_plan(cfg, FlowConfig(mode="folded", precision="fp32"),
                    SMOKE_SHAPE)
    pp = build_plan(cfg, FlowConfig(mode="pipelined", precision="fp32"),
                    SMOKE_SHAPE)
    params_f = lowering.init_params(pf, jax.random.key(0))
    params_p = lowering.init_params(pp, jax.random.key(0))
    yf, _, _ = lowering.make_apply(pf)(params_f, batch, mode="prefill")
    yp, _, _ = lowering.make_apply(pp)(params_p, batch, mode="prefill")
    assert relerr(yf, yp) < 1e-5


# ---------------------------------------------------------------------------
# LU/LT — tiling
# ---------------------------------------------------------------------------

def test_tile_divides_and_fits():
    for (m, k, n) in [(4096, 2048, 8192), (512, 14336, 4096),
                      (8, 2048, 102400)]:
        bm, bk, bn = tiling.select_matmul_tile(m, k, n, vmem=24 * 2 ** 20)
        assert m % bm == 0 and k % bk == 0 and n % bn == 0
        ws = (bm * bk + bk * bn) * 2 + bm * bn * 6
        assert ws <= 24 * 2 ** 20
        if n >= 128:
            assert bn % 128 == 0


def test_attention_tile_rules():
    bq, bk = tiling.select_attention_tile(32768, 32768, 128,
                                          vmem=24 * 2 ** 20)
    assert 32768 % bq == 0 and 32768 % bk == 0
    assert bq % 128 == 0 and bk % 128 == 0


def test_base_tiles_are_minimal():
    flow = FlowConfig().base()
    t = tiling.run(get_config("llama3.2-1b"), SHAPES["train_4k"], flow)
    assert t["matmul"] == (128, 128, 128)
