"""Graph-IR unit tests: validation, signatures, iso-group/period detection."""
import pytest

from repro.core.graph import Block, Graph, ParamSpec, iso_groups
from repro.configs import get_smoke, get_config, ARCHS
from repro.models.lm import build_graph


def _blk(tag, d=8):
    b = Block(f"b_{tag}", "layer")
    b.add("y", "matmul", "h",
          params=[ParamSpec(f"w", (d, d), ("d_model", "d_model"))])
    b.add("h", "add", "h", "y")
    return b


def test_validate_rejects_undefined_input():
    b = Block("x", "layer")
    b.add("h", "add", "h", "nope")
    with pytest.raises(AssertionError):
        Graph("g", [b]).validate()


def test_validate_requires_h_output():
    b = Block("x", "layer")
    b.add("z", "identity", "h")
    with pytest.raises(AssertionError):
        Graph("g", [b]).validate()


def test_signature_equal_for_isomorphic_blocks():
    assert _blk("a").signature() == _blk("b").signature()


def test_signature_differs_on_shape():
    assert _blk("a", 8).signature() != _blk("b", 16).signature()


def test_iso_groups_period1():
    blocks = [_blk(i) for i in range(5)]
    assert iso_groups(blocks) == [([0, 1, 2, 3, 4], 1)]


def test_iso_groups_period3_with_tail():
    """(A A B) x2 + (A A) — the RecurrentGemma pattern at small scale."""
    def a(i):
        return _blk(f"a{i}", 8)
    def b(i):
        return _blk(f"b{i}", 16)
    blocks = [a(0), a(1), b(2), a(3), a(4), b(5), a(6), a(7)]
    groups = iso_groups(blocks)
    assert groups[0] == ([0, 1, 2, 3, 4, 5], 3)
    # the tail is one run of period 1
    assert groups[1] == ([6, 7], 1)


def test_param_spec_role_check():
    with pytest.raises(AssertionError):
        ParamSpec("w", (4, 4), ("bogus_role", "d_model"))


@pytest.mark.parametrize("arch", ARCHS)
def test_all_graphs_validate(arch):
    g = build_graph(get_smoke(arch))
    g.validate()


def test_param_counts_match_published():
    """The exact configs must land on the published parameter counts."""
    from repro.core.estimator import count_params
    expected = {  # billions, ±2% (vocab padding, stub frontends)
        "llama3.2-1b": 1.24, "mixtral-8x7b": 46.7, "deepseek-moe-16b": 16.4,
        "qwen1.5-4b": 3.95, "rwkv6-7b": 7.6,
    }
    for arch, want in expected.items():
        got = count_params(get_config(arch)) / 1e9
        assert abs(got - want) / want < 0.02, (arch, got, want)


def test_moe_active_params():
    from repro.core.estimator import count_params
    cfg = get_config("mixtral-8x7b")
    active = count_params(cfg, active_only=True) / 1e9
    assert 12.0 < active < 13.5          # published: 12.9B
