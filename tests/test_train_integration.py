"""Training-loop integration: convergence, grad accumulation, checkpointing,
failure recovery, straggler mitigation."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import FlowConfig, ShapeConfig
from repro.core import lowering
from repro.core.plan import build_plan
from repro.data.pipeline import DataConfig, SyntheticImages, SyntheticLM
from repro.optim.adamw import AdamW
from repro.train import checkpoint as ckpt_lib
from repro.train.trainer import Trainer, TrainerConfig, make_train_step

from conftest import SMOKE_SHAPE, relerr


def _setup(arch="llama3.2-1b", **flow_kw):
    cfg = get_smoke(arch)
    plan = build_plan(cfg, FlowConfig(mode="folded", **flow_kw), SMOKE_SHAPE)
    return cfg, plan


def test_loss_decreases_lm():
    cfg, plan = _setup()
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                  global_batch=8))
    tr = Trainer(plan, AdamW(lr=3e-3, warmup_steps=5, total_steps=60),
                 TrainerConfig(steps=60, log_every=5))
    _, _, hist = tr.fit(data, jax.random.key(0))
    first, last = hist[0][1], hist[-1][1]
    assert last < first - 0.3, hist


def test_loss_decreases_cnn():
    cfg, plan = _setup("lenet5")
    data = SyntheticImages(DataConfig(vocab_size=10, seq_len=0,
                                      global_batch=16),
                           cfg.image_size, cfg.image_channels, 10)
    tr = Trainer(plan, AdamW(lr=1e-3, warmup_steps=5, total_steps=40),
                 TrainerConfig(steps=40, log_every=5))
    _, _, hist = tr.fit(data, jax.random.key(0))
    assert hist[-1][1] < hist[0][1] - 0.2, hist


def test_grad_accumulation_equivalence():
    """microbatches=2 must produce the same update as one full batch."""
    cfg, plan = _setup(precision="fp32")
    opt = AdamW(lr=1e-3, grad_clip=0.0, weight_decay=0.0)
    params = lowering.init_params(plan, jax.random.key(0))
    ostate = opt.init(params)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 16)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 16)),
                                   jnp.int32)}
    s1 = make_train_step(plan, opt, microbatches=1)
    s2 = make_train_step(plan, opt, microbatches=2)
    p1, _, m1 = s1(params, ostate, batch)
    p2, _, m2 = s2(params, ostate, batch)
    # microbatch losses are means of means (equal sizes) -> identical
    err = max(relerr(a, b) for a, b in zip(jax.tree.leaves(p1),
                                           jax.tree.leaves(p2)))
    assert err < 5e-3, (err, float(m1["loss"]), float(m2["loss"]))


def test_checkpoint_roundtrip(tmp_path):
    cfg, plan = _setup()
    params = lowering.init_params(plan, jax.random.key(0))
    opt = AdamW()
    state = opt.init(params)
    ckpt_lib.save(str(tmp_path), 7, {"params": params, "opt": state})
    assert ckpt_lib.latest_step(str(tmp_path)) == 7
    restored = ckpt_lib.restore(str(tmp_path), 7,
                                {"params": params, "opt": state})
    for a, b in zip(jax.tree.leaves(restored["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_async(tmp_path):
    cfg, plan = _setup()
    params = {"w": jnp.ones((4, 4))}
    for s in (1, 2, 3, 4, 5):
        t = ckpt_lib.save(str(tmp_path), s, params, wait=(s < 5), keep=2)
        if t:
            t.join()
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2 and ckpt_lib.latest_step(str(tmp_path)) == 5


def test_failure_recovery(tmp_path):
    """Inject a node failure mid-run; the trainer must restore from the last
    checkpoint and still reach the target step count."""
    cfg, plan = _setup()
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                  global_batch=8))
    tr = Trainer(plan, AdamW(lr=1e-3),
                 TrainerConfig(steps=30, ckpt_dir=str(tmp_path),
                               ckpt_every=10, fail_at_step=17, log_every=5))
    params, _, hist = tr.fit(data, jax.random.key(0))
    assert tr._restarts == 1
    assert max(s for s, _ in hist) >= 25
    assert ckpt_lib.latest_step(str(tmp_path)) == 30


def test_resume_from_checkpoint(tmp_path):
    """A second fit() resumes at the saved step, not from scratch."""
    cfg, plan = _setup()
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                  global_batch=8))
    t1 = Trainer(plan, AdamW(lr=1e-3),
                 TrainerConfig(steps=10, ckpt_dir=str(tmp_path),
                               ckpt_every=5))
    t1.fit(data, jax.random.key(0))
    t2 = Trainer(plan, AdamW(lr=1e-3),
                 TrainerConfig(steps=12, ckpt_dir=str(tmp_path),
                               ckpt_every=5))
    _, _, hist = t2.fit(data, jax.random.key(0))
    assert all(s >= 10 for s, _ in hist)      # resumed past step 10


def test_straggler_substitution():
    """A host missing its deadline serves the previous batch instead of
    stalling (bounded staleness)."""
    cfg = get_smoke("llama3.2-1b")
    slow = DataConfig(vocab_size=cfg.vocab_size, seq_len=8, global_batch=4,
                      deadline_s=0.01, delay_fn=lambda s: 0.05 if s == 3 else 0)
    data = SyntheticLM(slow)
    batches = [data.get(s) for s in range(5)]
    assert data.stale_steps == 1
    np.testing.assert_array_equal(batches[3]["tokens"], batches[2]["tokens"])
    assert not np.array_equal(batches[4]["tokens"], batches[3]["tokens"])


def test_gradient_compression_trains():
    cfg, plan = _setup()
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                  global_batch=8))
    tr = Trainer(plan, AdamW(lr=3e-3, compress="int8_ef", warmup_steps=5,
                             total_steps=40),
                 TrainerConfig(steps=40, log_every=5))
    _, _, hist = tr.fit(data, jax.random.key(0))
    assert hist[-1][1] < hist[0][1] - 0.2, hist


def test_deterministic_data_restart():
    cfg = get_smoke("llama3.2-1b")
    d1 = SyntheticLM(DataConfig(vocab_size=64, seq_len=8, global_batch=4))
    d2 = SyntheticLM(DataConfig(vocab_size=64, seq_len=8, global_batch=4))
    np.testing.assert_array_equal(d1.get(11)["tokens"], d2.get(11)["tokens"])


def test_elastic_host_partitioning():
    """2 hosts' shards concatenate to a deterministic global batch."""
    mk = lambda n, h: SyntheticLM(DataConfig(vocab_size=64, seq_len=8,
                                             global_batch=8, n_hosts=n,
                                             host_id=h))
    one = mk(1, 0).get(3)["tokens"]
    two = np.concatenate([mk(2, 0).get(3)["tokens"],
                          mk(2, 1).get(3)["tokens"]])
    assert one.shape == two.shape  # same global shape under re-partitioning
