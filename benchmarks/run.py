"""Benchmark harness — one function per paper table.

Prints ``name,us_per_call,derived`` CSV rows; the roofline table (from the
dry-run JSON, if present) is appended.  The serving tables (table 9 +
the mixed-traffic A/B) are additionally written machine-readable to
``BENCH_serving.json`` (``--out``); ``--smoke`` runs only those (the CI
artifact step).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

REPO = os.path.join(os.path.dirname(__file__), "..")


def serving_tables(T, concurrencies=(1, 4, 16), tune_db=None) -> dict:
    """Table 9 + the mixed-traffic and speculation A/Bs + the tunedb
    cold-vs-warm autotune comparison, as one payload."""
    table9 = T.table9_serving(concurrencies)
    mixed = T.table9_mixed_traffic()
    spec = T.table9_speculation()
    tunedb = T.table_tunedb_warmstart(tune_db)
    return {"table9": table9, "mixed_traffic": mixed, "speculation": spec,
            "tunedb_warmstart": tunedb}


def print_serving(doc: dict) -> None:
    for r in doc["table9"]:
        print(f"table9/{r['name']}/c{r['concurrency']},"
              f"{r['p50_latency_s'] * 1e6:.0f},"
              f"tok_per_s={r['tokens_per_s']:.1f};"
              f"p50_ms={r['p50_latency_s'] * 1e3:.1f};"
              f"p95_ms={r['p95_latency_s'] * 1e3:.1f};"
              f"ttft_p95_ms={r['p95_ttft_s'] * 1e3:.1f};"
              f"evictions={r['evictions']};refills={r['refills']};"
              f"prefix_hit_rate={r['prefix_hit_rate']:.2f};"
              f"prefill_tok={r['prefill_tokens_computed']};"
              f"syncs_per_tok={r['host_syncs_per_token']:.3f}")
    mt = doc["mixed_traffic"]
    for label in ("baseline", "optimized"):
        r = mt[label]
        print(f"table9/{r['name']},{r['p95_ttft_s'] * 1e6:.0f},"
              f"tok_per_s={r['tokens_per_s']:.1f};"
              f"ttft_p50_ms={r['p50_ttft_s'] * 1e3:.1f};"
              f"ttft_p95_ms={r['p95_ttft_s'] * 1e3:.1f};"
              f"syncs_per_tok={r['host_syncs_per_token']:.3f};"
              f"fori_segments={r['fori_segments']}")
    print(f"table9/mixed/verdict,0,"
          f"p95_ttft_improved={mt['p95_ttft_improved']};"
          f"host_syncs_reduced={mt['host_syncs_reduced']}")
    sp = doc["speculation"]
    for label in ("baseline", "speculative"):
        r = sp[label]
        extra = (f";acceptance_rate={r['acceptance_rate']:.2f};"
                 f"drafted={r['spec_tokens_drafted']};"
                 f"accepted={r['spec_tokens_accepted']};"
                 f"rolled_back={r['spec_rollback_tokens']}"
                 if label == "speculative" else "")
        print(f"table9/{r['name']},{r['p50_latency_s'] * 1e6:.0f},"
              f"tok_per_s={r['tokens_per_s']:.1f};"
              f"p50_ms={r['p50_latency_s'] * 1e3:.1f};"
              f"syncs_per_tok={r['host_syncs_per_token']:.3f}{extra}")
    print(f"table9/spec/verdict,0,"
          f"tokens_match={sp['tokens_match']};"
          f"speedup={sp['speedup']:.2f}x;"
          f"target={sp['target']:.1f}x;target_met={sp['target_met']}")
    td = doc["tunedb_warmstart"]
    print(f"tunedb/warmstart,{td['warm_tuning_s'] * 1e6:.0f},"
          f"cold_s={td['cold_tuning_s']:.2f};"
          f"warm_s={td['warm_tuning_s']:.2f};"
          f"speedup={td['speedup']:.2f}x;"
          f"cold_measured={td['cold_measured']};"
          f"warm_measured={td['warm_measured']};"
          f"flow_identical={td['flow_identical']};"
          f"engine_config_identical={td['engine_config_identical']}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="serving tables only (fast; the CI artifact step)")
    ap.add_argument("--out", default=os.path.join(REPO, "BENCH_serving.json"),
                    help="path for the machine-readable serving benchmark")
    ap.add_argument("--tune-db", default=None,
                    help="persistent autotune store for the cold-vs-warm "
                         "comparison (default: a fresh temp store; pass a "
                         "path to seed/reuse one across runs)")
    args = ap.parse_args(argv)

    from benchmarks import paper_tables as T

    print("name,us_per_call,derived")
    if not args.smoke:
        for name, params, mode, folded, tile in T.table2_resources():
            print(f"table2/{name},0,params={params};mode={mode};"
                  f"folded_layers={folded};tile={tile}")
        for name, mode, passes in T.table3_passes():
            on = "+".join(k for k, v in passes.items() if v)
            print(f"table3/{name},0,mode={mode};passes={on}")
        for name, t_base, t_opt, fps_b, fps_o, speed in T.table4_base_vs_opt():
            print(f"table4/{name}/base,{t_base:.1f},fps={fps_b:.2f}")
            print(f"table4/{name}/optimized,{t_opt:.1f},"
                  f"fps={fps_o:.2f};speedup={speed:.2f}x")
        for name, t_flow, t_hand, speed in T.table5_comparison():
            print(f"table5/{name}/flow,{t_flow:.1f},"
                  f"vs_handwritten={speed:.2f}x")
            print(f"table5/{name}/handwritten_xla,{t_hand:.1f},")
        for name, pname, compact in T.table6_pass_stats():
            print(f"table6/{name}/{pname},0,{compact}")
        for (name, us_b, us_t, fp_b, fp_t, speed, knobs,
             n_pruned, n_compiled) in T.table7_tuned_vs_base():
            print(f"table7/{name}/base,{us_b:.1f},est_bytes={fp_b:.3g}")
            print(f"table7/{name}/tuned,{us_t:.1f},est_bytes={fp_t:.3g};"
                  f"est_speedup={speed:.2f}x;knobs={knobs};"
                  f"pruned={n_pruned};compiled={n_compiled}")
        for (name, label, fp, step, bound,
             comm) in T.table8_sharded_vs_unsharded():
            print(f"table8/{name}/{label},{step * 1e6:.1f},"
                  f"mem_per_dev={fp / 2 ** 30:.2f}GiB;bound={bound};"
                  f"comm_bytes={comm:.3g}")

    doc = serving_tables(T, concurrencies=(1, 4) if args.smoke
                         else (1, 4, 16), tune_db=args.tune_db)
    print_serving(doc)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {os.path.relpath(args.out, REPO)}", file=sys.stderr)

    if not args.smoke:
        res = os.path.join(REPO, "results", "dryrun_baseline.json")
        for cand in (os.path.join(REPO, "results", "dryrun_optimized.json"),
                     res):
            if os.path.exists(cand):
                from benchmarks.roofline import build_table
                rows = build_table(json.load(open(cand)), pods=1)
                for r in rows:
                    step = max(r["compute_s"], r["memory_s"],
                               r["collective_s"])
                    print(f"roofline/{r['arch']}/{r['shape']},"
                          f"{step * 1e6:.0f},"
                          f"dominant={r['dominant']};"
                          f"roofline_frac={r['roofline_frac']:.3f};"
                          f"mem_gib={r['mem_per_dev_gib']:.2f}")
                break


if __name__ == "__main__":
    main()
