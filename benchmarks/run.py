"""Benchmark harness — one function per paper table.

Prints ``name,us_per_call,derived`` CSV rows; the roofline table (from the
dry-run JSON, if present) is appended.
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    from benchmarks import paper_tables as T

    print("name,us_per_call,derived")
    for name, params, mode, folded, tile in T.table2_resources():
        print(f"table2/{name},0,params={params};mode={mode};"
              f"folded_layers={folded};tile={tile}")
    for name, mode, passes in T.table3_passes():
        on = "+".join(k for k, v in passes.items() if v)
        print(f"table3/{name},0,mode={mode};passes={on}")
    for name, t_base, t_opt, fps_b, fps_o, speed in T.table4_base_vs_opt():
        print(f"table4/{name}/base,{t_base:.1f},fps={fps_b:.2f}")
        print(f"table4/{name}/optimized,{t_opt:.1f},"
              f"fps={fps_o:.2f};speedup={speed:.2f}x")
    for name, t_flow, t_hand, speed in T.table5_comparison():
        print(f"table5/{name}/flow,{t_flow:.1f},vs_handwritten={speed:.2f}x")
        print(f"table5/{name}/handwritten_xla,{t_hand:.1f},")
    for name, pname, compact in T.table6_pass_stats():
        print(f"table6/{name}/{pname},0,{compact}")
    for name, us_b, us_t, fp_b, fp_t, speed, knobs in T.table7_tuned_vs_base():
        print(f"table7/{name}/base,{us_b:.1f},est_bytes={fp_b:.3g}")
        print(f"table7/{name}/tuned,{us_t:.1f},est_bytes={fp_t:.3g};"
              f"est_speedup={speed:.2f}x;knobs={knobs}")
    for name, label, fp, step, bound, comm in T.table8_sharded_vs_unsharded():
        print(f"table8/{name}/{label},{step * 1e6:.1f},"
              f"mem_per_dev={fp / 2 ** 30:.2f}GiB;bound={bound};"
              f"comm_bytes={comm:.3g}")
    for (name, n, tps, p50, p95, evi, ref, hit,
         pf_tok) in T.table9_serving():
        print(f"table9/{name}/c{n},{p50 * 1e6:.0f},"
              f"tok_per_s={tps:.1f};p50_ms={p50 * 1e3:.1f};"
              f"p95_ms={p95 * 1e3:.1f};evictions={evi};refills={ref};"
              f"prefix_hit_rate={hit:.2f};prefill_tok={pf_tok}")

    res = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun_baseline.json")
    for cand in (os.path.join(os.path.dirname(__file__), "..", "results",
                              "dryrun_optimized.json"), res):
        if os.path.exists(cand):
            from benchmarks.roofline import build_table
            rows = build_table(json.load(open(cand)), pods=1)
            for r in rows:
                step = max(r["compute_s"], r["memory_s"], r["collective_s"])
                print(f"roofline/{r['arch']}/{r['shape']},{step * 1e6:.0f},"
                      f"dominant={r['dominant']};"
                      f"roofline_frac={r['roofline_frac']:.3f};"
                      f"mem_gib={r['mem_per_dev_gib']:.2f}")
            break


if __name__ == "__main__":
    main()
