"""Benchmarks reproducing the structure of the paper's tables on this
system (CPU-measurable scale; TPU numbers come from the dry-run/roofline).

Table II  — resource utilization       -> params / per-step memory / tiles
Table III — applied optimizations      -> pass-application matrix per network
Table IV  — base vs optimized FPS      -> wall-time of the two flow configs
Table V   — comparison to frameworks   -> our flow vs hand-written jnp/XLA
"""
from __future__ import annotations

import os
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import CNNS, get_config, get_smoke
from repro.configs.base import FlowConfig, SHAPES, ShapeConfig
from repro.core import lowering
from repro.core.estimator import count_params
from repro.core.plan import build_plan

SERVE = ShapeConfig("bench", "prefill", 64, 8)


def _bench(fn, *args, reps=5) -> float:
    """median microseconds per call (jitted, warmed)."""
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else None
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def _cnn_batch(cfg, B=4, seed=0):
    rng = np.random.RandomState(seed)
    return {"images": jnp.asarray(
        rng.randn(B, cfg.image_size, cfg.image_size, cfg.image_channels),
        jnp.float32)}


def _apply_fn(cfg, flow):
    plan = build_plan(cfg, flow, SERVE)
    params = lowering.init_params(plan, jax.random.key(0))
    apply = lowering.make_apply(plan)
    fn = jax.jit(lambda p, b: apply(p, b, mode="prefill")[0])
    return plan, params, fn


# ---------------------------------------------------------------------------

def table2_resources() -> List[Tuple]:
    """Params / flops / plan summary per network (the 'utilization' table)."""
    rows = []
    for name in CNNS + ["llama3.2-1b", "mixtral-8x7b"]:
        cfg = get_config(name)
        plan = build_plan(cfg, FlowConfig(), SHAPES["prefill_32k"]
                          if cfg.family != "cnn" else SERVE)
        folded = sum(u.reps for u in plan.units if u.folded)
        rows.append((name, count_params(cfg), plan.stream.mode,
                     folded, str(plan.tiles.get("matmul"))))
    return rows


def table3_passes() -> List[Tuple]:
    """Which passes apply per network (paper Table III)."""
    rows = []
    for name in CNNS + ["llama3.2-1b"]:
        cfg = get_config(name)
        plan = build_plan(cfg, FlowConfig(mode="auto"), SERVE)
        pk = any(u.folded for u in plan.units)
        rows.append((name, plan.stream.mode,
                     dict(PK=pk, LU_LT=plan.flow.tile_select,
                          LF=plan.flow.fuse_epilogues,
                          CW=plan.cache.vmem_accumulate,
                          OF=plan.flow.precision == "bf16",
                          CH_CE=plan.stream.mode == "pipelined")))
    return rows


def table4_base_vs_opt() -> List[Tuple]:
    """Base (all passes off) vs optimized inference wall time — the paper's
    headline result (Table IV), at CPU-runnable scale."""
    rows = []
    nets = [("lenet5", get_config("lenet5"), 8),
            ("mobilenetv1-64px", get_smoke("mobilenetv1"), 2),
            ("resnet34-64px", get_smoke("resnet34"), 2),
            ("llama3.2-1b-smoke", get_smoke("llama3.2-1b"), 4)]
    for name, cfg, B in nets:
        if cfg.family == "cnn":
            batch = _cnn_batch(cfg, B)
        else:
            batch = {"tokens": jnp.zeros((B, 64), jnp.int32)}
        _, p_base, f_base = _apply_fn(cfg, FlowConfig().base())
        # OF (bf16) targets the MXU; the CPU backend *emulates* bf16, so the
        # wall-time comparison holds precision fixed at fp32 (all other
        # passes on).  The bf16 byte savings show up in the dry-run numbers.
        _, p_opt, f_opt = _apply_fn(cfg, FlowConfig(precision="fp32"))
        t_base = _bench(f_base, p_base, batch)
        t_opt = _bench(f_opt, p_opt, batch)
        fps_base = B / (t_base / 1e6)
        fps_opt = B / (t_opt / 1e6)
        rows.append((name, t_base, t_opt, fps_base, fps_opt,
                     t_base / t_opt))
    return rows


def _lenet_handwritten():
    """Direct jnp LeNet-5 (the 'hand-written framework' comparison point)."""
    rng = jax.random.PRNGKey(0)
    ks = jax.random.split(rng, 5)
    params = {
        "c1": jax.random.normal(ks[0], (5, 5, 1, 6)) * 0.2,
        "c3": jax.random.normal(ks[1], (5, 5, 6, 16)) * 0.09,
        "f5": jax.random.normal(ks[2], (400, 120)) * 0.05,
        "f6": jax.random.normal(ks[3], (120, 84)) * 0.09,
        "out": jax.random.normal(ks[4], (84, 10)) * 0.1,
    }
    def fwd(p, x):
        y = jax.nn.relu(jax.lax.conv_general_dilated(
            x, p["c1"], (1, 1), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC")))
        y = jax.lax.reduce_window(y, 0.0, jax.lax.add, (1, 2, 2, 1),
                                  (1, 2, 2, 1), "SAME") / 4
        y = jax.nn.relu(jax.lax.conv_general_dilated(
            y, p["c3"], (1, 1), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC")))
        y = jax.lax.reduce_window(y, 0.0, jax.lax.add, (1, 2, 2, 1),
                                  (1, 2, 2, 1), "SAME") / 4
        y = y.reshape(y.shape[0], -1)
        y = jax.nn.relu(y @ p["f5"])
        y = jax.nn.relu(y @ p["f6"])
        return y @ p["out"]
    return params, jax.jit(fwd)


def table6_pass_stats() -> List[Tuple]:
    """Per-pass pipeline stats per network (the PassManager's report —
    the paper's per-optimization breakdown, §IV)."""
    rows = []
    for name in CNNS + ["llama3.2-1b"]:
        cfg = get_config(name)
        plan = build_plan(cfg, FlowConfig(mode="auto"), SERVE)
        for pname, st in plan.pass_stats.items():
            if not st.get("applied"):
                rows.append((name, pname, "skipped"))
                continue
            compact = ";".join(f"{k}={v}" for k, v in st.items()
                               if k not in ("applied", "tiles", "groups",
                                            "epilogues"))
            rows.append((name, pname, compact))
    return rows


def table7_tuned_vs_base() -> List[Tuple]:
    """Explorer-tuned vs base flow, by the analytic cost model: predicted
    step time and per-device footprint (the tuned-vs-base delta the paper's
    Table IV measures end-to-end), plus how many candidates the screens
    pruned statically vs how many paid a compile."""
    from repro.core import dse
    from repro.core.estimator import estimate_footprint, estimate_step_seconds
    rows = []
    nets = [(n, get_config(n)) for n in CNNS] + \
        [("llama3.2-1b-smoke", get_smoke("llama3.2-1b"))]
    for name, cfg in nets:
        base = FlowConfig().base()
        fp_b = estimate_footprint(cfg, SERVE, base)
        st_b = estimate_step_seconds(cfg, SERVE, base)
        er = dse.explore(cfg, SERVE, FlowConfig(mode="folded"))
        fp_t, st_t = er.best.footprint_bytes, er.best.step_s
        rows.append((name, st_b["step_s"] * 1e6, st_t * 1e6,
                     fp_b["total"], fp_t, st_b["step_s"] / max(st_t, 1e-12),
                     er.best.knob_str(),
                     er.n_rejected + er.n_static_pruned, len(er.validated)))
    return rows


def table8_sharded_vs_unsharded() -> List[Tuple]:
    """Estimator view of the sharding decision: per-device footprint, step
    time, dominant roof, and collective bytes for the unsharded flow vs
    dp/tp mesh factorizations of 8 devices — the mesh analogue of Table IV's
    base-vs-optimized delta."""
    from repro.core.estimator import (estimate_comm_bytes, estimate_footprint,
                                      estimate_step_seconds)
    rows = []
    shape = SHAPES["train_4k"]
    splits = [("unsharded", None),
              ("dp8", (("data", 8), ("model", 1))),
              ("dp4xtp2", (("data", 4), ("model", 2))),
              ("dp2xtp4", (("data", 2), ("model", 4)))]
    for name in ("llama3.2-1b", "mixtral-8x7b"):
        cfg = get_config(name)
        for label, split in splits:
            flow = FlowConfig(mode="folded", mesh_split=split)
            fp = estimate_footprint(cfg, shape, flow)
            st = estimate_step_seconds(cfg, shape, flow)
            comm = estimate_comm_bytes(cfg, shape, flow)
            rows.append((name, label, fp["total"], st["step_s"],
                         st["bound"], comm["total"]))
    return rows


_SERVING_METRIC_KEYS = (
    "tokens_per_s", "p50_latency_s", "p95_latency_s",
    "p50_ttft_s", "p95_ttft_s", "evictions", "refills",
    "prefix_hit_rate", "prefill_tokens_computed", "catchup_tokens",
    "host_syncs", "host_syncs_per_token", "fori_segments")


def _serving_row(name: str, n: int, metrics: Dict) -> Dict:
    row = {"name": name, "concurrency": n}
    row.update({k: metrics[k] for k in _SERVING_METRIC_KEYS})
    return row


def _serve_compiled():
    from repro import flow as rflow
    from repro.configs.base import ShapeConfig
    cfg = get_smoke("llama3.2-1b")
    cm = rflow.compile(cfg, ShapeConfig("bench_serve", "decode", 64, 4),
                       FlowConfig(mode="folded", precision="fp32"))
    params = cm.init_params(jax.random.PRNGKey(0))
    return cfg, cm, params


def table9_serving(concurrencies: Tuple[int, ...] = (1, 4, 16)
                   ) -> List[Dict]:
    """Serving-subsystem throughput/latency: Engine.run (continuous batching
    over the paged KV pool) at 1/4/16 concurrent requests — tokens/s, p50 and
    p95 request latency and TTFT, host syncs per generated token, the loop's
    eviction/refill counts, and (for the shared-prefix workload rows) the
    prefix-cache hit rate.  Rows are dicts (machine-readable: they land in
    BENCH_serving.json verbatim).

    Two workloads per concurrency: independent random prompts (``uniform``,
    prefix cache off — nothing to share) and a common-system-prompt batch
    (``shared-prefix``) served with the prefix cache on, the workload the
    block index + copy-on-write path exists for."""
    from repro.serving import (Engine, EngineConfig, shared_prefix_requests,
                               synthetic_requests)
    cfg, cm, params = _serve_compiled()
    eng = Engine(cm, params,
                 EngineConfig(max_batch=4, max_seq_len=64, block_size=8))
    eng_px = Engine(cm, params,
                    EngineConfig(max_batch=4, max_seq_len=64, block_size=8,
                                 prefix_cache=True))
    rows = []
    for n in concurrencies:
        for wl, e, reqs in (
                ("uniform", eng,
                 synthetic_requests(n, cfg.vocab_size, prompt_len=8,
                                    max_new_tokens=8, seed=n)),
                ("shared-prefix", eng_px,
                 shared_prefix_requests(n, cfg.vocab_size, prefix_len=24,
                                        tail_len=8, max_new_tokens=8,
                                        seed=n))):
            e.run(reqs)        # warm the tick programs for this concurrency
            m = e.run(reqs).metrics
            rows.append(_serving_row(f"llama3.2-1b-smoke/{wl}", n, m))
    return rows


def table9_mixed_traffic(n_long: int = 6, n_short: int = 18) -> Dict:
    """Mixed-traffic A/B: long cold prompts interleaved with short
    decode-heavy requests, served by the PR-5-era baseline engine
    (batched prefill, per-tick host loop) and by the chunked + host-free
    configuration (``chunked_prefill`` catch-up riding decode ticks,
    ``fori_seg`` on-device segments).  The optimized run must improve p95
    TTFT and cut host syncs per generated token — the wins this PR's two
    perf paths exist for."""
    from repro.serving import Engine, EngineConfig, Request
    cfg, cm, params = _serve_compiled()
    vocab = cfg.vocab_size

    def requests(seed=0):
        rng = np.random.RandomState(seed)
        longs = [Request(f"long{i}",
                         rng.randint(0, vocab, 48).astype(np.int32),
                         max_new_tokens=4) for i in range(n_long)]
        shorts = [Request(f"short{i}",
                          rng.randint(0, vocab, 8).astype(np.int32),
                          max_new_tokens=24) for i in range(n_short)]
        out, si, per = [], 0, max(1, n_short // max(n_long, 1))
        for lg in longs:
            out.append(lg)
            out.extend(shorts[si:si + per])
            si += per
        out.extend(shorts[si:])
        return out

    kw = dict(max_batch=4, max_seq_len=64, block_size=8,
              prompt_buckets=(8, 48, 64))
    configs = {
        "baseline": EngineConfig(**kw),
        "optimized": EngineConfig(**kw, chunked_prefill=True, chunk_size=8,
                                  fori_seg=8),
    }
    out: Dict = {"workload": {
        "n_long": n_long, "long_prompt": 48, "long_new_tokens": 4,
        "n_short": n_short, "short_prompt": 8, "short_new_tokens": 24}}
    for label, ecfg in configs.items():
        eng = Engine(cm, params, ecfg)
        eng.run(requests())                   # warm the tick programs
        m = eng.run(requests()).metrics
        out[label] = _serving_row(f"llama3.2-1b-smoke/mixed/{label}",
                                  n_long + n_short, m)
    out["p95_ttft_improved"] = (out["optimized"]["p95_ttft_s"]
                                < out["baseline"]["p95_ttft_s"])
    out["host_syncs_reduced"] = (out["optimized"]["host_syncs_per_token"]
                                 < out["baseline"]["host_syncs_per_token"])
    return out


def table9_speculation(n: int = 8) -> Dict:
    """Speculative-decoding A/B: the decode-heavy shared-prefix workload
    (generations revisit the shared context — the prompt-lookup drafter's
    home turf) served greedily by the plain per-token engine and by the
    same engine with the n-gram drafter.  Speculation is exact, so beyond
    tokens/s and the acceptance rate the block records ``tokens_match`` —
    byte-identity of every request's output — and whether the >= 1.5x
    decode-throughput target was met."""
    from repro.serving import Engine, EngineConfig, shared_prefix_requests
    cfg, cm, params = _serve_compiled()
    reqs = shared_prefix_requests(n, cfg.vocab_size, prefix_len=24,
                                  tail_len=8, max_new_tokens=96, seed=3)
    kw = dict(max_batch=4, max_seq_len=160, block_size=8)
    spec = "ngram:8"
    out: Dict = {"workload": {"n": n, "prefix_len": 24, "tail_len": 8,
                              "max_new_tokens": 96},
                 "drafter": spec}
    reports = {}
    for label, ecfg in (("baseline", EngineConfig(**kw)),
                        ("speculative", EngineConfig(**kw, speculation=spec))):
        eng = Engine(cm, params, ecfg)
        eng.run(reqs)                         # warm the tick programs
        rep = eng.run(reqs)
        reports[label] = rep
        m = rep.metrics
        row = _serving_row(f"llama3.2-1b-smoke/spec/{label}", n, m)
        row["acceptance_rate"] = m["spec_acceptance_rate"]
        row["spec_tokens_drafted"] = m["spec_tokens_drafted"]
        row["spec_tokens_accepted"] = m["spec_tokens_accepted"]
        row["spec_rollback_tokens"] = m["spec_rollback_tokens"]
        out[label] = row
    out["tokens_match"] = all(
        reports["baseline"].by_id[r.rid].tokens
        == reports["speculative"].by_id[r.rid].tokens for r in reqs)
    out["speedup"] = (out["speculative"]["tokens_per_s"]
                      / out["baseline"]["tokens_per_s"])
    out["target"] = 1.5
    out["target_met"] = bool(out["tokens_match"]
                             and out["speedup"] >= out["target"])
    return out


def table_tunedb_warmstart(db_path: str = None) -> Dict:
    """Cold vs warm serving autotune through the persistent store
    (repro.tunedb): the same ``autotune_decode`` twice against one fresh
    db.  The cold run pays every per-bucket flow-search compile and every
    microbench; the warm run serves exact-fingerprint records, so it must
    measure zero flow candidates and pin a byte-identical flow and
    EngineConfig.  Wall time and measured-candidate counts for both runs
    land machine-readable in BENCH_serving.json."""
    import tempfile
    from repro.core import dse
    from repro.serving.autotune import ServingProfile, autotune_decode
    path = db_path if db_path is not None else os.path.join(
        tempfile.mkdtemp(prefix="tunedb_bench"), "tune.jsonl")
    prof = ServingProfile(name="bench", batch_buckets=(1, 2), max_seq_len=64,
                          block_sizes=(8, 16), chunk_sizes=(1, 2),
                          fori_segs=(0, 4), spec_ks=(0, 2))

    def run():
        t0 = time.perf_counter()
        at = autotune_decode("llama3.2-1b", smoke=True, profile=prof,
                             validate="compile", use_cache=False, db=path)
        return time.perf_counter() - t0, at

    dse.clear_explore_cache()
    cold_s, at_cold = run()
    warm_s, at_warm = run()
    return {
        "db": path,
        "cold_tuning_s": cold_s,
        "warm_tuning_s": warm_s,
        "speedup": cold_s / max(warm_s, 1e-9),
        "cold_measured": at_cold.n_measured,
        "warm_measured": at_warm.n_measured,
        "warm_statuses": {str(b): s
                          for b, s in at_warm.tunedb_statuses.items()},
        "flow_identical": at_cold.flow_for() == at_warm.flow_for(),
        "engine_config_identical":
            at_cold.engine_config() == at_warm.engine_config(),
    }


def table5_comparison() -> List[Tuple]:
    """Our optimized flow vs a hand-written jnp/XLA implementation (the
    'TVM/TensorFlow CPU' stand-in)."""
    cfg = get_config("lenet5")
    B = 8
    batch = _cnn_batch(cfg, B)
    _, p_opt, f_opt = _apply_fn(cfg, FlowConfig())
    t_flow = _bench(f_opt, p_opt, batch)
    hp, hf = _lenet_handwritten()
    t_hand = _bench(hf, hp, batch["images"])
    return [("lenet5", t_flow, t_hand, t_hand / t_flow)]
