"""Roofline analysis (deliverable g): three terms per (arch × shape × mesh)
from the dry-run's compiled artifacts.

  compute term    = HLO_FLOPs / (chips × 197 TFLOP/s bf16)
  memory term     = HLO_bytes / (chips × 819 GB/s HBM)
  collective term = collective_bytes / (chips × 50 GB/s/link ICI)

HLO_FLOPs / collective_bytes come from the optimized-HLO parser with
while-trip multiplication (``cost_analysis`` counts scan bodies once —
probed).  The parser's numbers are *per device* (the SPMD module), so the
terms drop the ``chips ×`` denominator.  HLO_bytes uses the trip-corrected
dot operand/result bytes as the HBM-traffic proxy (matmul-dominated
programs), with the analytic kernel-path estimate as cross-check.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link


def terms(cell: Dict) -> Dict:
    n_dev = 1
    for d in cell["mesh"]:
        n_dev *= d
    fl = cell["hlo"]["flops_hlo"]               # per device
    cb = cell["hlo"]["collective_bytes"]        # per device
    mb = cell["hlo"]["dot_bytes"]               # per device (proxy)
    t_c = fl / PEAK_FLOPS
    t_m = mb / HBM_BW
    t_x = cb / ICI_BW
    dominant = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
    mf = cell.get("model_flops", 0.0)
    useful = mf / (fl * n_dev) if fl else 0.0
    # roofline fraction: useful model FLOPs over the time the dominant term
    # implies at peak
    step_t = max(t_c, t_m, t_x)
    frac = (mf / n_dev / PEAK_FLOPS) / step_t if step_t else 0.0
    return {
        "arch": cell["arch"], "shape": cell["shape"],
        "pods": 2 if cell.get("multi_pod") else 1,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dominant[1],
        "model_flops_ratio": useful,
        "roofline_frac": frac,
        "mem_per_dev_gib": cell["memory"]["per_device_bytes"] / 2 ** 30,
        "fits": cell["memory"].get("fits_budget",
                                   cell["memory"].get("fits_16g")),
        "compile_s": cell.get("compile_s"),
    }


def build_table(results: List[Dict], pods: int = 1) -> List[Dict]:
    out = []
    for c in results:
        if "error" in c or "skipped" in c:
            continue
        if (2 if c.get("multi_pod") else 1) != pods:
            continue
        out.append(terms(c))
    return out


def render(rows: List[Dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'collect_s':>10s} {'dominant':>10s} {'useful':>7s} "
           f"{'roofline':>9s} {'mem GiB':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['compute_s']:10.4f} "
            f"{r['memory_s']:10.4f} {r['collective_s']:10.4f} "
            f"{r['dominant']:>10s} {r['model_flops_ratio']:7.2f} "
            f"{r['roofline_frac']:9.3f} {r['mem_per_dev_gib']:8.2f}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun_baseline.json")
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    with open(args.results) as f:
        results = json.load(f)
    rows = build_table(results, pods=args.pods)
    print(render(rows))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
