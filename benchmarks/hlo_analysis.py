"""Optimized-HLO text analysis: per-program FLOPs, collective bytes and
while-loop trip accounting.

``compiled.cost_analysis()`` counts a scan body ONCE (probed), so folded
(scan-over-layers) programs under-report by the trip count.  This module
parses ``compiled.as_text()`` into computations, extracts

* dot/convolution FLOPs (from output shape × contracted dims),
* collective operand bytes (all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute, counting ``-start`` once),
* while trip counts (the integer bound in the condition computation),

and folds costs up the call graph with trip multiplication — giving the
true per-step totals the roofline needs.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALL1_RE = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")
_CALLN_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(stype: str) -> int:
    """bytes of 'bf16[2,3]{1,0}' or a tuple '(bf16[..], f32[..])'."""
    total = 0
    for m in _SHAPE_RE.finditer(stype):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_dims(stype: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(stype)
    if not m or m.group(1) not in DTYPE_BYTES:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.shapes: Dict[str, str] = {}      # instr name -> type string
        self.flops = 0.0
        self.coll: Dict[str, float] = {}      # collective kind -> bytes
        self.calls: List[Tuple[str, str]] = []  # (kind, computation)
        self.whiles: List[Tuple[str, str]] = []  # (cond, body[, trip])
        self.trip_const: Optional[int] = None  # if this is a condition comp
        self.dot_bytes = 0.0                   # operand+output bytes of dots
        self.convert_src: Dict[str, str] = {}  # convert instr -> source instr


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = None
    for line in text.splitlines():
        s = line.strip()
        if not s:
            continue
        if s.startswith("HloModule"):
            continue
        # computation header: `%name (params...) -> type {` or `ENTRY ...`
        if s.endswith("{") and ("(" in s) and ("=" not in s.split("(")[0]):
            header = s.split("(")[0].strip()
            name = header.replace("ENTRY", "").strip().lstrip("%")
            cur = Computation(name)
            comps[name] = cur
            if "ENTRY" in s:
                entry = name
            continue
        if s == "}" or s.startswith("}"):
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(s)
        if not m:
            continue
        iname, rest = m.group(1), m.group(2)
        if rest.startswith("("):               # tuple-typed output
            depth = 0
            for i, ch in enumerate(rest):
                depth += (ch == "(") - (ch == ")")
                if depth == 0:
                    break
            stype = rest[: i + 1]
        else:
            stype = rest.split(" ", 1)[0]
        cur.shapes[iname] = stype
        body = rest[len(stype):]

        opm = re.match(r"\s*([\w\-]+)\(", body)
        op = opm.group(1) if opm else ""

        if op == "parameter":
            pass
        if op == "constant" and "s32[]" in stype or (op == "constant" and
                                                     "s64[]" in stype):
            cm = re.search(r"constant\((\-?\d+)\)", body)
            if cm:
                v = int(cm.group(1))
                if cur.trip_const is None or v > cur.trip_const:
                    cur.trip_const = v
        if op == "convert":
            srcs = re.findall(r"%([\w.\-]+)", body.split(")", 1)[0])
            if srcs:
                cur.convert_src[iname] = srcs[0]
        if op == "dot":
            out = _shape_dims(stype)
            ops_names = re.findall(r"%([\w.\-]+)", body.split(")", 1)[0])
            lhs_t = cur.shapes.get(ops_names[0]) if ops_names else None
            cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", body)
            if out and lhs_t and cm:
                lhs = _shape_dims(lhs_t)
                contract = 1
                for d in cm.group(1).split(","):
                    if d and lhs:
                        contract *= lhs[1][int(d)]
                n_out = 1
                for d in out[1]:
                    n_out *= d
                cur.flops += 2.0 * n_out * contract
                cur.dot_bytes += _shape_bytes(stype)
                for on in ops_names[:2]:
                    # the CPU backend legalizes bf16 dots by upconverting
                    # operands to f32; charge the pre-convert (TPU-native)
                    # width instead so HBM-byte accounting is target-true.
                    b = _shape_bytes(cur.shapes.get(on, ""))
                    src = cur.convert_src.get(on)
                    if src is not None:
                        sb = _shape_bytes(cur.shapes.get(src, ""))
                        if 0 < sb < b:
                            b = sb
                    cur.dot_bytes += b
        if op == "convolution":
            out = _shape_dims(stype)
            ops_names = re.findall(r"%([\w.\-]+)", body.split(")", 1)[0])
            if out and len(ops_names) >= 2:
                k_t = cur.shapes.get(ops_names[1])
                k = _shape_dims(k_t) if k_t else None
                if k:
                    n_out = 1
                    for d in out[1]:
                        n_out *= d
                    kelems = 1
                    for d in k[1]:
                        kelems *= d
                    # flops ~= 2 * out_elems * (kernel elems / cout)
                    cout = out[1][-1] if out[1] else 1
                    cur.flops += 2.0 * n_out * max(kelems // max(cout, 1), 1)
        for kind in COLLECTIVES:
            if op == kind or op == kind + "-start":
                ops_names = re.findall(r"%([\w.\-]+)", body.split(")", 1)[0])
                b = 0
                for on in ops_names:
                    b += _shape_bytes(cur.shapes.get(on, ""))
                if b == 0:  # fall back to output size
                    b = _shape_bytes(stype)
                cur.coll[kind] = cur.coll.get(kind, 0.0) + b
                break
        if op == "while":
            cm = re.search(r"condition=%?([\w.\-]+)", body)
            bm = re.search(r"body=%?([\w.\-]+)", body)
            tm = _TRIP_RE.search(body)
            if cm and bm:
                cur.whiles.append((cm.group(1), bm.group(1),
                                   int(tm.group(1)) if tm else None))
        else:
            for cm in _CALL1_RE.finditer(body):
                cur.calls.append((op, cm.group(1)))
            for cm in _CALLN_RE.finditer(body):
                for callee in re.split(r"[,\s%]+", cm.group(1)):
                    if callee:
                        cur.calls.append((op, callee))

    comps["__entry__"] = comps.get(entry, Computation("none"))
    return comps


def aggregate(comps: Dict[str, Computation]) -> Dict[str, object]:
    """Fold costs up the call graph from ENTRY, multiplying through whiles."""
    memo: Dict[str, Tuple[float, Dict[str, float], float]] = {}
    trips_seen: List[int] = []

    def cost(name: str, depth=0) -> Tuple[float, Dict[str, float], float]:
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None or depth > 64:
            return 0.0, {}, 0.0
        memo[name] = (0.0, {}, 0.0)            # cycle guard
        fl = c.flops
        co = dict(c.coll)
        db = c.dot_bytes
        for _, callee in c.calls:
            if callee in comps and callee != name:
                f2, c2, d2 = cost(callee, depth + 1)
                fl += f2
                db += d2
                for k, v in c2.items():
                    co[k] = co.get(k, 0.0) + v
        for cond, body, bc_trip in c.whiles:
            trip = bc_trip
            if trip is None:
                trip = comps.get(cond).trip_const if comps.get(cond) else None
            trip = trip if (trip and 0 < trip < 10 ** 7) else 1
            trips_seen.append(trip)
            f2, c2, d2 = cost(body, depth + 1)
            fc, cc, dc = cost(cond, depth + 1)
            fl += f2 * trip + fc * trip
            db += d2 * trip + dc * trip
            for k, v in c2.items():
                co[k] = co.get(k, 0.0) + v * trip
            for k, v in cc.items():
                co[k] = co.get(k, 0.0) + v * trip
        memo[name] = (fl, co, db)
        return memo[name]

    fl, co, db = cost("__entry__")
    return {"flops_hlo": fl, "collectives": co,
            "collective_bytes": sum(co.values()),
            "dot_bytes": db, "while_trips": trips_seen}


def analyze_hlo(text: str) -> Dict[str, object]:
    return aggregate(parse_hlo(text))
