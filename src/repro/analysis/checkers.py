"""The declarative checker suite behind :func:`repro.analysis.verify_plan`.

Each checker is a generator ``(plan, ecfg) -> Iterator[Diagnostic]`` over one
contract family; ``verify_plan`` runs them all against a built
:class:`~repro.core.plan.ExecutionPlan` *without compiling anything* and
returns the collected :class:`VerificationResult`.  Heavy repro imports stay
inside the checker bodies so importing :mod:`repro.analysis` (which the
serving constructors do) never drags in jax-adjacent modules.
"""
from __future__ import annotations

from typing import Any, Iterator, List, Set, Tuple

from repro.analysis import rules
from repro.analysis.diagnostics import (ERROR, WARNING, Diagnostic,
                                        VerificationResult)

# every key the kernel layer or a pass reads out of ``plan.tiles``
_TILE_KEYS = ("matmul", "attention", "decode_attention", "conv2d",
              "wkv_chunk", "ce_chunk")


# ---------------------------------------------------------------------------
# cross-pass contracts (X)
# ---------------------------------------------------------------------------


def check_graph(plan: Any, ecfg: Any = None) -> Iterator[Diagnostic]:
    """X007 — the graph IR's SSA discipline (the assertions ``validate``
    makes fatal, surfaced as a diagnostic instead)."""
    try:
        plan.graph.validate()
    except AssertionError as e:
        yield Diagnostic("X007", ERROR, str(e), where="graph")


def check_units(plan: Any, ecfg: Any = None) -> Iterator[Diagnostic]:
    """X001 — folding units must partition the graph's block indices
    exactly once (a lost or doubled block silently drops/repeats layers)."""
    seen: List[int] = []
    for u in plan.units:
        seen.extend(u.indices)
    want = list(range(len(plan.graph.blocks)))
    if sorted(seen) != want:
        missing = sorted(set(want) - set(seen))
        dup = sorted({i for i in seen if seen.count(i) > 1})
        extra = sorted(set(seen) - set(want))
        yield Diagnostic(
            "X001", ERROR,
            f"units cover blocks {sorted(set(seen))} of {len(want)}: "
            f"missing={missing} duplicated={dup} out_of_range={extra}",
            where="folding")


def check_tiles(plan: Any, ecfg: Any = None) -> Iterator[Diagnostic]:
    """X002 — rule 2 (even division): selected tile dims divide their
    problem dims, so no prologue/epilogue grid steps exist.  X008 — the
    tile table only carries keys some kernel or pass consumes."""
    cfg, shape, tiles = plan.cfg, plan.shape, plan.tiles
    for key in tiles:
        if key not in _TILE_KEYS:
            yield Diagnostic(
                "X008", ERROR,
                f"tile entry {key!r} has no consumer (known: "
                f"{list(_TILE_KEYS)})", where="tiling", op=key)
    if not plan.flow.tile_select:
        return          # base flow: fixed minimal tiles, kernels pad
    seq = shape.seq_len if shape.kind != "decode" else 1
    m = max(seq, 8)
    dims = {"matmul": (("m", m), ("k", cfg.d_model), ("n", cfg.d_ff))}
    if cfg.attention is not None:
        dims["attention"] = (("q", max(seq, 8)), ("kv", shape.seq_len))
    for key, named in dims.items():
        tile = tiles.get(key)
        if tile is None:
            continue
        for (dim_name, dim), t in zip(named, tile):
            if t < 1 or dim % t != 0:
                yield Diagnostic(
                    "X002", ERROR,
                    f"{key} tile {tile}: block {dim_name}={t} does not "
                    f"divide problem dim {dim_name}={dim}",
                    where="tiling", op=key)


def check_stream(plan: Any, ecfg: Any = None) -> Iterator[Diagnostic]:
    """X003 — the stream plan's stage layout stays inside the graph."""
    sp = plan.stream
    n_blocks = len(plan.graph.blocks)
    if sp.mode not in ("folded", "pipelined"):
        yield Diagnostic("X003", ERROR, f"unknown mode {sp.mode!r}",
                         where="streaming")
    if sp.n_stages < 1 or sp.microbatches < 1:
        yield Diagnostic(
            "X003", ERROR,
            f"n_stages={sp.n_stages} microbatches={sp.microbatches} "
            "must both be >= 1", where="streaming")
    bounds = tuple(sp.stage_boundaries)
    if not bounds or any(b < 0 or b >= n_blocks for b in bounds) \
            or list(bounds) != sorted(bounds):
        yield Diagnostic(
            "X003", ERROR,
            f"stage_boundaries {bounds} must be non-empty, ascending and "
            f"within [0, {n_blocks})", where="streaming")


def _iter_param_shapes(plan: Any) -> Iterator[Tuple[str, Tuple[int, ...]]]:
    """(param key, shape) exactly as the ShardingPass/lowering name them."""
    from repro.core.lowering import unit_key
    graph = plan.graph
    for unit in plan.units:
        ukey = unit_key(graph, unit)
        if not unit.folded:
            for s in graph.blocks[unit.indices[0]].param_specs():
                yield f"{ukey}/{s.name}", tuple(s.shape)
        else:
            for j in range(unit.period):
                for s in graph.blocks[unit.indices[j]].param_specs():
                    yield f"{ukey}/{j}:{s.name}", \
                        (unit.reps,) + tuple(s.shape)


def check_sharding(plan: Any, ecfg: Any = None) -> Iterator[Diagnostic]:
    """X004/X005 — every recorded PartitionSpec names mesh axes that exist
    and whose size product divides the sharded dim (jit rejects uneven
    shards at run time; this catches them at plan time)."""
    sp = plan.sharding
    if sp is None:
        return
    axis_sizes = sp.axis_sizes
    shapes = dict(_iter_param_shapes(plan))
    for key, pspec in sp.param_specs.items():
        shape = shapes.get(key)
        for i, entry in enumerate(pspec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            unknown = [a for a in axes if a not in axis_sizes]
            if unknown:
                yield Diagnostic(
                    "X005", ERROR,
                    f"param {key!r} dim {i} names axes {unknown} missing "
                    f"from mesh {sorted(axis_sizes)}",
                    where="sharding", op=key)
                continue
            size = 1
            for a in axes:
                size *= axis_sizes[a]
            if shape is not None and i < len(shape) and shape[i] % size != 0:
                yield Diagnostic(
                    "X004", ERROR,
                    f"param {key!r} dim {i} of size {shape[i]} not "
                    f"divisible by axes {axes} (= {size})",
                    where="sharding", op=key)


def check_kernel_table(plan: Any, ecfg: Any = None) -> Iterator[Diagnostic]:
    """X006 — the plan's kernel table references ops/backends the registry
    knows.  K201 — a pallas resolution must have a registered impl (a ``ref``
    resolution always has one: ops outside the reference table carry their
    fallback inline at the call site)."""
    from repro.kernels.registry import REGISTRY
    known_ops = set(REGISTRY.ops())
    for op, backend in plan.kernels.items():
        if op not in known_ops:
            yield Diagnostic(
                "X006", ERROR,
                f"kernel table references unknown op {op!r}",
                where="kernels", op=op)
            continue
        if backend not in ("ref", "pallas", "pallas_interpret"):
            yield Diagnostic(
                "X006", ERROR,
                f"op {op!r} resolved to unknown backend {backend!r}",
                where="kernels", op=op)
            continue
        if backend != "ref" and not REGISTRY.has(op, backend):
            yield Diagnostic(
                "K201", ERROR,
                f"op {op!r} resolved to {backend!r} but no such impl is "
                f"registered (have: {REGISTRY.backends(op)})",
                where="kernels", op=op)


# ---------------------------------------------------------------------------
# kernel contracts (K)
# ---------------------------------------------------------------------------


def check_kernel_contracts(plan: Any, ecfg: Any = None) -> Iterator[Diagnostic]:
    """The declared :class:`~repro.kernels.registry.KernelContract` of every
    pallas-resolved impl, evaluated against the plan:

    * K202 — the tile's working set fits the flow's VMEM budget,
    * K203 — state donation only reaches donation-safe kernels,
    * K204 — capability predicates that reject on static facts (op attrs /
      cfg) mean a silent dispatch-time fall-through to ref: surfaced as a
      warning with the impl's machine-readable reason.
    """
    from repro.kernels.registry import REGISTRY
    budget = plan.flow.vmem_budget_bytes
    for op, backend in plan.kernels.items():
        if backend not in ("pallas", "pallas_interpret") \
                or not REGISTRY.has(op, backend):
            continue
        impl = REGISTRY.get(op, backend)
        contract = impl.contract
        if contract is None:
            continue
        if contract.tile_key and contract.workingset is not None:
            tile = plan.tiles.get(contract.tile_key)
            if tile is not None:
                ws = contract.workingset(tile, plan.cfg)
                if ws > budget:
                    yield Diagnostic(
                        "K202", ERROR,
                        f"{op} tile {tile} working set {ws} B exceeds "
                        f"vmem_budget_bytes={budget}",
                        where=op)
        if plan.cache.donate_state and not contract.donation_safe:
            yield Diagnostic(
                "K203", ERROR,
                f"{op} declares unsafe input_output_aliases but the plan "
                "donates state (cache.donate_state=True)",
                where=op)
    # static capability rejection: walk the ops the model actually executes
    seen: Set[Tuple[str, str]] = set()
    for block in plan.graph.blocks:
        for mop in block.ops:
            backend = plan.kernels.get(mop.op)
            if backend not in ("pallas", "pallas_interpret") \
                    or not REGISTRY.has(mop.op, backend):
                continue
            contract = REGISTRY.get(mop.op, backend).contract
            if contract is None or contract.static_reject is None:
                continue
            reason = contract.static_reject(mop.attrs, plan.cfg)
            if reason and (mop.op, reason) not in seen:
                seen.add((mop.op, reason))
                yield Diagnostic(
                    "K204", WARNING,
                    f"{mop.op} will fall back to ref at dispatch: {reason}",
                    where=mop.op, op=block.name)


# ---------------------------------------------------------------------------
# mesh-split divisibility (M)
# ---------------------------------------------------------------------------


def check_mesh(plan: Any, ecfg: Any = None) -> Iterator[Diagnostic]:
    """M401–M403 — the even-division screen over the flow's mesh split.
    Warnings, not errors: a pinned uneven split still compiles (the solver
    leaves axes it cannot use unsharded), but it wastes devices."""
    split = plan.flow.mesh_split
    if not split:
        return
    hit = rules.mesh_split_rejection(plan.cfg, plan.shape, plan.flow, split)
    if hit is not None:
        code, reason = hit
        yield Diagnostic(code, WARNING, reason, where="sharding")


# ---------------------------------------------------------------------------
# serving invariants (S) + pool bounds (K205)
# ---------------------------------------------------------------------------


def check_serving(plan: Any, ecfg: Any = None) -> Iterator[Diagnostic]:
    """S301–S307/K205 — the EngineConfig envelope against the shared rules
    (only when an engine config is being verified alongside the plan)."""
    if ecfg is None:
        return
    where = "serving"
    sp = getattr(ecfg, "speculation", None)
    for code, msg in (
            ("S306", rules.chunk_in_range(ecfg.chunk_size, ecfg.max_seq_len)),
            ("S303", rules.fori_seg_valid(ecfg.fori_seg)),
            ("S302", rules.chunk_ladder(ecfg.chunk_buckets, ecfg.chunk_size)),
            ("S304", rules.batch_ladder(ecfg.batch_buckets, ecfg.max_batch)),
            ("S305", rules.prompt_ladder(ecfg.prompt_buckets,
                                         ecfg.max_seq_len)),
            ("S301", rules.block_divides_buckets(ecfg.block_size,
                                                 ecfg.prompt_buckets)),
            ("S307", rules.speculation_valid(
                sp.kind, sp.draft_k, sp.draft_cfg, ecfg.max_seq_len,
                ecfg.fori_seg) if sp is not None else None),
    ):
        if msg is not None:
            yield Diagnostic(code, ERROR, msg, where=where)
    msg = rules.pool_admits_full_slot(ecfg.num_blocks, ecfg.blocks_per_slot)
    if msg is not None:
        yield Diagnostic("K205", ERROR, msg,
                         where="paged_decode_attention")


CHECKERS = (check_graph, check_units, check_tiles, check_stream,
            check_sharding, check_kernel_table, check_kernel_contracts,
            check_mesh, check_serving)


# ---------------------------------------------------------------------------
# pass-pipeline ordering (P)
# ---------------------------------------------------------------------------

_REQUIRED_ARTIFACTS = ("graph", "units", "tiles", "stream", "prec", "cache")


def verify_pipeline(manager: Any) -> VerificationResult:
    """Static ordering check over a :class:`PassManager`: every pass declares
    the plan artifacts it reads/writes; a reader scheduled before its writer
    (P101), or a pipeline that never produces a required artifact (P102), is
    flagged before the pipeline ever runs."""
    res = VerificationResult(n_checks=2)
    written: Set[str] = set()
    for p in manager.passes:
        for key in p.reads:
            if key not in written:
                res.diagnostics.append(Diagnostic(
                    "P101", ERROR,
                    f"pass {p.name!r} reads {key!r} but no earlier pass "
                    f"writes it (written so far: {sorted(written)})",
                    where=p.name, op=key))
        written |= set(p.writes)
    for key in _REQUIRED_ARTIFACTS:
        if key not in written:
            res.diagnostics.append(Diagnostic(
                "P102", ERROR,
                f"pipeline {[p.name for p in manager.passes]} never writes "
                f"required artifact {key!r}",
                where="pipeline", op=key))
    return res


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def verify_plan(plan: Any, cfg: Any = None, shape: Any = None,
                flow: Any = None, *, ecfg: Any = None,
                pipeline: Any = None) -> VerificationResult:
    """Run every checker over ``plan`` without compiling; returns the
    structured diagnostic list.  ``cfg``/``shape``/``flow`` default to the
    plan's own (they exist as overrides so a caller can verify a plan
    against the cell it is *about* to be used for); ``ecfg`` adds the
    serving-invariant checkers; ``pipeline`` adds the pass-ordering check
    for a custom :class:`PassManager`."""
    import dataclasses as _dc
    if cfg is not None or shape is not None or flow is not None:
        plan = _dc.replace(plan) if _dc.is_dataclass(plan) else plan
        if cfg is not None:
            plan.cfg = cfg
        if shape is not None:
            plan.shape = shape
        if flow is not None:
            plan.flow = flow
    res = VerificationResult()
    for checker in CHECKERS:
        res.n_checks += 1
        res.diagnostics.extend(checker(plan, ecfg))
    if pipeline is not None:
        sub = verify_pipeline(pipeline)
        res.n_checks += sub.n_checks
        res.diagnostics.extend(sub.diagnostics)
    return res


def verify_engine_config(plan: Any, ecfg: Any) -> VerificationResult:
    """Serving-only verification: the plan's checkers plus the EngineConfig
    envelope (S-codes, pool bounds)."""
    return verify_plan(plan, ecfg=ecfg)


def static_flow_diagnostics(cfg: Any, shape: Any,
                            flow: Any) -> List[Diagnostic]:
    """The DSE's pre-plan screen: flow-knob validity (F501).  Cheap enough
    to run on every enumerated candidate — no graph build, no passes."""
    out: List[Diagnostic] = []
    msg = rules.flow_knob_rejection(flow)
    if msg is not None:
        out.append(Diagnostic("F501", ERROR, msg, where="flow"))
    return out
