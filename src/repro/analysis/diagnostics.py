"""Structured diagnostics for the static plan/kernel verifier.

Every check in :mod:`repro.analysis` reports through one vocabulary: a
:class:`Diagnostic` with a *stable code* (documented in README §Static
verification and pinned by the negative-test suite), a severity, a
human-readable message, and the provenance of the rule — which pass or
kernel owns the contract that was violated.  The code, not the message, is
the machine interface: messages may be reworded, codes may not.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

ERROR = "error"        # plan would miscompile, crash, or silently fall back
WARNING = "warning"    # plan compiles but a declared contract degrades
SEVERITIES = (ERROR, WARNING)

# code -> one-line meaning; the README table and ``launch/check.py --codes``
# render this, and the negative-test suite asserts every entry fires.
DIAGNOSTIC_CODES: Dict[str, str] = {
    # cross-pass plan coherence (X)
    "X001": "folding units do not partition the graph blocks exactly once",
    "X002": "a selected tile dim does not divide its problem dim (rule 2)",
    "X003": "stream plan stage boundaries/counts are out of range",
    "X004": "a PartitionSpec shards a param dim the mesh axes do not divide",
    "X005": "a PartitionSpec references an axis missing from the mesh",
    "X006": "kernel table references an unknown op or backend",
    "X007": "graph IR is invalid (undefined read, block not ending in 'h')",
    "X008": "tile table carries a key no kernel or pass consumes",
    # pass-pipeline ordering (P)
    "P101": "a pass reads a plan artifact before any pass writes it",
    "P102": "pipeline never writes a required plan artifact",
    # kernel contracts (K)
    "K201": "plan resolves an op to a backend with no registered impl",
    "K202": "kernel tile working set exceeds the flow's VMEM budget",
    "K203": "donated state reaches a kernel declared donation-unsafe",
    "K204": "capability predicate statically rejects; op falls back to ref",
    "K205": "paged pool too small for one slot's block chain (gather bounds)",
    # serving invariants (S) — shared with EngineConfig/ServingProfile
    "S301": "block_size does not divide every prompt bucket",
    "S302": "chunk-bucket ladder malformed (rung 1 / final rung / positive)",
    "S303": "fori_seg must be 0 (off) or >= 2",
    "S304": "batch-bucket ladder malformed (positive / ends at max_batch)",
    "S305": "prompt-bucket ladder malformed (positive / within max_seq_len)",
    "S306": "chunk_size outside [1, max_seq_len]",
    "S307": "speculation config invalid (drafter kind / draft_k / "
            "draft cfg / fori_seg clash)",
    # mesh-split divisibility (M) — shared with split_rejection_reason
    "M401": "global batch not divisible by the dp factor",
    "M402": "tp factor divides none of the tp-shardable dims",
    "M403": "pp factor invalid for this cell (non-train or uneven layers)",
    # flow-level knob screen (F) — the DSE's pre-plan static pruner
    "F501": "flow knob holds a value no pass or registry accepts",
    # persistent autotune store (T) — repro.tunedb records
    "T601": "tunedb record no longer verifies against the current plan "
            "(stale knobs / search space / code version); re-measuring",
}


@dataclass(frozen=True)
class Diagnostic:
    """One verifier finding.  ``where`` names the owning pass (``tiling``,
    ``sharding``, ...) or kernel (``attention``); ``op`` narrows to the
    graph op or config field when one is implicated."""
    code: str
    severity: str
    message: str
    where: str = ""
    op: str = ""

    def __post_init__(self) -> None:
        if self.code not in DIAGNOSTIC_CODES:
            raise ValueError(f"unknown diagnostic code {self.code!r}")
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}")

    def format(self) -> str:
        loc = self.where + (f":{self.op}" if self.op else "")
        return f"[{self.code}] {self.severity} {loc}: {self.message}"


@dataclass
class VerificationResult:
    """The outcome of one :func:`repro.analysis.verify_plan` run."""
    diagnostics: List[Diagnostic] = field(default_factory=list)
    n_checks: int = 0                    # checker functions executed

    @property
    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == ERROR]

    @property
    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == WARNING]

    @property
    def ok(self) -> bool:
        return not self.errors

    def codes(self) -> Tuple[str, ...]:
        return tuple(d.code for d in self.diagnostics)

    def summary_line(self) -> str:
        """One deterministic line for ``plan.describe()`` / the check CLI."""
        if not self.diagnostics:
            return f"ok ({self.n_checks} checks)"
        e, w = self.errors, self.warnings
        parts = []
        if e:
            parts.append(f"{len(e)} errors [" +
                         " ".join(sorted({d.code for d in e})) + "]")
        if w:
            parts.append(f"{len(w)} warnings [" +
                         " ".join(sorted({d.code for d in w})) + "]")
        status = "FAIL" if e else "ok"
        return f"{status} ({self.n_checks} checks, " + ", ".join(parts) + ")"

    def describe(self) -> str:
        lines = [self.summary_line()]
        lines += ["  " + d.format() for d in self.diagnostics]
        return "\n".join(lines)


class PlanVerificationError(ValueError):
    """Raised by ``flow.compile(verify=True)`` before any jit when the plan
    fails static verification; carries the full result."""

    def __init__(self, result: VerificationResult) -> None:
        self.result = result
        super().__init__("plan failed static verification:\n"
                         + result.describe())
