"""repro.analysis — static verification of execution plans, kernel
contracts, and serving invariants.

The paper's compilation flow is safe to automate because every optimization
is checked before synthesis; this package is that gate for the repro stack.
``verify_plan(plan)`` runs a suite of declarative checkers over a built
:class:`~repro.core.plan.ExecutionPlan` *without compiling* and returns
structured :class:`Diagnostic` objects (stable code, severity, provenance).
It is wired in three places:

* ``repro.flow.compile(verify=True)`` raises :class:`PlanVerificationError`
  with the full diagnostic list before any jit;
* ``repro.core.dse.explore`` statically prunes invalid candidates before
  compile-in-the-loop validation (``ExploreResult.n_static_pruned``);
* ``python -m repro.launch.check --cfg lenet5`` runs it from CI.

:mod:`repro.analysis.rules` is additionally the single source of truth for
the serving/mesh invariants that ``EngineConfig.__post_init__``,
``ServingProfile`` and ``split_rejection_reason`` used to duplicate.
"""
from repro.analysis.diagnostics import (  # noqa: F401
    DIAGNOSTIC_CODES, ERROR, WARNING, Diagnostic, PlanVerificationError,
    VerificationResult)
from repro.analysis.checkers import (  # noqa: F401
    CHECKERS, static_flow_diagnostics, verify_engine_config, verify_pipeline,
    verify_plan)
from repro.analysis import rules  # noqa: F401

__all__ = [
    "CHECKERS", "DIAGNOSTIC_CODES", "Diagnostic", "ERROR",
    "PlanVerificationError", "VerificationResult", "WARNING", "rules",
    "static_flow_diagnostics", "verify_engine_config", "verify_pipeline",
    "verify_plan",
]
