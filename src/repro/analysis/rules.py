"""Shared invariant rules — the single source of truth for checks that used
to be duplicated across ``EngineConfig.__post_init__``, ``ServingProfile``
and ``split_rejection_reason``.

Each rule is a pure function returning ``None`` when the invariant holds or
the exact message the legacy call site raised (error text is part of the
test surface).  Constructors keep raising ``ValueError(msg)``; the verifier
wraps the same messages in :class:`~repro.analysis.diagnostics.Diagnostic`
objects, so a rule can never drift between the two consumers.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# serving ladders (EngineConfig.__post_init__)
# ---------------------------------------------------------------------------


def chunk_in_range(chunk_size: int, max_seq_len: int) -> Optional[str]:
    if not 1 <= chunk_size <= max_seq_len:
        return (f"chunk_size must be in [1, max_seq_len="
                f"{max_seq_len}], got {chunk_size}")
    return None


def fori_seg_valid(fori_seg: int) -> Optional[str]:
    if fori_seg == 1 or fori_seg < 0:
        return f"fori_seg must be 0 (off) or >= 2, got {fori_seg}"
    return None


def chunk_ladder(chunk_buckets: Sequence[int],
                 chunk_size: int) -> Optional[str]:
    """Rungs of the per-tick chunk ladder: positive, rung 1 first (plain
    decode ticks), final rung == chunk_size.  ``chunk_buckets`` is the
    normalized (sorted, deduped) ladder."""
    buckets = tuple(chunk_buckets)
    if any(b < 1 for b in buckets):
        return "chunk buckets must be positive"
    if not buckets or buckets[0] != 1:
        return ("chunk_buckets must include rung 1 (plain decode "
                f"ticks), got {buckets}")
    if buckets[-1] != chunk_size:
        return (f"chunk_buckets must end at chunk_size="
                f"{chunk_size}, got {buckets}")
    return None


def batch_ladder(batch_buckets: Sequence[int], max_batch: int) -> Optional[str]:
    buckets = tuple(batch_buckets)
    if any(b < 1 for b in buckets):
        return "batch buckets must be positive"
    if not buckets or buckets[-1] != max_batch:
        return (f"batch_buckets must end at max_batch={max_batch}, "
                f"got {buckets}")
    return None


def prompt_ladder(prompt_buckets: Sequence[int],
                  max_seq_len: int) -> Optional[str]:
    buckets = tuple(prompt_buckets)
    if any(b < 1 for b in buckets):
        return "prompt buckets must be positive"
    if buckets and buckets[-1] > max_seq_len:
        return f"prompt buckets exceed max_seq_len={max_seq_len}"
    return None


def block_divides_buckets(block_size: int,
                          prompt_buckets: Sequence[int]) -> Optional[str]:
    """The paged pool packs prompt K/V block-by-block and the prefix index
    hashes block-aligned runs: every prompt-bucket rung must be a whole
    number of blocks."""
    bad = [b for b in prompt_buckets if b % block_size]
    if bad:
        return (f"block_size={block_size} must divide every prompt "
                f"bucket; offending rungs {bad} (of "
                f"{list(prompt_buckets)})")
    return None


def speculation_valid(kind: str, draft_k: int, draft_cfg: Any,
                      max_seq_len: int, fori_seg: int) -> Optional[str]:
    """The EngineConfig.speculation envelope: a known drafter kind, a
    verify cell that fits the sequence envelope, a named draft config when
    the drafter is a model, and no fori segments (acceptance is decided on
    the host every tick, so a host-free segment can never carry a
    speculative slot)."""
    kinds = ("ngram", "draft", "null")
    if kind not in kinds:
        return (f"speculation drafter kind must be one of {kinds}, "
                f"got {kind!r}")
    if draft_k < 1:
        return f"speculation draft_k must be >= 1, got {draft_k}"
    if draft_k + 1 > max_seq_len:
        return (f"speculation draft_k={draft_k} needs a (B, {draft_k + 1}) "
                f"verify cell, beyond max_seq_len={max_seq_len}")
    if kind == "draft" and not draft_cfg:
        return ("speculation kind 'draft' needs a draft model config name "
                "(draft:<cfg>:<k>)")
    if fori_seg:
        return (f"speculation and fori_seg={fori_seg} are mutually "
                "exclusive: acceptance is decided on the host every tick")
    return None


def pool_admits_full_slot(num_blocks: Optional[int],
                          blocks_per_slot: int) -> Optional[str]:
    """Scalar-prefetch bounds for the paged decode kernel: the block-table
    gather indexes ``[0, num_blocks)``; a pool smaller than one slot's full
    chain plus the trash block can never admit a max-length request, and
    block 0 (trash) must always exist."""
    if num_blocks is None:               # full provisioning — always admits
        return None
    need = 1 + blocks_per_slot
    if num_blocks < need:
        return (f"num_blocks={num_blocks} cannot hold one slot's chain: "
                f"need >= {need} (blocks_per_slot={blocks_per_slot} + the "
                "trash block) for in-bounds block-table gathers")
    return None


# ---------------------------------------------------------------------------
# serving profiles (ServingProfile.__post_init__ — candidate sets)
# ---------------------------------------------------------------------------


def profile_batch_buckets(batch_buckets: Sequence[int]) -> Optional[str]:
    buckets = tuple(batch_buckets)
    if not buckets or tuple(sorted(buckets)) != buckets:
        return "batch_buckets must be ascending and non-empty"
    if any(b < 1 for b in buckets):
        return "batch_buckets must be positive"
    return None


def profile_block_sizes(block_sizes: Sequence[int],
                        max_seq_len: int) -> Optional[str]:
    sizes = tuple(block_sizes)
    if any(b < 1 or b > max_seq_len for b in sizes):
        return "block sizes must be in [1, max_seq_len]"
    if any(max_seq_len % b for b in sizes):
        return ("every candidate block size must divide max_seq_len "
                "(EngineConfig requires whole-block prompt buckets); got "
                f"{sizes} vs max_seq_len={max_seq_len}")
    return None


def profile_chunk_sizes(chunk_sizes: Sequence[int],
                        max_seq_len: int) -> Optional[str]:
    sizes = tuple(chunk_sizes)
    if not sizes or any(k < 1 or k > max_seq_len for k in sizes):
        return (f"chunk sizes must be in [1, max_seq_len]; got "
                f"{sizes}")
    return None


def profile_fori_segs(fori_segs: Sequence[int]) -> Optional[str]:
    segs = tuple(fori_segs)
    if any(s == 1 or s < 0 for s in segs):
        return (f"fori segment candidates must be 0 (off) or >= 2; got "
                f"{segs}")
    return None


def profile_spec_ks(spec_ks: Sequence[int],
                    max_seq_len: int) -> Optional[str]:
    ks = tuple(spec_ks)
    if not ks or any(k < 0 or k + 1 > max_seq_len for k in ks):
        return ("speculation draft_k candidates must be 0 (off) or fit a "
                f"(B, k+1) verify cell within max_seq_len={max_seq_len}; "
                f"got {ks}")
    return None


# ---------------------------------------------------------------------------
# mesh-split divisibility (split_rejection_reason / the DSE screen)
# ---------------------------------------------------------------------------


def mesh_split_rejection(cfg: Any, shape: Any, flow: Any,
                         split: Tuple[Tuple[str, int], ...]
                         ) -> Optional[Tuple[str, str]]:
    """The paper's even-division rule across devices, as (code, reason).

    ``M401`` — global batch vs the dp factor; ``M402`` — tp vs the
    tp-shardable dims; ``M403`` — pp applicability.  ``None`` means the
    split yields even shards everywhere."""
    from repro.core.passes.sharding import split_roles
    sizes = dict(split)
    dp_axes, tp_axis, pp_axis = split_roles(flow, split)
    dp = 1
    for a in dp_axes:
        dp *= sizes.get(a, 1)
    tp = sizes.get(tp_axis, 1) if tp_axis else 1
    pp = sizes.get(pp_axis, 1) if pp_axis else 1
    if shape.global_batch % dp != 0:
        return "M401", f"batch {shape.global_batch} not divisible by dp={dp}"
    if tp > 1:
        if cfg.family == "cnn":
            return "M402", "tp axis would idle for the cnn family"
        # the solver shards the first divisible TP_ROLE dim — viable as soon
        # as any of them divides
        dims = ([cfg.moe.num_experts] if cfg.moe else []) + \
            [cfg.d_ff, cfg.padded_vocab] + \
            ([cfg.attention.n_heads] if cfg.attention else [])
        if not any(d % tp == 0 for d in dims):
            return ("M402",
                    f"tp={tp} divides none of the tp-shardable dims {dims}")
    if pp > 1:
        if shape.kind != "train" or cfg.family == "cnn":
            return "M403", "pp applies to LM train cells only"
        if cfg.n_layers % pp != 0:
            return "M403", f"{cfg.n_layers} layers not divisible by pp={pp}"
    return None


# ---------------------------------------------------------------------------
# flow-level knob screen (the DSE's pre-plan static pruner)
# ---------------------------------------------------------------------------

_PRECISIONS = ("bf16", "fp32")
_MODES = ("auto", "folded", "pipelined")


def flow_knob_rejection(flow: Any) -> Optional[str]:
    """Cheap validity screen over one ``FlowConfig`` — every violation here
    would crash or nonsense a later pass, so the explorer drops the
    candidate before building (let alone compiling) a plan."""
    from repro.kernels.registry import canon_backend
    try:
        canon_backend(flow.kernel_backend)
    except ValueError as e:
        return str(e)
    if flow.precision not in _PRECISIONS:
        return (f"precision must be one of {_PRECISIONS}, "
                f"got {flow.precision!r}")
    if flow.mode not in _MODES:
        return f"mode must be one of {_MODES}, got {flow.mode!r}"
    if flow.microbatches < 1:
        return f"microbatches must be >= 1, got {flow.microbatches}"
    if flow.scan_unroll < 1:
        return f"scan_unroll must be >= 1, got {flow.scan_unroll}"
    if flow.ce_chunk < 1:
        return f"ce_chunk must be >= 1, got {flow.ce_chunk}"
    if flow.vmem_budget_bytes < 1:
        return (f"vmem_budget_bytes must be positive, got "
                f"{flow.vmem_budget_bytes}")
    if flow.tile_overrides is not None:
        from repro.core.passes.tiling import TILE_KEYS
        try:
            pairs = tuple(flow.tile_overrides)
        except TypeError:
            return (f"tile_overrides must be a sequence of "
                    f"(tile_key, tile) pairs, got "
                    f"{flow.tile_overrides!r}")
        for pair in pairs:
            if not (isinstance(pair, (tuple, list)) and len(pair) == 2):
                return (f"tile_overrides entries must be (tile_key, tile) "
                        f"pairs, got {pair!r}")
            key = pair[0]
            if key not in TILE_KEYS:
                return (f"tile_overrides key {key!r} is not a known tile "
                        f"key {TILE_KEYS}")
    return None
