"""Training loop: sharded train_step, microbatched gradient accumulation,
checkpoint/restart, failure injection + automatic recovery, straggler-aware
data loading.  The step itself is a single donated jit program — the paper's
autorun analogue (no host round-trips inside a step)."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import lowering
from repro.core.plan import ExecutionPlan
from repro.optim.adamw import AdamW, AdamWState
from repro.train import checkpoint as ckpt_lib


@dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    async_ckpt: bool = False
    log_every: int = 10
    # fault-tolerance test hooks
    fail_at_step: Optional[int] = None        # inject a failure once
    max_restarts: int = 2


def make_train_step(plan: ExecutionPlan, opt: AdamW, microbatches: int = 1):
    loss_fn = lowering.make_loss_fn(plan)

    def train_step(params, opt_state: AdamWState, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def mb_slice(i, b):
                return jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // microbatches),
                        x.shape[0] // microbatches), b)

            def one(i, carry):
                gacc, lacc = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb_slice(i, batch))
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g)
                return gacc, lacc + l
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, lsum = jax.lax.fori_loop(0, microbatches, one, (g0, 0.0))
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = lsum / microbatches
            metrics = {}
        params, opt_state, om = opt.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


class Trainer:
    def __init__(self, plan: ExecutionPlan, opt: AdamW,
                 tcfg: TrainerConfig, mesh=None, rules=None):
        # the launch layer hands us a repro.flow.CompiledModel; plan-based
        # construction stays for core-level tests and the legacy shims
        from repro.flow import CompiledModel
        if isinstance(plan, CompiledModel):
            mesh = mesh if mesh is not None else plan.mesh
            rules = rules if rules is not None else plan.rules
            plan = plan.plan
        self.plan, self.opt, self.tcfg = plan, opt, tcfg
        self.mesh, self.rules = mesh, rules
        self.step_fn = None
        self._restarts = 0

    # -- setup ----------------------------------------------------------------
    def init(self, rng) -> tuple:
        params = lowering.init_params(self.plan, rng)
        opt_state = self.opt.init(params)
        if self.rules is not None:
            psh = self.rules.params_shardings(self.plan)
            params = jax.tree.map(jax.device_put, params, psh)
            osh = AdamWState(
                jax.device_put(opt_state.step),
                jax.tree.map(jax.device_put, opt_state.mu, psh),
                jax.tree.map(jax.device_put, opt_state.nu, psh),
                None if opt_state.err is None else
                jax.tree.map(jax.device_put, opt_state.err, psh))
            opt_state = osh
        return params, opt_state

    def compile_step(self, microbatches: int = 1):
        fn = make_train_step(self.plan, self.opt, microbatches)
        donate = (0, 1)
        if self.mesh is not None:
            with self.mesh:
                self.step_fn = jax.jit(fn, donate_argnums=donate)
        else:
            self.step_fn = jax.jit(fn, donate_argnums=donate)
        return self.step_fn

    # -- main loop with restart-on-failure -------------------------------------
    def fit(self, data, rng, hooks: Dict[str, Callable] = ()):
        tcfg = self.tcfg
        params, opt_state = self.init(rng)
        start = 0
        if tcfg.ckpt_dir:
            last = ckpt_lib.latest_step(tcfg.ckpt_dir)
            if last is not None:
                params, opt_state = self.restore(last, params, opt_state)
                start = last
        if self.step_fn is None:
            self.compile_step(max(self.plan.flow.microbatches, 1))
        history = []
        step = start
        while step < tcfg.steps:
            try:
                batch = {k: jnp.asarray(v) for k, v in data.get(step).items()}
                if (tcfg.fail_at_step is not None and step == tcfg.fail_at_step
                        and self._restarts == 0):
                    self._restarts += 1
                    raise RuntimeError("injected node failure")
                params, opt_state, metrics = self.step_fn(
                    params, opt_state, batch)
                if step % tcfg.log_every == 0:
                    history.append((step, float(metrics["loss"])))
                if tcfg.ckpt_dir and (step + 1) % tcfg.ckpt_every == 0:
                    ckpt_lib.save(tcfg.ckpt_dir, step + 1,
                                  {"params": params, "opt": opt_state},
                                  wait=not tcfg.async_ckpt)
                step += 1
            except RuntimeError as e:
                # node failure: restore from the last checkpoint and continue
                if self._restarts > tcfg.max_restarts or not tcfg.ckpt_dir:
                    raise
                last = ckpt_lib.latest_step(tcfg.ckpt_dir)
                if last is None:
                    params, opt_state = self.init(rng)
                    step = 0
                else:
                    params, opt_state = self.restore(last, params, opt_state)
                    step = last
        return params, opt_state, history

    def restore(self, step, params_like, opt_like):
        shardings = None
        if self.rules is not None:
            psh = self.rules.params_shardings(self.plan)
            shardings = {"params": psh, "opt": AdamWState(
                None, psh, psh, None if opt_like.err is None else psh)}
        tree = ckpt_lib.restore(self.tcfg.ckpt_dir, step,
                                {"params": params_like, "opt": opt_like},
                                shardings)
        return tree["params"], tree["opt"]
