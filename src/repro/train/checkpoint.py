"""Mesh-agnostic checkpointing with async save and elastic restore.

Layout: one ``.npy`` per pytree leaf (path-encoded filename) plus
``meta.json``.  Arrays are written as *global* logical arrays, so a restore
may re-shard onto a different mesh (elastic scaling) — the restore path takes
a sharding tree and ``device_put``s each leaf.  Saves go through a temp dir +
atomic rename (a crash mid-save never corrupts the latest checkpoint), and
can run on a background thread (async checkpointing).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_seg(p) for p in path)
        flat[key] = leaf
    return flat


def _seg(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(ckpt_dir: str, step: int, tree, wait: bool = True,
         keep: int = 3) -> Optional[threading.Thread]:
    """Write checkpoint for ``step``.  With wait=False, runs in background."""
    flat = _flatten(tree)
    # fetch to host while the caller's arrays are still alive
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

    def _write():
        tmp = os.path.join(ckpt_dir, f".tmp_{step}")
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        names = {}
        for i, (k, v) in enumerate(host.items()):
            fn = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fn), v)
            names[k] = fn
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "leaves": names}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep)

    if wait:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like, shardings=None):
    """Restore into the structure of ``like``; re-shard with ``shardings``
    (a matching pytree of NamedSharding, or None for default placement) —
    the elastic-scaling path: the saved mesh need not match."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    flat_like = _flatten(like)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    out = {}
    for k, leaf in flat_like.items():
        arr = np.load(os.path.join(d, meta["leaves"][k]))
        arr = arr.astype(leaf.dtype)
        if k in flat_sh:
            out[k] = jax.device_put(arr, flat_sh[k])
        else:
            out[k] = jax.device_put(arr)
    # unflatten back into the structure of `like`
    treedef = jax.tree_util.tree_structure(like)
    keys = list(_flatten(like).keys())
    return jax.tree_util.tree_unflatten(treedef, [out[k] for k in keys])
