"""Graph builders for the LM-family architectures (all ten assigned archs)."""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.core.graph import Block, Graph, ParamSpec as P
from repro.models.layers import (
    emit_attention, emit_glu_ffn, emit_mlp_ffn, emit_moe_ffn,
    emit_rglru_block, emit_rwkv6_channelmix, emit_rwkv6_timemix,
)


def _embed_block(cfg: ModelConfig, scale: bool) -> Block:
    b = Block("embed", "embed")
    b.add("h", "embed", "h",
          params=[P("table", (cfg.padded_vocab, cfg.d_model), ("vocab", "d_model"),
                    "embed")],
          scale_by_sqrt_d=scale)
    return b


def _head_block(cfg: ModelConfig, tied_ref: str = "embed/table") -> Block:
    b = Block("head", "head")
    params = [P("final_norm_scale", (cfg.d_model,), ("d_model",), "ones")]
    if cfg.norm_kind == "layernorm":
        params.append(P("final_norm_bias", (cfg.d_model,), ("d_model",), "zeros"))
    b.add("hn", "norm", "h", params=params, kind=cfg.norm_kind, eps=cfg.norm_eps)
    if cfg.tie_embeddings:
        b.add("h", "unembed", "hn", tied=tied_ref,
              true_vocab=cfg.vocab_size)
    else:
        b.add("h", "unembed", "hn",
              params=[P("lm_head", (cfg.padded_vocab, cfg.d_model),
                        ("vocab", "d_model"), "embed")],
              true_vocab=cfg.vocab_size)
    return b


def _decoder_layer(cfg: ModelConfig, li: int, kind: str) -> Block:
    b = Block(f"layer{li}", "layer", attrs={"index": li, "mix": kind})
    # temporal mixing
    if kind == "attn":
        emit_attention(b, cfg, cfg.attention, li)
    elif kind == "local_attn":
        a = cfg.attention
        emit_attention(b, cfg, a, li)
    elif kind == "rec":
        if cfg.recurrence.kind == "rg_lru":
            emit_rglru_block(b, cfg, cfg.recurrence, li)
        else:
            emit_rwkv6_timemix(b, cfg, cfg.recurrence, li)
    else:
        raise ValueError(kind)
    # channel mixing
    if cfg.ffn_kind == "moe" and li >= cfg.moe.first_dense_layers:
        emit_moe_ffn(b, cfg, cfg.moe)
    elif cfg.ffn_kind == "moe":
        # leading dense layers of a MoE model (deepseek-moe layer 0)
        emit_glu_ffn(b, _with_dff(cfg, cfg.moe.first_dense_d_ff), "silu")
    elif cfg.ffn_kind == "swiglu":
        emit_glu_ffn(b, cfg, "silu")
    elif cfg.ffn_kind == "geglu":
        emit_glu_ffn(b, cfg, "gelu")
    elif cfg.ffn_kind == "gelu_mlp":
        emit_mlp_ffn(b, cfg, "gelu", bias=cfg.family == "audio")
    elif cfg.ffn_kind == "rwkv_cm":
        emit_rwkv6_channelmix(b, cfg, li)
    else:
        raise ValueError(cfg.ffn_kind)
    return b


def _with_dff(cfg: ModelConfig, d_ff: int) -> ModelConfig:
    import dataclasses
    return dataclasses.replace(cfg, d_ff=d_ff)


def build_decoder_graph(cfg: ModelConfig) -> Graph:
    """Decoder-only LM (dense / MoE / hybrid / ssm / vlm)."""
    blocks = [_embed_block(cfg, scale=cfg.family == "hybrid")]
    if cfg.n_patch_tokens:
        # multimodal stub: project precomputed patch embeddings and prepend.
        b = Block("mm_project", "mm")
        d_vis = cfg.d_vision
        b.add("p1", "patch_proj", "h",
              params=[P("mm_w1", (d_vis, cfg.d_model), ("none", "d_model")),
                      P("mm_b1", (cfg.d_model,), ("d_model",), "zeros"),
                      P("mm_w2", (cfg.d_model, cfg.d_model), ("d_model", "d_model")),
                      P("mm_b2", (cfg.d_model,), ("d_model",), "zeros")],
              n_patches=cfg.n_patch_tokens, d_vision=d_vis)
        b.add("h", "identity", "p1")
        blocks.append(b)
    for li, kind in enumerate(cfg.layer_kinds):
        blocks.append(_decoder_layer(cfg, li, kind))
    blocks.append(_head_block(cfg))
    g = Graph(cfg.name, blocks, meta={"config": cfg})
    g.validate()
    return g


def build_encdec_graph(cfg: ModelConfig) -> Graph:
    """Encoder–decoder (whisper): frontend is a STUB — the input provides
    precomputed frame embeddings of shape (B, encoder_seq, d_model)."""
    blocks: list[Block] = []
    b = Block("enc_embed", "enc_embed")
    b.add("h", "frames_in", "h", encoder_seq=cfg.encoder_seq)  # + sinusoidal pos
    blocks.append(b)
    import dataclasses
    enc_cfg = dataclasses.replace(cfg, norm_kind="layernorm")
    for li in range(cfg.n_encoder_layers):
        eb = Block(f"enc{li}", "encoder_layer", attrs={"index": li})
        a = dataclasses.replace(cfg.attention, causal=False, rope=None)
        emit_attention(eb, enc_cfg, a, li, prefix="enc_")
        emit_mlp_ffn(eb, enc_cfg, "gelu", bias=True, prefix="enc_")
        blocks.append(eb)
    fe = Block("enc_final", "enc_final", attrs={"captures_cross": True})
    fe.add("h", "norm", "h",
           params=[P("enc_fnorm_scale", (cfg.d_model,), ("d_model",), "ones"),
                   P("enc_fnorm_bias", (cfg.d_model,), ("d_model",), "zeros")],
           kind="layernorm", eps=cfg.norm_eps)
    blocks.append(fe)

    db = Block("dec_embed", "dec_embed")
    db.add("h", "embed", "h",
           params=[P("table", (cfg.padded_vocab, cfg.d_model), ("vocab", "d_model"),
                     "embed")],
           scale_by_sqrt_d=False, learned_pos=True, max_pos=cfg.max_seq_len)
    blocks.append(db)
    for li in range(cfg.n_layers):
        lb = Block(f"dec{li}", "decoder_layer", attrs={"index": li})
        a = dataclasses.replace(cfg.attention, rope=None)  # whisper: no rope
        emit_attention(lb, enc_cfg, a, li, prefix="dec_")
        emit_attention(lb, enc_cfg, a, li, prefix="xdec_", cross=True)
        emit_mlp_ffn(lb, enc_cfg, "gelu", bias=True, prefix="dec_")
        blocks.append(lb)
    blocks.append(_head_block(dataclasses.replace(cfg, norm_kind="layernorm",
                                                  tie_embeddings=True),
                              tied_ref="dec_embed/table"))
    g = Graph(cfg.name, blocks, meta={"config": cfg})
    g.validate()
    return g


def build_graph(cfg: ModelConfig) -> Graph:
    if cfg.family == "cnn":
        from repro.models.cnn import build_cnn_graph
        return build_cnn_graph(cfg)
    if cfg.n_encoder_layers:
        return build_encdec_graph(cfg)
    return build_decoder_graph(cfg)
