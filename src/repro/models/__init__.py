from repro.models.lm import build_graph  # noqa: F401
