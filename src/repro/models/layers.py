"""Micro-op emitters for the standard layer families.

These functions append micro-ops to a :class:`~repro.core.graph.Block`.  They
emit the *unoptimized* op-level program (separate matmul / bias / activation /
norm ops) — the paper's "base" kernels.  The fusion pass later rewrites these
into fused ops, exactly as the paper fuses activation/batch-norm loops into
convolution loops.
"""
from __future__ import annotations

from typing import Optional

from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig, RecurrenceConfig
from repro.core.graph import Block, ParamSpec as P


# ---------------------------------------------------------------------------
# Attention sub-block
# ---------------------------------------------------------------------------

def emit_attention(b: Block, cfg: ModelConfig, a: AttentionConfig, li: int,
                   prefix: str = "", x: str = "h", cross: bool = False) -> None:
    d = cfg.d_model
    H, KV, Dh = a.n_heads, a.n_kv_heads, a.head_dim
    pn = lambda s: f"{prefix}{s}"

    b.add("an", "norm", x,
          params=[P(pn("attn_norm_scale"), (d,), ("d_model",), "ones")] +
                 ([P(pn("attn_norm_bias"), (d,), ("d_model",), "zeros")]
                  if cfg.norm_kind == "layernorm" else []),
          kind=cfg.norm_kind, eps=cfg.norm_eps)

    b.add("q", "matmul", "an", params=[P(pn("wq"), (d, H * Dh), ("d_model", "heads"))])
    kv_src = "cross" if cross else "an"
    b.add("k", "matmul", kv_src, params=[P(pn("wk"), (d, KV * Dh), ("d_model", "heads"))])
    b.add("v", "matmul", kv_src, params=[P(pn("wv"), (d, KV * Dh), ("d_model", "heads"))])
    if a.qkv_bias:
        b.add("q", "bias_add", "q", params=[P(pn("bq"), (H * Dh,), ("heads",), "zeros")])
        b.add("k", "bias_add", "k", params=[P(pn("bk"), (KV * Dh,), ("heads",), "zeros")])
        b.add("v", "bias_add", "v", params=[P(pn("bv"), (KV * Dh,), ("heads",), "zeros")])

    b.add("qh", "split_heads", "q", n=H, dh=Dh)
    b.add("kh", "split_heads", "k", n=KV, dh=Dh)
    b.add("vh", "split_heads", "v", n=KV, dh=Dh)

    if a.rope and not cross:
        rd = int(Dh * a.rope_pct)
        b.add("qh", "rope", "qh", "positions", base=a.rope_base, rot_dim=rd)
        b.add("kh", "rope", "kh", "positions", base=a.rope_base, rot_dim=rd)

    # bidirectional (encoder) self-attention has no decode step -> stateless
    skey = None
    if cross:
        skey = f"{prefix}xkv{li}"
    elif a.causal:
        skey = f"{prefix}kv{li}"
    b.add("ao", "attention", "qh", "kh", "vh", "positions",
          causal=a.causal and not cross, window=a.window,
          softcap=a.logits_softcap, state_key=skey, cross=cross)
    b.add("am", "merge_heads", "ao")
    b.add("aout", "matmul", "am",
          params=[P(pn("wo"), (H * Dh, d), ("heads_in", "d_model"))])
    if a.out_bias:
        b.add("aout", "bias_add", "aout", params=[P(pn("bo"), (d,), ("d_model",), "zeros")])
    b.add("h", "add", x, "aout")


# ---------------------------------------------------------------------------
# FFN sub-blocks
# ---------------------------------------------------------------------------

def emit_glu_ffn(b: Block, cfg: ModelConfig, act: str, prefix: str = "") -> None:
    d, f = cfg.d_model, cfg.d_ff
    pn = lambda s: f"{prefix}{s}"
    b.add("fn", "norm", "h",
          params=[P(pn("ffn_norm_scale"), (d,), ("d_model",), "ones")] +
                 ([P(pn("ffn_norm_bias"), (d,), ("d_model",), "zeros")]
                  if cfg.norm_kind == "layernorm" else []),
          kind=cfg.norm_kind, eps=cfg.norm_eps)
    b.add("g", "matmul", "fn", params=[P(pn("w_gate"), (d, f), ("d_model", "d_ff"))])
    b.add("ga", "act", "g", kind=act)
    b.add("u", "matmul", "fn", params=[P(pn("w_up"), (d, f), ("d_model", "d_ff"))])
    b.add("gu", "mul", "ga", "u")
    b.add("fo", "matmul", "gu", params=[P(pn("w_down"), (f, d), ("d_ff", "d_model"))])
    b.add("h", "add", "h", "fo")


def emit_mlp_ffn(b: Block, cfg: ModelConfig, act: str = "gelu",
                 bias: bool = False, prefix: str = "") -> None:
    d, f = cfg.d_model, cfg.d_ff
    pn = lambda s: f"{prefix}{s}"
    b.add("fn", "norm", "h",
          params=[P(pn("ffn_norm_scale"), (d,), ("d_model",), "ones")] +
                 ([P(pn("ffn_norm_bias"), (d,), ("d_model",), "zeros")]
                  if cfg.norm_kind == "layernorm" else []),
          kind=cfg.norm_kind, eps=cfg.norm_eps)
    b.add("u", "matmul", "fn", params=[P(pn("w_up"), (d, f), ("d_model", "d_ff"))])
    if bias:
        b.add("u", "bias_add", "u", params=[P(pn("b_up"), (f,), ("d_ff",), "zeros")])
    b.add("ua", "act", "u", kind=act)
    b.add("fo", "matmul", "ua", params=[P(pn("w_down"), (f, d), ("d_ff", "d_model"))])
    if bias:
        b.add("fo", "bias_add", "fo", params=[P(pn("b_down"), (d,), ("d_model",), "zeros")])
    b.add("h", "add", "h", "fo")


def emit_moe_ffn(b: Block, cfg: ModelConfig, m: MoEConfig, prefix: str = "") -> None:
    d = cfg.d_model
    E, fe = m.num_experts, m.d_expert
    pn = lambda s: f"{prefix}{s}"
    b.add("fn", "norm", "h",
          params=[P(pn("ffn_norm_scale"), (d,), ("d_model",), "ones")],
          kind=cfg.norm_kind, eps=cfg.norm_eps)
    params = [
        P(pn("router"), (d, E), ("d_model", "expert")),
        P(pn("we_gate"), (E, d, fe), ("expert", "d_model", "d_ff")),
        P(pn("we_up"), (E, d, fe), ("expert", "d_model", "d_ff")),
        P(pn("we_down"), (E, fe, d), ("expert", "d_ff", "d_model")),
    ]
    if m.num_shared:
        fs = m.d_shared_eff * m.num_shared
        params += [
            P(pn("ws_gate"), (d, fs), ("d_model", "d_ff")),
            P(pn("ws_up"), (d, fs), ("d_model", "d_ff")),
            P(pn("ws_down"), (fs, d), ("d_ff", "d_model")),
        ]
    b.add("mo", "moe_ffn", "fn", params=params,
          top_k=m.top_k, num_experts=E, num_shared=m.num_shared,
          capacity_factor=m.capacity_factor, act="silu",
          aux_weight=m.router_aux_weight)
    b.add("h", "add", "h", "mo")


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (Griffin / RecurrentGemma)
# ---------------------------------------------------------------------------

def emit_rglru_block(b: Block, cfg: ModelConfig, r: RecurrenceConfig, li: int,
                     prefix: str = "") -> None:
    d, w = cfg.d_model, r.width
    nb = max(1, cfg.attention.n_heads if cfg.attention else 1)  # gate blocks
    pn = lambda s: f"{prefix}{s}"
    b.add("rn", "norm", "h",
          params=[P(pn("rec_norm_scale"), (d,), ("d_model",), "ones")],
          kind=cfg.norm_kind, eps=cfg.norm_eps)
    # two branches: gate (GeLU) and recurrent
    b.add("gy", "matmul", "rn", params=[P(pn("w_gate_br"), (d, w), ("d_model", "d_ff"))])
    b.add("gy", "act", "gy", kind="gelu")
    b.add("rx", "matmul", "rn", params=[P(pn("w_rec_br"), (d, w), ("d_model", "d_ff"))])
    b.add("rc", "conv1d_causal", "rx",
          params=[P(pn("conv_w"), (r.conv_width, w), ("conv_k", "d_ff")),
                  P(pn("conv_b"), (w,), ("d_ff",), "zeros")],
          width=r.conv_width, state_key=f"{prefix}conv{li}")
    b.add("rl", "rg_lru", "rc",
          params=[P(pn("lru_lambda"), (w,), ("d_ff",), "lru_lambda"),
                  P(pn("lru_wa"), (nb, w // nb, w // nb), ("heads", "d_ff", "d_ff"),
                    init_scale=(w // nb) ** -0.5),
                  P(pn("lru_ba"), (w,), ("d_ff",), "zeros"),
                  P(pn("lru_wx"), (nb, w // nb, w // nb), ("heads", "d_ff", "d_ff"),
                    init_scale=(w // nb) ** -0.5),
                  P(pn("lru_bx"), (w,), ("d_ff",), "zeros")],
          n_blocks=nb, c=8.0, state_key=f"{prefix}lru{li}")
    b.add("rg", "mul", "rl", "gy")
    b.add("ro", "matmul", "rg", params=[P(pn("w_rec_out"), (w, d), ("d_ff", "d_model"))])
    b.add("h", "add", "h", "ro")


# ---------------------------------------------------------------------------
# RWKV6 (Finch) blocks
# ---------------------------------------------------------------------------

def emit_rwkv6_timemix(b: Block, cfg: ModelConfig, r: RecurrenceConfig, li: int,
                       prefix: str = "") -> None:
    d = cfg.d_model
    H, dh = r.n_heads, r.head_dim
    rank = r.lora_rank
    pn = lambda s: f"{prefix}{s}"
    b.add("tn", "norm", "h",
          params=[P(pn("tm_norm_scale"), (d,), ("d_model",), "ones"),
                  P(pn("tm_norm_bias"), (d,), ("d_model",), "zeros")],
          kind="layernorm", eps=1e-5)
    b.add("tm", "rwkv6_timemix", "tn",
          params=[
              # token-shift base mixes (one per r,k,v,w,g channel set)
              P(pn("mu_base"), (5, d), ("none", "d_model"), "rwkv_mix"),
              # data-dependent mix LoRA: d -> 5*rank -> 5*d
              P(pn("mu_lora_a"), (d, 5 * rank), ("d_model", "lora"), init_scale=1e-2),
              P(pn("mu_lora_b"), (5, rank, d), ("none", "lora", "d_model"), "zeros"),
              # projections
              P(pn("w_r"), (d, H * dh), ("d_model", "heads")),
              P(pn("w_k"), (d, H * dh), ("d_model", "heads")),
              P(pn("w_v"), (d, H * dh), ("d_model", "heads")),
              P(pn("w_g"), (d, H * dh), ("d_model", "heads")),
              # data-dependent decay: w0 + lora
              P(pn("decay_base"), (H * dh,), ("heads",), "rwkv_decay"),
              P(pn("decay_lora_a"), (d, rank), ("d_model", "lora"), init_scale=1e-2),
              P(pn("decay_lora_b"), (rank, H * dh), ("lora", "heads"), "zeros"),
              # per-channel bonus u
              P(pn("bonus"), (H * dh,), ("heads",), "rwkv_decay"),
              # per-head group-norm + output
              P(pn("ln_x_scale"), (H * dh,), ("heads",), "ones"),
              P(pn("ln_x_bias"), (H * dh,), ("heads",), "zeros"),
              P(pn("w_o"), (H * dh, d), ("heads_in", "d_model")),
          ],
          n_heads=H, head_dim=dh, lora_rank=rank,
          state_key=f"{prefix}wkv{li}")
    b.add("h", "add", "h", "tm")


def emit_rwkv6_channelmix(b: Block, cfg: ModelConfig, li: int, prefix: str = "") -> None:
    d, f = cfg.d_model, cfg.d_ff
    pn = lambda s: f"{prefix}{s}"
    b.add("cn", "norm", "h",
          params=[P(pn("cm_norm_scale"), (d,), ("d_model",), "ones"),
                  P(pn("cm_norm_bias"), (d,), ("d_model",), "zeros")],
          kind="layernorm", eps=1e-5)
    b.add("cm", "rwkv6_channelmix", "cn",
          params=[P(pn("cm_mu"), (2, d), ("none", "d_model"), "rwkv_mix"),
                  P(pn("cw_r"), (d, d), ("d_model", "d_model")),
                  P(pn("cw_k"), (d, f), ("d_model", "d_ff")),
                  P(pn("cw_v"), (f, d), ("d_ff", "d_model"))],
          state_key=f"{prefix}cm{li}")
    b.add("h", "add", "h", "cm")
