"""The paper's own networks — LeNet-5, MobileNetV1, ResNet-34 — as graphs.

These run through the exact same compilation flow (fusion folds batch-norm and
ReLU into the convolutions — the paper's LF pass; folding groups the repeated
depthwise-separable / residual blocks — the paper's PK pass).  Layout is NHWC.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.core.graph import Block, Graph, ParamSpec as P


def _conv(b: Block, name: str, cin: int, cout: int, k: int, stride: int = 1,
          x: str = "h", out: str = "h", padding: str = "SAME") -> str:
    b.add(out, "conv2d", x,
          params=[P(f"{name}_w", (k, k, cin, cout),
                    ("conv_k", "conv_k", "channels", "d_model"))],
          stride=stride, padding=padding)
    return out


def _dwconv(b: Block, name: str, c: int, k: int, stride: int = 1) -> None:
    b.add("h", "depthwise_conv2d", "h",
          params=[P(f"{name}_w", (k, k, c, 1),
                    ("conv_k", "conv_k", "channels", "none"))],
          stride=stride, padding="SAME")


def _bn(b: Block, name: str, c: int, x: str = "h", out: str = "h") -> None:
    b.add(out, "batchnorm", x,
          params=[P(f"{name}_scale", (c,), ("channels",), "ones"),
                  P(f"{name}_bias", (c,), ("channels",), "zeros"),
                  P(f"{name}_mean", (c,), ("channels",), "zeros"),
                  P(f"{name}_var", (c,), ("channels",), "ones")],
          eps=1e-5)


def _relu(b: Block, x: str = "h", out: str = "h") -> None:
    b.add(out, "act", x, kind="relu")


def build_lenet5(cfg: ModelConfig) -> Graph:
    blocks = []
    b = Block("stem", "cnn_stem")
    b.add("h", "image_in", "h", size=cfg.image_size, channels=cfg.image_channels)
    _conv(b, "c1", cfg.image_channels, 6, 5, padding="VALID")
    _relu(b)
    b.add("h", "avgpool2d", "h", window=2, stride=2)
    blocks.append(b)
    b = Block("c3", "cnn_block")
    _conv(b, "c3", 6, 16, 5, padding="VALID")
    _relu(b)
    b.add("h", "avgpool2d", "h", window=2, stride=2)
    blocks.append(b)
    b = Block("fc", "cnn_head")
    b.add("h", "flatten", "h")
    b.add("h", "matmul", "h", params=[P("f5_w", (400, 120), ("none", "d_model"))])
    b.add("h", "bias_add", "h", params=[P("f5_b", (120,), ("d_model",), "zeros")])
    _relu(b)
    b.add("h", "matmul", "h", params=[P("f6_w", (120, 84), ("none", "d_model"))])
    b.add("h", "bias_add", "h", params=[P("f6_b", (84,), ("d_model",), "zeros")])
    _relu(b)
    b.add("h", "matmul", "h", params=[P("out_w", (84, cfg.vocab_size),
                                        ("none", "vocab"))])
    b.add("h", "bias_add", "h", params=[P("out_b", (cfg.vocab_size,), ("vocab",),
                                          "zeros")])
    blocks.append(b)
    return Graph(cfg.name, blocks, meta={"config": cfg})


_MOBILENET_PLAN = [  # (cout, stride) for each depthwise-separable block
    (64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
    (512, 1), (512, 1), (512, 1), (512, 1), (512, 1), (1024, 2), (1024, 1),
]


def build_mobilenetv1(cfg: ModelConfig) -> Graph:
    blocks = []
    b = Block("stem", "cnn_stem")
    b.add("h", "image_in", "h", size=cfg.image_size, channels=cfg.image_channels)
    _conv(b, "stem", cfg.image_channels, 32, 3, stride=2)
    _bn(b, "stem_bn", 32)
    _relu(b)
    blocks.append(b)
    cin = 32
    for i, (cout, s) in enumerate(_MOBILENET_PLAN):
        b = Block(f"ds{i}", "cnn_block", attrs={"index": i})
        _dwconv(b, "dw", cin, 3, stride=s)
        _bn(b, "dw_bn", cin)
        _relu(b)
        _conv(b, "pw", cin, cout, 1)
        _bn(b, "pw_bn", cout)
        _relu(b)
        blocks.append(b)
        cin = cout
    b = Block("head", "cnn_head")
    b.add("h", "global_avgpool", "h")
    b.add("h", "matmul", "h", params=[P("fc_w", (1024, cfg.vocab_size),
                                        ("none", "vocab"))])
    b.add("h", "bias_add", "h", params=[P("fc_b", (cfg.vocab_size,), ("vocab",),
                                          "zeros")])
    blocks.append(b)
    return Graph(cfg.name, blocks, meta={"config": cfg})


_RESNET34_PLAN = [(64, 3, 1), (128, 4, 2), (256, 6, 2), (512, 3, 2)]


def build_resnet34(cfg: ModelConfig) -> Graph:
    blocks = []
    b = Block("stem", "cnn_stem")
    b.add("h", "image_in", "h", size=cfg.image_size, channels=cfg.image_channels)
    _conv(b, "stem", cfg.image_channels, 64, 7, stride=2)
    _bn(b, "stem_bn", 64)
    _relu(b)
    b.add("h", "maxpool2d", "h", window=3, stride=2)
    blocks.append(b)
    cin = 64
    bi = 0
    for cout, reps, stride in _RESNET34_PLAN:
        for r in range(reps):
            s = stride if r == 0 else 1
            b = Block(f"res{bi}", "cnn_block", attrs={"index": bi})
            b.add("sc", "identity", "h")
            if s != 1 or cin != cout:
                _conv(b, "proj", cin, cout, 1, stride=s, x="sc", out="sc")
                _bn(b, "proj_bn", cout, x="sc", out="sc")
            _conv(b, "c1", cin, cout, 3, stride=s)
            _bn(b, "bn1", cout)
            _relu(b)
            _conv(b, "c2", cout, cout, 3)
            _bn(b, "bn2", cout)
            b.add("h", "add", "h", "sc")
            _relu(b)
            blocks.append(b)
            cin = cout
            bi += 1
    b = Block("head", "cnn_head")
    b.add("h", "global_avgpool", "h")
    b.add("h", "matmul", "h", params=[P("fc_w", (512, cfg.vocab_size),
                                        ("none", "vocab"))])
    b.add("h", "bias_add", "h", params=[P("fc_b", (cfg.vocab_size,), ("vocab",),
                                          "zeros")])
    blocks.append(b)
    return Graph(cfg.name, blocks, meta={"config": cfg})


def build_cnn_graph(cfg: ModelConfig) -> Graph:
    g = {"lenet5": build_lenet5, "mobilenetv1": build_mobilenetv1,
         "resnet34": build_resnet34}[cfg.name](cfg)
    g.validate()
    return g
