"""AdamW in pure JAX, sharding-transparent (moments mirror param shardings).

Includes optional int8 gradient compression with error feedback — the
distributed-optimization trick applied inside the gradient-accumulation loop
(the quantization the compressed all-reduce would introduce, with the error
carried forward so the sequence of updates stays unbiased).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any
    err: Optional[Any] = None      # error-feedback buffers (compression)


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    compress: Optional[str] = None           # None | "int8_ef"
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1

    def init(self, params) -> AdamWState:
        z = lambda p: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p)
        err = z(params) if self.compress else None
        return AdamWState(jnp.zeros((), jnp.int32), z(params), z(params), err)

    def schedule(self, step):
        warm = jnp.minimum(step / max(self.warmup_steps, 1), 1.0)
        t = jnp.clip((step - self.warmup_steps) /
                     max(self.total_steps - self.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        frac = self.min_lr_frac + (1 - self.min_lr_frac) * cos
        return self.lr * warm * frac

    def compress_grads(self, grads, err):
        """int8 quantize (per-tensor scale) with error feedback."""
        def one(g, e):
            gf = g.astype(jnp.float32) + e
            scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
            deq = q.astype(jnp.float32) * scale
            return deq, gf - deq
        flat = jax.tree.map(one, grads, err)
        deq = jax.tree.map(lambda t: t[0], flat,
                           is_leaf=lambda t: isinstance(t, tuple))
        new_err = jax.tree.map(lambda t: t[1], flat,
                               is_leaf=lambda t: isinstance(t, tuple))
        return deq, new_err

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        err = state.err
        if self.compress == "int8_ef":
            grads, err = self.compress_grads(grads, err)
        gsq = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
        gnorm = jnp.sqrt(gsq)
        scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9)) \
            if self.grad_clip else 1.0
        step = state.step + 1
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)
        lr = self.schedule(state.step.astype(jnp.float32))

        def upd(p, g, m, v):
            g = g * scale
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * jnp.square(g)
            mh = m / b1c
            vh = v / b2c
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if self.weight_decay and p.ndim >= 2:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state.mu, state.nu)
        new_p = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_p, AdamWState(step, new_m, new_v, err), \
            {"grad_norm": gnorm, "lr": lr}
