"""Static verification launcher — the CI gate over the shipped configs.

Builds the default plan for each requested config (no jit, no allocation),
runs :func:`repro.analysis.verify_plan` over it, and prints one summary line
per config plus every diagnostic.  Exit status 1 when any config produces an
error-severity diagnostic, so CI can gate on it.

Usage:
  python -m repro.launch.check --cfg lenet5
  python -m repro.launch.check --all [--smoke] [--shape decode_32k]
  python -m repro.launch.check --codes          # list the diagnostic codes
"""
import argparse
import sys
from typing import List, Optional, Tuple

from repro.analysis import DIAGNOSTIC_CODES, verify_plan
from repro.configs import ARCHS, CNNS, SHAPES, get_config, get_smoke
from repro.configs.base import FlowConfig, ShapeConfig


def default_shape(family: str) -> ShapeConfig:
    """A small CPU-checkable cell per family: CNNs get an image batch, LMs a
    short decode cell (the serving-relevant kind)."""
    if family == "cnn":
        return ShapeConfig("check", "prefill", 64, 8)
    return ShapeConfig("check", "decode", 128, 4)


def check_config(name: str, *, smoke: bool = False,
                 shape: Optional[ShapeConfig] = None,
                 flow: Optional[FlowConfig] = None) -> Tuple[str, List[str]]:
    """(summary_line, formatted diagnostics) for one config's default plan."""
    from repro.core.plan import _build_plan
    cfg = get_smoke(name) if smoke else get_config(name)
    shape = shape if shape is not None else default_shape(cfg.family)
    flow = flow if flow is not None else FlowConfig()
    plan = _build_plan(cfg, flow, shape)
    result = verify_plan(plan)
    return result.summary_line(), [d.format() for d in result.diagnostics]


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.check",
        description="statically verify execution plans (no compilation)")
    ap.add_argument("--cfg", "--arch", dest="cfg", default=None,
                    help="one config name (see repro.configs)")
    ap.add_argument("--all", action="store_true",
                    help="verify every shipped config")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke configs")
    ap.add_argument("--shape", default=None,
                    help="shape-cell name from repro.configs.SHAPES "
                         "(default: a small per-family check cell)")
    ap.add_argument("--codes", action="store_true",
                    help="print the diagnostic code table and exit")
    args = ap.parse_args(argv)

    if args.codes:
        for code, meaning in DIAGNOSTIC_CODES.items():
            print(f"{code}  {meaning}")
        return 0

    if not args.cfg and not args.all:
        ap.error("pass --cfg NAME or --all")
    names = ARCHS + CNNS if args.all else [args.cfg]
    shape = SHAPES[args.shape] if args.shape else None

    failed = False
    for name in names:
        summary, diags = check_config(name, smoke=args.smoke, shape=shape)
        print(f"{name:24s} {summary}")
        for line in diags:
            print(f"    {line}")
        failed = failed or summary.startswith("FAIL")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
