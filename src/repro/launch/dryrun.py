import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ MUST be the first two lines, before ANY other import: jax locks the
# device count on first init.  setdefault (not assignment) so tests that
# import run_cell under their own smaller device count are not clobbered.

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh with ShapeDtypeStruct stand-ins (no allocation), record
memory_analysis / cost_analysis / the collective schedule, and emit the JSON
the roofline analysis reads.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""
import argparse
import json
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import flow as rflow
from repro.configs import (SHAPES, cells, get_config)
from repro.configs.base import FlowConfig, ModelConfig, ShapeConfig
from repro.core import lowering
from repro.launch.mesh import make_production_mesh
from repro.optim.adamw import AdamW
from repro.train.trainer import make_train_step


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, shardable, no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    from repro.core.dse import abstract_inputs
    return abstract_inputs(cfg, shape)


def build_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               mesh=None, flow: Optional[FlowConfig] = None):
    """Build (plan, rules, step_fn, abstract args, shardings) for one cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    flow = flow or FlowConfig(mode="folded")
    cm = rflow.compile(cfg, shape, flow, mesh=mesh)
    plan, rules = cm.plan, cm.rules
    pshapes = cm.param_shapes()
    psh = rules.params_shardings(plan)
    bspecs = input_specs(cfg, shape)
    bsh = rules.batch_sharding(bspecs)

    import jax.sharding as js
    rep = js.NamedSharding(mesh, js.PartitionSpec())
    B = shape.global_batch
    logits_sh = js.NamedSharding(
        mesh, rules.act_pspec(("batch", "none", "vocab"),
                              (B, 1, cfg.padded_vocab)))
    if shape.kind == "train":
        opt = AdamW()
        step = make_train_step(plan, opt, microbatches=flow.microbatches)
        ostate_abs = jax.eval_shape(opt.init, pshapes)
        from repro.optim.adamw import AdamWState
        osh = AdamWState(rep, psh, psh, None)
        args = (pshapes, ostate_abs, bspecs)
        shardings = (psh, osh, bsh)
        out_shardings = (psh, osh, None)      # metrics: unspecified
        donate = (0, 1)
        fn = step
    elif shape.kind == "prefill":
        apply = cm.apply
        ssh = lowering.state_shardings(plan, B, rules)
        def fn(params, batch):
            logits, state, _ = apply(params, batch, mode="prefill")
            return logits, state
        args = (pshapes, bspecs)
        shardings = (psh, bsh)
        out_shardings = (logits_sh, ssh)
        donate = ()
    else:  # decode
        apply = cm.apply
        state_abs = cm.init_state(B, abstract=True)
        ssh = lowering.state_shardings(plan, B, rules)
        def fn(params, batch, state, idx):
            logits, new_state, _ = apply(params, batch, state=state,
                                         cache_index=idx, mode="decode")
            return logits, new_state
        args = (pshapes, bspecs, state_abs,
                jax.ShapeDtypeStruct((), jnp.int32))
        shardings = (psh, bsh, ssh, rep)
        out_shardings = (logits_sh, ssh)      # matches input -> buffers alias
        donate = (2,)
    return plan, mesh, fn, args, shardings, out_shardings, donate


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             mesh=None, flow: Optional[FlowConfig] = None,
             want_hlo: bool = True) -> Dict[str, Any]:
    from repro.core.ops_impl import set_cpu_safe_dots
    set_cpu_safe_dots(False)     # compile-only: keep the TPU-faithful program
    if mesh is not None:
        multi_pod = "pod" in mesh.axis_names
    t0 = time.time()
    plan, mesh, fn, args, shardings, out_shardings, donate = build_cell(
        arch, shape_name, multi_pod=multi_pod, mesh=mesh, flow=flow)
    res: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": list(mesh.devices.shape),
                           "multi_pod": multi_pod,
                           "mode": plan.stream.mode,
                           "folds": [[u.reps, u.period] for u in plan.units
                                     if u.folded],
                           "pass_stats": plan.pass_stats,
                           "pass_timings_ms": plan.pass_timings_ms}
    with mesh:
        jfn = jax.jit(fn, in_shardings=shardings,
                      out_shardings=out_shardings, donate_argnums=donate)
        lowered = jfn.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    mem = compiled.memory_analysis()
    res["lower_s"] = round(t1 - t0, 2)
    res["compile_s"] = round(t2 - t1, 2)
    res["memory"] = {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "code_bytes": mem.generated_code_size_in_bytes,
    }
    from repro.core.dse import per_device_bytes
    per_dev = per_device_bytes(mem)
    res["memory"]["per_device_bytes"] = per_dev
    budget = plan.flow.tuning.hbm_bytes
    res["memory"]["budget_bytes"] = budget
    res["memory"]["fits_budget"] = bool(per_dev < budget)
    ca = compiled.cost_analysis() or {}
    res["cost_analysis"] = {k: float(ca[k]) for k in
                            ("flops", "bytes accessed") if k in ca}
    if want_hlo:
        from benchmarks.hlo_analysis import analyze_hlo
        txt = compiled.as_text()
        res["hlo"] = analyze_hlo(txt)
        del txt
    # analytic cross-check
    from repro.core.estimator import model_flops, hbm_bytes_kernel_path
    cfg = get_config(arch)
    res["model_flops"] = model_flops(cfg, SHAPES[shape_name])
    res["est_kernel_bytes"] = hbm_bytes_kernel_path(cfg, SHAPES[shape_name])
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--flow-mode", default="folded")
    ap.add_argument("--autotune", action="store_true",
                    help="DSE: pick train-cell microbatch counts so the "
                         "per-device footprint fits HBM")
    ap.add_argument("--explore", action="store_true",
                    help="full DSE: estimator-pruned candidate sweep with "
                         "compile-in-the-loop validation of the top-k")
    ap.add_argument("--hbm-gib", type=float, default=None,
                    help="per-device HBM budget in GiB (default: 16, v5e)")
    args = ap.parse_args()

    results = []
    todo = []
    if args.all:
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        for a, s, runnable in cells(include_skipped=True):
            for mp in meshes:
                todo.append((a, s, runnable, mp))
    else:
        todo = [(args.arch, args.shape, True, args.multi_pod)]

    mesh_cache = {}
    for a, s, runnable, mp in todo:
        if not runnable:
            results.append({"arch": a, "shape": s, "multi_pod": mp,
                            "skipped": "full-attention arch: long-context "
                            "decode inapplicable (see DESIGN.md)"})
            print(f"SKIP {a} x {s}")
            continue
        if mp not in mesh_cache:
            mesh_cache[mp] = make_production_mesh(multi_pod=mp)
        try:
            base_flow = FlowConfig(mode=args.flow_mode)
            if args.hbm_gib is not None:
                from repro.configs.base import TuningConfig
                import dataclasses as _dc
                base_flow = _dc.replace(base_flow, tuning=TuningConfig(
                    hbm_bytes=int(args.hbm_gib * 2 ** 30)))
            if args.explore:
                from repro.core import dse
                mesh = mesh_cache[mp]
                n_dev = int(mesh.devices.size)
                records = {}       # reuse the validator's compiles for `best`

                def validator(flow):
                    records[flow] = run_cell(a, s, mesh=mesh, flow=flow)
                    return records[flow]["memory"]

                # the production mesh is fixed here: pin its factorization
                # (the DSE still searches every other pass dimension)
                er = dse.explore(get_config(a), SHAPES[s], base_flow,
                                 devices=n_dev, mesh=mesh,
                                 validator=validator)
                print(er.describe())
                r = records.get(er.best.flow) or run_cell(
                    a, s, multi_pod=mp, mesh=mesh, flow=er.best.flow)
                r["dse"] = {"knobs": er.best.knob_str(),
                            "n_enumerated": er.n_enumerated,
                            "validated": len(er.validated),
                            "budget_bytes": er.budget_bytes}
            elif args.autotune and SHAPES[s].kind == "train":
                from repro.core.dse import autotune_train_cell
                _, r = autotune_train_cell(a, s, mesh_cache[mp], base_flow)
            else:
                r = run_cell(a, s, multi_pod=mp, mesh=mesh_cache[mp],
                             flow=base_flow)
            gb = r["memory"]["per_device_bytes"] / 2 ** 30
            budget_gb = r["memory"]["budget_bytes"] / 2 ** 30
            fit = "" if r["memory"]["fits_budget"] else " OVER-BUDGET"
            print(f"OK   {a} x {s} pods={1+mp} compile={r['compile_s']}s "
                  f"mem/dev={gb:.2f}GiB (budget {budget_gb:.2f}GiB{fit}) "
                  f"flops={r['cost_analysis'].get('flops', 0):.3g}")
        except Exception as e:  # noqa: BLE001 — a failing cell is a bug report
            r = {"arch": a, "shape": s, "multi_pod": mp,
                 "error": f"{type(e).__name__}: {e}"}
            print(f"FAIL {a} x {s} pods={1+mp}: {type(e).__name__}: {str(e)[:200]}")
        results.append(r)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
