"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

``--smoke`` selects the reduced config (CPU-runnable); without it the full
config is used (real-cluster scale).  ``--pp`` enables the cross-pod pipeline
(streaming/CH execution mode).
"""
from __future__ import annotations

import argparse

import jax

from repro import flow as rflow
from repro.configs.base import FlowConfig, ShapeConfig
from repro.data.pipeline import DataConfig, SyntheticImages, SyntheticLM
from repro.optim.adamw import AdamW
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--mode", default="folded")
    ap.add_argument("--backend", default="auto",
                    help="kernel backend policy: auto | reference | pallas "
                         "| pallas_interpret")
    ap.add_argument("--autotune", action="store_true",
                    help="explore the pass design space (estimator-pruned, "
                         "compile-validated) instead of the fixed flow")
    args = ap.parse_args()

    shape = ShapeConfig("cli", "train", args.seq, args.batch)
    cm = rflow.compile(
        args.arch, shape,
        FlowConfig(mode=args.mode, microbatches=args.microbatches),
        backend=args.backend, autotune=args.autotune, smoke=args.smoke)
    if args.autotune:
        print(cm.explore_result.describe())
    print(cm.describe(stats=True))
    cfg = cm.cfg

    if cfg.family == "cnn":
        data = SyntheticImages(
            DataConfig(vocab_size=cfg.vocab_size, seq_len=0,
                       global_batch=args.batch),
            cfg.image_size, cfg.image_channels, cfg.vocab_size)
    else:
        data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=args.seq,
                                      global_batch=args.batch))
    opt = AdamW(lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
                total_steps=args.steps,
                compress="int8_ef" if args.compress else None)
    tr = Trainer(cm, opt, TrainerConfig(
        steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, log_every=max(1, args.steps // 20)))
    _, _, hist = tr.fit(data, jax.random.key(0))
    for s, l in hist:
        print(f"step {s:6d}  loss {l:.4f}")


if __name__ == "__main__":
    main()
