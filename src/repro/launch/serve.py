"""Batched serving driver.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --batch 4 --prompt-len 32 --steps 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import flow as rflow
from repro.configs.base import FlowConfig, ShapeConfig
from repro.serving.engine import Engine, EngineConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--backend", default="auto",
                    help="kernel backend policy: auto | reference | pallas "
                         "| pallas_interpret")
    ap.add_argument("--on-device-loop", action="store_true")
    ap.add_argument("--autotune", action="store_true",
                    help="explore the pass design space (estimator-pruned, "
                         "compile-validated) for the decode cell")
    args = ap.parse_args()

    shape = ShapeConfig("cli", "decode", args.prompt_len + args.steps,
                        args.batch)
    cm = rflow.compile(args.arch, shape, FlowConfig(mode="folded"),
                       backend=args.backend, autotune=args.autotune,
                       smoke=args.smoke)
    if args.autotune:
        print(cm.explore_result.describe())
    print(cm.describe(stats=True))
    cfg = cm.cfg
    params = cm.init_params(jax.random.key(0))
    eng = Engine(cm, params, EngineConfig(temperature=args.temperature))

    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.n_patch_tokens:
        batch["patches"] = jnp.asarray(
            rng.randn(args.batch, cfg.n_patch_tokens, cfg.d_vision),
            jnp.float32)
    if cfg.n_encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.randn(args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)

    t0 = time.time()
    if args.on_device_loop:
        toks = eng.generate_fori(batch, args.steps)
    else:
        toks, _ = eng.generate(batch, args.steps)
    dt = time.time() - t0
    tps = args.batch * args.steps / dt
    print(f"generated {toks.shape} in {dt:.2f}s ({tps:.1f} tok/s)")
    print(np.asarray(toks)[:2])


if __name__ == "__main__":
    main()
