"""Batched serving driver.

Single-batch generation (the original mode)::

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --batch 4 --prompt-len 32 --steps 16

Continuous-batching replay (the serving subsystem, end to end)::

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests requests.jsonl --max-batch 4 --max-seq-len 64

where ``requests.jsonl`` holds one request per line, e.g.
``{"id": "a", "prompt": [1, 2, 3], "max_new_tokens": 8}`` or
``{"prompt_len": 12, "seed": 7}`` for a synthetic prompt.  Use
``--requests synthetic:N`` to replay N generated requests without a file.
``--serving-autotune`` first searches the decode-cell design space
(measured-ranked) and pins the winning flow + block size.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import flow as rflow
from repro.configs.base import FlowConfig, ShapeConfig
from repro.serving import (Engine, EngineConfig, load_requests_jsonl,
                           synthetic_requests)


def _run_replay(args) -> None:
    spec = None if args.speculation in (None, "off", "none", "") \
        else args.speculation
    ecfg = EngineConfig(temperature=args.temperature,
                        max_batch=args.max_batch,
                        max_seq_len=args.max_seq_len,
                        block_size=args.block_size,
                        prefix_cache=bool(args.prefix_cache),
                        chunk_size=args.chunk_size,
                        chunked_prefill=args.chunked_prefill,
                        fori_seg=args.fori_seg,
                        speculation=spec,
                        trace=args.trace is not None)
    if args.serving_autotune:
        from repro.serving.autotune import ServingProfile, autotune_decode
        prof = ServingProfile(name="cli",
                              batch_buckets=ecfg.batch_buckets,
                              max_seq_len=args.max_seq_len,
                              block_sizes=(8, 16, 32))
        at = autotune_decode(args.arch, profile=prof, smoke=args.smoke,
                             validate=args.validate, db=args.tune_db)
        print(at.describe())
        cm = at.compile()
        ecfg = at.engine_config(
            temperature=args.temperature,
            trace=args.trace is not None,
            # explicit --prefix-cache / --no-prefix-cache overrides the
            # tuned pick; unset defers to the measured A/B
            prefix_cache=at.prefix_cache if args.prefix_cache is None
            else args.prefix_cache,
            # explicit CLI chunk/fori knobs likewise override the tuned ones
            **({"chunk_size": args.chunk_size,
                "chunked_prefill": True} if args.chunked_prefill else {}),
            **({"fori_seg": args.fori_seg} if args.fori_seg else {}),
            **({"speculation": spec, "fori_seg": 0} if spec else {}))
    else:
        shape = ShapeConfig("serve", "decode", args.max_seq_len,
                            args.max_batch)
        cm = rflow.compile(args.arch, shape, FlowConfig(mode="folded"),
                           backend=args.backend, smoke=args.smoke)
    params = cm.init_params(jax.random.key(0))
    eng = Engine(cm, params, ecfg)
    if args.requests.startswith("synthetic:"):
        n = int(args.requests.split(":", 1)[1])
        reqs = synthetic_requests(n, cm.cfg.vocab_size,
                                  prompt_len=args.prompt_len,
                                  max_new_tokens=args.steps)
    else:
        reqs = load_requests_jsonl(args.requests, cm.cfg.vocab_size)
    report = eng.run(reqs)
    print(eng.describe())
    if args.trace:
        eng.tracer.to_chrome(args.trace)
        print(f"wrote {len(eng.tracer)} trace events to {args.trace} "
              "(load in Perfetto / chrome://tracing, or summarize with "
              "python -m repro.launch.obs summarize)")
    if args.metrics:
        import json
        snap = report.registry.snapshot() if report.registry is not None \
            else dict(report.metrics)
        with open(args.metrics, "w") as f:
            json.dump(snap, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {len(snap)} metrics to {args.metrics}")
    m = report.metrics
    if m["prefix_cache"]:
        print(f"prefix-cache hit rate: {m['prefix_hit_rate'] * 100:.1f}% "
              f"({m['prefix_hits']} of {m['n_requests']} requests seeded; "
              f"{m['prefill_tokens_computed']} of {m['prompt_tokens_total']} "
              f"prompt tokens computed)")
    if m["speculation"]:
        print(f"speculation [{m['spec_drafter']}]: acceptance rate "
              f"{m['spec_acceptance_rate'] * 100:.1f}% "
              f"({m['spec_tokens_accepted']} of {m['spec_tokens_drafted']} "
              f"draft tokens accepted over {m['spec_ticks']} verify ticks; "
              f"{m['spec_rollback_tokens']} rolled back)")
    for r in report.results[: args.show]:
        print(f"  {r.rid}: prompt={r.prompt_len} -> {r.tokens} "
              f"({r.finish_reason}, {r.latency_s * 1e3:.0f}ms)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--backend", default="auto",
                    help="kernel backend policy: auto | reference | pallas "
                         "| pallas_interpret")
    ap.add_argument("--on-device-loop", action="store_true")
    ap.add_argument("--autotune", action="store_true",
                    help="explore the pass design space (estimator-pruned, "
                         "compile-validated) for the decode cell")
    # continuous-batching replay mode
    ap.add_argument("--requests", default=None,
                    help="jsonl file (or synthetic:N) of requests to serve "
                         "through Engine.run with continuous batching")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="decode slots for the replay mode")
    ap.add_argument("--max-seq-len", type=int, default=128,
                    help="per-request prompt+generation cap (replay mode)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged KV-cache block size (replay mode)")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="share identical prompt prefixes across requests "
                         "through the block index (copy-on-write; replay "
                         "mode); the replay report includes the hit rate. "
                         "Unset + --serving-autotune defers to the measured "
                         "A/B; --no-prefix-cache forces it off")
    ap.add_argument("--chunk-size", type=int, default=1,
                    help="catch-up chunk width k: prompt tails advance up "
                         "to k tokens per decode tick through the (B, k) "
                         "paged cell (replay mode)")
    ap.add_argument("--chunked-prefill", action="store_true",
                    help="admit cold prompts without a batched prefill and "
                         "drain them k tokens per tick (vLLM-style chunked "
                         "prefill; replay mode)")
    ap.add_argument("--fori-seg", type=int, default=0,
                    help="host-free decode: run this many steady-state "
                         "decode ticks as one on-device fori_loop segment "
                         "(0 = per-tick host loop; replay mode)")
    ap.add_argument("--speculation", default="off",
                    help="speculative decoding: ngram:<k> (prompt-lookup "
                         "drafter), draft:<cfg>:<k> (small-model drafter), "
                         "null:<k>, or off.  Exact — greedy output is "
                         "byte-identical to the per-token loop; the replay "
                         "report prints the acceptance rate (replay mode)")
    ap.add_argument("--serving-autotune", action="store_true",
                    help="search the decode-cell flow space per batch "
                         "bucket and pin the winner before replay")
    ap.add_argument("--validate", default="measure",
                    choices=("measure", "compile", "none"),
                    help="autotune ranking mode (--serving-autotune)")
    ap.add_argument("--tune-db", default=None, metavar="PATH",
                    help="persistent autotune store (repro.tunedb JSONL): "
                         "--serving-autotune reads banked winners instead "
                         "of re-measuring and writes new ones back; "
                         "maintain with python -m repro.launch.tune")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record a per-tick span timeline (EngineConfig."
                         "trace) and write it as Chrome trace-event JSON — "
                         "loads in Perfetto; replay mode only")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="write the run's MetricsRegistry snapshot (dotted "
                         "metric names) as JSON; replay mode only")
    ap.add_argument("--show", type=int, default=4,
                    help="requests to print after a replay")
    args = ap.parse_args()

    if args.requests is not None:
        _run_replay(args)
        return

    shape = ShapeConfig("cli", "decode", args.prompt_len + args.steps,
                        args.batch)
    cm = rflow.compile(args.arch, shape, FlowConfig(mode="folded"),
                       backend=args.backend, autotune=args.autotune,
                       smoke=args.smoke)
    if args.autotune:
        print(cm.explore_result.describe())
    print(cm.describe(stats=True))
    cfg = cm.cfg
    params = cm.init_params(jax.random.key(0))
    eng = Engine(cm, params, EngineConfig(temperature=args.temperature))

    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.n_patch_tokens:
        batch["patches"] = jnp.asarray(
            rng.randn(args.batch, cfg.n_patch_tokens, cfg.d_vision),
            jnp.float32)
    if cfg.n_encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.randn(args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)

    t0 = time.time()
    if args.on_device_loop:
        toks = eng.generate_fori(batch, args.steps)
    else:
        toks, _ = eng.generate(batch, args.steps)
    dt = time.time() - t0
    tps = args.batch * args.steps / dt
    print(f"generated {toks.shape} in {dt:.2f}s ({tps:.1f} tok/s)")
    print(np.asarray(toks)[:2])


if __name__ == "__main__":
    main()
