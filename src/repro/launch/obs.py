"""Trace-file analysis CLI: per-phase breakdown of an engine timeline.

``python -m repro.launch.obs summarize out.trace.json`` reads a trace
written by ``launch/serve.py --trace`` (Chrome trace-event JSON or the
JSONL form) and prints where the run's wall time went:

* total wall from the ``cat="run"`` span (``engine.run``), falling back to
  the event extent when a run span is absent (e.g. a truncated JSONL log);
* a per-phase table over the ``cat="phase"`` spans (admit / decode /
  chunked-prefill / spec-verify / decode-fori) — these tile the loop body,
  so their percentages sum to the trace's loop coverage;
* sub-phase spans (``cat="sub"``: cow-fork, evict) shown separately —
  they nest *inside* phase spans and would double-count in the tiling;
* top stall causes, tallied from ``stall=...`` attributes on admit spans.
"""
from __future__ import annotations

import argparse
import sys
from collections import defaultdict
from typing import Any, Dict, List, Optional, Tuple

from repro.obs.trace import Event, load_trace


def _span_extent(events: List[Event]) -> float:
    """Wall time in us covered by the events (max end - min start)."""
    spans = [e for e in events if e.get("ph") == "X"]
    if not spans:
        return 0.0
    t0 = min(float(e["ts"]) for e in spans)
    t1 = max(float(e["ts"]) + float(e.get("dur", 0.0)) for e in spans)
    return t1 - t0


def _phase_key(ev: Event) -> str:
    args = ev.get("args") or {}
    return str(args.get("phase", ev.get("name", "?")))


def summarize(events: List[Event]) -> Dict[str, Any]:
    """Aggregate a trace into the structure ``_print_summary`` renders
    (kept separate so tests can assert on numbers, not stdout)."""
    runs = [e for e in events if e.get("ph") == "X" and e.get("cat") == "run"]
    total_us = sum(float(e.get("dur", 0.0)) for e in runs) \
        if runs else _span_extent(events)

    phases: Dict[str, List[float]] = defaultdict(list)
    subs: Dict[str, List[float]] = defaultdict(list)
    stalls: Dict[str, int] = defaultdict(int)
    for ev in events:
        if ev.get("ph") != "X":
            continue
        cat = ev.get("cat")
        dur = float(ev.get("dur", 0.0))
        if cat == "phase":
            phases[_phase_key(ev)].append(dur)
        elif cat == "sub":
            subs[str(ev.get("name", "?"))].append(dur)
        stall = (ev.get("args") or {}).get("stall")
        if stall:
            stalls[str(stall)] += 1

    def rows(groups: Dict[str, List[float]]) -> List[Tuple[str, int, float]]:
        out = [(name, len(ds), sum(ds)) for name, ds in groups.items()]
        out.sort(key=lambda r: -r[2])
        return out

    covered_us = sum(sum(ds) for ds in phases.values())
    return {
        "total_us": total_us,
        "n_events": sum(1 for e in events if e.get("ph") == "X"),
        "phases": rows(phases),
        "subs": rows(subs),
        "stalls": sorted(stalls.items(), key=lambda kv: -kv[1]),
        "covered_us": covered_us,
        "coverage": covered_us / total_us if total_us > 0 else 0.0,
    }


def _print_summary(s: Dict[str, Any]) -> None:
    total = s["total_us"]
    print(f"trace: {s['n_events']} spans, "
          f"total {total / 1e3:.2f} ms (engine.run)")
    print(f"{'phase':<18} {'count':>6} {'total_ms':>10} {'%':>6}")
    for name, n, us in s["phases"]:
        pct = 100.0 * us / total if total > 0 else 0.0
        print(f"{name:<18} {n:>6} {us / 1e3:>10.2f} {pct:>5.1f}%")
    print(f"{'(loop coverage)':<18} {'':>6} "
          f"{s['covered_us'] / 1e3:>10.2f} {100.0 * s['coverage']:>5.1f}%")
    if s["subs"]:
        print("sub-phases (nested inside the above, not additive):")
        for name, n, us in s["subs"]:
            pct = 100.0 * us / total if total > 0 else 0.0
            print(f"  {name:<16} {n:>6} {us / 1e3:>10.2f} {pct:>5.1f}%")
    if s["stalls"]:
        print("top stall causes:")
        for cause, n in s["stalls"]:
            print(f"  {cause:<24} x{n}")
    else:
        print("no stalls recorded")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.obs",
        description="analyze traces written by launch/serve.py --trace")
    sub = ap.add_subparsers(dest="cmd", required=True)
    p_sum = sub.add_parser("summarize",
                           help="per-phase time breakdown + stall causes")
    p_sum.add_argument("trace", help="trace file (Chrome JSON or JSONL)")
    args = ap.parse_args(argv)

    if args.cmd == "summarize":
        events = load_trace(args.trace)
        if not events:
            print(f"{args.trace}: no events", file=sys.stderr)
            return 1
        _print_summary(summarize(events))
        return 0
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
