"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state.  Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model); the pod axis
carries either extra data parallelism (default) or the pipeline dimension
(streaming mode — the paper's channels become pod→pod ppermutes).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-grade multi-device tests (requires the host
    platform device count to be raised in a subprocess)."""
    return jax.make_mesh(shape, axes)
