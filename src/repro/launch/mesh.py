"""Production mesh construction — thin wrappers over :class:`MeshSpec`.

The topology lives in the spec constants (compile-time values the flow and
the DSE consume); only ``build()`` touches jax device state, so importing
this module never initializes devices.  Single pod: (16, 16) = 256 chips,
axes (data, model).  Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data,
model); the pod axis carries either extra data parallelism (default) or the
pipeline dimension (streaming mode — the paper's channels become pod→pod
ppermutes).
"""
from __future__ import annotations

from repro.distributed.meshspec import MeshSpec

PRODUCTION_SPEC = MeshSpec((("data", 16), ("model", 16)))
MULTI_POD_SPEC = MeshSpec((("pod", 2), ("data", 16), ("model", 16)))


def make_production_mesh(*, multi_pod: bool = False):
    return (MULTI_POD_SPEC if multi_pod else PRODUCTION_SPEC).build()


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-grade multi-device tests (requires the host
    platform device count to be raised in a subprocess)."""
    return MeshSpec(tuple(zip(axes, shape))).build()
