"""Persistent-autotune-store launcher — inspect and maintain a tunedb.

The store (:mod:`repro.tunedb`) banks measured DSE searches, serving
microbench winners, and per-kernel tile schedules; this CLI is its
maintenance surface:

Usage:
  python -m repro.launch.tune show --db tune.jsonl [--kind explore] [-v]
  python -m repro.launch.tune gc --db tune.jsonl [--keep-stale]
  python -m repro.launch.tune export --db tune.jsonl [--out records.json]

``show`` prints the store summary and one line per record (``-v`` adds the
full key/value payloads).  ``gc`` compacts the append-only log to the
latest record per fingerprint, dropping records from other code versions
unless ``--keep-stale``.  ``export`` writes the indexed records as one
JSON document (stdout by default) for offline analysis or seeding another
machine's store.
"""
import argparse
import dataclasses
import json
import sys
from typing import List, Optional

from repro import tunedb


def _fmt_record(rec: tunedb.TuneRecord, verbose: bool) -> List[str]:
    stale = "" if rec.code_version == tunedb.CODE_VERSION else " STALE"
    head = (f"{rec.kind:8s} {rec.fingerprint[:16]}  dev={rec.device}"
            f"  ver={rec.code_version}{stale}")
    if not verbose:
        return [head]
    return [head,
            "    key:   " + tunedb.canonical_json(rec.key),
            "    value: " + tunedb.canonical_json(rec.value)]


def cmd_show(db: tunedb.TuneDB, *, kind: Optional[str],
             verbose: bool) -> int:
    st = db.stats()
    print(f"tunedb {st['path']}: {st['records']} records "
          f"{st['by_kind']} stale={st['stale']} "
          f"skipped_on_load={st['skipped_on_load']}")
    for rec in db.records(kind):
        for line in _fmt_record(rec, verbose):
            print(line)
    return 0


def cmd_gc(db: tunedb.TuneDB, *, keep_stale: bool) -> int:
    out = db.gc(drop_stale=not keep_stale)
    print(f"tunedb {db.path}: kept={out['kept']} "
          f"dropped_stale={out['dropped_stale']}")
    return 0


def cmd_export(db: tunedb.TuneDB, *, kind: Optional[str],
               out: Optional[str]) -> int:
    recs = [tunedb.encode_value(dataclasses.asdict(r))
            for r in db.records(kind)]
    doc = json.dumps({"code_version": tunedb.CODE_VERSION,
                      "schema": tunedb.SCHEMA_VERSION,
                      "records": recs}, indent=2, sort_keys=True)
    if out:
        with open(out, "w", encoding="utf-8") as f:
            f.write(doc + "\n")
        print(f"exported {len(recs)} records to {out}")
    else:
        print(doc)
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.tune",
        description="inspect/maintain a persistent autotune store")
    sub = ap.add_subparsers(dest="cmd", required=True)
    for name, helpline in (("show", "print the store summary and records"),
                           ("gc", "compact the log (latest per fingerprint)"),
                           ("export", "dump records as one JSON document")):
        p = sub.add_parser(name, help=helpline)
        p.add_argument("--db", required=True, help="path of the JSONL store")
        if name in ("show", "export"):
            p.add_argument("--kind", default=None, choices=tunedb.KINDS,
                           help="only records of this kind")
        if name == "show":
            p.add_argument("-v", "--verbose", action="store_true",
                           help="print full key/value payloads")
        if name == "gc":
            p.add_argument("--keep-stale", action="store_true",
                           help="keep records from other code versions")
        if name == "export":
            p.add_argument("--out", default=None,
                           help="output file (default: stdout)")
    args = ap.parse_args(argv)

    db = tunedb.TuneDB(args.db)
    if args.cmd == "show":
        return cmd_show(db, kind=args.kind, verbose=args.verbose)
    if args.cmd == "gc":
        return cmd_gc(db, keep_stale=args.keep_stale)
    return cmd_export(db, kind=args.kind, out=args.out)


if __name__ == "__main__":
    sys.exit(main())
