"""repro.obs — zero-dependency tracing + metrics for the whole stack.

The paper's evaluation method is measurement: per-network, per-optimization
breakdowns of where the cycles go.  Our stack grew eight PRs of machinery
whose telemetry was ad-hoc — hand-rolled ``time.perf_counter()`` stopwatches
in five modules and counters scattered over ``RunReport.metrics``, the block
pool, the scheduler and the kernel registry.  This package is the single
observability layer they all publish into:

* :class:`~repro.obs.trace.Tracer` — nested spans with attributes in a
  bounded ring buffer; thread-safe; a **no-op when disabled** (one boolean
  check on the hot path).  Context-manager (``with tracer.span(...)``),
  explicit (``sp = tracer.span(...); sp.end()``) and decorator
  (``@tracer.trace()``) APIs.  Exports Chrome trace-event JSON (loads in
  Perfetto / ``chrome://tracing``) and a JSONL event log.
* :class:`~repro.obs.metrics.MetricsRegistry` — typed counters, gauges and
  histograms under stable dotted names (``serving.prefix.hits``,
  ``pool.blocks.live``, ``kernels.dispatch.rejections``, …).  The serving
  engine's ``RunReport.metrics`` is a snapshot of a per-run registry;
  ``benchmarks/run.py`` derives ``BENCH_serving.json`` from the same
  snapshot.

Module-level defaults: :data:`TRACER` (compile-side spans — pass runs,
flow stages, DSE candidate validation, autotune microbenchmarks — all time
through it whether or not recording is on) and :data:`METRICS`
(process-level counters such as kernel dispatch rejections).

Everything here is stdlib-only: no jax, no numpy — the tracer must be
importable from the innermost compile loop without adding a dependency
edge, and the exactness gates stay (engine outputs are byte-identical with
tracing on or off).
"""
from __future__ import annotations

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               METRICS)
from repro.obs.trace import Span, Tracer, TRACER

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "METRICS",
    "MetricsRegistry",
    "Span",
    "TRACER",
    "Tracer",
]
