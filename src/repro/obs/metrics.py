"""Typed metrics registry: counters, gauges, histograms under dotted names.

One :class:`MetricsRegistry` holds every instrument published during a unit
of work (the serving engine builds a fresh one per ``run()``; a module-level
:data:`METRICS` collects process-lifetime counters such as kernel dispatch
rejections).  Instruments are created on first use and addressed by stable
dotted names — the metric-name table in the README is the schema::

    reg = MetricsRegistry()
    reg.counter("serving.prefix.hits").inc()
    reg.gauge("pool.blocks.live").set(12)
    reg.histogram("serving.latency_s").observe(0.03)
    snap = reg.snapshot()      # flat {dotted-name: value} dict

``snapshot()`` flattens everything into plain scalars: a counter
contributes its count, a gauge its last value plus ``<name>.peak``, a
histogram ``<name>.count`` / ``.mean`` / ``.max`` / ``.p50`` / ``.p95``.
The percentile is the same nearest-rank formula the serving report always
used, so a report assembled from the snapshot is bit-identical to the old
hand-assembled dict.

Thread-safety: instrument creation is lock-protected; the individual
updates are plain attribute writes (the GIL makes ``+=`` on the serving
host loop safe, and the engine is single-threaded by construction).
"""
from __future__ import annotations

import math
import threading
from typing import Any, Dict, List, Optional, Union

Number = Union[int, float]


class Counter:
    """Monotonic count.  ``inc`` / ``add`` only go up."""

    __slots__ = ("name", "value")

    def __init__(self, name: str) -> None:
        self.name = name
        self.value: Number = 0

    def inc(self, n: Number = 1) -> None:
        if n < 0:
            raise ValueError(f"{self.name}: counters only go up (got {n})")
        self.value += n

    add = inc

    def __repr__(self) -> str:
        return f"<Counter {self.name}={self.value}>"


class Gauge:
    """Last-write-wins value; the peak since creation rides along (the
    serving report's ``peak_used_blocks`` / ``peak_live_tokens``)."""

    __slots__ = ("name", "value", "peak")

    def __init__(self, name: str) -> None:
        self.name = name
        self.value: Number = 0
        self.peak: Number = 0

    def set(self, v: Number) -> None:
        self.value = v
        if v > self.peak:
            self.peak = v

    def __repr__(self) -> str:
        return f"<Gauge {self.name}={self.value} peak={self.peak}>"


class Histogram:
    """Value distribution with nearest-rank percentiles.

    Keeps raw observations up to ``max_samples`` (serving runs observe one
    latency per request — small); beyond that, new observations still feed
    count/sum/max but the percentile reservoir stops growing."""

    __slots__ = ("name", "samples", "count", "total", "max_value",
                 "max_samples")

    def __init__(self, name: str, max_samples: int = 65536) -> None:
        self.name = name
        self.samples: List[float] = []
        self.count = 0
        self.total = 0.0
        self.max_value = 0.0
        self.max_samples = max_samples

    def observe(self, v: Number) -> None:
        f = float(v)
        self.count += 1
        self.total += f
        if f > self.max_value:
            self.max_value = f
        if len(self.samples) < self.max_samples:
            self.samples.append(f)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile (``p`` in [0, 1]); 0.0 when empty —
        exactly the serving report's historical formula."""
        xs = sorted(self.samples)
        if not xs:
            return 0.0
        return xs[min(len(xs) - 1, int(math.ceil(p * len(xs))) - 1)]

    def __repr__(self) -> str:
        return f"<Histogram {self.name} n={self.count} mean={self.mean:.4g}>"


Instrument = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    """Dotted-name → typed instrument, created on first use.

    Re-requesting a name returns the existing instrument; requesting it as
    a different type raises (the name *is* the schema)."""

    def __init__(self) -> None:
        self._instruments: Dict[str, Instrument] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, kind: type) -> Instrument:
        inst = self._instruments.get(name)
        if inst is None:
            with self._lock:
                inst = self._instruments.get(name)
                if inst is None:
                    inst = kind(name)
                    self._instruments[name] = inst
        if not isinstance(inst, kind):
            raise TypeError(
                f"metric {name!r} is a {type(inst).__name__}, "
                f"requested as {kind.__name__}")
        return inst

    def counter(self, name: str) -> Counter:
        inst = self._get(name, Counter)
        assert isinstance(inst, Counter)
        return inst

    def gauge(self, name: str) -> Gauge:
        inst = self._get(name, Gauge)
        assert isinstance(inst, Gauge)
        return inst

    def histogram(self, name: str) -> Histogram:
        inst = self._get(name, Histogram)
        assert isinstance(inst, Histogram)
        return inst

    def get(self, name: str) -> Optional[Instrument]:
        return self._instruments.get(name)

    def names(self) -> List[str]:
        return sorted(self._instruments)

    def clear(self) -> None:
        with self._lock:
            self._instruments.clear()

    def snapshot(self) -> Dict[str, Any]:
        """Flat ``{dotted-name: scalar}`` view of every instrument (see the
        module docstring for the per-type flattening)."""
        out: Dict[str, Any] = {}
        for name in sorted(self._instruments):
            inst = self._instruments[name]
            if isinstance(inst, Counter):
                out[name] = inst.value
            elif isinstance(inst, Gauge):
                out[name] = inst.value
                out[name + ".peak"] = inst.peak
            else:
                out[name + ".count"] = inst.count
                out[name + ".mean"] = inst.mean
                out[name + ".max"] = inst.max_value
                out[name + ".p50"] = inst.percentile(0.50)
                out[name + ".p95"] = inst.percentile(0.95)
        return out


#: Process-level registry: long-lived publishers (the kernel registry's
#: dispatch-rejection counter) land here; per-run registries are built by
#: their owners (``Engine.run``).
METRICS = MetricsRegistry()
