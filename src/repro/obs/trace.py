"""Nested-span tracer with a bounded ring buffer and Chrome-trace export.

One :class:`Tracer` owns a ring buffer of finished span events.  Spans are
opened with :meth:`Tracer.span` (recorded only while the tracer is enabled;
a shared no-op span otherwise — the disabled path is a single boolean
check) or :meth:`Tracer.timed` (always wall-clocked, recorded only while
enabled — the drop-in replacement for hand-rolled ``t0 = perf_counter()``
blocks whose elapsed time feeds existing stats).  Every finished span
becomes one Chrome trace-event dict (``ph="X"`` complete event with
``name``/``cat``/``ts``/``dur``/``pid``/``tid``/``args``), so the export
loads directly in Perfetto or ``chrome://tracing``.

Thread-safety: the buffer append and tid interning are lock-protected; the
span stack is thread-local, so nesting depth is correct per thread.
"""
from __future__ import annotations

import functools
import json
import threading
import time
from collections import deque
from typing import (Any, Callable, Deque, Dict, Iterator, List, Optional,
                    TypeVar)

_F = TypeVar("_F", bound=Callable[..., Any])

#: One exported trace event (Chrome trace-event "complete" format).
Event = Dict[str, Any]


class Span:
    """One open span.  Usable as a context manager or ended explicitly via
    :meth:`end` (idempotent — the first call wins); ``set()`` attaches
    attributes at any point before the end.  ``elapsed_s`` is valid after
    the span has ended (and live-reads while it is still open)."""

    __slots__ = ("_tracer", "_record", "name", "cat", "attrs", "_t0",
                 "_t_end", "depth", "_ended")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 attrs: Dict[str, Any], record: bool) -> None:
        self._tracer = tracer
        self._record = record
        self.name = name
        self.cat = cat
        self.attrs = attrs
        self.depth = tracer._push(self) if record else 0
        self._t0 = tracer._clock()
        self._t_end: Optional[float] = None
        self._ended = False

    # -- lifecycle -----------------------------------------------------------
    def set(self, **attrs: Any) -> "Span":
        self.attrs.update(attrs)
        return self

    def end(self, **attrs: Any) -> None:
        """Close the span (no-op on a second call) and record its event."""
        if self._ended:
            return
        self._ended = True
        self._t_end = self._tracer._clock()
        if attrs:
            self.attrs.update(attrs)
        if self._record:
            self._tracer._pop(self)
            self._tracer._emit(self)

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.end()

    # -- timing --------------------------------------------------------------
    @property
    def elapsed_s(self) -> float:
        end = self._t_end if self._t_end is not None else self._tracer._clock()
        return end - self._t0

    @property
    def elapsed_ms(self) -> float:
        return self.elapsed_s * 1e3


class _NoopSpan(Span):
    """The shared disabled-path span: every operation is a no-op and the
    elapsed time is 0.0 (callers needing wall time use ``timed()``)."""

    def __init__(self) -> None:  # no tracer, no clock reads
        pass

    def set(self, **attrs: Any) -> "Span":
        return self

    def end(self, **attrs: Any) -> None:
        return None

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc: Any) -> None:
        return None

    @property
    def elapsed_s(self) -> float:
        return 0.0


_NOOP = _NoopSpan()


class Tracer:
    """Bounded, thread-safe span recorder.

    * ``enabled=False`` (the default): :meth:`span` returns a shared no-op
      span after one boolean check — nothing is timed or stored.
    * ``max_events`` bounds the ring buffer: the newest events win, the
      oldest are dropped (``n_dropped`` counts them).
    * ``clock`` is injectable (defaults to ``time.perf_counter``) so span
      timelines are deterministic under test.
    """

    def __init__(self, enabled: bool = False, *, max_events: int = 65536,
                 clock: Callable[[], float] = time.perf_counter,
                 pid: int = 0) -> None:
        if max_events < 1:
            raise ValueError(f"max_events must be >= 1, got {max_events}")
        self.enabled = enabled
        self.max_events = max_events
        self.pid = pid
        self._clock = clock
        self._epoch = clock()
        self._buf: Deque[Event] = deque(maxlen=max_events)
        self._lock = threading.Lock()
        self._local = threading.local()
        self._tids: Dict[int, int] = {}      # thread ident -> small tid
        self.n_dropped = 0

    # -- span plumbing (internal) -------------------------------------------
    def _stack(self) -> List[Span]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = []
            self._local.stack = st
        return st

    def _push(self, span: Span) -> int:
        st = self._stack()
        depth = len(st)
        st.append(span)
        return depth

    def _pop(self, span: Span) -> None:
        st = self._stack()
        if st and st[-1] is span:
            st.pop()
        elif span in st:                     # out-of-order end: drop through
            st.remove(span)

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(ident, len(self._tids))
        return tid

    def _emit(self, span: Span) -> None:
        t_end = span._t_end if span._t_end is not None else self._clock()
        ev: Event = {
            "name": span.name,
            "cat": span.cat or "span",
            "ph": "X",
            "ts": (span._t0 - self._epoch) * 1e6,
            "dur": (t_end - span._t0) * 1e6,
            "pid": self.pid,
            "tid": self._tid(),
            "args": dict(span.attrs),
            "depth": span.depth,
        }
        with self._lock:
            if len(self._buf) == self.max_events:
                self.n_dropped += 1
            self._buf.append(ev)

    # -- public API ----------------------------------------------------------
    def span(self, name: str, cat: str = "span", **attrs: Any) -> Span:
        """Open a recorded span — or the shared no-op span when disabled
        (the hot-path contract: one boolean check, no clock read)."""
        if not self.enabled:
            return _NOOP
        return Span(self, name, cat, attrs, record=True)

    def timed(self, name: str, cat: str = "timed", **attrs: Any) -> Span:
        """Open an always-wall-clocked span, recorded only while enabled —
        the one-code-path replacement for hand-rolled stopwatch blocks:
        ``elapsed_s`` is valid whether or not tracing is on."""
        return Span(self, name, cat, attrs, record=self.enabled)

    def trace(self, name: Optional[str] = None,
              cat: str = "fn") -> Callable[[_F], _F]:
        """Decorator form: the wrapped call runs inside a span (named after
        the function unless overridden); zero overhead beyond one boolean
        check while disabled."""
        def deco(fn: _F) -> _F:
            label = name if name is not None else fn.__qualname__

            @functools.wraps(fn)
            def wrapper(*args: Any, **kw: Any) -> Any:
                if not self.enabled:
                    return fn(*args, **kw)
                with self.span(label, cat=cat):
                    return fn(*args, **kw)
            return wrapper  # type: ignore[return-value]
        return deco

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self.n_dropped = 0

    def events(self) -> List[Event]:
        """Snapshot of the ring buffer, oldest first."""
        with self._lock:
            return list(self._buf)

    def __len__(self) -> int:
        return len(self._buf)

    # -- export --------------------------------------------------------------
    def to_chrome(self, path: Optional[str] = None) -> Dict[str, Any]:
        """Chrome trace-event JSON (``{"traceEvents": [...]}``): loads in
        Perfetto (ui.perfetto.dev) and ``chrome://tracing``.  Written to
        ``path`` when given; the document is returned either way."""
        events = sorted(self.events(), key=lambda e: (e["ts"], -e["dur"]))
        doc: Dict[str, Any] = {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {"producer": "repro.obs",
                          "n_dropped": self.n_dropped},
        }
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
                f.write("\n")
        return doc

    def to_jsonl(self, path: str) -> None:
        """One JSON event per line (stream-appendable log form)."""
        with open(path, "w") as f:
            for ev in self.events():
                f.write(json.dumps(ev))
                f.write("\n")


def load_trace(path: str) -> List[Event]:
    """Read a trace written by :meth:`Tracer.to_chrome` (a traceEvents
    document or a bare event array) or :meth:`Tracer.to_jsonl`."""
    with open(path) as f:
        text = f.read()
    try:
        doc = json.loads(text)
    except json.JSONDecodeError:              # JSONL: one event per line
        return [json.loads(line) for line in text.splitlines()
                if line.strip()]
    if isinstance(doc, dict):
        events = doc.get("traceEvents", [])
    else:
        events = doc
    if not isinstance(events, list):
        raise ValueError(f"{path}: not a trace-event document")
    return events


def _iter_spans(events: List[Event]) -> Iterator[Event]:
    for ev in events:
        if ev.get("ph") == "X":
            yield ev


#: Module default: compile-side code (pass runs, flow stages, DSE candidate
#: validation, autotune microbenchmarks) times through this tracer so every
#: stopwatch in the stack is one code path; enable it to watch a compile.
TRACER = Tracer(enabled=False)
