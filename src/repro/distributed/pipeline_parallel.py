"""Cross-pod pipeline parallelism — the paper's channelization (CH) on TPU.

In pipelined execution the paper keeps every layer's kernel resident and
streams activations through OpenCL channels.  Across pods, the analogue is
GPipe: the folded layer stack is sharded over the ``pod`` axis (each pod owns
a contiguous run of layers), and microbatch activations stream pod→pod via
``jax.lax.ppermute`` — the ICI link is the channel, the number of in-flight
microbatches is the channel depth.  Inside the shard_map only ``pod`` is
manual; ``data``/``model`` sharding stays automatic (GSPMD), so FSDP/TP
compose with the pipeline.

Applies to plans whose layers fold into a single scan group with
``reps % n_stages == 0`` (true for all ten assigned archs on a 2-pod mesh).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import lowering
from repro.core.graph import Graph
from repro.core.ops_impl import OPS, Ctx
from repro.core.plan import ExecutionPlan


def _single_fold_unit(plan: ExecutionPlan):
    folded = [u for u in plan.units if u.folded]
    assert len(folded) == 1, (
        "pipeline mode requires a single folded layer group; got "
        f"{len(folded)} (use folded execution instead)")
    return folded[0]


def make_pipeline_loss(plan: ExecutionPlan, mesh, n_microbatches: int,
                       pp_axis: Optional[str] = None):
    """Returns loss(params, batch) running a GPipe schedule over ``pp_axis``.

    params uses the standard lowering layout; the folded group's stacked
    params are sharded over ``pp_axis`` on their layer dim.  The stage
    assignment comes from the plan's recorded ShardingPlan when present
    (``plan.sharding`` — the ShardingPass's decision); ``pp_axis`` and the
    stage count then must agree with the runtime mesh.
    """
    graph = plan.graph
    unit = _single_fold_unit(plan)
    ukey = lowering.unit_key(graph, unit)
    sp = plan.sharding
    if pp_axis is None:
        pp_axis = sp.pp_axis if sp is not None and sp.pp_axis else "pod"
    n_stages = mesh.shape[pp_axis]
    if sp is not None and sp.pp_axis == pp_axis and sp.n_stages > 1:
        assert sp.n_stages == n_stages, (
            f"plan assigned {sp.n_stages} pipeline stages but mesh axis "
            f"{pp_axis!r} has size {n_stages}")
        assert len(sp.stage_of_layer) == unit.reps, (sp.stage_of_layer,
                                                     unit.reps)
        # the GPipe layout below shards the stacked layer dim evenly over
        # pp_axis — exactly the contiguous equal runs the pass assigns
        per = unit.reps // n_stages
        assert sp.stage_of_layer == tuple(r // per for r in range(unit.reps))
    assert unit.reps % n_stages == 0, (unit.reps, n_stages)
    nmb = n_microbatches
    cfg = plan.cfg
    protos = [graph.blocks[i] for i in unit.indices[:unit.period]]
    embed_block = graph.blocks[0]
    head_block = graph.blocks[-1]

    def run_stage_layers(gparams, h):
        outer = Ctx(mode="train", plan=plan)

        def body(carry, step_params):
            c = Ctx(mode="train", plan=plan)
            c.state_in = {}
            c.state_out = {}
            e = {"h": carry, "positions": None, "cross": None}
            S = carry.shape[1]
            e["positions"] = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32), (carry.shape[0], S))
            for j, blk in enumerate(protos):
                e["h"] = lowering._run_block(c, blk, step_params, e,
                                             "train", j=j)
            return e["h"], None
        body = jax.checkpoint(body, prevent_cse=False)
        h, _ = jax.lax.scan(body, h, gparams)
        return h

    def embed(eparams, tokens):
        ctx = Ctx(mode="train", plan=plan)
        env = {"h": tokens,
               "positions": jnp.broadcast_to(
                   jnp.arange(tokens.shape[1], dtype=jnp.int32),
                   tokens.shape)}
        return lowering._run_block(ctx, embed_block, eparams, env, "train")

    def head_loss(hparams, tied, h, labels):
        ctx = Ctx(mode="train", plan=plan)
        env = {"h": h}
        for op in head_block.ops:
            if op.op == "unembed":
                break
            args = [env[i] for i in op.ins]
            env[op.out] = OPS[op.op](
                ctx, op, lowering._param_slice(op, hparams, None), *args)
        un = head_block.ops[-1]
        hn = env[un.ins[0]]
        table = tied if un.attrs.get("tied") else hparams["lm_head"]
        loss, _ = lowering._chunked_ce(ctx, hn, table, labels,
                                       cfg.vocab_size,
                                       plan.tiles.get("ce_chunk", 256))
        return loss

    def pipe(params, tokens_mb, labels_mb):
        """Runs inside shard_map; pod axis manual."""
        ax = jax.lax.axis_index(pp_axis)
        gparams = params[ukey]                     # layer dim already local
        eparams = params.get(embed_block.name, {})
        hparams = params.get(head_block.name, {})
        tied = params[embed_block.name]["table"] \
            if head_block.ops[-1].attrs.get("tied") else 0.0
        B, S = tokens_mb.shape[1], tokens_mb.shape[2]
        d = cfg.d_model
        dt = jnp.bfloat16 if plan.flow.precision == "bf16" else jnp.float32
        T = nmb + n_stages - 1
        perm = [(s, s + 1) for s in range(n_stages - 1)]

        def step(carry, t):
            h_out_prev, loss_acc = carry
            h_in = jax.lax.ppermute(h_out_prev, pp_axis, perm)
            mb = t - ax
            mb_c = jnp.clip(mb, 0, nmb - 1)
            toks = jax.lax.dynamic_index_in_dim(tokens_mb, mb_c, 0, False)
            labs = jax.lax.dynamic_index_in_dim(labels_mb, mb_c, 0, False)
            x = jax.lax.cond(ax == 0,
                             lambda: embed(eparams, toks).astype(dt),
                             lambda: h_in)
            h_out = run_stage_layers(gparams, x)
            # the accumulator stays rank-1: scalar residuals of this scan
            # trip a shape-bookkeeping bug in the pre-0.6 shard_map transpose
            lmb = jax.lax.cond(
                jnp.logical_and(ax == n_stages - 1,
                                jnp.logical_and(mb >= 0, mb < nmb)),
                lambda: head_loss(hparams, tied, h_out, labs).reshape(1),
                lambda: jnp.zeros((1,), jnp.float32))
            return (h_out, loss_acc + lmb), None

        h0 = jnp.zeros((B, S, d), dt)
        (_, loss), _ = jax.lax.scan(step, (h0, jnp.zeros((1,), jnp.float32)),
                                    jnp.arange(T, dtype=jnp.int32))
        # per-stage partial loss (non-zero on the last stage only), returned
        # sharded over pp_axis and summed outside the manual region — a
        # replicated scalar output would need an in-region psum whose
        # transpose the pre-0.6 shard_map rejects under check_rep=False
        return loss / nmb

    # shard_map wiring: stacked layer params split over pod; rest replicated
    def pspec_for(path_key: str):
        return P(pp_axis) if path_key == ukey else P()

    in_specs = ({k: jax.tree.map(lambda _: P(pp_axis), v) if k == ukey
                 else jax.tree.map(lambda _: P(), v)
                 for k, v in lowering.param_shapes(plan).items()},
                P(), P())

    def loss_fn(params, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        B = tokens.shape[0]
        assert B % nmb == 0
        tmb = tokens.reshape(nmb, B // nmb, -1)
        lmb = labels.reshape(nmb, B // nmb, -1)
        from repro.core.compat import shard_map
        f = shard_map(pipe, mesh, in_specs, P(pp_axis),
                      axis_names={pp_axis})
        return jnp.sum(f(params, tmb, lmb))

    return loss_fn
