"""Runtime binding of the plan's sharding decisions to a live ``jax.Mesh``.

The *solver* (role -> mesh-axis assignment with divisibility checks) lives
in :mod:`repro.core.passes.sharding` — partitioning is a compilation
decision the ``ShardingPass`` records on the ``ExecutionPlan``
(``plan.sharding``).  ``ShardingRules`` here turns those decisions into
``NamedSharding`` trees and ``with_sharding_constraint`` calls against a
concrete mesh: when the plan carries a ``ShardingPlan`` whose factorization
matches the mesh, the recorded per-param ``PartitionSpec``s are used
verbatim; otherwise (legacy plans, ad-hoc meshes) the same solver is run on
the fly, so both paths make identical decisions.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.graph import ParamSpec
from repro.core.passes.sharding import (  # noqa: F401  (re-exported: the
    ACT_ROLE_AXES, FSDP_ROLES, TP_ROLES,  # tables' historical home is here)
    solve_act_pspec, solve_param_pspec)


@dataclass
class ShardingRules:
    mesh: Mesh
    dp: Tuple[str, ...] = ("data",)
    tp: Optional[str] = "model"

    @property
    def dp_size(self) -> int:
        n = 1
        for a in self.dp:
            n *= self.mesh.shape[a]
        return n

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp] if self.tp else 1

    @property
    def _axis_sizes(self) -> Dict[str, int]:
        return {a: int(self.mesh.shape[a]) for a in self.mesh.axis_names}

    # -- parameters ---------------------------------------------------------
    def param_pspec(self, spec: ParamSpec, shape: Tuple[int, ...],
                    stacked: bool) -> P:
        roles = (("layers",) + spec.roles) if stacked else spec.roles
        return solve_param_pspec(roles, shape, self.dp, self.tp,
                                 self._axis_sizes)

    def param_sharding(self, spec: ParamSpec, shape: Tuple[int, ...],
                       stacked: bool) -> NamedSharding:
        return NamedSharding(self.mesh, self.param_pspec(spec, shape, stacked))

    def _axis_size(self, entry) -> int:
        if entry is None:
            return 1
        if isinstance(entry, tuple):
            n = 1
            for a in entry:
                n *= self.mesh.shape[a]
            return n
        return self.mesh.shape[entry]

    def _drop_undivisible(self, ps: P, shape: Tuple[int, ...]) -> P:
        entries = []
        for i, entry in enumerate(ps):
            if entry is not None and shape[i] % self._axis_size(entry) != 0:
                entry = None
            entries.append(entry)
        return P(*entries)

    # -- activations --------------------------------------------------------
    def act_pspec(self, roles: Tuple[str, ...],
                  shape: Tuple[int, ...]) -> P:
        return solve_act_pspec(roles, shape, self.dp, self.tp,
                               self._axis_sizes)

    def constrain_act(self, x, roles: Tuple[str, ...]):
        if len(roles) != x.ndim:
            roles = tuple(roles[: x.ndim]) + ("none",) * (x.ndim - len(roles))
        ps = self.act_pspec(roles, x.shape)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, ps))

    # -- whole-tree helpers ---------------------------------------------------
    def _plan_specs(self, plan) -> Optional[Dict[str, P]]:
        """The ShardingPass's recorded per-param specs, when they were solved
        for this mesh's factorization (else None -> solve on the fly)."""
        sp = getattr(plan, "sharding", None)
        if sp is None or not sp.param_specs:
            return None
        if dict(sp.mesh.axes) != self._axis_sizes:
            return None                     # plan solved for another mesh
        return sp.param_specs

    def params_shardings(self, plan) -> Dict[str, Any]:
        """Sharding tree matching the params pytree of ``plan`` — read from
        the plan's recorded ShardingPlan when available."""
        from repro.core.lowering import param_specs_tree, param_shapes
        shapes = param_shapes(plan)
        specs = param_specs_tree(plan)
        recorded = self._plan_specs(plan) or {}

        def one(top, leaf):
            ps = recorded.get(f"{top}/{leaf}")
            if ps is None:                 # not recorded: solve on the fly
                sv, sh = specs[top][leaf], shapes[top][leaf]
                ps = self.param_pspec(sv[0], sh.shape, sv[1])
            return NamedSharding(self.mesh, ps)

        return {top: {leaf: one(top, leaf) for leaf in leaves}
                for top, leaves in shapes.items()}

    def batch_sharding(self, batch_shapes: Dict[str, Any]) -> Dict[str, Any]:
        out = {}
        for k, v in batch_shapes.items():
            ent = self.dp if len(self.dp) > 1 else self.dp[0]
            if v.shape[0] % self.dp_size != 0:
                ent = None
            out[k] = NamedSharding(self.mesh, P(ent))
        return out

    def state_sharding(self, state_tree) -> Any:
        """KV caches: (…, C, KV, Dh) length over tp, batch over dp; recurrence
        states: batch over dp.  Applied by leaf shape heuristics."""
        def one(x):
            shape = x.shape
            ent_dp = self.dp if len(self.dp) > 1 else self.dp[0]
            entries = [None] * len(shape)
            # find batch dim: first dim divisible by dp (stacked states have
            # a leading layers dim; batch is dim 0 or 1)
            for i in range(min(2, len(shape))):
                if shape[i] % self.dp_size == 0:
                    entries[i] = ent_dp
                    bdim = i
                    break
            else:
                bdim = -1
            if self.tp and len(shape) >= bdim + 2 and bdim >= 0:
                # KV caches: (B, C, KV, Dh) / stacked (L, B, C, KV, Dh)
                if len(shape) - bdim == 4 or (len(shape) - bdim == 2
                                              and x.dtype == jax.numpy.int32):
                    c = bdim + 1
                    if shape[c] % self.tp_size == 0:
                        entries[c] = self.tp
            return NamedSharding(self.mesh, P(*entries))
        return jax.tree.map(one, state_tree)
