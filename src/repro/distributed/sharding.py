"""Divisibility-aware sharding solver.

Maps ParamSpec dimension *roles* onto mesh axes:

* **tp ("model")** — d_ff (Megatron column/row FFN), vocab (embedding/head),
  expert (EP, when num_experts divides the axis), heads (storage sharding of
  attention projections; compute-level attention parallelism is context
  parallelism over the sequence, which works for every head count).
* **fsdp (dp axes)** — the largest remaining divisible dim (d_model first):
  ZeRO-3-style parameter + optimizer-state sharding; XLA inserts the
  all-gathers at use and reduce-scatters the gradients.

Activations are constrained by role tuples at strategic points (attention
entry/exit = context parallelism, MoE dispatch buffers, logits).  Every
assignment checks divisibility — jit rejects uneven shards — and never uses
a mesh axis twice in one spec.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.graph import ParamSpec

# role -> priority order for the tp axis (first divisible wins).
# "heads_in" is deliberately absent: the attention out-projection stays
# row-local (its input is already sequence-sharded by context parallelism).
TP_ROLES = ("expert", "d_ff", "vocab", "heads")
# role -> priority for fsdp
FSDP_ROLES = ("d_model", "heads", "heads_in", "d_ff", "vocab", "expert",
              "layers")

ACT_ROLE_AXES = {
    "batch": "__dp__",
    "seq_cp": "__tp__",      # context-parallel sequence sharding
    "kv_len": "__tp__",      # decode: KV cache length over tp
    "vocab": "__tp__",
    "d_ff": "__tp__",
    "expert": "__tp__",
    "heads": "__tp__",
    "gather": None,          # force replication (KV all-gather)
    "none": None,
    "seq": None,
}


@dataclass
class ShardingRules:
    mesh: Mesh
    dp: Tuple[str, ...] = ("data",)
    tp: Optional[str] = "model"

    @property
    def dp_size(self) -> int:
        n = 1
        for a in self.dp:
            n *= self.mesh.shape[a]
        return n

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp] if self.tp else 1

    # -- parameters ---------------------------------------------------------
    def param_pspec(self, spec: ParamSpec, shape: Tuple[int, ...],
                    stacked: bool) -> P:
        roles = (("layers",) + spec.roles) if stacked else spec.roles
        assert len(roles) == len(shape), (spec.name, roles, shape)
        entries: list = [None] * len(roles)
        used_tp = self.tp is None
        for want in TP_ROLES:
            if used_tp:
                break
            for i, r in enumerate(roles):
                if r == want and shape[i] % self.tp_size == 0:
                    entries[i] = self.tp
                    used_tp = True
                    break
        dp_ent = self.dp if len(self.dp) > 1 else self.dp[0]
        for want in FSDP_ROLES:
            done = False
            for i, r in enumerate(roles):
                if (r == want and entries[i] is None
                        and shape[i] % self.dp_size == 0):
                    entries[i] = dp_ent
                    done = True
                    break
            if done:
                break
        return P(*entries)

    def param_sharding(self, spec: ParamSpec, shape: Tuple[int, ...],
                       stacked: bool) -> NamedSharding:
        return NamedSharding(self.mesh, self.param_pspec(spec, shape, stacked))

    def _axis_size(self, entry) -> int:
        if entry is None:
            return 1
        if isinstance(entry, tuple):
            n = 1
            for a in entry:
                n *= self.mesh.shape[a]
            return n
        return self.mesh.shape[entry]

    def _drop_undivisible(self, ps: P, shape: Tuple[int, ...]) -> P:
        entries = []
        for i, entry in enumerate(ps):
            if entry is not None and shape[i] % self._axis_size(entry) != 0:
                entry = None
            entries.append(entry)
        return P(*entries)

    # -- activations --------------------------------------------------------
    def act_pspec(self, roles: Tuple[str, ...],
                  shape: Tuple[int, ...]) -> P:
        entries = []
        used = set()
        for i, r in enumerate(roles):
            ax = ACT_ROLE_AXES.get(r)
            if ax == "__dp__":
                ent = self.dp if len(self.dp) > 1 else self.dp[0]
                flat = self.dp
            elif ax == "__tp__":
                ent = self.tp
                flat = (self.tp,)
            else:
                ent = None
                flat = ()
            if ent is not None and (set(flat) & used
                                    or shape[i] % self._axis_size(ent) != 0):
                ent = None
                flat = ()
            used |= set(flat)
            entries.append(ent)
        return P(*entries)

    def constrain_act(self, x, roles: Tuple[str, ...]):
        if len(roles) != x.ndim:
            roles = tuple(roles[: x.ndim]) + ("none",) * (x.ndim - len(roles))
        ps = self.act_pspec(roles, x.shape)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, ps))

    # -- whole-tree helpers ---------------------------------------------------
    def params_shardings(self, plan) -> Dict[str, Any]:
        """Sharding tree matching the params pytree of ``plan``."""
        from repro.core.lowering import param_specs_tree, param_shapes
        specs = param_specs_tree(plan)
        shapes = param_shapes(plan)
        return jax.tree.map(
            lambda sv, sh: self.param_sharding(sv[0], sh.shape, sv[1]),
            specs, shapes, is_leaf=lambda v: isinstance(v, tuple)
            and len(v) == 2 and isinstance(v[1], bool))

    def batch_sharding(self, batch_shapes: Dict[str, Any]) -> Dict[str, Any]:
        out = {}
        for k, v in batch_shapes.items():
            ent = self.dp if len(self.dp) > 1 else self.dp[0]
            if v.shape[0] % self.dp_size != 0:
                ent = None
            out[k] = NamedSharding(self.mesh, P(ent))
        return out

    def state_sharding(self, state_tree) -> Any:
        """KV caches: (…, C, KV, Dh) length over tp, batch over dp; recurrence
        states: batch over dp.  Applied by leaf shape heuristics."""
        def one(x):
            shape = x.shape
            ent_dp = self.dp if len(self.dp) > 1 else self.dp[0]
            entries = [None] * len(shape)
            # find batch dim: first dim divisible by dp (stacked states have
            # a leading layers dim; batch is dim 0 or 1)
            for i in range(min(2, len(shape))):
                if shape[i] % self.dp_size == 0:
                    entries[i] = ent_dp
                    bdim = i
                    break
            else:
                bdim = -1
            if self.tp and len(shape) >= bdim + 2 and bdim >= 0:
                # KV caches: (B, C, KV, Dh) / stacked (L, B, C, KV, Dh)
                if len(shape) - bdim == 4 or (len(shape) - bdim == 2
                                              and x.dtype == jax.numpy.int32):
                    c = bdim + 1
                    if shape[c] % self.tp_size == 0:
                        entries[c] = self.tp
            return NamedSharding(self.mesh, P(*entries))
        return jax.tree.map(one, state_tree)
