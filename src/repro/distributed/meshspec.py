"""MeshSpec — the device-mesh topology as a compile-time value.

The mesh used to be a hard-coded shape in ``launch/mesh.py`` and the
partitioning decisions a side effect of launch wiring; ``MeshSpec`` makes
the topology a first-class input of the compilation flow.  It is a frozen,
hashable (axis name, size) tuple, so it can live on ``FlowConfig``
(``mesh_split``), participate in DSE fingerprints, and be recorded on the
``ExecutionPlan`` — independent of any live ``jax.Mesh``.

``MeshSpec.of`` normalizes every accepted spelling of a mesh:

* a ``MeshSpec`` (identity),
* an axis-size dict ``{"data": 2, "model": 2}`` (insertion order kept),
* a ``(("data", 2), ("model", 2))`` tuple,
* a live ``jax.sharding.Mesh`` (names + sizes extracted).

``build()`` binds the spec to real devices (``jax.make_mesh``) — the only
place a device is touched.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Mapping, Tuple


@dataclass(frozen=True)
class MeshSpec:
    axes: Tuple[Tuple[str, int], ...]          # ordered (axis name, size)

    def __post_init__(self):
        names = [a for a, _ in self.axes]
        if len(names) != len(set(names)):
            raise ValueError(f"duplicate mesh axis names: {names}")
        for a, n in self.axes:
            if n < 1:
                raise ValueError(f"mesh axis {a!r} has non-positive size {n}")

    # -- constructors -------------------------------------------------------
    @classmethod
    def of(cls, mesh) -> "MeshSpec":
        """Normalize a MeshSpec | axis-size dict | (name, size) tuple |
        jax Mesh into a MeshSpec."""
        if isinstance(mesh, MeshSpec):
            return mesh
        if isinstance(mesh, Mapping):
            return cls(tuple((str(k), int(v)) for k, v in mesh.items()))
        if isinstance(mesh, tuple):
            return cls(tuple((str(k), int(v)) for k, v in mesh))
        axis_names = getattr(mesh, "axis_names", None)
        shape = getattr(mesh, "shape", None)       # Mesh.shape: name -> size
        if axis_names is not None and shape is not None:
            return cls(tuple((a, int(shape[a])) for a in axis_names))
        raise TypeError(
            f"cannot interpret {type(mesh).__name__} as a mesh spec; pass a "
            "MeshSpec, an axis-size dict, a ((name, size), ...) tuple, or a "
            "jax Mesh")

    # -- views --------------------------------------------------------------
    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(a for a, _ in self.axes)

    @property
    def sizes(self) -> Tuple[int, ...]:
        return tuple(n for _, n in self.axes)

    @property
    def shape(self) -> Dict[str, int]:
        return dict(self.axes)

    @property
    def size(self) -> int:
        n = 1
        for _, s in self.axes:
            n *= s
        return n

    def axis_size(self, name: str) -> int:
        return dict(self.axes).get(name, 1)

    def describe(self) -> str:
        return ",".join(f"{a}:{n}" for a, n in self.axes)

    # -- device binding -----------------------------------------------------
    def build(self):
        """Bind to the local devices: ``jax.make_mesh(sizes, names)``.
        Requires ``self.size`` visible devices."""
        import jax
        return jax.make_mesh(self.sizes, self.names)
