"""Flash-attention Pallas kernel (causal / sliding-window, GQA).

Tiling (block_q × block_k) is chosen by the tiling pass so q/k/v tiles, the
fp32 score block, and the fp32 output accumulator fit VMEM — the HBM-side S²
score matrix of the reference path never exists (the paper's loop-fusion +
cached-writes story applied to attention).  Online softmax state (running
max / sum / output) lives in VMEM scratch across the K grid axis.

Sliding windows skip K blocks wholly outside [q_lo - window, q_hi]; causal
masking skips blocks above the diagonal (the analogue of not generating
hardware for loop iterations that are statically dead).

Masking is positional: per-row position arrays for queries and keys ride
into the kernel as (1, bq) / (1, bk) VMEM rows, with padded entries carrying
-1 (masked as keys, garbage-and-discarded as queries).  Callers that pass no
``positions`` get broadcast aranges — bit-identical to index-space masking —
while the serving engine's left-padded bucketed prefill passes per-row
shifted aranges (``arange(S) - pad``), making bucketed prefill exact on the
Pallas path.  The static block-skip tests stay in index space, which is
valid precisely because each row's q and k positions share one shift: the
positions contract is *per-row monotone shifted arange*, not arbitrary
per-token positions.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, qp_ref, kp_ref, o_ref, m_ref, l_ref,
            acc_ref, *, nk: int, bq: int, bk: int, causal: bool,
            window: Optional[int], softcap: Optional[float], scale: float,
            q_offset: int):
    i = pl.program_id(2)
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # static block skips run in index space: with per-row shifted-arange
    # positions, kpos <= qpos iff k_idx <= q_idx (the shift cancels), so a
    # block dead under the index-space test is dead under the positional
    # mask too
    q_lo = i * bq + q_offset
    k_lo = kb * bk
    run = jnp.asarray(True)
    if causal:
        run = jnp.logical_and(run, k_lo <= q_lo + bq - 1)
    if window:
        run = jnp.logical_and(run, k_lo + bk - 1 >= q_lo - window + 1)

    @pl.when(run)
    def _():
        q = q_ref[0, 0].astype(jnp.float32) * scale    # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        qpos = qp_ref[0][:, None]                      # (bq, 1)
        kpos = kp_ref[0][None, :]                      # (1, bk)
        valid = kpos >= 0                              # pad keys masked
        if causal:
            valid &= kpos <= qpos
        if window:
            valid &= kpos > qpos - window
        s = jnp.where(valid, s, NEG)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v_ref[0, 0].astype(jnp.float32),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(kb == nk - 1)
    def _():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    positions: Optional[jax.Array] = None,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    tile: Tuple[int, int] = (256, 512),
                    q_offset: int = 0,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Skv, KV, D) with H = KV * G.
    Returns (B, Sq, H, D).  ``q_offset`` is the absolute position of q[0]
    (used when queries are a sequence-parallel shard).

    ``positions`` — optional (B, Sq) per-row absolute token positions used
    for BOTH queries and keys (self-attention over one token stream; requires
    Skv == Sq and q_offset == 0).  Entries < 0 mark padding: such keys are
    masked everywhere and such query rows produce garbage the caller
    discards.  Contract: valid entries per row must form a contiguous
    shifted arange (left-padded bucketed prefill), which keeps the kernel's
    index-space block skipping exact.  ``None`` keeps the classic broadcast
    arange and is bit-identical to the pre-positional kernel."""
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    if positions is None:
        qp = jnp.broadcast_to(
            jnp.arange(Sq, dtype=jnp.int32) + q_offset, (B, Sq))
        kp = jnp.broadcast_to(jnp.arange(Skv, dtype=jnp.int32), (B, Skv))
    else:
        if positions.shape != (B, Sq):
            raise ValueError(
                f"positions must be (B, Sq)=({B}, {Sq}); "
                f"got {positions.shape}")
        if Skv != Sq:
            raise ValueError(
                "per-row positions require self-attention shapes "
                f"(Skv == Sq); got Sq={Sq}, Skv={Skv}")
        if q_offset:
            raise ValueError("positions and q_offset are mutually exclusive "
                             "(positions are absolute)")
        qp = kp = positions.astype(jnp.int32)
    bq, bk = tile
    bq = min(bq, _rup(Sq, 8))
    bk = min(bk, _rup(Skv, 128))
    Sqp, Skp = _rup(Sq, bq), _rup(Skv, bk)
    qt = jnp.pad(q, ((0, 0), (0, Sqp - Sq), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    kt = jnp.pad(k, ((0, 0), (0, Skp - Skv), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    vt = jnp.pad(v, ((0, 0), (0, Skp - Skv), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    # pad positions with -1: the padded tail is masked positionally (the
    # pre-positional kernel's kv_len test, folded into the arrays)
    qpp = jnp.pad(qp, ((0, 0), (0, Sqp - Sq)), constant_values=-1)
    kpp = jnp.pad(kp, ((0, 0), (0, Skp - Skv)), constant_values=-1)
    nq, nk = Sqp // bq, Skp // bk
    grid = (B, H, nq, nk)

    kern = functools.partial(
        _kernel, nk=nk, bq=bq, bk=bk, causal=causal, window=window,
        softcap=softcap, scale=D ** -0.5, q_offset=q_offset)
    out = pl.pallas_call(
        kern, grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, kb: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, kb, G=G: (b, h // G, kb, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, kb, G=G: (b, h // G, kb, 0)),
            pl.BlockSpec((1, bq), lambda b, h, i, kb: (b, i)),
            pl.BlockSpec((1, bk), lambda b, h, i, kb: (b, kb)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, kb: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sqp, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret)(qt, kt, vt, qpp, kpp)
    return out.transpose(0, 2, 1, 3)[:, :Sq]


def _rup(n, m):
    return (n + m - 1) // m * m
