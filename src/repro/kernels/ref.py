"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def _act(x, kind):
    from repro.core.ops_impl import _act as a
    return a(x, kind)


def matmul_fused_ref(x, w, *, bias=None, w2=None, act=None, out_dtype=None):
    y = jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))
    if w2 is not None:
        y2 = jnp.matmul(x.astype(jnp.float32), w2.astype(jnp.float32))
        y = _act(y, act or "silu") * y2
        act = None
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if act:
        y = _act(y, act)
    return y.astype(out_dtype or x.dtype)


def flash_attention_ref(q, k, v, *, positions=None, causal=True, window=None,
                        softcap=None, q_offset=0):
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32) * D ** -0.5
    qg = qf.reshape(B, Sq, KV, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    if positions is None:
        qpos = jnp.arange(Sq)[:, None] + q_offset
        kpos = jnp.arange(k.shape[1])[None, :]
        valid = jnp.ones((Sq, k.shape[1]), bool)
        if causal:
            valid &= kpos <= qpos
        if window:
            valid &= kpos > qpos - window
        mask = valid[None, None, None]                   # (1,1,1,Sq,Skv)
    else:
        # per-row positions (left-padded rows): pad keys (< 0) are masked
        # everywhere; pad query rows yield garbage the caller discards
        pos = positions.astype(jnp.int32)
        qpos = pos[:, :, None]                           # (B, Sq, 1)
        kpos = pos[:, None, :]                           # (B, 1, Skv)
        valid = kpos >= 0
        if causal:
            valid &= kpos <= qpos
        if window:
            valid &= kpos > qpos - window
        mask = valid[:, None, None]                      # (B,1,1,Sq,Skv)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def decode_attention_ref(q, kc, vc, pos, qpos, *, window=None, softcap=None):
    B, _, H, D = q.shape
    KV = kc.shape[2]
    G = H // KV
    qg = q.astype(jnp.float32).reshape(B, KV, G, D) * D ** -0.5
    s = jnp.einsum("bkgd,bskd->bkgs", qg, kc.astype(jnp.float32))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    valid = (pos >= 0) & (pos <= qpos)
    if window:
        valid &= pos > qpos - window
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, vc.astype(jnp.float32))
    return o.reshape(B, 1, H, D).astype(q.dtype)


def paged_decode_attention_ref(q, kp, vp, bt, lens, *, qpos=None, window=None,
                               softcap=None, compute_dtype=None):
    """Reference paged-KV decode attention (the registry's ``ref`` fallback).

    Gathers each row's blocks through its block table into a contiguous
    (B, nblk*bs, KV, D) view, then mirrors :func:`repro.core.ops_impl._sdpa`'s
    decode math operation-for-operation so the paged path is *byte-identical*
    to the rolling-cache reference path when the gathered length matches.

    ``qpos`` (B, Sq) absolute query positions unlocks the chunked catch-up
    mode (Sq > 1); rows < 0 are padding (masked everywhere, output garbage
    the caller discards).  Defaults to ``lens[:, None]`` — the classic
    single-token decode, byte-identical to the pre-chunk reference.
    """
    B, Sq, H, D = q.shape
    bs, KV = kp.shape[1], kp.shape[2]
    nblk = bt.shape[1]
    G = H // KV
    dt = compute_dtype if compute_dtype is not None else q.dtype
    C = nblk * bs
    kc = kp[bt].reshape(B, C, KV, D)          # gather over the block table
    vc = vp[bt].reshape(B, C, KV, D)
    kpos = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32), (B, C))
    if qpos is None:
        qpos = lens.reshape(B, 1).astype(jnp.int32)
    else:
        qpos = qpos.astype(jnp.int32)
    scale = D ** -0.5
    qf = (q * scale).astype(dt)
    kf = kc.astype(dt)
    vf = vc.astype(dt)
    qg = qf.reshape(B, Sq, KV, G, D)
    s = jnp.einsum("bckgd,bskd->bkgcs", qg, kf,
                   preferred_element_type=jnp.float32)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    valid = kpos[:, None, None, None, :] >= 0
    valid &= qpos[:, None, None, :, None] >= 0
    valid &= kpos[:, None, None, None, :] <= qpos[:, None, None, :, None]
    if window:
        valid &= kpos[:, None, None, None, :] > (
            qpos[:, None, None, :, None] - window)
    s = jnp.where(valid, s, -1e30)
    pr = jax.nn.softmax(s, axis=-1).astype(dt)
    o = jnp.einsum("bkgcs,bskd->bckgd", pr, vf,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, H, D).astype(dt)


def copy_block_ref(pool, src, dst):
    """Reference copy-on-write block copy (the registry's ``ref`` fallback):
    pool row ``dst`` := pool row ``src``.  Handles the folded layout's
    leading reps dimension (block axis is always ``-4``)."""
    blk = jnp.take(pool, jnp.asarray(src, jnp.int32), axis=-4)
    return pool.at[..., dst, :, :, :].set(blk)


def conv2d_fused_ref(x, w, *, stride=1, padding="SAME", bn=None, act=None):
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32), (stride, stride),
        padding, dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if bn is not None:
        scale, bias, mean, var = [t.astype(jnp.float32) for t in bn]
        y = (y - mean) * (jax.lax.rsqrt(var + 1e-5) * scale) + bias
    if act:
        y = _act(y, act)
    return y.astype(x.dtype)
