"""Diagonal linear-recurrence scan Pallas kernel (RG-LRU temporal mixing).

h_t = a_t ⊙ h_{t-1} + b_t over (B, S, W).  The FPGA analogue of this op is a
deeply pipelined accumulator chain; on TPU the kernel keeps the running state
in VMEM scratch and streams S sequentially while the width dimension rides
the VPU lanes — grid (B, W/bw), one resident state vector per instance (the
sequential axis never touches HBM between steps; the pure-XLA fallback is an
associative scan with O(log S) round trips).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, o_ref, h_ref, *, seq: int):
    h_ref[...] = jnp.zeros_like(h_ref)

    def step(t, _):
        h = a_ref[0, t, :] * h_ref[0, :] + b_ref[0, t, :]
        h_ref[0, :] = h
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, seq, step, 0)


def lru_scan(a: jax.Array, b: jax.Array, *, block_w: int = 512,
             interpret: bool = False) -> jax.Array:
    """a, b: (B, S, W) -> h: (B, S, W) with h_0 = 0."""
    B, S, W = a.shape
    bw = min(block_w, _rup(W, 128))
    Wp = _rup(W, bw)
    ap = jnp.pad(a, ((0, 0), (0, 0), (0, Wp - W)))
    bp = jnp.pad(b, ((0, 0), (0, 0), (0, Wp - W)))
    kern = functools.partial(_kernel, seq=S)
    out = pl.pallas_call(
        kern, grid=(B, Wp // bw),
        in_specs=[pl.BlockSpec((1, S, bw), lambda i, j: (i, 0, j)),
                  pl.BlockSpec((1, S, bw), lambda i, j: (i, 0, j))],
        out_specs=pl.BlockSpec((1, S, bw), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((B, S, Wp), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        interpret=interpret)(ap, bp)
    return out[:, :, :W]


def lru_scan_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    def comb(u, v):
        (a1, b1), (a2, b2) = u, v
        return a2 * a1, a2 * b1 + b2
    _, h = jax.lax.associative_scan(comb, (a.astype(jnp.float32),
                                           b.astype(jnp.float32)), axis=1)
    return h.astype(a.dtype)


def _rup(n, m):
    return (n + m - 1) // m * m
