"""Fused tiled matmul Pallas kernel — the flow's workhorse (paper: conv/FC).

Embodies four paper passes on TPU:
* LU/LT — the (bm, bk, bn) BlockSpec tiling is the unroll/tile factor,
  MXU-aligned (multiples of 128) and VMEM-bounded (tiling pass).
* CW   — partial sums live in an fp32 VMEM scratch across the K grid axis;
  HBM is written exactly once, at the last K step (``pl.when``).
* LF   — the epilogue (bias / activation / GLU pair) is applied in VMEM
  before the single write-back; no intermediate tensor ever reaches HBM.
* OF   — bf16 operands feed the MXU with fp32 accumulation.

The unoptimized variant (``cached_writes=False``) accumulates in the output
dtype through the output block each K step — the paper's base kernel
(read-modify-write accumulation) — used for the base/optimized comparison.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _epilogue(acc, acc2, bias_ref, act):
    from repro.core.ops_impl import _act
    if acc2 is not None:                      # GLU pair: act(x@w1) * (x@w2)
        acc = _act(acc, act or "silu") * acc2
        act = None
    if bias_ref is not None:
        acc = acc + bias_ref[...].astype(jnp.float32)
    if act:
        acc = _act(acc, act)
    return acc


def _kernel(x_ref, w_ref, *rest, acc_ref=None, acc2_ref=None, nk: int,
            act: Optional[str], has_bias: bool, has_w2: bool,
            vmem_accum: bool):
    idx = 0
    w2_ref = rest[idx] if has_w2 else None
    idx += int(has_w2)
    bias_ref = rest[idx] if has_bias else None
    idx += int(has_bias)
    o_ref = rest[idx]

    k = pl.program_id(2)
    part = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    part2 = (jnp.dot(x_ref[...], w2_ref[...],
                     preferred_element_type=jnp.float32) if has_w2 else None)

    if vmem_accum:
        @pl.when(k == 0)
        def _():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            if has_w2:
                acc2_ref[...] = jnp.zeros_like(acc2_ref)

        acc_ref[...] += part
        if has_w2:
            acc2_ref[...] += part2

        @pl.when(k == nk - 1)
        def _():
            r = _epilogue(acc_ref[...],
                          acc2_ref[...] if has_w2 else None, bias_ref, act)
            o_ref[...] = r.astype(o_ref.dtype)
    else:
        # base behaviour: accumulate through the output block in out-dtype
        # (one write-back per K step, precision lost to out-dtype).
        @pl.when(k == 0)
        def _():
            o_ref[...] = jnp.zeros_like(o_ref)
        o_ref[...] += part.astype(o_ref.dtype)
        @pl.when(k == nk - 1)
        def _():
            r = _epilogue(o_ref[...].astype(jnp.float32), None, bias_ref, act)
            o_ref[...] = r.astype(o_ref.dtype)


def matmul_fused(x: jax.Array, w: jax.Array, *, bias=None, w2=None,
                 act: Optional[str] = None,
                 tile: Tuple[int, int, int] = (256, 512, 256),
                 out_dtype=None, vmem_accum: bool = True,
                 interpret: bool = False) -> jax.Array:
    """y = epilogue(x @ w [, x @ w2]) with (M,K)x(K,N); leading dims of x are
    flattened into M.  Pads every dim to the tile grid and slices back."""
    if vmem_accum and w2 is not None:
        pass
    assert not (w2 is not None and not vmem_accum), \
        "base (non-CW) kernel does not support the GLU epilogue"
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[-1]
    M = 1
    for d in lead:
        M *= d
    x2 = x.reshape(M, K)
    bm, bk, bn = tile
    bm = min(bm, _rup(M, 8))
    bk = min(bk, _rup(K, 128))
    bn = min(bn, _rup(N, 128))
    Mp, Kp, Np = _rup(M, bm), _rup(K, bk), _rup(N, bn)
    x2 = _pad2(x2, Mp, Kp)
    wp = _pad2(w, Kp, Np)
    w2p = _pad2(w2, Kp, Np) if w2 is not None else None
    bp = (jnp.pad(bias, (0, Np - N))[None, :].astype(jnp.float32)
          if bias is not None else None)
    nk = Kp // bk
    grid = (Mp // bm, Np // bn, nk)

    in_specs = [pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))]
    operands = [x2, wp]
    if w2 is not None:
        in_specs.append(pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)))
        operands.append(w2p)
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
        operands.append(bp)

    odt = out_dtype or x.dtype
    scratch = [pltpu.VMEM((bm, bn), jnp.float32)]
    if w2 is not None:
        scratch.append(pltpu.VMEM((bm, bn), jnp.float32))

    kernel = functools.partial(
        _kernel, nk=nk, act=act, has_bias=bias is not None,
        has_w2=w2 is not None, vmem_accum=vmem_accum)
    if vmem_accum:
        def kbody(*refs):
            n_in = len(operands)
            sc = refs[n_in + 1:]
            kernel(refs[0], refs[1], *refs[2:n_in + 1],
                   acc_ref=sc[0], acc2_ref=sc[1] if w2 is not None else None)
        y = pl.pallas_call(
            kbody, grid=grid, in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            out_shape=jax.ShapeDtypeStruct((Mp, Np), odt),
            scratch_shapes=scratch, interpret=interpret)(*operands)
    else:
        def kbody(*refs):
            n_in = len(operands)
            kernel(refs[0], refs[1], *refs[2:n_in + 1])
        y = pl.pallas_call(
            kbody, grid=grid, in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            out_shape=jax.ShapeDtypeStruct((Mp, Np), odt),
            interpret=interpret)(*operands)
    return y[:M, :N].reshape(*lead, N)


def _rup(n: int, m: int) -> int:
    return (n + m - 1) // m * m


def _pad2(a, r, c):
    return jnp.pad(a.astype(a.dtype), ((0, r - a.shape[0]), (0, c - a.shape[1])))
