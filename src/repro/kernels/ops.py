"""Jit'd wrappers routing the op layer onto the Pallas kernels.

``interpret=True`` executes kernel bodies on CPU for validation; on the TPU
target ``interpret=False`` compiles through Mosaic.  Tile parameters come
from the tiling pass (plan.tiles); ``None`` falls back to kernel defaults.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import matmul_fused as _mm
from repro.kernels import attention as _fa
from repro.kernels import decode_attention as _da
from repro.kernels import conv2d as _cv


def matmul_fused(x, w, *, bias=None, w2=None, act=None, tile=None,
                 out_dtype=None, vmem_accum=True, interpret=False):
    return _mm.matmul_fused(
        x, w, bias=bias, w2=w2, act=act,
        tile=tile or (256, 512, 256), out_dtype=out_dtype,
        vmem_accum=vmem_accum, interpret=interpret)


def flash_attention(q, k, v, positions=None, *, causal=True, window=None,
                    softcap=None, tile=None, q_offset=0, interpret=False):
    return _fa.flash_attention(
        q, k, v, positions=positions, causal=causal, window=window,
        softcap=softcap, tile=tile or (256, 512), q_offset=q_offset,
        interpret=interpret)


def decode_attention(q, kc, vc, pos, qpos, *, window=None, softcap=None,
                     tile=None, interpret=False):
    return _da.decode_attention(
        q, kc, vc, pos, qpos, window=window, softcap=softcap,
        block_k=tile or 2048, interpret=interpret)


def paged_decode_attention(q, kp, vp, bt, lens, *, qpos=None, window=None,
                           softcap=None, tile=None, interpret=False):
    # the paged path has no free tile knob: the physical pool block is the
    # kernel's KV block (tile accepted for wrapper uniformity).  qpos (B, Sq)
    # unlocks the chunked catch-up mode (Sq = k > 1).
    return _da.paged_decode_attention(q, kp, vp, bt, lens, qpos=qpos,
                                      window=window, softcap=softcap,
                                      interpret=interpret)


def copy_block(pool, src, dst, *, interpret=False):
    return _da.copy_block(pool, src, dst, interpret=interpret)


def conv2d_fused(x, w, *, stride=1, padding="SAME", bn=None, act=None,
                 tile=None, interpret=False):
    # the tiling pass hands (block_h, block_c); a bare int means block_c only
    if isinstance(tile, tuple):
        block_h, block_c = tile
    else:
        block_h, block_c = None, (tile or 128)
    return _cv.conv2d_fused(x, w, stride=stride, padding=padding, bn=bn,
                            act=act, block_c=block_c, block_h=block_h,
                            interpret=interpret)
