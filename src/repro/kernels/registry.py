"""KernelRegistry — pluggable per-op kernel-backend selection.

The paper's flow emits one accelerator per network; end-to-end compilers that
followed it (DNNVM's heterogeneous ISA mapping, the FPGA-CNN survey's
backend taxonomy) put a *registry* between the op layer and the kernel
implementations: each op may have several implementations, keyed by backend,
each guarded by a capability predicate, and the flow resolves the pair at
plan-build time.

This module is that seam for the repro stack:

* implementations register under ``(op, backend)`` with backends drawn from
  ``{"ref", "pallas"}`` — ``pallas_interpret`` is the Pallas implementation
  executed through the interpreter (CPU validation), not a separate entry;
* every op in :data:`repro.core.ops_impl.OPS` implicitly owns a ``ref``
  entry (the pure-XLA implementation *is* the reference backend);
* ``resolve(op, "auto")`` picks per op: Pallas where a Pallas implementation
  exists and the platform runs Mosaic (TPU), the reference path elsewhere;
* the resolution for a whole plan (:meth:`KernelRegistry.resolve_all`) is
  recorded on the ``ExecutionPlan`` by the ``kernels`` pass, shows up in
  ``plan.describe()`` and is a DSE tunable (``FlowConfig.kernel_backend``).

Call-site capability predicates (dtype/rank/attribute constraints that are
only known with concrete operands) are checked at dispatch time by
:func:`plan_kernel`; a failing predicate falls back to the reference path.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

BACKENDS = ("ref", "pallas", "pallas_interpret", "auto")

_ALIASES = {"reference": "ref", "ref": "ref", "pallas": "pallas",
            "pallas_interpret": "pallas_interpret", "auto": "auto"}


def canon_backend(name: str) -> str:
    """Canonical backend name (``reference`` → ``ref``)."""
    try:
        return _ALIASES[name]
    except KeyError:
        raise ValueError(
            f"unknown kernel backend {name!r}; expected one of "
            f"{sorted(set(_ALIASES))}") from None


def _default_platform() -> str:
    import jax
    return jax.default_backend()


@dataclass(frozen=True)
class KernelImpl:
    """One registered kernel implementation.

    ``supports`` is the call-site capability predicate: it receives the
    keyword facts the op layer passes to :func:`plan_kernel` (operand arrays,
    attrs like ``groups``/``window``) and returns whether this implementation
    can handle them.  ``platforms`` gates plan-time resolution (a Pallas
    kernel compiled through Mosaic is TPU-only; in interpret mode it runs
    anywhere)."""
    op: str
    backend: str
    fn: Callable
    supports: Callable[..., bool] = field(default=lambda **kw: True)
    platforms: Tuple[str, ...] = ("cpu", "gpu", "tpu")

    def __repr__(self) -> str:
        return f"<KernelImpl {self.op}/{self.backend}>"


class KernelRegistry:
    """Maps ``(op, backend)`` → :class:`KernelImpl` and resolves backends."""

    def __init__(self):
        self._impls: Dict[Tuple[str, str], KernelImpl] = {}

    # -- registration -------------------------------------------------------
    def register(self, op: str, backend: str, fn: Optional[Callable] = None,
                 *, supports: Optional[Callable[..., bool]] = None,
                 platforms: Tuple[str, ...] = ("cpu", "gpu", "tpu")):
        """Register ``fn`` as the ``backend`` implementation of ``op``.
        Usable directly or as a decorator."""
        backend = canon_backend(backend)
        if backend == "auto":
            raise ValueError("'auto' is a resolution policy, not a backend")

        def _add(f: Callable) -> Callable:
            self._impls[(op, backend)] = KernelImpl(
                op, backend, f, supports or (lambda **kw: True), platforms)
            return f

        return _add if fn is None else _add(fn)

    # -- lookup -------------------------------------------------------------
    def _ref_ops(self) -> Dict[str, Callable]:
        from repro.core.ops_impl import OPS
        return OPS

    def ops(self) -> Tuple[str, ...]:
        """All ops the registry can resolve (reference table ∪ registered)."""
        names = set(self._ref_ops()) | {op for op, _ in self._impls}
        return tuple(sorted(names))

    def accelerated_ops(self) -> Tuple[str, ...]:
        """Ops with at least one non-reference implementation."""
        return tuple(sorted({op for (op, b) in self._impls if b != "ref"}))

    def has(self, op: str, backend: str) -> bool:
        backend = canon_backend(backend)
        if backend == "pallas_interpret":   # interpret reuses the pallas impl
            backend = "pallas"
        if backend == "ref":
            return (op, "ref") in self._impls or op in self._ref_ops()
        return (op, backend) in self._impls

    def get(self, op: str, backend: str) -> KernelImpl:
        backend = canon_backend(backend)
        key = "pallas" if backend == "pallas_interpret" else backend
        impl = self._impls.get((op, key))
        if impl is None and key == "ref":
            fn = self._ref_ops().get(op)
            if fn is not None:
                impl = KernelImpl(op, "ref", fn)
        if impl is None:
            raise KeyError(f"no {backend!r} implementation registered for "
                           f"op {op!r} (have: {self.backends(op)})")
        return impl

    def backends(self, op: str) -> Tuple[str, ...]:
        out = {b for (o, b) in self._impls if o == op}
        if op in self._ref_ops():
            out.add("ref")
        return tuple(sorted(out))

    # -- resolution ---------------------------------------------------------
    def resolve(self, op: str, backend: str = "auto",
                platform: Optional[str] = None) -> str:
        """Plan-time backend choice for one op.

        ``auto`` → Pallas where an implementation exists and the platform
        compiles it natively (TPU), reference elsewhere.  An explicit Pallas
        request degrades to ``ref`` for ops with no Pallas implementation
        (e.g. ``norm``), mirroring the old in-op string checks."""
        backend = canon_backend(backend)
        platform = platform if platform is not None else _default_platform()
        if backend == "auto":
            if (op, "pallas") in self._impls and platform == "tpu" \
                    and platform in self._impls[(op, "pallas")].platforms:
                return "pallas"
            return "ref"
        if backend in ("pallas", "pallas_interpret"):
            return backend if (op, "pallas") in self._impls else "ref"
        return "ref"

    def resolve_all(self, backend: str = "auto",
                    platform: Optional[str] = None) -> Dict[str, str]:
        """Resolution table for every known op (recorded on the plan)."""
        platform = platform if platform is not None else _default_platform()
        return {op: self.resolve(op, backend, platform) for op in self.ops()}


REGISTRY = KernelRegistry()


def plan_kernel(plan, op: str, **facts) -> Optional[Tuple[Callable, bool]]:
    """Dispatch helper for the op layer.

    Returns ``(fn, interpret)`` when the plan resolves ``op`` to a Pallas
    implementation whose capability predicate accepts the call-site
    ``facts``; ``None`` means take the reference path.  Plans built by
    pipelines without the ``kernels`` pass fall back to resolving the flow's
    ``kernel_backend`` on the fly."""
    resolved = plan.kernels.get(op) if plan.kernels else None
    if resolved is None:
        resolved = REGISTRY.resolve(op, plan.flow.kernel_backend)
    if resolved not in ("pallas", "pallas_interpret"):
        return None
    impl = REGISTRY.get(op, "pallas")
    if not impl.supports(**facts):
        return None
    return impl.fn, resolved == "pallas_interpret"


# ---------------------------------------------------------------------------
# Built-in Pallas registrations (the kernels/ package)
# ---------------------------------------------------------------------------

def _register_builtin():
    from repro.kernels import ops as kops
    from repro.kernels.lru_scan import lru_scan

    REGISTRY.register(
        "matmul", "pallas", kops.matmul_fused,
        supports=lambda x=None, w=None, **kw:
            x is not None and w is not None and x.ndim >= 2 and w.ndim == 2)
    REGISTRY.register(
        "glu_matmul", "pallas", kops.matmul_fused,
        supports=lambda x=None, w=None, **kw:
            x is not None and w is not None and x.ndim >= 2 and w.ndim == 2)
    REGISTRY.register(
        "attention", "pallas", kops.flash_attention,
        # window == 0 is a degenerate cell some configs use to disable the
        # flash path; cross-attention caches K/V outside the kernel
        supports=lambda window=None, cross=False, **kw:
            window != 0 and not cross)
    REGISTRY.register("decode_attention", "pallas", kops.decode_attention)
    # paged-KV serving path: the Pallas kernel gathers pool blocks through
    # the block table (scalar prefetch); the explicit ref entry is the
    # fallback the serving engine's decode uses off-TPU
    from repro.kernels.ref import copy_block_ref, paged_decode_attention_ref
    REGISTRY.register("paged_decode_attention", "pallas",
                      kops.paged_decode_attention)
    REGISTRY.register("paged_decode_attention", "ref",
                      paged_decode_attention_ref)
    # prefix-cache copy-on-write fork: one pool block copied over another
    REGISTRY.register("copy_block", "pallas", kops.copy_block)
    REGISTRY.register("copy_block", "ref", copy_block_ref)
    REGISTRY.register(
        "conv2d", "pallas", kops.conv2d_fused,
        supports=lambda groups=1, **kw: groups == 1)
    REGISTRY.register("rg_lru", "pallas", lru_scan)


_register_builtin()
