"""KernelRegistry — pluggable per-op kernel-backend selection.

The paper's flow emits one accelerator per network; end-to-end compilers that
followed it (DNNVM's heterogeneous ISA mapping, the FPGA-CNN survey's
backend taxonomy) put a *registry* between the op layer and the kernel
implementations: each op may have several implementations, keyed by backend,
each guarded by a capability predicate, and the flow resolves the pair at
plan-build time.

This module is that seam for the repro stack:

* implementations register under ``(op, backend)`` with backends drawn from
  ``{"ref", "pallas"}`` — ``pallas_interpret`` is the Pallas implementation
  executed through the interpreter (CPU validation), not a separate entry;
* every op in :data:`repro.core.ops_impl.OPS` implicitly owns a ``ref``
  entry (the pure-XLA implementation *is* the reference backend);
* ``resolve(op, "auto")`` picks per op: Pallas where a Pallas implementation
  exists and the platform runs Mosaic (TPU), the reference path elsewhere;
* the resolution for a whole plan (:meth:`KernelRegistry.resolve_all`) is
  recorded on the ``ExecutionPlan`` by the ``kernels`` pass, shows up in
  ``plan.describe()`` and is a DSE tunable (``FlowConfig.kernel_backend``).

Call-site capability predicates (dtype/rank/attribute constraints that are
only known with concrete operands) are checked at dispatch time by
:func:`plan_kernel`; a failing predicate falls back to the reference path
with a machine-readable reason (``DISPATCH_REJECTIONS`` counts them, and
the static verifier surfaces the statically-decidable ones as ``K204``
diagnostics via each impl's declared :class:`KernelContract`).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

from repro.obs import METRICS

BACKENDS = ("ref", "pallas", "pallas_interpret", "auto")

_ALIASES = {"reference": "ref", "ref": "ref", "pallas": "pallas",
            "pallas_interpret": "pallas_interpret", "auto": "auto"}


def canon_backend(name: str) -> str:
    """Canonical backend name (``reference`` → ``ref``)."""
    try:
        return _ALIASES[name]
    except KeyError:
        raise ValueError(
            f"unknown kernel backend {name!r}; expected one of "
            f"{sorted(set(_ALIASES))}") from None


def _default_platform() -> str:
    import jax
    return jax.default_backend()


@dataclass(frozen=True)
class KernelContract:
    """The statically-checkable contract a kernel impl declares; consumed by
    :mod:`repro.analysis` (``verify_plan``) without compiling anything.

    * ``tile_key``/``workingset`` — which ``plan.tiles`` entry the kernel's
      BlockSpecs come from and its VMEM working set ``(tile, cfg) -> bytes``
      (checked against ``flow.vmem_budget_bytes``: K202);
    * ``donation_safe`` — whether the kernel's ``input_output_aliases`` use
      is safe under donated state (a donation-unsafe kernel under
      ``cache.donate_state`` is K203);
    * ``index_space`` — ``"block_table"`` marks a scalar-prefetch gather
      whose indices must stay inside the paged pool (K205 checks the pool
      geometry on the serving side);
    * ``static_reject`` — the statically-decidable part of the capability
      predicate, ``(op_attrs, cfg) -> Optional[reason]``: a non-None reason
      means dispatch will silently fall back to ref (surfaced as K204);
    * ``tile_candidates`` — ``(cfg, shape) -> tuple of tiles``: the
      kernel's searchable tile schedules (e.g. ``(block_q, block_kv)``
      pairs for flash attention).  Declaring it makes the ``tile_key``
      entry a recordable, warm-startable tunable: the serving autotune's
      ``tune_kernel_tiles`` benches each candidate through
      ``FlowConfig.tile_overrides`` and banks the winner in the tunedb."""
    tile_key: Optional[str] = None
    workingset: Optional[Callable[[Any, Any], int]] = None
    donation_safe: bool = True
    index_space: Optional[str] = None
    static_reject: Optional[Callable[[Dict[str, Any], Any],
                                     Optional[str]]] = None
    tile_candidates: Optional[Callable[[Any, Any],
                                       Tuple[Any, ...]]] = None


@dataclass(frozen=True)
class KernelImpl:
    """One registered kernel implementation.

    ``supports`` is the call-site capability predicate: it receives the
    keyword facts the op layer passes to :func:`plan_kernel` (operand arrays,
    attrs like ``groups``/``window``) and returns whether this implementation
    can handle them.  ``rejects`` is its machine-readable form — same facts
    in, ``None`` (accepted) or a reason string out; when registered,
    ``supports`` is derived from it.  ``platforms`` gates plan-time
    resolution (a Pallas kernel compiled through Mosaic is TPU-only; in
    interpret mode it runs anywhere).  ``contract`` is the declared static
    contract the verifier checks (see :class:`KernelContract`)."""
    op: str
    backend: str
    fn: Callable
    supports: Callable[..., bool] = field(default=lambda **kw: True)
    platforms: Tuple[str, ...] = ("cpu", "gpu", "tpu")
    rejects: Optional[Callable[..., Optional[str]]] = None
    contract: Optional[KernelContract] = None

    def reject_reason(self, **facts) -> Optional[str]:
        """``None`` when this impl can serve the call-site facts, else the
        machine-readable reason dispatch falls back to the reference path."""
        if self.rejects is not None:
            return self.rejects(**facts)
        if self.supports(**facts):
            return None
        return f"capability predicate rejected {self.op}/{self.backend}"

    def __repr__(self) -> str:
        return f"<KernelImpl {self.op}/{self.backend}>"


class KernelRegistry:
    """Maps ``(op, backend)`` → :class:`KernelImpl` and resolves backends."""

    def __init__(self):
        self._impls: Dict[Tuple[str, str], KernelImpl] = {}

    # -- registration -------------------------------------------------------
    def register(self, op: str, backend: str, fn: Optional[Callable] = None,
                 *, supports: Optional[Callable[..., bool]] = None,
                 rejects: Optional[Callable[..., Optional[str]]] = None,
                 contract: Optional[KernelContract] = None,
                 platforms: Tuple[str, ...] = ("cpu", "gpu", "tpu")):
        """Register ``fn`` as the ``backend`` implementation of ``op``.
        Usable directly or as a decorator.  ``rejects`` is the machine-
        readable capability predicate (facts -> Optional[reason]); when
        given, ``supports`` is derived from it."""
        backend = canon_backend(backend)
        if backend == "auto":
            raise ValueError("'auto' is a resolution policy, not a backend")
        if rejects is not None and supports is None:
            supports = lambda **kw: rejects(**kw) is None  # noqa: E731

        def _add(f: Callable) -> Callable:
            self._impls[(op, backend)] = KernelImpl(
                op, backend, f, supports or (lambda **kw: True), platforms,
                rejects=rejects, contract=contract)
            return f

        return _add if fn is None else _add(fn)

    # -- lookup -------------------------------------------------------------
    def _ref_ops(self) -> Dict[str, Callable]:
        from repro.core.ops_impl import OPS
        return OPS

    def ops(self) -> Tuple[str, ...]:
        """All ops the registry can resolve (reference table ∪ registered)."""
        names = set(self._ref_ops()) | {op for op, _ in self._impls}
        return tuple(sorted(names))

    def accelerated_ops(self) -> Tuple[str, ...]:
        """Ops with at least one non-reference implementation."""
        return tuple(sorted({op for (op, b) in self._impls if b != "ref"}))

    def has(self, op: str, backend: str) -> bool:
        backend = canon_backend(backend)
        if backend == "pallas_interpret":   # interpret reuses the pallas impl
            backend = "pallas"
        if backend == "ref":
            return (op, "ref") in self._impls or op in self._ref_ops()
        return (op, backend) in self._impls

    def get(self, op: str, backend: str) -> KernelImpl:
        backend = canon_backend(backend)
        key = "pallas" if backend == "pallas_interpret" else backend
        impl = self._impls.get((op, key))
        if impl is None and key == "ref":
            fn = self._ref_ops().get(op)
            if fn is not None:
                impl = KernelImpl(op, "ref", fn)
        if impl is None:
            raise KeyError(f"no {backend!r} implementation registered for "
                           f"op {op!r} (have: {self.backends(op)})")
        return impl

    def backends(self, op: str) -> Tuple[str, ...]:
        out = {b for (o, b) in self._impls if o == op}
        if op in self._ref_ops():
            out.add("ref")
        return tuple(sorted(out))

    # -- resolution ---------------------------------------------------------
    def resolve(self, op: str, backend: str = "auto",
                platform: Optional[str] = None) -> str:
        """Plan-time backend choice for one op.

        ``auto`` → Pallas where an implementation exists and the platform
        compiles it natively (TPU), reference elsewhere.  An explicit Pallas
        request degrades to ``ref`` for ops with no Pallas implementation
        (e.g. ``norm``), mirroring the old in-op string checks."""
        backend = canon_backend(backend)
        platform = platform if platform is not None else _default_platform()
        if backend == "auto":
            if (op, "pallas") in self._impls and platform == "tpu" \
                    and platform in self._impls[(op, "pallas")].platforms:
                return "pallas"
            return "ref"
        if backend in ("pallas", "pallas_interpret"):
            return backend if (op, "pallas") in self._impls else "ref"
        return "ref"

    def resolve_all(self, backend: str = "auto",
                    platform: Optional[str] = None) -> Dict[str, str]:
        """Resolution table for every known op (recorded on the plan)."""
        platform = platform if platform is not None else _default_platform()
        return {op: self.resolve(op, backend, platform) for op in self.ops()}


REGISTRY = KernelRegistry()

# dispatch-time fall-throughs to ref, keyed by (op, machine-readable reason).
# The verifier catches the statically-decidable subset (K204) at plan time;
# this counter makes the residual operand-dependent ones observable too.
DISPATCH_REJECTIONS: Dict[Tuple[str, str], int] = {}


def plan_kernel(plan, op: str, **facts) -> Optional[Tuple[Callable, bool]]:
    """Dispatch helper for the op layer.

    Returns ``(fn, interpret)`` when the plan resolves ``op`` to a Pallas
    implementation whose capability predicate accepts the call-site
    ``facts``; ``None`` means take the reference path (the reject reason is
    recorded in :data:`DISPATCH_REJECTIONS`).  Plans built by pipelines
    without the ``kernels`` pass fall back to resolving the flow's
    ``kernel_backend`` on the fly."""
    resolved = plan.kernels.get(op) if plan.kernels else None
    if resolved is None:
        resolved = REGISTRY.resolve(op, plan.flow.kernel_backend)
    if resolved not in ("pallas", "pallas_interpret"):
        return None
    impl = REGISTRY.get(op, "pallas")
    reason = impl.reject_reason(**facts)
    if reason is not None:
        key = (op, reason)
        DISPATCH_REJECTIONS[key] = DISPATCH_REJECTIONS.get(key, 0) + 1
        METRICS.counter("kernels.dispatch.rejections").inc()
        return None
    return impl.fn, resolved == "pallas_interpret"


# ---------------------------------------------------------------------------
# Built-in Pallas registrations (the kernels/ package)
# ---------------------------------------------------------------------------

def _matmul_reject(x=None, w=None, **kw) -> Optional[str]:
    if x is None or w is None:
        return "matmul operands not provided to the dispatch predicate"
    if not (x.ndim >= 2 and w.ndim == 2):
        return (f"operand ranks (x.ndim={x.ndim}, w.ndim={w.ndim}) need "
                "x.ndim >= 2 and w.ndim == 2")
    return None


def _attention_reject(window=None, cross=False, **kw) -> Optional[str]:
    # window == 0 is a degenerate cell some configs use to disable the
    # flash path; cross-attention caches K/V outside the kernel
    if window == 0:
        return "window=0 disables the flash path"
    if cross:
        return "cross-attention caches K/V outside the kernel"
    return None


def _attention_static_reject(attrs, cfg) -> Optional[str]:
    return _attention_reject(window=attrs.get("window"),
                             cross=attrs.get("cross", False))


def _conv2d_reject(groups=1, **kw) -> Optional[str]:
    if groups != 1:
        return f"grouped conv (groups={groups}) has no Pallas path"
    return None


def _matmul_workingset(tile, cfg) -> int:
    # x(bm,bk) + w(bk,bn) in bf16 + fp32 accumulator + bf16 out tile —
    # the same model select_matmul_tile sizes against (passes/tiling.py)
    bm, bk, bn = tile
    return (bm * bk + bk * bn) * 2 + bm * bn * (4 + 2)


def _attention_workingset(tile, cfg) -> int:
    # q, k, v tiles + fp32 scores + fp32 accumulator
    bq, bk = tile
    hd = cfg.attention.head_dim if cfg.attention is not None else 0
    return (bq + 2 * bk) * hd * 2 + bq * bk * 4 + bq * hd * 4


def _decode_attention_workingset(tile, cfg) -> int:
    # one K and one V block of block_k positions + fp32 partials
    bk = int(tile)
    hd = cfg.attention.head_dim if cfg.attention is not None else 0
    return 2 * bk * hd * 2 + bk * 4


def _attention_tile_candidates(cfg, shape) -> Tuple[Tuple[int, int], ...]:
    """Searchable (block_q, block_kv) schedules for flash attention: the
    MXU-aligned grid around the selector's static choice, capped at the
    cell's sequence length (rule 2: blocks never exceed the problem)."""
    seq = max(int(getattr(shape, "seq_len", 128)), 128)
    qs = [q for q in (128, 256, 512) if q <= seq]
    kvs = [k for k in (128, 256, 512, 1024) if k <= seq]
    return tuple((q, k) for q in qs for k in kvs)


def _conv2d_tile_candidates(cfg, shape) -> Tuple[Tuple[int, int], ...]:
    """Searchable (block_h, block_c) schedules for the fused conv kernel:
    VPU-lane-aligned rows x channel blocks, capped at the image height."""
    h = int(getattr(cfg, "image_size", 0)) or 32
    hs = [b for b in (8, 16, 32) if b <= h]
    return tuple((bh, bc) for bh in hs for bc in (128, 256))


_MATMUL_CONTRACT = KernelContract(
    tile_key="matmul", workingset=_matmul_workingset)


def _register_builtin():
    from repro.kernels import ops as kops
    from repro.kernels.lru_scan import lru_scan

    REGISTRY.register("matmul", "pallas", kops.matmul_fused,
                      rejects=_matmul_reject, contract=_MATMUL_CONTRACT)
    REGISTRY.register("glu_matmul", "pallas", kops.matmul_fused,
                      rejects=_matmul_reject, contract=_MATMUL_CONTRACT)
    REGISTRY.register(
        "attention", "pallas", kops.flash_attention,
        rejects=_attention_reject,
        contract=KernelContract(tile_key="attention",
                                workingset=_attention_workingset,
                                static_reject=_attention_static_reject,
                                tile_candidates=_attention_tile_candidates))
    REGISTRY.register(
        "decode_attention", "pallas", kops.decode_attention,
        contract=KernelContract(tile_key="decode_attention",
                                workingset=_decode_attention_workingset))
    # paged-KV serving path: the Pallas kernel gathers pool blocks through
    # the block table (scalar prefetch); the explicit ref entry is the
    # fallback the serving engine's decode uses off-TPU.  index_space
    # declares the gather bounds contract the serving verifier checks
    # against the pool geometry (K205).
    from repro.kernels.ref import copy_block_ref, paged_decode_attention_ref
    _paged = KernelContract(index_space="block_table")
    REGISTRY.register("paged_decode_attention", "pallas",
                      kops.paged_decode_attention, contract=_paged)
    REGISTRY.register("paged_decode_attention", "ref",
                      paged_decode_attention_ref, contract=_paged)
    # prefix-cache copy-on-write fork: one pool block copied over another.
    # input_output_aliases donates the pool in place; safe because the COW
    # call site always copies src -> freshly-allocated dst (never aliased).
    _copy = KernelContract(index_space="block_table", donation_safe=True)
    REGISTRY.register("copy_block", "pallas", kops.copy_block,
                      contract=_copy)
    REGISTRY.register("copy_block", "ref", copy_block_ref, contract=_copy)
    REGISTRY.register(
        "conv2d", "pallas", kops.conv2d_fused,
        rejects=_conv2d_reject,
        contract=KernelContract(
            tile_key="conv2d",
            static_reject=lambda attrs, cfg:
                _conv2d_reject(groups=attrs.get("groups", 1)),
            tile_candidates=_conv2d_tile_candidates))
    REGISTRY.register("rg_lru", "pallas", lru_scan)


_register_builtin()
