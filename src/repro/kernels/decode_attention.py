"""Split-KV flash-decoding Pallas kernel.

One query token attends over a long (rolling) KV cache.  The cache length is
split into blocks along the grid's innermost axis; each block contributes to
an online-softmax accumulator in VMEM scratch (the distributed form — shards
of the cache on different chips — combines the same (m, l, acc) triples with
a psum at the lowering layer).  Masking is position-based: cache slots hold
absolute positions (-1 = empty), so full, rolling and sliding-window caches
all use one kernel.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, pos_ref, qpos_ref, o_ref,
            m_ref, l_ref, acc_ref, *, nk: int,
            window: Optional[int], softcap: Optional[float], scale: float):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)   # (G, bk)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    kpos = pos_ref[0]                                    # (bk,)
    qpos = qpos_ref[0, 0]
    valid = (kpos >= 0) & (kpos <= qpos)
    if window:
        valid &= kpos > qpos - window
    s = jnp.where(valid[None, :], s, NEG)
    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v_ref[0, 0].astype(jnp.float32), preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(kb == nk - 1)
    def _():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention(q: jax.Array, kc: jax.Array, vc: jax.Array,
                     pos: jax.Array, qpos: jax.Array, *,
                     window: Optional[int] = None,
                     softcap: Optional[float] = None,
                     block_k: int = 2048,
                     interpret: bool = False) -> jax.Array:
    """q: (B, 1, H, D); kc/vc: (B, C, KV, D); pos: (B, C) absolute positions
    (-1 empty); qpos: (B, 1).  Returns (B, 1, H, D)."""
    B, _, H, D = q.shape
    C, KV = kc.shape[1], kc.shape[2]
    G = H // KV
    bk = min(block_k, _rup(C, 128))
    Cp = _rup(C, bk)
    kt = jnp.pad(kc, ((0, 0), (0, Cp - C), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    vt = jnp.pad(vc, ((0, 0), (0, Cp - C), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    pp = jnp.pad(pos, ((0, 0), (0, Cp - C)), constant_values=-1)
    qt = q.reshape(B, KV, G, D)                          # group per kv head
    nk = Cp // bk
    grid = (B, KV, nk)

    kern = functools.partial(_kernel, nk=nk, window=window, softcap=softcap,
                             scale=D ** -0.5)
    out = pl.pallas_call(
        kern, grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, kb: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, kb: (b, h, kb, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, kb: (b, h, kb, 0)),
            pl.BlockSpec((1, bk), lambda b, h, kb: (b, kb)),
            pl.BlockSpec((1, 1), lambda b, h, kb: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, kb: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, D), jnp.float32)],
        interpret=interpret)(qt, kt, vt, pp, qpos)
    return out.reshape(B, 1, H, D)


def _rup(n, m):
    return (n + m - 1) // m * m


# ---------------------------------------------------------------------------
# Paged decode attention: gather over block tables (the serving subsystem's
# KV-pool lookup path)
# ---------------------------------------------------------------------------

def _paged_kernel(tbl_ref, lens_ref, q_ref, k_ref, v_ref, qp_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, bs: int, nblk: int,
                  window: Optional[int], softcap: Optional[float],
                  scale: float):
    jb = pl.program_id(2)

    @pl.when(jb == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (Sq*G, d)
    k = k_ref[0, :, 0].astype(jnp.float32)               # (bs, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)   # (Sq*G, bs)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    # block j of the table holds token positions [j*bs, (j+1)*bs); the pool
    # block it maps to was selected by the BlockSpec index_map (scalar
    # prefetch), so masking is purely positional.  Query positions arrive
    # pre-expanded to one row per (chunk token, group) pair; rows < 0 are
    # padding (fully masked → zero output, discarded by the caller).
    kpos = jb * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]
    qrow = qp_ref[0][:, None]                            # (Sq*G, 1)
    valid = (qrow >= 0) & (kpos[None, :] <= qrow)
    if window:
        valid &= kpos[None, :] > qrow - window
    s = jnp.where(valid, s, NEG)
    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v_ref[0, :, 0].astype(jnp.float32),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(jb == nblk - 1)
    def _():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _copy_block_kernel(idx_ref, pool_ref, out_ref):
    out_ref[...] = pool_ref[...]


def copy_block(pool: jax.Array, src, dst, *,
               interpret: bool = False) -> jax.Array:
    """Copy pool block ``src`` over pool block ``dst`` — the serving
    subsystem's copy-on-write fork.  ``pool`` is ``(NB, bs, KV, D)`` or the
    folded ``(reps, NB, bs, KV, D)``; returns the pool with row ``dst``
    replaced.

    The block ids ride the scalar-prefetch channel so the BlockSpec
    ``index_map`` aims one DMA per grid step straight at the source block,
    and the pool operand is aliased to the output: only block ``dst`` moves,
    not the pool."""
    lead = pool.ndim == 5
    p5 = pool if lead else pool[None]
    R, NB, bs, KV, D = p5.shape
    idx = jnp.stack([jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32)])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(R,),
        in_specs=[pl.BlockSpec((1, 1, bs, KV, D),
                               lambda r, idx: (r, idx[0], 0, 0, 0))],
        out_specs=pl.BlockSpec((1, 1, bs, KV, D),
                               lambda r, idx: (r, idx[1], 0, 0, 0)),
    )
    out = pl.pallas_call(
        _copy_block_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(p5.shape, p5.dtype),
        input_output_aliases={1: 0},     # pool buffer updated in place
        interpret=interpret)(idx, p5)
    return out if lead else out[0]


def paged_decode_attention(q: jax.Array, kp: jax.Array, vp: jax.Array,
                           bt: jax.Array, lens: jax.Array, *,
                           qpos: Optional[jax.Array] = None,
                           window: Optional[int] = None,
                           softcap: Optional[float] = None,
                           interpret: bool = False) -> jax.Array:
    """Decode / chunked catch-up attention over a paged KV pool.

    q: (B, Sq, H, D); kp/vp: (NB, bs, KV, D) device-resident block pools;
    bt: (B, nblk) int32 block table (pool block id per logical block);
    lens: (B,) int32 current decode position per row (token ``lens[b]`` has
    just been written at logical offset ``lens[b]``).  Returns (B, Sq, H, D).

    ``qpos`` — optional (B, Sq) int32 absolute positions of the query
    tokens, required when Sq > 1 (chunked prefill catch-up: row b scores a
    whole chunk of ``Sq = k`` freshly written tokens against its pool
    blocks in one pass).  Entries < 0 mark padding rows whose output is
    zero and discarded.  Defaults to ``lens[:, None]`` — the classic
    single-token decode, bit-identical to the pre-chunk kernel.

    Block tables and lengths ride the scalar-prefetch channel
    (:class:`pltpu.PrefetchScalarGridSpec`): the BlockSpec ``index_map``
    reads ``bt[b, j]`` to aim each grid step's DMA at the right pool block —
    the gather never materializes a contiguous per-request cache.
    """
    B, Sq, H, D = q.shape
    bs, KV = kp.shape[1], kp.shape[2]
    nblk = bt.shape[1]
    G = H // KV
    if qpos is None:
        qpos = lens.reshape(B, 1).astype(jnp.int32)
    # rows ordered (chunk token, group): row r ↔ token r // G, group r % G
    qt = (q.reshape(B, Sq, KV, G, D).transpose(0, 2, 1, 3, 4)
          .reshape(B, KV, Sq * G, D))
    # expand positions to one entry per kernel row (host-side repeat keeps
    # the kernel body free of gathers/reshapes Mosaic dislikes)
    qpe = jnp.repeat(qpos.astype(jnp.int32), G, axis=1)   # (B, Sq*G)
    kern = functools.partial(_paged_kernel, bs=bs, nblk=nblk, window=window,
                             softcap=softcap, scale=D ** -0.5)
    R = Sq * G
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, nblk),
        in_specs=[
            pl.BlockSpec((1, 1, R, D), lambda b, h, j, tbl, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda b, h, j, tbl, ln: (tbl[b, j], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda b, h, j, tbl, ln: (tbl[b, j], 0, h, 0)),
            pl.BlockSpec((1, R), lambda b, h, j, tbl, ln: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, R, D),
                               lambda b, h, j, tbl, ln: (b, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((R, 1), jnp.float32),
                        pltpu.VMEM((R, 1), jnp.float32),
                        pltpu.VMEM((R, D), jnp.float32)],
    )
    out = pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, R, D), q.dtype),
        interpret=interpret)(
        bt.astype(jnp.int32), lens.astype(jnp.int32), qt, kp, vp, qpe)
    return (out.reshape(B, KV, Sq, G, D).transpose(0, 2, 1, 3, 4)
            .reshape(B, Sq, H, D))
