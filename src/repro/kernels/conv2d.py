"""Direct 2-D convolution Pallas kernel with fused BN/activation epilogue.

The paper's workhorse op.  Grid: (batch, C_out tiles, H_out row blocks).
Each step keeps the full (padded) input feature map of one image in VMEM —
CNN maps at these sizes are far below the VMEM budget — and contracts the
kh×kw taps for one block of ``block_h`` output rows as shifted
(block_h·W_out, C_in)×(C_in, bc) matmuls on the MXU (the TPU-native analogue
of unrolling the filter loops: taps become statically unrolled matmuls, not
scalar MACCs).  The inference-folded batch-norm and activation apply in VMEM
before the single write-back (LF + CW).

The tiling pass hands ``(block_h, block_c)`` — the LU/LT row/channel tile
pair; both components are honoured (rule 2: blocks divide the output dims,
falling back to the largest divisor).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, *rest, kh: int, kw: int, stride: int,
            bh: int, wo: int, act: Optional[str], has_bn: bool):
    from repro.core.ops_impl import _act
    if has_bn:
        scale_ref, bias_ref, mean_ref, var_ref = rest[:4]
    o_ref = rest[-1]
    r0 = pl.program_id(2) * bh * stride         # first input row of the block
    x = x_ref[0].astype(jnp.float32)            # (Hp, Wp, CI)
    w = w_ref[...].astype(jnp.float32)          # (kh, kw, CI, bc)
    ci = x.shape[-1]
    bc = w.shape[-1]
    acc = jnp.zeros((bh * wo, bc), jnp.float32)
    for dh in range(kh):
        for dw in range(kw):
            sub = jax.lax.dynamic_slice(
                x, (r0 + dh, dw, 0),
                ((bh - 1) * stride + 1, (wo - 1) * stride + 1, ci))
            xs = sub[::stride, ::stride, :].reshape(bh * wo, ci)
            acc += jnp.dot(xs, w[dh, dw], preferred_element_type=jnp.float32)
    if has_bn:
        inv = jax.lax.rsqrt(var_ref[...].astype(jnp.float32) + 1e-5)
        acc = ((acc - mean_ref[...]) * (inv * scale_ref[...])
               + bias_ref[...])
    if act:
        acc = _act(acc, act)
    o_ref[0] = acc.reshape(bh, wo, bc).astype(o_ref.dtype)


def _fit_block(n: int, target: Optional[int]) -> int:
    """Largest divisor of ``n`` <= target (rule 2: even division)."""
    if target is None or target >= n:
        return n
    b = max(min(target, n), 1)
    while n % b:
        b -= 1
    return b


def conv2d_fused(x: jax.Array, w: jax.Array, *, stride: int = 1,
                 padding: str = "SAME", bn=None, act: Optional[str] = None,
                 block_c: int = 128, block_h: Optional[int] = None,
                 interpret: bool = False) -> jax.Array:
    """x: (N, H, W, CI) NHWC; w: (kh, kw, CI, CO) HWIO."""
    N, H, W, CI = x.shape
    kh, kw, _, CO = w.shape
    if padding == "SAME":
        ho = -(-H // stride)
        wo = -(-W // stride)
        ph = max((ho - 1) * stride + kh - H, 0)
        pw = max((wo - 1) * stride + kw - W, 0)
        x = jnp.pad(x, ((0, 0), (ph // 2, ph - ph // 2),
                        (pw // 2, pw - pw // 2), (0, 0)))
    else:
        ho = (H - kh) // stride + 1
        wo = (W - kw) // stride + 1
    # row blocks index the input via dynamic_slice; both paddings guarantee
    # x.shape[1] >= (ho-1)*stride + kh, so every block's extent is in range
    bc = _fit_block(CO, min(block_c, CO))
    bh = _fit_block(ho, block_h)
    grid = (N, CO // bc, ho // bh)
    in_specs = [pl.BlockSpec((1,) + x.shape[1:], lambda n, j, i: (n, 0, 0, 0)),
                pl.BlockSpec((kh, kw, CI, bc), lambda n, j, i: (0, 0, 0, j))]
    operands = [x, w]
    if bn is not None:
        for t in bn:
            in_specs.append(pl.BlockSpec((bc,), lambda n, j, i: (j,)))
            operands.append(t.astype(jnp.float32))
    kern = functools.partial(_kernel, kh=kh, kw=kw, stride=stride, bh=bh,
                             wo=wo, act=act, has_bn=bn is not None)
    return pl.pallas_call(
        kern, grid=grid, in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bh, wo, bc), lambda n, j, i: (n, i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((N, ho, wo, CO), x.dtype),
        interpret=interpret)(*operands)
