"""Direct 2-D convolution Pallas kernel with fused BN/activation epilogue.

The paper's workhorse op.  Grid: (batch, C_out tiles).  Each step keeps the
full (padded) input feature map of one image in VMEM — CNN maps at these
sizes are far below the VMEM budget — and contracts the kh×kw taps as
shifted (H·W, C_in)×(C_in, bc) matmuls on the MXU (the TPU-native analogue
of unrolling the filter loops: taps become statically unrolled matmuls, not
scalar MACCs).  The inference-folded batch-norm and activation apply in VMEM
before the single write-back (LF + CW).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, *rest, kh: int, kw: int, stride: int,
            ho: int, wo: int, act: Optional[str], has_bn: bool):
    from repro.core.ops_impl import _act
    if has_bn:
        scale_ref, bias_ref, mean_ref, var_ref = rest[:4]
    o_ref = rest[-1]
    x = x_ref[0].astype(jnp.float32)            # (Hp, Wp, CI)
    w = w_ref[...].astype(jnp.float32)          # (kh, kw, CI, bc)
    ci = x.shape[-1]
    bc = w.shape[-1]
    acc = jnp.zeros((ho * wo, bc), jnp.float32)
    for dh in range(kh):
        for dw in range(kw):
            xs = jax.lax.slice(
                x, (dh, dw, 0),
                (dh + (ho - 1) * stride + 1, dw + (wo - 1) * stride + 1, ci),
                (stride, stride, 1)).reshape(ho * wo, ci)
            acc += jnp.dot(xs, w[dh, dw], preferred_element_type=jnp.float32)
    if has_bn:
        inv = jax.lax.rsqrt(var_ref[...].astype(jnp.float32) + 1e-5)
        acc = ((acc - mean_ref[...]) * (inv * scale_ref[...])
               + bias_ref[...])
    if act:
        acc = _act(acc, act)
    o_ref[0] = acc.reshape(ho, wo, bc).astype(o_ref.dtype)


def conv2d_fused(x: jax.Array, w: jax.Array, *, stride: int = 1,
                 padding: str = "SAME", bn=None, act: Optional[str] = None,
                 block_c: int = 128, interpret: bool = False) -> jax.Array:
    """x: (N, H, W, CI) NHWC; w: (kh, kw, CI, CO) HWIO."""
    N, H, W, CI = x.shape
    kh, kw, _, CO = w.shape
    if padding == "SAME":
        ho = -(-H // stride)
        wo = -(-W // stride)
        ph = max((ho - 1) * stride + kh - H, 0)
        pw = max((wo - 1) * stride + kw - W, 0)
        x = jnp.pad(x, ((0, 0), (ph // 2, ph - ph // 2),
                        (pw // 2, pw - pw // 2), (0, 0)))
    else:
        ho = (H - kh) // stride + 1
        wo = (W - kw) // stride + 1
    bc = min(block_c, CO)
    while CO % bc:
        bc //= 2
    bc = max(bc, 1)
    grid = (N, CO // bc)
    in_specs = [pl.BlockSpec((1,) + x.shape[1:], lambda n, j: (n, 0, 0, 0)),
                pl.BlockSpec((kh, kw, CI, bc), lambda n, j: (0, 0, 0, j))]
    operands = [x, w]
    if bn is not None:
        for t in bn:
            in_specs.append(pl.BlockSpec((bc,), lambda n, j: (j,)))
            operands.append(t.astype(jnp.float32))
    kern = functools.partial(_kernel, kh=kh, kw=kw, stride=stride, ho=ho,
                             wo=wo, act=act, has_bn=bn is not None)
    return pl.pallas_call(
        kern, grid=grid, in_specs=in_specs,
        out_specs=pl.BlockSpec((1, ho, wo, bc), lambda n, j: (n, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((N, ho, wo, CO), x.dtype),
        interpret=interpret)(*operands)
