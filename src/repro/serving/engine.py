"""Batched serving engine: prefill → decode with donated rolling caches.

The decode step is one jitted program with donated state (paper: autorun —
no host control between tokens beyond the sampling loop); ``generate_fori``
additionally runs N decode steps inside a single on-device ``fori_loop``
(fully host-free generation, the strongest autorun analogue).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import lowering
from repro.core.plan import ExecutionPlan


@dataclass
class EngineConfig:
    temperature: float = 0.0          # 0 = greedy
    seed: int = 0


class Engine:
    def __init__(self, plan: ExecutionPlan, params, ecfg: EngineConfig = None,
                 mesh=None):
        self.plan = plan
        self.params = params
        self.ecfg = ecfg or EngineConfig()
        self.mesh = mesh
        self.apply = lowering.make_apply(plan)
        ctx = mesh if mesh is not None else _nullcontext()
        with ctx:
            self._prefill = jax.jit(
                lambda p, b: self.apply(p, b, mode="prefill"))
            self._decode = jax.jit(
                lambda p, b, st, i: self.apply(p, b, state=st,
                                               cache_index=i, mode="decode"),
                donate_argnums=(2,))

    def _sample(self, logits, rng):
        if self.ecfg.temperature == 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            rng, logits / self.ecfg.temperature, axis=-1).astype(jnp.int32)

    def generate(self, batch: Dict[str, Any], steps: int
                 ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        """Prefill on the prompt batch, then decode ``steps`` tokens."""
        S = batch["tokens"].shape[1]
        logits, state, _ = self._prefill(self.params, batch)
        rng = jax.random.key(self.ecfg.seed)
        tok = self._sample(logits[:, -1], rng)
        out = [tok]
        for t in range(steps - 1):
            rng, k = jax.random.split(rng)
            lg, state, _ = self._decode(self.params, {"tokens": tok[:, None]},
                                        state, jnp.int32(S + t))
            tok = self._sample(lg[:, -1], k)
            out.append(tok)
        return jnp.stack(out, axis=1), state

    def generate_fori(self, batch: Dict[str, Any], steps: int) -> jnp.ndarray:
        """Fully on-device generation: the whole decode loop is one program."""
        S = batch["tokens"].shape[1]
        apply = self.apply
        params = self.params

        def run(params, batch):
            logits, state, _ = apply(params, batch, mode="prefill")
            tok0 = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            B = tok0.shape[0]
            toks = jnp.zeros((B, steps), jnp.int32)
            toks = toks.at[:, 0].set(tok0)

            def body(t, carry):
                toks, state = carry
                cur = jax.lax.dynamic_slice_in_dim(toks, t, 1, axis=1)
                lg, state, _ = apply(params, {"tokens": cur}, state=state,
                                     cache_index=(S + t).astype(jnp.int32),
                                     mode="decode")
                nxt = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)
                toks = jax.lax.dynamic_update_slice_in_dim(
                    toks, nxt[:, None], t + 1, axis=1)
                return toks, state

            toks, _ = jax.lax.fori_loop(0, steps - 1, body,
                                        (toks, state))
            return toks

        ctx = self.mesh if self.mesh is not None else _nullcontext()
        with ctx:
            return jax.jit(run)(params, batch)


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
