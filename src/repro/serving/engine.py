"""Batched serving engine: prefill → decode with donated rolling caches.

The Engine is a thin consumer of :class:`repro.flow.CompiledModel` — the
compiled model owns the jitted prefill/decode/generate stages (paper:
autorun — no host control between tokens beyond the sampling loop);
``generate_fori`` runs N decode steps inside a single on-device
``fori_loop`` (fully host-free generation, the strongest autorun analogue).
The Engine adds the serving-side policy: bound parameters and sampling
configuration.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple, Union

import jax.numpy as jnp

from repro.core.plan import ExecutionPlan
from repro.flow import CompiledModel


@dataclass
class EngineConfig:
    temperature: float = 0.0          # 0 = greedy
    seed: int = 0


class Engine:
    def __init__(self, compiled: Union[CompiledModel, ExecutionPlan], params,
                 ecfg: EngineConfig = None, mesh=None):
        if isinstance(compiled, ExecutionPlan):   # legacy plan-based wiring
            compiled = CompiledModel.from_plan(compiled, mesh=mesh)
        elif mesh is not None and mesh is not compiled.mesh:
            # honour an explicitly requested mesh: rewrap so the jitted
            # stages build inside it
            compiled = CompiledModel.from_plan(compiled.plan, mesh=mesh)
        self.compiled = compiled
        self.plan = compiled.plan
        self.params = params
        self.ecfg = ecfg or EngineConfig()
        self.mesh = compiled.mesh

    def generate(self, batch: Dict[str, Any], steps: int
                 ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        """Prefill on the prompt batch, then decode ``steps`` tokens."""
        return self.compiled.generate(
            self.params, batch, steps,
            temperature=self.ecfg.temperature, seed=self.ecfg.seed)

    def generate_fori(self, batch: Dict[str, Any], steps: int) -> jnp.ndarray:
        """Fully on-device generation: the whole decode loop is one program."""
        return self.compiled.generate_fori(self.params, batch, steps)
