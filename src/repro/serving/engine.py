"""Serving engine: continuous batching over a paged KV-cache.

The Engine is the serving-side consumer of :class:`repro.flow.CompiledModel`
(the compiled model owns the jitted prefill/decode stages; the paper's
autorun kernels are the reason the host does nothing between tokens beyond
sampling).  On top of it the Engine adds the production loop:

* ``run(requests)`` — continuous batching: a FIFO queue feeds ``max_batch``
  slots; finished sequences are evicted and new prompts prefilled into the
  freed slots between decode ticks (``serving/scheduler.py``), with KV state
  held in a paged block pool (``serving/kvcache.py``) so memory scales with
  live tokens;
* shape bucketing — prompt lengths and batch sizes round up to a fixed
  ladder, so every tick reuses a jitted program instead of retracing;
* per-request latency / throughput metrics, surfaced in ``describe()``;
* ``generate`` / ``generate_fori`` — the single-batch rolling-cache paths,
  unchanged.

Bucketed prefill left-pads prompts and threads explicit per-row positions
through the model (``batch["positions"]``); padded rows carry negative
positions, which both attention paths mask out (the Pallas flash kernel's
mask is positional too, so bucketed prefill is exact on either backend;
decode is position-driven everywhere).

Two perf paths sit on top of the basic tick loop, both gated to stay
byte-identical to it:

* **chunked prefill** (``chunk_size`` / ``chunked_prefill``) — slots
  catching up on a prompt tail (prefix-cache hits, and with
  ``chunked_prefill`` every cold prompt) advance ``chunk_size`` tokens per
  tick through a ``(B, k)`` catch-up cell, interleaved with ongoing decodes
  in the same tick, instead of stalling the batch one token at a time;
* **host-free decode segments** (``fori_seg``) — steady-state stretches
  with no scheduling events (no admissions pending in a slot, no tail
  catch-up, every slot at least ``fori_seg`` tokens from its budget) run as
  one on-device ``fori_loop`` with in-loop sampling: one host round-trip
  per segment instead of per token.  The loop falls back to per-tick host
  stepping whenever admit/evict/COW/finish bookkeeping needs the host.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple,
                    Union)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import ExecutionPlan
from repro.flow import CompiledModel
from repro.obs import MetricsRegistry, Tracer
from repro.serving.kvcache import (PagedKVCache, blocks_for_tokens,
                                   merge_state, slice_state)
from repro.serving.scheduler import (Request, RequestResult, Scheduler,
                                     bucket_for)
from repro.serving.speculation import sample_targets


def _pow2_ladder(lo: int, hi: int) -> Tuple[int, ...]:
    out = []
    b = lo
    while b < hi:
        out.append(b)
        b *= 2
    out.append(hi)
    return tuple(out)


@dataclass
class EngineConfig:
    """Serving-policy knobs: sampling, the slot/shape envelope, and the
    paged KV-pool geometry.  Validated at construction; bucket ladders
    default to powers of two capped by the envelope."""
    temperature: float = 0.0          # 0 = greedy
    seed: int = 0
    # serving envelope
    max_batch: int = 4                # decode slots (continuous batching)
    max_seq_len: int = 128            # per-request prompt + generation cap
    batch_buckets: Optional[Tuple[int, ...]] = None
    prompt_buckets: Optional[Tuple[int, ...]] = None
    # paged KV pool
    block_size: int = 16
    num_blocks: Optional[int] = None  # pool size; None = full provisioning
    # prefix caching (shared prompt blocks, copy-on-write); a match below
    # min_ratio coverage is treated as a miss — the uncovered tail catches
    # up one token per decode tick, so marginal hits would trade one
    # batched prefill for a long sequential tail
    prefix_cache: bool = False
    prefix_cache_min_ratio: float = 0.5
    # chunked prefill: slots catching up on a prompt tail advance
    # chunk_size tokens per decode tick through a (B, k) cell; with
    # chunked_prefill on, cold prompts skip the monolithic prefill batch
    # entirely and drain the same way (vLLM-style).  chunk_buckets is the
    # per-tick chunk ladder (rung 1 = plain decode tick).
    chunk_size: int = 1
    chunked_prefill: bool = False
    chunk_buckets: Optional[Tuple[int, ...]] = None
    # host-free decode: run fori_seg decode ticks as one on-device
    # fori_loop (sampling in-loop) when no scheduling event can occur
    # within the segment; 0 disables
    fori_seg: int = 0
    # speculative decoding: a drafter proposes up to draft_k continuation
    # tokens per slot per tick; the engine verifies them in one
    # (B, draft_k+1) cell, commits the accepted prefix plus one target
    # token, and rolls the rest back through the ledger.  Exact: greedy
    # output is byte-identical to the 1-token loop, sampled output is
    # drafter-invariant (per-request rng streams).  Accepts a
    # SpeculationConfig or a spec string ("ngram:4" | "draft:<cfg>:4" |
    # "null:2" | "off"); None disables.  Mutually exclusive with fori_seg
    # (S307): acceptance is decided on the host every tick.
    speculation: Optional[Any] = None
    # debugging/parity: keep the sampled-step logits on each RequestResult
    capture_logits: bool = False
    # observability: record a per-tick span timeline (phase, batch bucket,
    # queue depth, pool occupancy, host-sync count) into the engine's
    # Tracer ring buffer — export with launch/serve.py --trace or
    # Engine.tracer.to_chrome().  Off by default; the disabled path is one
    # boolean check per span site, and outputs are byte-identical either
    # way (tracing never touches sampling, scheduling, or device state).
    trace: bool = False
    trace_max_events: int = 65536

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >=1, got {self.max_batch}")
        if self.max_seq_len < 1:
            raise ValueError(
                f"max_seq_len must be >=1, got {self.max_seq_len}")
        if self.block_size < 1:
            raise ValueError(f"block_size must be >=1, got {self.block_size}")
        if self.temperature < 0:
            raise ValueError("temperature must be >= 0")
        if not 0.0 <= self.prefix_cache_min_ratio <= 1.0:
            raise ValueError("prefix_cache_min_ratio must be in [0, 1]")
        # the invariants below are shared with the static verifier
        # (repro.analysis checkers S301-S307): each rule lives once in
        # repro.analysis.rules and is raised here with its legacy message
        from repro.analysis import rules as _rules

        def _check(msg):
            if msg is not None:
                raise ValueError(msg)

        _check(_rules.chunk_in_range(self.chunk_size, self.max_seq_len))
        _check(_rules.fori_seg_valid(self.fori_seg))
        if isinstance(self.speculation, str):
            from repro.serving.speculation import SpeculationConfig
            self.speculation = SpeculationConfig.parse(self.speculation)
        if self.speculation is not None:
            sp = self.speculation
            _check(_rules.speculation_valid(sp.kind, sp.draft_k, sp.draft_cfg,
                                            self.max_seq_len, self.fori_seg))
        if self.chunk_buckets is None:
            self.chunk_buckets = (1,) if self.chunk_size == 1 \
                else (1, self.chunk_size)
        else:
            self.chunk_buckets = tuple(sorted(set(
                int(b) for b in self.chunk_buckets)))
            _check(_rules.chunk_ladder(self.chunk_buckets, self.chunk_size))
        if self.batch_buckets is None:
            self.batch_buckets = _pow2_ladder(1, self.max_batch)
        else:
            self.batch_buckets = tuple(sorted(set(int(b)
                                                  for b in self.batch_buckets)))
            _check(_rules.batch_ladder(self.batch_buckets, self.max_batch))
        if self.prompt_buckets is None:
            self.prompt_buckets = _pow2_ladder(
                min(max(8, self.block_size), self.max_seq_len),
                self.max_seq_len)
        else:
            self.prompt_buckets = tuple(sorted(set(int(b)
                                                   for b in self.prompt_buckets)))
            _check(_rules.prompt_ladder(self.prompt_buckets,
                                        self.max_seq_len))
            if self.prompt_buckets[-1] < self.max_seq_len:
                self.prompt_buckets += (self.max_seq_len,)
        # the paged pool packs prompt K/V block-by-block and the prefix
        # index hashes block-aligned runs: every prompt-bucket rung (and
        # hence max_seq_len, the final rung) must be a whole number of
        # blocks, not just the envelope
        _check(_rules.block_divides_buckets(self.block_size,
                                            self.prompt_buckets))

    @property
    def blocks_per_slot(self) -> int:
        return blocks_for_tokens(self.max_seq_len, self.block_size)

    @property
    def tick_buckets(self) -> Tuple[int, ...]:
        """Per-tick column ladder for step 2b: the chunk ladder, plus the
        ``draft_k + 1`` verify-cell rung when speculation is on (spec rows
        and catch-up rows bucket through the same jitted (B, k) cells)."""
        if self.speculation is None:
            return self.chunk_buckets
        return tuple(sorted({*self.chunk_buckets, 1,
                             self.speculation.draft_k + 1}))


@dataclass
class RunReport:
    """Engine.run outcome: per-request results plus loop-level metrics.

    ``metrics`` keeps its historical flat key schema (pinned by
    ``tests/test_bench_schema.py``) but is assembled from ``registry`` — a
    per-run :class:`~repro.obs.MetricsRegistry` snapshot under stable
    dotted names (``serving.prefix.hits``, ``pool.blocks.live``, …)."""
    results: List[RequestResult]
    metrics: Dict[str, Any]
    registry: Optional[MetricsRegistry] = field(default=None, repr=False)

    @property
    def by_id(self) -> Dict[Any, RequestResult]:
        return {r.rid: r for r in self.results}

    def describe(self) -> str:
        m = self.metrics
        out = (
            f"serving[{m['n_requests']} req] "
            f"{m['generated_tokens']} tok in {m['wall_s']:.3f}s "
            f"({m['tokens_per_s']:.1f} tok/s)\n"
            f"  latency: p50={m['p50_latency_s'] * 1e3:.1f}ms "
            f"p95={m['p95_latency_s'] * 1e3:.1f}ms "
            f"ttft_p50={m['p50_ttft_s'] * 1e3:.1f}ms "
            f"ttft_p95={m['p95_ttft_s'] * 1e3:.1f}ms\n"
            f"  loop: ticks={m['decode_ticks']} "
            f"prefill_batches={m['prefill_batches']} "
            f"admissions={m['admissions']} evictions={m['evictions']} "
            f"refills={m['refills']} "
            f"fori_segments={m['fori_segments']} "
            f"host_syncs/tok={m['host_syncs_per_token']:.3f}\n"
            f"  kv-pool: {m['pool_blocks']} blocks x {m['block_size']} tok, "
            f"peak_used={m['peak_used_blocks']} "
            f"peak_live_tokens={m['peak_live_tokens']}")
        if m.get("prefix_cache"):
            out += (
                f"\n  prefix-cache: hits={m['prefix_hits']}/"
                f"{m['prefix_hits'] + m['prefix_misses']} "
                f"hit_rate={m['prefix_hit_rate'] * 100:.1f}% "
                f"(cached {m['prefix_cached_tokens']}/"
                f"{m['prompt_tokens_total']} prompt tok) "
                f"cow_forks={m['cow_forks']} "
                f"cache_evictions={m['prefix_cache_evictions']} "
                f"prefill_computed={m['prefill_tokens_computed']}")
        if m.get("speculation"):
            out += (
                f"\n  speculation: {m['spec_drafter']} "
                f"accepted={m['spec_tokens_accepted']}/"
                f"{m['spec_tokens_drafted']} "
                f"({m['spec_acceptance_rate'] * 100:.1f}%) "
                f"spec_ticks={m['spec_ticks']} "
                f"rolled_back={m['spec_rollback_tokens']} "
                f"fork_undos={m['spec_fork_undos']}")
        return out


class Engine:
    def __init__(self, compiled: Union[CompiledModel, ExecutionPlan], params,
                 ecfg: Optional[EngineConfig] = None, mesh=None,
                 clock: Optional[Callable[[], float]] = None):
        if isinstance(compiled, ExecutionPlan):   # legacy plan-based wiring
            compiled = CompiledModel.from_plan(compiled, mesh=mesh)
        elif mesh is not None and mesh is not compiled.mesh:
            # honour an explicitly requested mesh: rewrap so the jitted
            # stages build inside it
            compiled = CompiledModel.from_plan(compiled.plan, mesh=mesh)
        self.compiled = compiled
        self.plan = compiled.plan
        self.params = params
        self.ecfg = ecfg if ecfg is not None else EngineConfig()
        self.mesh = compiled.mesh
        # one clock drives wall_s, latency/TTFT (through the Scheduler) and
        # the span timeline, so an injected clock makes every timing in the
        # report deterministic under test
        self.clock: Callable[[], float] = \
            clock if clock is not None else time.perf_counter
        self.tracer = Tracer(enabled=self.ecfg.trace,
                             max_events=self.ecfg.trace_max_events,
                             clock=self.clock)
        self.last_report: Optional[RunReport] = None
        self.last_cache: Optional[PagedKVCache] = None
        # speculative decoding: the drafter is built lazily on first use (a
        # draft-model drafter compiles a second cell) and cached across
        # run() calls; drafter_override lets tests inject a custom Drafter
        self.drafter_override = None
        self._drafter = None
        self._drafter_key = None

    # -- single-batch generation (rolling cache) -----------------------------
    def generate(self, batch: Dict[str, Any], steps: int
                 ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        """Prefill on the prompt batch, then decode ``steps`` tokens."""
        return self.compiled.generate(
            self.params, batch, steps,
            temperature=self.ecfg.temperature, seed=self.ecfg.seed)

    def generate_fori(self, batch: Dict[str, Any], steps: int) -> jnp.ndarray:
        """Fully on-device generation: the whole decode loop is one program."""
        return self.compiled.generate_fori(self.params, batch, steps)

    # -- continuous-batching serving loop ------------------------------------
    def _sample(self, logits, key, temperature: float):
        # one sampling policy for every path: generate(), generate_fori()
        # and the run() loop all go through CompiledModel._sample
        return self.compiled._sample(logits, key, temperature)

    def _get_drafter(self, spec):
        if self.drafter_override is not None:
            return self.drafter_override
        from repro.serving.speculation import build_drafter
        key = (spec.kind, spec.draft_cfg, spec.ngram_max, spec.ngram_min)
        if self._drafter is None or self._drafter_key != key:
            self._drafter = build_drafter(
                spec, max_seq_len=self.ecfg.max_seq_len,
                target_cfg=self.plan.cfg)
            self._drafter_key = key
        return self._drafter

    def new_cache(self) -> PagedKVCache:
        e = self.ecfg
        return PagedKVCache(self.plan, e.max_batch, block_size=e.block_size,
                            blocks_per_slot=e.blocks_per_slot,
                            num_blocks=e.num_blocks,
                            prefix_cache=e.prefix_cache,
                            min_match_ratio=e.prefix_cache_min_ratio)

    def run(self, requests: Sequence[Request]) -> RunReport:
        """Serve ``requests`` to completion with continuous batching over
        the paged KV pool; returns per-request results + loop metrics
        (also kept as ``self.last_report`` for ``describe()``).

        With ``prefix_cache=True`` admissions are matched against the block
        index first: a hit seeds the slot's block table from shared blocks
        and feeds only the uncovered prompt tail through decode ticks
        (mid-sequence prefill — exact, byte-identical to the cold path),
        with copy-on-write forks keeping shared blocks immutable."""
        e = self.ecfg
        cache = self.new_cache()
        self.last_cache = cache
        sched = Scheduler(e.max_batch, e.block_size, cache.pool,
                          max_seq_len=e.max_seq_len, clock=self.clock,
                          prefix=cache if e.prefix_cache else None,
                          chunk_prefill=e.chunked_prefill)
        for r in requests:
            sched.submit(r)
        # Left-padded (bucketed) prefill is only exact when every
        # cross-position op masks by the positions array: recurrent/conv
        # temporal-mixing ops never see positions at all and would consume
        # the pad tokens as real context.  Enforce exact prompt buckets
        # there rather than silently corrupt.  (Both attention backends
        # mask positionally — the flash kernel included — so attention-only
        # models pad safely on any backend.)
        has_recurrence = any(not en.paged and en.op.op != "attention"
                             for en in cache._entries)
        pad_unsafe = has_recurrence
        if (e.chunk_size > 1 or e.chunked_prefill) and \
                any(not en.paged for en in cache._entries):
            raise ValueError(
                f"{self.plan.cfg.name}: chunked prefill (chunk_size > 1 or "
                "chunked_prefill) needs every per-request state entry to be "
                "paged self-attention; recurrent or cross-attention state "
                "can only advance one token per tick")
        spec = e.speculation
        spec_on = spec is not None
        if spec_on and any(not en.paged for en in cache._entries):
            raise ValueError(
                f"{self.plan.cfg.name}: speculative decoding needs every "
                "per-request state entry to be paged self-attention; "
                "rollback truncates block chains, which rolling or "
                "cross-attention state cannot express")
        drafter = self._get_drafter(spec) if spec_on else None
        base_key = jax.random.key(e.seed) if spec_on else None
        vocab = self.plan.cfg.vocab_size
        tokens_drafted = tokens_accepted = spec_ticks = 0

        rng = jax.random.key(e.seed)
        tr = self.tracer
        tr.clear()
        # per-run metrics registry: pool-occupancy gauges are set at the
        # same three sites that tracked peak_used/peak_live before (the
        # gauge keeps the peak), counters are published once after the loop
        reg = MetricsRegistry()
        g_pool_live = reg.gauge("pool.blocks.live")
        g_pool_cached = reg.gauge("pool.blocks.cached")
        g_pool_free = reg.gauge("pool.blocks.free")
        g_live_tokens = reg.gauge("pool.tokens.live")

        def note_pool():
            g_pool_live.set(cache.pool.used_blocks)
            g_pool_cached.set(cache.pool.cached_blocks)
            g_pool_free.set(cache.pool.free_blocks)
            g_live_tokens.set(cache.live_tokens())

        t0 = self.clock()
        ticks = prefill_batches = 0
        prefill_tokens = catchup_tokens = prompt_tokens_total = 0
        host_syncs = fori_segments = 0

        def evict_finished():
            sp = tr.span("evict", cat="sub")
            n = 0
            for sidx in sched.finished():
                cache.evict(sidx)
                sched.evict(sidx)
                n += 1
            sp.end(evicted=n)

        run_sp = tr.span("engine.run", cat="run", requests=len(requests),
                         max_batch=e.max_batch)
        while sched.has_work():
            # 1. admit into freed slots: prefix-cache hits seed their block
            #    tables from shared blocks (the uncovered tail catches up
            #    through decode ticks); the rest take the bucketed
            #    left-padded prefill
            sp_admit = tr.span("tick.admit", cat="phase", phase="admit",
                               queue=len(sched.queue))
            admitted = sched.admissions()
            prompt_tokens_total += sum(a.request.prompt_len for a in admitted)
            for a in admitted:
                if a.covered:
                    cache.admit_cached(a.slot, a.request.prompt,
                                       a.reserve_tokens, a.match)
                elif a.chunked:
                    cache.admit_tail(a.slot, a.request.prompt,
                                     a.reserve_tokens)
            adm = [a for a in admitted if not a.covered and not a.chunked]
            if not admitted and not sched.active_slots:
                # nothing running and the queue head still can't be admitted:
                # its block budget exceeds the whole pool — fail loudly
                # instead of spinning
                head = sched.queue[0][0]
                raise RuntimeError(
                    f"request {head.rid!r} needs "
                    f"{head.total_budget} tokens of KV but the pool can "
                    f"never free enough blocks "
                    f"({cache.pool.num_blocks - 1} x {e.block_size} tokens)")
            if adm:
                Bp = bucket_for(len(adm), e.batch_buckets)
                Sp = bucket_for(max(a.request.prompt_len for a in adm),
                                e.prompt_buckets)
                sp_prefill = tr.span("prefill", cat="sub", batch=Bp,
                                     bucket=Sp, n=len(adm))
                if Sp > self.plan.cache_len:
                    raise ValueError(
                        f"prompt bucket {Sp} exceeds the compiled cell's "
                        f"cache length {self.plan.cache_len}; compile the "
                        f"model with a decode shape covering max_seq_len")
                tokens = np.zeros((Bp, Sp), np.int32)
                positions = np.full((Bp, Sp), -1, np.int32)
                for i, a in enumerate(adm):
                    pad = Sp - a.request.prompt_len
                    if pad and pad_unsafe:
                        raise ValueError(
                            f"request {a.request.rid!r}: prompt length "
                            f"{a.request.prompt_len} needs left-padding to "
                            f"bucket {Sp}, but the model has recurrent "
                            "temporal-mixing state that consumes pad tokens "
                            "unmasked; use exact prompt_buckets matching "
                            "the prompt lengths")
                    tokens[i, pad:] = a.request.prompt
                    positions[i] = np.arange(Sp, dtype=np.int32) - pad
                logits, pstate, _ = self.compiled.prefill(
                    self.params, {"tokens": jnp.asarray(tokens),
                                  "positions": jnp.asarray(positions)})
                if spec_on and e.temperature > 0:
                    # per-request rng streams: the first generated token is
                    # commit index 0 of its request's stream, so prefilled
                    # and speculative ticks draw from one counter sequence
                    serials = np.full(Bp, -1, np.int32)
                    for i, a in enumerate(adm):
                        serials[i] = sched.slots[a.slot].serial
                    toks = np.asarray(sample_targets(
                        logits[:, -1][:, None, :], base_key,
                        jnp.asarray(serials), jnp.zeros(Bp, jnp.int32),
                        e.temperature))[:, 0]
                else:
                    rng, k = jax.random.split(rng)
                    toks = np.asarray(
                        self._sample(logits[:, -1], k, e.temperature))
                host_syncs += 1
                for i, a in enumerate(adm):
                    cache.admit(a.slot, a.request.prompt_len,
                                a.reserve_tokens, pstate, i,
                                Sp - a.request.prompt_len,
                                prompt=a.request.prompt)
                    if e.capture_logits:
                        sched.slots[a.slot].result.logits.append(
                            np.asarray(logits[i, -1]))
                    sched.record_token(a.slot, int(toks[i]), first=True)
                prefill_batches += 1
                prefill_tokens += sum(a.request.prompt_len for a in adm)
                sp_prefill.end()
                note_pool()
                evict_finished()
            if tr.enabled:
                # queue blocked with nothing admitted: name the bottleneck
                stall = None
                if sched.queue and not admitted:
                    stall = "no-free-slot" \
                        if not any(s.free for s in sched.slots) \
                        else "no-free-kv-blocks"
                sp_admit.end(admitted=len(admitted),
                             pool_live=cache.pool.used_blocks,
                             pool_free=cache.pool.free_blocks,
                             **({"stall": stall} if stall else {}))

            # 2. advance the occupied slots (batch-bucketed): a host-free
            #    fori segment when nothing can interrupt it, otherwise one
            #    (possibly chunked) decode tick.
            active = sched.active_slots
            if not active:
                continue
            B = bucket_for(sched.high_water, e.batch_buckets)

            # 2a. host-free segment: when no scheduling event can occur for
            #     the next fori_seg ticks — no slot is catching up, and every
            #     slot has at least fori_seg tokens of budget left — run the
            #     whole stretch as one on-device fori_loop with in-loop
            #     sampling.  COW safety: refcounts only change at admission
            #     and eviction, neither of which can happen mid-segment, so
            #     a fork can never *become* needed after prepare_decode; and
            #     rem >= fori_seg keeps every row inside its reserved chain
            #     (a stop-token slot keeps ticking on device — its post-stop
            #     tokens are dropped here and the slot evicted right after).
            rem = min(s.request.max_new_tokens - s.result.n_generated
                      for s in (sched.slots[i] for i in active))
            if e.fori_seg >= 2 and not e.capture_logits and not spec_on \
                    and rem >= e.fori_seg \
                    and not any(sched.slots[i].pending for i in active):
                T = e.fori_seg
                sp_fori = tr.span("tick.fori", cat="phase",
                                  phase="decode-fori", batch=B, seg=T,
                                  queue=len(sched.queue))
                sp_cow = tr.span("cow-fork", cat="sub")
                cache.prepare_decode(active)   # COW forks before any write
                sp_cow.end()
                tok0 = np.zeros(B, np.int32)
                pos0 = np.zeros(B, np.int32)
                for i in active:
                    tok0[i] = sched.slots[i].last_token
                    pos0[i] = sched.slots[i].pos
                part = slice_state(cache.state, cache.slot_axes, B)
                seg = self.compiled.decode_segment(
                    T, temperature=e.temperature)
                toks_dev, new_part, rng = seg(
                    self.params, part, jnp.asarray(tok0), jnp.asarray(pos0),
                    rng)
                cache.state = merge_state(cache.state, new_part,
                                          cache.slot_axes, B)
                cache.note_decode_tick(active, {i: T for i in active})
                toks = np.asarray(toks_dev)    # ONE host sync for T tokens
                host_syncs += 1
                for i in active:
                    s = sched.slots[i]
                    stop = s.request.stop_token
                    for t in range(T):
                        sched.record_token(i, int(toks[i, t]))
                        if stop is not None and int(toks[i, t]) == stop:
                            break
                ticks += T
                fori_segments += 1
                note_pool()
                evict_finished()
                if tr.enabled:
                    sp_fori.end(pool_live=cache.pool.used_blocks,
                                host_syncs=host_syncs)
                continue

            # 2b. one decode tick over the occupied slots.  Slots catching
            #     up on a prompt tail feed their next chunk_size prompt
            #     tokens (a (B, k) catch-up cell, k from the chunk ladder);
            #     caught-up slots advance one sampled token in column 0 of
            #     the same tick.  With speculation on, caught-up slots may
            #     instead carry a verify row [last_token, d_1..d_j]: every
            #     column scores in the same cell, acceptance is decided on
            #     the host, and the ledger rolls rejected columns back.
            sp_tick = tr.span("tick.decode", cat="phase", phase="decode",
                              batch=B, queue=len(sched.queue))
            proposals: Dict[int, np.ndarray] = {}
            if spec_on:
                for i in active:
                    s = sched.slots[i]
                    if s.pending or s.request.speculate is False:
                        continue
                    # cap keeps every possible commit (n_acc + 1 <= j + 1)
                    # inside the request's remaining budget and reservation
                    cap = min(spec.draft_k,
                              s.request.max_new_tokens
                              - s.result.n_generated - 1)
                    if cap < 1:
                        continue
                    hist = np.concatenate(
                        [np.asarray(s.request.prompt, np.int32),
                         np.asarray(s.result.tokens, np.int32)])
                    d = np.asarray(drafter.propose(hist, cap),
                                   np.int32).reshape(-1)[:cap]
                    bad = np.nonzero((d < 0) | (d >= vocab))[0]
                    if bad.size:          # out-of-vocab drafts never match
                        d = d[:int(bad[0])]
                    if d.size:
                        proposals[i] = d
                        cache.spec_begin(i)
            sp_cow = tr.span("cow-fork", cat="sub")
            cache.prepare_decode(active)       # COW forks before any write
            sp_cow.end()
            need = max((len(proposals[i]) + 1 if i in proposals
                        else min(len(sched.slots[i].pending), e.chunk_size)
                        for i in active), default=1)
            k_tick = bucket_for(max(need, 1), e.tick_buckets)
            if tr.enabled:
                sp_tick.set(
                    k=k_tick,
                    phase=("spec-verify" if proposals else
                           "chunked-prefill" if any(
                               sched.slots[i].pending for i in active)
                           else "decode"))
            fills: Dict[int, int] = {}
            if k_tick > 1:
                tokens = np.zeros((B, k_tick), np.int32)
                positions = np.full((B, k_tick), -1, np.int32)
                sel = np.zeros(B, np.int64)
                for s in sched.slots[:B]:
                    if s.free:
                        continue
                    if s.index in proposals:
                        d = proposals[s.index]
                        m = d.size + 1
                        tokens[s.index, 0] = s.last_token
                        tokens[s.index, 1:m] = d
                        positions[s.index, :m] = \
                            s.pos + np.arange(m, dtype=np.int32)
                        fills[s.index] = m
                        sel[s.index] = 0
                    elif s.pending:
                        m = min(len(s.pending), k_tick)
                        tokens[s.index, :m] = s.pending[:m]
                        positions[s.index, :m] = \
                            s.pos + np.arange(m, dtype=np.int32)
                        fills[s.index] = m
                        sel[s.index] = m - 1
                    else:
                        tokens[s.index, 0] = s.last_token
                        positions[s.index, 0] = s.pos
                        fills[s.index] = 1
            else:
                tokens = np.zeros((B, 1), np.int32)
                positions = np.zeros((B, 1), np.int32)
                sel = np.zeros(B, np.int64)
                for s in sched.slots[:B]:
                    if not s.free:
                        tokens[s.index, 0] = \
                            s.pending[0] if s.pending else s.last_token
                        positions[s.index, 0] = s.pos
                        fills[s.index] = 1
            part = slice_state(cache.state, cache.slot_axes, B)
            logits, new_part, _ = self.compiled.decode(
                self.params, {"tokens": jnp.asarray(tokens),
                              "positions": jnp.asarray(positions)},
                part, jnp.int32(0))
            cache.state = merge_state(cache.state, new_part,
                                      cache.slot_axes, B)
            cache.note_decode_tick(active, fills)
            if spec_on:
                # every column's target token at once: column c of row i is
                # the token the target model emits at commit index
                # t0s[i] + c.  At temperature 0 that's a plain argmax
                # (rng-free, byte-identical to the 1-token loop); sampled,
                # each (serial, index) pair owns one counter-mode key, so
                # the draw is independent of tick packing and drafters.
                if e.temperature > 0:
                    serials = np.full(B, -1, np.int32)
                    t0s = np.zeros(B, np.int32)
                    for i in active:
                        s = sched.slots[i]
                        serials[i] = s.serial
                        # catch-up rows: only the final column (the first
                        # generated token) can commit — index 0 there
                        t0s[i] = s.result.n_generated - (fills[i] - 1) \
                            if s.pending else s.result.n_generated
                    targets = np.asarray(sample_targets(
                        logits, base_key, jnp.asarray(serials),
                        jnp.asarray(t0s), e.temperature))
                else:
                    targets = np.asarray(jnp.argmax(logits, axis=-1))
                lg_np = np.asarray(logits) if e.capture_logits else None
            else:
                rng, k = jax.random.split(rng)
                # each row samples from its last fed column's logits
                # (column 0 for plain decode rows, the chunk's last fill
                # for catch-up rows)
                last_lg = jnp.take_along_axis(
                    logits, jnp.asarray(sel)[:, None, None], axis=1)[:, 0]
                toks = np.asarray(self._sample(last_lg, k, e.temperature))
            host_syncs += 1
            spec_commits: Dict[int, int] = {}
            for sidx in active:
                s = sched.slots[sidx]
                if s.pending:
                    m = fills[sidx]
                    catchup_tokens += m
                    sched.note_catchup(sidx, m)
                    if s.pending:      # tail not done: discard sample
                        continue
                    # prompt fully resident: index its blocks, and the
                    # sample from the last tail token's logits is the
                    # first generated token
                    cache.register_prompt(sidx)
                    if e.capture_logits:
                        s.result.logits.append(
                            np.asarray(logits[sidx, int(sel[sidx])]))
                    tok = int(targets[sidx, m - 1]) if spec_on \
                        else int(toks[sidx])
                    sched.record_token(sidx, tok, first=True)
                elif sidx in proposals:
                    # acceptance walk: draft d[c] survives iff it equals
                    # the target token of its column; the committed tokens
                    # are the accepted prefix plus the first mismatch's
                    # target (the bonus token on accept-all)
                    d = proposals[sidx]
                    j = int(d.size)
                    n_acc = 0
                    while n_acc < j and \
                            int(targets[sidx, n_acc]) == int(d[n_acc]):
                        n_acc += 1
                    n_commit = n_acc + 1
                    tokens_drafted += j
                    tokens_accepted += n_acc
                    s.result.tokens_drafted += j
                    s.result.tokens_accepted += n_acc
                    spec_commits[sidx] = n_commit
                    stop = s.request.stop_token
                    for c in range(n_commit):
                        if e.capture_logits:
                            s.result.logits.append(lg_np[sidx, c])
                        tok = int(targets[sidx, c])
                        sched.record_token(sidx, tok)
                        if stop is not None and tok == stop:
                            break
                else:
                    if e.capture_logits:
                        s.result.logits.append(
                            np.asarray(logits[sidx, int(sel[sidx])]))
                    tok = int(targets[sidx, 0]) if spec_on \
                        else int(toks[sidx])
                    sched.record_token(sidx, tok)
            if spec_commits:
                # all windows close together: one batched device resync
                # for every rolled-back slot (must precede eviction — the
                # prefix index only ever sees committed tokens)
                cache.spec_commit_many(spec_commits)
            if proposals:
                spec_ticks += 1
            ticks += 1
            note_pool()
            evict_finished()
            if tr.enabled:
                sp_tick.end(pool_live=cache.pool.used_blocks,
                            host_syncs=host_syncs)

        run_sp.end(ticks=ticks, host_syncs=host_syncs)
        wall = self.clock() - t0
        results = sched.results
        gen = sum(r.n_generated for r in results)
        led = cache.ledger

        # publish every loop counter into the per-run registry; the
        # report's flat legacy keys are a view over the snapshot (the
        # dotted names are the stable schema — README "Observability")
        reg.counter("serving.requests").inc(len(results))
        reg.counter("serving.tokens.generated").inc(gen)
        reg.counter("serving.tokens.prompt").inc(prompt_tokens_total)
        reg.counter("serving.tokens.prefill_computed").inc(
            prefill_tokens + catchup_tokens)
        reg.counter("serving.tokens.catchup").inc(catchup_tokens)
        reg.counter("serving.ticks").inc(ticks)
        reg.counter("serving.prefill.batches").inc(prefill_batches)
        reg.counter("serving.fori.segments").inc(fori_segments)
        # host_syncs counts the device->host round-trips the loop performed
        # (one per prefill sample, per tick sample, per fori segment)
        reg.counter("serving.host_syncs").inc(host_syncs)
        reg.gauge("serving.wall_s").set(wall)
        reg.gauge("serving.tokens_per_s").set(
            gen / wall if wall > 0 else float("inf"))
        reg.gauge("serving.host_syncs_per_token").set(
            host_syncs / gen if gen else 0.0)
        h_lat = reg.histogram("serving.latency_s")
        h_ttft = reg.histogram("serving.ttft_s")
        for r in results:
            h_lat.observe(r.latency_s)
            h_ttft.observe(r.ttft_s)
        sched.publish_metrics(reg)
        cache.pool.publish_metrics(reg)
        led.publish_metrics(reg)
        reg.gauge("pool.blocks.total").set(cache.num_blocks)
        reg.gauge("pool.bytes").set(cache.pool_bytes())
        reg.gauge("serving.prefix.hit_rate").set(
            led.cached_tokens / prompt_tokens_total
            if prompt_tokens_total else 0.0)
        reg.counter("serving.spec.ticks").inc(spec_ticks)
        reg.counter("serving.spec.tokens_drafted").inc(tokens_drafted)
        reg.counter("serving.spec.tokens_accepted").inc(tokens_accepted)
        reg.gauge("serving.spec.acceptance_rate").set(
            tokens_accepted / tokens_drafted if tokens_drafted else 0.0)

        snap = reg.snapshot()
        report = RunReport(results=results, registry=reg, metrics={
            "n_requests": snap["serving.requests"],
            "generated_tokens": snap["serving.tokens.generated"],
            "wall_s": snap["serving.wall_s"],
            "tokens_per_s": snap["serving.tokens_per_s"],
            "p50_latency_s": snap["serving.latency_s.p50"],
            "p95_latency_s": snap["serving.latency_s.p95"],
            "p50_ttft_s": snap["serving.ttft_s.p50"],
            "p95_ttft_s": snap["serving.ttft_s.p95"],
            "decode_ticks": snap["serving.ticks"],
            "prefill_batches": snap["serving.prefill.batches"],
            # serving-policy knobs echo straight from the config
            "chunk_size": e.chunk_size,
            "chunked_prefill": e.chunked_prefill,
            "fori_seg": e.fori_seg,
            "fori_segments": snap["serving.fori.segments"],
            "host_syncs": snap["serving.host_syncs"],
            "host_syncs_per_token": snap["serving.host_syncs_per_token"],
            "admissions": snap["serving.sched.admissions"],
            "evictions": snap["serving.sched.evictions"],
            "refills": snap["serving.sched.refills"],
            "pool_blocks": snap["pool.blocks.total"],
            "block_size": e.block_size,
            "peak_used_blocks": snap["pool.blocks.live.peak"],
            "peak_live_tokens": snap["pool.tokens.live.peak"],
            "pool_bytes": snap["pool.bytes"],
            # prefix-cache outcome (zeros when the toggle is off)
            "prefix_cache": e.prefix_cache,
            "prefix_hits": snap["serving.prefix.hits"],
            "prefix_misses": snap["serving.prefix.misses"],
            "prefix_cached_tokens": snap["serving.prefix.cached_tokens"],
            "prefix_cache_evictions": snap["serving.prefix.evictions"],
            "cow_forks": snap["serving.prefix.cow_forks"],
            "prompt_tokens_total": snap["serving.tokens.prompt"],
            "prefill_tokens_computed":
                snap["serving.tokens.prefill_computed"],
            "catchup_tokens": snap["serving.tokens.catchup"],
            "prefix_hit_rate": snap["serving.prefix.hit_rate"],
            # speculative-decoding outcome (off -> False + zeros)
            "speculation": spec_on,
            "spec_drafter": spec.describe() if spec_on else "off",
            "spec_draft_k": spec.draft_k if spec_on else 0,
            "spec_ticks": snap["serving.spec.ticks"],
            "spec_tokens_drafted": snap["serving.spec.tokens_drafted"],
            "spec_tokens_accepted": snap["serving.spec.tokens_accepted"],
            "spec_acceptance_rate": snap["serving.spec.acceptance_rate"],
            "spec_rollback_tokens": snap["serving.spec.rollback_tokens"],
            "spec_fork_undos": snap["serving.spec.fork_undos"],
        })
        self.last_report = report
        return report

    # -- reporting -----------------------------------------------------------
    def describe(self, stats: bool = False) -> str:
        """Flow report + serving envelope + the last run's metrics."""
        e = self.ecfg
        lines = [self.compiled.describe(stats=stats),
                 f"  serving: slots={e.max_batch} max_seq_len={e.max_seq_len} "
                 f"block={e.block_size} "
                 f"batch_buckets={list(e.batch_buckets)} "
                 f"prompt_buckets={list(e.prompt_buckets)} "
                 f"prefix_cache={'on' if e.prefix_cache else 'off'} "
                 f"chunk={e.chunk_size}"
                 f"{'+chunked_prefill' if e.chunked_prefill else ''} "
                 f"fori_seg={e.fori_seg or 'off'} "
                 f"spec={e.speculation.describe() if e.speculation else 'off'}"]
        if self.last_report is not None:
            lines.append("  " +
                         self.last_report.describe().replace("\n", "\n  "))
        return "\n".join(lines)
