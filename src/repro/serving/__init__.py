"""repro.serving — the production serving subsystem.

* :mod:`repro.serving.engine` — the Engine: continuous-batching ``run``
  loop, single-batch ``generate`` paths, metrics.
* :mod:`repro.serving.scheduler` — request queue, admission control, slots.
* :mod:`repro.serving.kvcache` — paged KV-cache manager (block pool, block
  tables, prefill packing, the refcounting ledger behind prefix caching).
* :mod:`repro.serving.prefix` — content-hashed prefix index (shared prompt
  blocks, copy-on-write seeds for new requests).
* :mod:`repro.serving.speculation` — speculative decoding: drafters, the
  batched verify cell's target sampling, draft->verify->rollback config.
* :mod:`repro.serving.autotune` — engine-level decode autotune over the DSE.
"""
from repro.serving.engine import Engine, EngineConfig, RunReport
from repro.serving.kvcache import (BlockLedger, BlockPool, PagedKVCache,
                                   PrefixMatch)
from repro.serving.prefix import PrefixIndex, block_hashes
from repro.serving.scheduler import (Request, RequestResult, Scheduler,
                                     load_requests_jsonl,
                                     shared_prefix_requests,
                                     synthetic_requests)
from repro.serving.speculation import (Drafter, DraftModelDrafter,
                                       NGramDrafter, NullDrafter,
                                       SpeculationConfig, build_drafter,
                                       sample_targets)

__all__ = ["Engine", "EngineConfig", "RunReport", "BlockLedger", "BlockPool",
           "PagedKVCache", "PrefixIndex", "PrefixMatch", "Request",
           "RequestResult", "Scheduler", "block_hashes",
           "load_requests_jsonl", "shared_prefix_requests",
           "synthetic_requests", "Drafter", "DraftModelDrafter",
           "NGramDrafter", "NullDrafter", "SpeculationConfig",
           "build_drafter", "sample_targets"]
