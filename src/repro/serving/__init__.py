"""repro.serving — the production serving subsystem.

* :mod:`repro.serving.engine` — the Engine: continuous-batching ``run``
  loop, single-batch ``generate`` paths, metrics.
* :mod:`repro.serving.scheduler` — request queue, admission control, slots.
* :mod:`repro.serving.kvcache` — paged KV-cache manager (block pool, block
  tables, prefill packing).
* :mod:`repro.serving.autotune` — engine-level decode autotune over the DSE.
"""
from repro.serving.engine import Engine, EngineConfig, RunReport
from repro.serving.kvcache import BlockPool, PagedKVCache
from repro.serving.scheduler import (Request, RequestResult, Scheduler,
                                     load_requests_jsonl, synthetic_requests)

__all__ = ["Engine", "EngineConfig", "RunReport", "BlockPool", "PagedKVCache",
           "Request", "RequestResult", "Scheduler", "load_requests_jsonl",
           "synthetic_requests"]
