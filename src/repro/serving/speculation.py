"""Speculative decoding over the paged KV pool: draft -> verify -> rollback.

One verify tick amortizes a model step over up to ``draft_k + 1`` tokens: a
drafter proposes ``j <= draft_k`` continuation tokens for a slot, the engine
feeds ``[last_token, d_1..d_j]`` as one row of the same ``(B, k)`` cell the
chunked catch-up path uses (``paged_decode_attention`` with explicit
``qpos`` — the mask is purely positional, so the speculative columns score
exactly as a sequential replay would), and every column's logits come back
at once.  Acceptance is *sample-from-target*: column ``c`` is sampled (or
argmaxed, at temperature 0) into the target token ``x_c``; draft ``d_{c+1}``
is accepted iff it equals ``x_c``, and the committed tokens of the tick are
``x_0..x_r`` where ``r`` is the first mismatch (or ``j``, the bonus token,
on accept-all).  Every emitted token is therefore a true sample from the
target model's distribution given its committed prefix — the standard
rejection-sampling identity specialized to deterministic drafters — which
gives two hard guarantees the tests pin down:

* greedy speculative output is **byte-identical** to the 1-token host loop
  (argmax doesn't care how many columns the tick carried);
* sampled speculative output is **drafter-invariant**: the token at commit
  index ``t`` of request ``serial`` always draws from
  ``fold_in(fold_in(key(seed), serial), t)`` (:func:`sample_targets`), so
  any drafter — including the null drafter that proposes nothing — produces
  the same byte stream.

Rejected columns leave garbage K/V behind the committed length; it is never
attended (the causal positional mask only admits ``kpos <= qpos`` and later
writes overwrite it first), but the device-side lengths and the ledger must
roll back — :meth:`repro.serving.kvcache.BlockLedger.spec_begin` /
``spec_commit`` snapshot and truncate, undoing COW forks that served only
rejected tokens so the pool never leaks under partial acceptance.

Drafters are advisory: a wrong (or out-of-vocab) proposal only lowers the
acceptance rate, never changes output.  Built-ins:

* :class:`NGramDrafter` — prompt-lookup: propose the continuation of the
  most recent earlier occurrence of the history's trailing n-gram (free;
  strong on shared-prefix and self-repetitive decode);
* :class:`DraftModelDrafter` — a small registered config compiled through
  ``flow.compile`` and rolled greedily ``k`` tokens;
* :class:`NullDrafter` — proposes nothing (the sampled-parity baseline).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

_EMPTY = np.empty(0, np.int32)


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SpeculationConfig:
    """The ``EngineConfig.speculation`` knob: which drafter, how many draft
    tokens per verify tick.  Invariants (kind, draft_k vs the envelope, the
    fori_seg clash) live in ``repro.analysis.rules.speculation_valid`` —
    diagnostic S307."""
    kind: str = "ngram"                # "ngram" | "draft" | "null"
    draft_k: int = 4                   # drafts per verify tick (cell is k+1)
    draft_cfg: Optional[str] = None    # registered config name (kind="draft")
    ngram_max: int = 3                 # longest trailing n-gram to look up
    ngram_min: int = 1

    @classmethod
    def parse(cls, text: str) -> Optional["SpeculationConfig"]:
        """``"ngram:4" | "draft:<cfg>:4" | "null:2" | "off"`` (CLI form)."""
        t = text.strip()
        if t in ("", "off", "none"):
            return None
        parts = t.split(":")
        if parts[0] == "draft":
            if len(parts) != 3:
                raise ValueError(
                    f"speculation spec {text!r}: expected draft:<cfg>:<k>")
            return cls(kind="draft", draft_cfg=parts[1],
                       draft_k=int(parts[2]))
        if len(parts) > 2:
            raise ValueError(
                f"speculation spec {text!r}: expected <kind>:<k> or off")
        k = int(parts[1]) if len(parts) == 2 else 4
        return cls(kind=parts[0], draft_k=k)

    def describe(self) -> str:
        if self.kind == "draft":
            return f"draft:{self.draft_cfg}:{self.draft_k}"
        return f"{self.kind}:{self.draft_k}"


# ---------------------------------------------------------------------------
# target sampling (the rng streams the exactness guarantee hangs on)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("temperature",))
def sample_targets(logits, base_key, serials, t0s, temperature: float):
    """Per-request counter-mode target sampling for the verify cell.

    Row ``i``, column ``c`` draws from
    ``fold_in(fold_in(base_key, serials[i]), t0s[i] + c)`` — the key is a
    pure function of (request serial, commit index), independent of how
    many columns this tick carried, which slots shared it, or what any
    drafter proposed.  That makes sampled speculative output
    drafter-invariant byte-for-byte (the accept-all rng-parity test rides
    this).  ``logits``: (B, K, V); ``serials``/``t0s``: (B,) int32; returns
    (B, K) int32 targets.
    """
    K = logits.shape[1]

    def row(lg, serial, t0):
        rk = jax.random.fold_in(base_key, serial)

        def col(lg_c, c):
            return jax.random.categorical(
                jax.random.fold_in(rk, t0 + c), lg_c / temperature)

        return jax.vmap(col)(lg, jnp.arange(K, dtype=jnp.int32))

    return jax.vmap(row)(logits, serials, t0s).astype(jnp.int32)


# ---------------------------------------------------------------------------
# drafters
# ---------------------------------------------------------------------------

class Drafter:
    """Drafter protocol: ``propose(history, k)`` returns up to ``k`` int32
    continuation tokens for a request whose committed tokens (prompt +
    generated) are ``history``.  Proposals are advisory — they steer the
    acceptance rate, never the output."""
    kind = "base"

    def propose(self, history: np.ndarray, k: int) -> np.ndarray:
        raise NotImplementedError


class NullDrafter(Drafter):
    """Proposes nothing: every tick degrades to a plain 1-token decode.
    Exists as the baseline for the sampled drafter-invariance tests."""
    kind = "null"

    def propose(self, history: np.ndarray, k: int) -> np.ndarray:
        return _EMPTY


class NGramDrafter(Drafter):
    """Prompt-lookup drafting: find the most recent earlier occurrence of
    the history's trailing n-gram (longest n first) and propose the tokens
    that followed it.  When the continuation runs off the end of history
    (the most recent match sits near the tail — always the case once decode
    settles into a short repetition cycle), the drafted tokens are appended
    to the lookup window and the search repeats, so a period-p cycle drafts
    all ``k`` tokens instead of truncating at the tail.  Zero model cost;
    strong whenever decode revisits its own context — shared system
    prompts, code, repetitive spans."""
    kind = "ngram"

    def __init__(self, max_n: int = 3, min_n: int = 1):
        if not 1 <= min_n <= max_n:
            raise ValueError(f"need 1 <= min_n <= max_n, "
                             f"got ({min_n}, {max_n})")
        self.max_n = max_n
        self.min_n = min_n

    def _lookup(self, h: np.ndarray, k: int) -> np.ndarray:
        H = int(h.size)
        for n in range(min(self.max_n, H - 1), self.min_n - 1, -1):
            pat = h[H - n:]
            # candidate starts 0..H-1-n: a match must have at least one
            # continuation token, and the trailing gram itself (start H-n)
            # is excluded
            w = np.lib.stride_tricks.sliding_window_view(h, n)[:H - n]
            hits = np.nonzero((w == pat).all(axis=1))[0]
            if hits.size:
                s = int(hits[-1])
                return h[s + n: s + n + k]
        return _EMPTY

    def propose(self, history: np.ndarray, k: int) -> np.ndarray:
        h = np.asarray(history, np.int64).reshape(-1)
        if h.size < 2 or k < 1:
            return _EMPTY
        out = self._lookup(h, k)
        # each extension round drafts >= 1 token or breaks, so this
        # terminates after at most k rounds
        while 0 < out.size < k:
            ext = self._lookup(np.concatenate([h, out]), k - int(out.size))
            if not ext.size:
                break
            out = np.concatenate([out, ext])
        return out.astype(np.int32)


class DraftModelDrafter(Drafter):
    """A small registered config compiled via ``flow.compile`` and rolled
    greedily: one right-padded prefill over the history, then ``k - 1``
    single-token decode steps through its own rolling cache.  Out-of-vocab
    proposals (draft vocab larger than the target's) are truncated by the
    engine — like every drafter, this one is advisory only."""
    kind = "draft"

    def __init__(self, draft_cfg: Any, *, max_seq_len: int,
                 smoke: bool = False):
        from repro import flow as rflow
        from repro.configs.base import FlowConfig, ShapeConfig
        self.cm = rflow.compile(
            draft_cfg, ShapeConfig("spec_draft", "decode", max_seq_len, 1),
            FlowConfig(mode="folded", precision="fp32"), smoke=smoke)
        self.params = self.cm.init_params(jax.random.key(0))
        self.cache_len = self.cm.plan.cache_len

    def propose(self, history: np.ndarray, k: int) -> np.ndarray:
        h = np.asarray(history, np.int32).reshape(-1)
        L = int(h.size)
        k = min(k, self.cache_len - L)
        if L < 1 or k < 1:
            return _EMPTY
        # bucket the prefill width (bounded retraces: one per pow2 rung)
        S = 8
        while S < L:
            S *= 2
        S = min(S, self.cache_len)
        tokens = np.zeros((1, S), np.int32)
        tokens[0, :L] = h
        positions = np.full((1, S), -1, np.int32)
        positions[0, :L] = np.arange(L, dtype=np.int32)
        logits, state, _ = self.cm.prefill(
            self.params, {"tokens": jnp.asarray(tokens),
                          "positions": jnp.asarray(positions)})
        out = [int(jnp.argmax(logits[0, L - 1]))]
        for t in range(1, k):
            lg, state, _ = self.cm.decode(
                self.params,
                {"tokens": jnp.asarray([[out[-1]]], jnp.int32)},
                state, jnp.int32(L + t - 1))
            out.append(int(jnp.argmax(lg[0, -1])))
        return np.asarray(out, np.int32)


def build_drafter(spec: SpeculationConfig, *, max_seq_len: int,
                  target_cfg: Any = None) -> Drafter:
    """Instantiate the drafter a :class:`SpeculationConfig` names.  The
    draft-model drafter inherits the target's smoke-ness so the CI smoke
    models draft against smoke-sized configs."""
    if spec.kind == "ngram":
        return NGramDrafter(spec.ngram_max, spec.ngram_min)
    if spec.kind == "null":
        return NullDrafter()
    if spec.kind == "draft":
        return DraftModelDrafter(spec.draft_cfg, max_seq_len=max_seq_len,
                                 smoke=_is_smoke(target_cfg))
    raise ValueError(f"unknown drafter kind {spec.kind!r}")


def _is_smoke(cfg: Any) -> bool:
    if cfg is None:
        return False
    try:
        from repro.configs import get_smoke
        return get_smoke(cfg.name) == cfg
    except Exception:
        return False
