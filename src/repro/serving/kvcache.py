"""Paged KV-cache manager: fixed-size blocks from a device-resident pool.

The rolling per-request cache reserves ``max_seq_len x n_slots`` tokens of
K/V for every layer whether the slots are live or not.  The paged manager
replaces it for serving: K/V live in a per-layer *pool* of fixed-size blocks,
each slot owns a chain of blocks recorded in a block table, and the decode
lookup path (``kernels/decode_attention.paged_decode_attention`` on TPU, the
registered ref fallback elsewhere) gathers through the table — device memory
scales with *live tokens*, not ``max_seq_len x batch``.

Layout notes:

* block 0 of every pool is the reserved **trash block**: freed slots park
  their block tables on it, so the decode tick's unconditional append for
  inactive slots lands in memory no live request owns;
* attention state per key becomes ``{"kp", "vp", "bt", "len"}`` — pools
  (blocks, block_size, KV, Dh), per-slot block table (slots, nblk) and
  per-slot decode position (slots,).  ``repro.core.ops_impl.op_attention``
  recognizes this layout at trace time;
* every *other* stateful op (conv/LRU/RWKV recurrences, cross-attention
  K/V) keeps its dense layout with the slot dimension where the batch was;
* folded units carry the usual leading ``reps`` (layers) dimension on every
  leaf; block tables are replicated per layer (ints, negligible).

The manager is the host side: a free-list allocator plus the device-side
packing of prefill caches into pool blocks (`admit`) and slot recycling
(`evict`).  The scheduler decides *when* to admit/evict; the engine wires
both to the compiled model.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

import functools

from repro.core.lowering import _op_state_shapes, _mk_state, unit_key
from repro.core.plan import ExecutionPlan

TRASH_BLOCK = 0


# Donated scatter of prompt blocks into a pool: under jit the pool buffer is
# reused in place (on backends that support donation) instead of a whole-pool
# copy per admitted request.  Retraces are bounded: one per (nlead,
# nblk_used) pair, and nblk_used <= blocks_per_slot.
@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_blocks(pool, bidx, seg):
    return pool.at[bidx].set(seg)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_blocks_folded(pool, bidx, seg):
    return pool.at[:, bidx].set(seg)


# ---------------------------------------------------------------------------
# host-side block allocator
# ---------------------------------------------------------------------------

class BlockPool:
    """Free-list allocator over pool block ids.  Block 0 is the trash block
    and is never handed out."""

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("pool needs >= 2 blocks (one is the trash block)")
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._free_set = set(self._free)    # O(1) double-free detection

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    def can_allocate(self, n: int) -> bool:
        return n <= len(self._free)

    def allocate(self, n: int) -> List[int]:
        if not self.can_allocate(n):
            raise RuntimeError(
                f"KV pool exhausted: want {n} blocks, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(out)
        return out

    def release(self, blocks: List[int]) -> None:
        for b in blocks:
            if b == TRASH_BLOCK:
                raise ValueError("trash block cannot be released")
            if b in self._free_set:
                raise ValueError(f"double free of block {b}")
            self._free.append(b)
            self._free_set.add(b)


# ---------------------------------------------------------------------------
# paged serving state
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class _Entry:
    """One stateful op's place in the serving-state tree."""
    ukey: str
    skey: str
    op: Any                  # MicroOp
    paged: bool              # attention (non-cross) -> paged pool layout
    nlead: int               # 0, or 1 for folded units (leading reps dim)
    reps: int


def _state_entries(plan: ExecutionPlan) -> List[_Entry]:
    graph = plan.graph
    out: List[_Entry] = []
    for unit in plan.units:
        ukey = unit_key(graph, unit)
        if unit.folded:
            protos = [graph.blocks[unit.indices[j]] for j in range(unit.period)]
            nlead, reps = 1, unit.reps
        else:
            protos = [graph.blocks[unit.indices[0]]]
            nlead, reps = 0, 1
        for blk in protos:
            for op in blk.stateful_ops():
                paged = op.op == "attention" and not op.attrs.get("cross")
                out.append(_Entry(ukey, op.attrs["state_key"], op, paged,
                                  nlead, reps))
    return out


def blocks_for_tokens(tokens: int, block_size: int) -> int:
    return max(1, math.ceil(tokens / block_size))


class PagedKVCache:
    """Device state + host allocator for one compiled plan's decode cell.

    ``state`` is the pytree handed to the jitted decode stage in place of the
    rolling cache; ``slot_axes`` mirrors it with the index of each leaf's
    slot dimension (-1 for pool leaves, which are slot-agnostic) so the
    engine can slice the tree down to a batch bucket and merge the result
    back (:func:`slice_state` / :func:`merge_state`).
    """

    def __init__(self, plan: ExecutionPlan, n_slots: int, *,
                 block_size: int, blocks_per_slot: int,
                 num_blocks: Optional[int] = None):
        if block_size < 1 or blocks_per_slot < 1 or n_slots < 1:
            raise ValueError("block_size, blocks_per_slot, n_slots must be >=1")
        self.plan = plan
        self.cfg = plan.cfg
        self.n_slots = n_slots
        self.block_size = block_size
        self.blocks_per_slot = blocks_per_slot    # block-table width
        # default: full provisioning (every slot can hold its whole chain)
        # plus the trash block; tighter pools exercise admission control
        self.num_blocks = num_blocks if num_blocks is not None \
            else 1 + n_slots * blocks_per_slot
        self.pool = BlockPool(self.num_blocks)
        self.slot_blocks: List[List[int]] = [[] for _ in range(n_slots)]
        self._slot_len: List[int] = [0] * n_slots
        self._entries = _state_entries(plan)
        if not any(e.paged for e in self._entries):
            raise ValueError(
                f"{plan.cfg.name} has no self-attention KV state; the paged "
                "cache applies to attention decoder models")
        self.state, self.slot_axes = self._build()

    # -- construction --------------------------------------------------------
    @property
    def capacity_tokens(self) -> int:
        """Per-slot token capacity (block-table width x block size)."""
        return self.blocks_per_slot * self.block_size

    def _build(self) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        plan, cfg = self.plan, self.cfg
        dt = plan.prec.compute_dtype
        NB, bs, nblk = self.num_blocks, self.block_size, self.blocks_per_slot
        state: Dict[str, Any] = {}
        axes: Dict[str, Any] = {}
        for e in self._entries:
            lead = (e.reps,) if e.nlead else ()
            ust = state.setdefault(e.ukey, {})
            uax = axes.setdefault(e.ukey, {})
            if e.paged:
                att = cfg.attention
                KV, Dh = att.n_kv_heads, att.head_dim
                ust[e.skey] = {
                    "kp": jnp.zeros(lead + (NB, bs, KV, Dh), dt),
                    "vp": jnp.zeros(lead + (NB, bs, KV, Dh), dt),
                    "bt": jnp.zeros(lead + (self.n_slots, nblk), jnp.int32),
                    "len": jnp.zeros(lead + (self.n_slots,), jnp.int32),
                }
                uax[e.skey] = {"kp": -1, "vp": -1,
                               "bt": e.nlead, "len": e.nlead}
            else:
                shapes = _op_state_shapes(e.op, cfg, self.n_slots,
                                          plan.cache_len, dt)
                made = _mk_state(shapes, lead)
                if e.op.op == "attention":       # cross-attn nested dict
                    ust[e.skey] = made
                    uax[e.skey] = {suf: e.nlead for suf in made}
                else:
                    for suf, v in made.items():
                        ust[e.skey + suf] = v
                        uax[e.skey + suf] = e.nlead
        return state, axes

    # -- accounting ----------------------------------------------------------
    def live_tokens(self) -> int:
        """Tokens currently resident across live slots (host view)."""
        return int(sum(self._slot_len))

    def pool_bytes(self) -> int:
        """Device bytes held by the K/V pools (all layers)."""
        total = 0
        for e in self._entries:
            if not e.paged:
                continue
            st = self.state[e.ukey][e.skey]
            total += st["kp"].size * st["kp"].dtype.itemsize
            total += st["vp"].size * st["vp"].dtype.itemsize
        return total

    # -- admit / evict -------------------------------------------------------
    def admit(self, slot: int, prompt_len: int, reserve_tokens: int,
              prefill_state: Dict[str, Any], row: int, pad: int) -> List[int]:
        """Move request ``row`` of a (rolling-layout) prefill state into
        ``slot``: allocate its block chain, copy the prompt K/V into pool
        blocks, point the slot's block-table row at the chain, set its
        decode position, and copy the non-attention recurrent state into the
        slot row.  ``pad`` is the request's left-padding inside the bucketed
        prefill batch; ``reserve_tokens`` (>= prompt_len) is the chain
        capacity to allocate up front (prompt + generation budget), the
        admission-control quantity.
        """
        if self.slot_blocks[slot]:
            raise RuntimeError(f"slot {slot} is occupied")
        if reserve_tokens < prompt_len:
            raise ValueError("reserve_tokens must cover the prompt")
        if reserve_tokens > self.capacity_tokens:
            raise ValueError(
                f"request needs {reserve_tokens} tokens; slot capacity is "
                f"{self.capacity_tokens} (blocks_per_slot x block_size)")
        bs = self.block_size
        nblk_used = blocks_for_tokens(prompt_len, bs)
        n_alloc = blocks_for_tokens(reserve_tokens, bs)
        blocks = self.pool.allocate(n_alloc)
        self.slot_blocks[slot] = blocks
        self._slot_len[slot] = prompt_len

        table_row = np.zeros(self.blocks_per_slot, np.int32)
        table_row[:n_alloc] = blocks
        table_row = jnp.asarray(table_row)
        bidx = jnp.asarray(blocks[:nblk_used], jnp.int32)
        Lb = nblk_used * bs

        for e in self._entries:
            ust = self.state[e.ukey]
            if e.paged:
                pst = prefill_state[e.ukey][e.skey]
                st = ust[e.skey]
                new = dict(st)
                for pool_key, cache_key in (("kp", "k"), ("vp", "v")):
                    src = pst[cache_key]               # lead+(Bp, C, KV, Dh)
                    rowv = src[:, row] if e.nlead else src[row]
                    ax = e.nlead                       # cache-length axis
                    pw = [(0, 0)] * rowv.ndim
                    pw[ax] = (0, bs)                   # room for the tail block
                    rowv = jnp.pad(rowv, pw)
                    seg = lax.slice_in_dim(rowv, pad, pad + Lb, axis=ax)
                    seg = seg.reshape(seg.shape[:ax] + (nblk_used, bs)
                                      + seg.shape[ax + 1:])
                    scatter = _scatter_blocks_folded if e.nlead \
                        else _scatter_blocks
                    new[pool_key] = scatter(st[pool_key], bidx, seg)
                new["bt"] = (st["bt"].at[:, slot].set(table_row) if e.nlead
                             else st["bt"].at[slot].set(table_row))
                new["len"] = (st["len"].at[:, slot].set(prompt_len)
                              if e.nlead
                              else st["len"].at[slot].set(prompt_len))
                ust[e.skey] = new
            elif e.op.op == "attention":               # cross-attn {k, v}
                pst = prefill_state[e.ukey][e.skey]
                st = dict(ust[e.skey])
                for suf, leaf in st.items():
                    src = pst[suf]
                    rowv = src[:, row] if e.nlead else src[row]
                    st[suf] = (leaf.at[:, slot].set(rowv) if e.nlead
                               else leaf.at[slot].set(rowv))
                ust[e.skey] = st
            else:
                made = _op_state_shapes(e.op, self.cfg, 1, 1, None)
                for suf in made:
                    key = e.skey + suf
                    src = prefill_state[e.ukey][key]
                    rowv = src[:, row] if e.nlead else src[row]
                    leaf = ust[key]
                    ust[key] = (leaf.at[:, slot].set(rowv) if e.nlead
                                else leaf.at[slot].set(rowv))
        return blocks

    def note_decode_tick(self, active_slots) -> None:
        """Mirror the device-side ``len`` increment for live slots (the
        device increments every row; only live slots count as live tokens)."""
        for s in active_slots:
            self._slot_len[s] += 1

    def evict(self, slot: int) -> int:
        """Free ``slot``'s block chain and park it on the trash block.
        Returns the number of blocks released."""
        blocks = self.slot_blocks[slot]
        if not blocks:
            return 0
        self.pool.release(blocks)
        self.slot_blocks[slot] = []
        self._slot_len[slot] = 0
        for e in self._entries:
            if not e.paged:
                continue
            st = self.state[e.ukey][e.skey]
            zrow = jnp.zeros((self.blocks_per_slot,), jnp.int32)
            new = dict(st)
            new["bt"] = (st["bt"].at[:, slot].set(zrow) if e.nlead
                         else st["bt"].at[slot].set(zrow))
            new["len"] = (st["len"].at[:, slot].set(0) if e.nlead
                          else st["len"].at[slot].set(0))
            self.state[e.ukey][e.skey] = new
        return len(blocks)


# ---------------------------------------------------------------------------
# batch-bucket slicing (shape-bucketed decode ticks)
# ---------------------------------------------------------------------------

def slice_state(state: Dict[str, Any], slot_axes: Dict[str, Any],
                n: int) -> Dict[str, Any]:
    """First ``n`` slot rows of every slot-indexed leaf (pool leaves pass
    through whole) — the decode tick's batch bucket."""
    def f(x, ax):
        if ax < 0 or x.shape[ax] == n:
            return x
        return lax.slice_in_dim(x, 0, n, axis=ax)
    return jax.tree.map(f, state, slot_axes)


def merge_state(full: Dict[str, Any], part: Dict[str, Any],
                slot_axes: Dict[str, Any], n: int) -> Dict[str, Any]:
    """Merge a bucketed decode tick's updated state back over the full slot
    range.  Pool leaves (slot-agnostic) are taken from ``part`` wholesale —
    they were donated into the tick; slot-indexed leaves splice the updated
    rows over the untouched tail."""
    def f(xf, xp, ax):
        if ax < 0 or xf.shape[ax] == n:
            return xp
        rest = lax.slice_in_dim(xf, n, xf.shape[ax], axis=ax)
        return jnp.concatenate([xp, rest], axis=ax)
    return jax.tree.map(f, full, part, slot_axes)
