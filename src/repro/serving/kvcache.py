"""Paged KV-cache manager: fixed-size blocks from a device-resident pool.

The rolling per-request cache reserves ``max_seq_len x n_slots`` tokens of
K/V for every layer whether the slots are live or not.  The paged manager
replaces it for serving: K/V live in a per-layer *pool* of fixed-size blocks,
each slot owns a chain of blocks recorded in a block table, and the decode
lookup path (``kernels/decode_attention.paged_decode_attention`` on TPU, the
registered ref fallback elsewhere) gathers through the table — device memory
scales with *live tokens*, not ``max_seq_len x batch``.

Layout notes:

* block 0 of every pool is the reserved **trash block**: freed slots park
  their block tables on it, so the decode tick's unconditional append for
  inactive slots lands in memory no live request owns;
* attention state per key becomes ``{"kp", "vp", "bt", "len"}`` — pools
  (blocks, block_size, KV, Dh), per-slot block table (slots, nblk) and
  per-slot decode position (slots,).  ``repro.core.ops_impl.op_attention``
  recognizes this layout at trace time;
* every *other* stateful op (conv/LRU/RWKV recurrences, cross-attention
  K/V) keeps its dense layout with the slot dimension where the batch was;
* folded units carry the usual leading ``reps`` (layers) dimension on every
  leaf; block tables are replicated per layer (ints, negligible).

Prefix caching (``prefix_cache=True``) layers block *sharing* on top:
blocks are refcounted, fully-filled prompt blocks are registered in a
:class:`repro.serving.prefix.PrefixIndex` keyed by chained content hashes,
and a new request whose prompt prefix matches seeds its block table from
the cached blocks and only computes the uncovered tail.  Shared blocks are
copy-on-write: decode never writes a block with ``refcount > 1`` — the
owner forks it first (``kernels/decode_attention.copy_block``, ref fallback
through the registry).  Blocks whose last reference drops park on an LRU
list, still indexed, and are reclaimed only under allocation pressure.

The host/device split is explicit: :class:`BlockLedger` is the pure-host
bookkeeping (pool, chains, index, match/charge/fork decisions — no jax, so
the property-based suite can drive random interleavings against the real
logic), and :class:`PagedKVCache` mirrors the ledger's decisions onto the
device-resident pools and block tables.
"""
from __future__ import annotations

import math
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

import functools

from repro.core.lowering import _op_state_shapes, _mk_state, unit_key
from repro.core.plan import ExecutionPlan
from repro.obs import MetricsRegistry
from repro.serving.prefix import BlockHash, PrefixIndex, block_hashes

TRASH_BLOCK = 0


# Donated scatter of prompt blocks into a pool: under jit the pool buffer is
# reused in place (on backends that support donation) instead of a whole-pool
# copy per admitted request.  Retraces are bounded: one per (nlead,
# nblk_used) pair, and nblk_used <= blocks_per_slot.
@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_blocks(pool, bidx, seg):
    return pool.at[bidx].set(seg)


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _set_table_rows(bt, ln, slots, rows, lens):
    return bt.at[slots].set(rows), ln.at[slots].set(lens)


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _set_table_rows_folded(bt, ln, slots, rows, lens):
    # (lead, slots, ...) layout: rows/lens broadcast across the lead axis
    return bt.at[:, slots].set(rows), ln.at[:, slots].set(lens)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_blocks_folded(pool, bidx, seg):
    return pool.at[:, bidx].set(seg)


# ---------------------------------------------------------------------------
# host-side block allocator
# ---------------------------------------------------------------------------

class BlockPool:
    """Refcounted free-list allocator over pool block ids.

    Block 0 is the trash block and is never handed out.  Every other block
    is in exactly one of three states:

    * **free** — on the free list, refcount 0, contents meaningless;
    * **live** — refcount >= 1 (slot chains and COW spares hold the refs);
    * **cached** — refcount 0 but still indexed by the prefix cache; parked
      on an LRU list and reclaimed (oldest first, ``on_cache_evict`` fired
      so the index can forget it) only when the free list runs dry.

    ``allocate`` + ``release`` keep their original semantics for the
    non-sharing paths: allocated blocks start at refcount 1, ``release``
    decrements, and a double release raises.
    """

    def __init__(self, num_blocks: int,
                 on_cache_evict: Optional[Callable[[int], None]] = None):
        if num_blocks < 2:
            raise ValueError("pool needs >= 2 blocks (one is the trash block)")
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._free_set = set(self._free)    # O(1) double-free detection
        self._ref: Dict[int, int] = {}      # live blocks -> refcount
        self._lru: "OrderedDict[int, None]" = OrderedDict()  # cached, ref 0
        self._cached_tag: set = set()       # blocks the prefix index holds
        self.on_cache_evict = on_cache_evict
        self.n_cache_evictions = 0

    @property
    def free_blocks(self) -> int:
        """Allocatable blocks: truly free + reclaimable cached."""
        return len(self._free) + len(self._lru)

    @property
    def used_blocks(self) -> int:
        """Live (referenced) blocks."""
        return len(self._ref)

    @property
    def cached_blocks(self) -> int:
        return len(self._lru)

    def publish_metrics(self, reg: "MetricsRegistry") -> None:
        """Publish pool occupancy + reclaim counters (``pool.blocks.*``)."""
        reg.gauge("pool.blocks.live").set(self.used_blocks)
        reg.gauge("pool.blocks.cached").set(self.cached_blocks)
        reg.gauge("pool.blocks.free").set(self.free_blocks)
        reg.counter("pool.cache_evictions").inc(self.n_cache_evictions)

    def can_allocate(self, n: int) -> bool:
        return n <= self.free_blocks

    def allocate(self, n: int) -> List[int]:
        if not self.can_allocate(n):
            raise RuntimeError(
                f"KV pool exhausted: want {n} blocks, {self.free_blocks} free")
        out: List[int] = []
        for _ in range(n):
            if self._free:
                b = self._free.pop()
                self._free_set.discard(b)
            else:                            # reclaim the LRU-oldest cached
                b, _ = self._lru.popitem(last=False)
                self._cached_tag.discard(b)
                self.n_cache_evictions += 1
                if self.on_cache_evict is not None:
                    self.on_cache_evict(b)
            self._ref[b] = 1
            out.append(b)
        return out

    def refcount(self, b: int) -> int:
        return self._ref.get(b, 0)

    def incref(self, b: int) -> None:
        """Add a reference: live blocks bump the count, cached blocks are
        revived off the LRU list.  Free blocks cannot be referenced."""
        if b == TRASH_BLOCK:
            raise ValueError("trash block cannot be referenced")
        if b in self._ref:
            self._ref[b] += 1
        elif b in self._lru:
            del self._lru[b]
            self._ref[b] = 1
        else:
            raise ValueError(f"block {b} is free; cannot reference it")

    def decref(self, b: int) -> None:
        if b == TRASH_BLOCK:
            raise ValueError("trash block cannot be released")
        if b not in self._ref:
            raise ValueError(f"double free of block {b}")
        self._ref[b] -= 1
        if self._ref[b] == 0:
            del self._ref[b]
            if b in self._cached_tag:        # indexed: park, most-recent
                self._lru[b] = None
            else:
                self._free.append(b)
                self._free_set.add(b)

    def release(self, blocks: List[int]) -> None:
        for b in blocks:
            self.decref(b)

    def mark_cached(self, b: int) -> None:
        """The prefix index now points at ``b``: when its refcount drops to
        zero it parks on the LRU list instead of the free list."""
        if b == TRASH_BLOCK:
            raise ValueError("trash block cannot be cached")
        if b not in self._ref and b not in self._lru:
            raise ValueError(f"block {b} is free; cannot cache it")
        self._cached_tag.add(b)

    def is_cached(self, b: int) -> bool:
        return b in self._cached_tag

    def check_invariants(self) -> None:
        """Every block is in exactly one state; the trash block is in none;
        counts conserve.  Raises AssertionError on violation (the
        property-based suite calls this after every operation)."""
        free, lru, live = set(self._free), set(self._lru), set(self._ref)
        assert TRASH_BLOCK not in free | lru | live, "trash block leaked"
        assert free == self._free_set and len(self._free) == len(free), \
            "free list / free set diverged"
        assert not (free & lru) and not (free & live) and not (lru & live), \
            "block in two states at once"
        assert free | lru | live == set(range(1, self.num_blocks)), \
            "block count not conserved"
        assert all(c >= 1 for c in self._ref.values()), "live refcount < 1"
        assert self._cached_tag <= (lru | live), "cached tag on a free block"
        assert lru <= self._cached_tag, "parked block without a cache tag"


# ---------------------------------------------------------------------------
# prefix matching + host-side ledger
# ---------------------------------------------------------------------------

@dataclass
class PrefixMatch:
    """A locked prefix-cache hit: pool blocks (refcounts already bumped)
    holding ``covered_raw`` prompt tokens, of which the engine may skip
    ``covered`` (at least the last prompt token is always recomputed — its
    logits seed sampling)."""
    blocks: List[int]
    hashes: List[BlockHash]
    covered: int
    covered_raw: int

    @property
    def needs_cow_spare(self) -> bool:
        """True when the write at position ``covered`` lands inside a
        matched block: the admission charges one spare block so the
        copy-on-write fork can never fail on an exhausted pool."""
        return self.covered_raw > self.covered


class BlockLedger:
    """Host-side accounting for one paged cache: the pool, per-slot block
    chains, COW spares, and (optionally) the prefix index.

    Pure bookkeeping — no jax — mirroring exactly the decisions
    :class:`PagedKVCache` applies to device state, so property-based tests
    can drive millions of admit/decode/finish/evict interleavings against
    the real allocator logic.  Invariants are checked by :meth:`check`.
    """

    def __init__(self, num_blocks: int, n_slots: int, block_size: int,
                 blocks_per_slot: int, *, prefix_cache: bool = False,
                 min_match_ratio: float = 0.5):
        self.block_size = block_size
        self.blocks_per_slot = blocks_per_slot
        self.n_slots = n_slots
        self.min_match_ratio = min_match_ratio
        self.pool = BlockPool(num_blocks, on_cache_evict=self._on_reclaim)
        self.index: Optional[PrefixIndex] = \
            PrefixIndex() if prefix_cache else None
        self.chains: List[List[int]] = [[] for _ in range(n_slots)]
        self.spares: List[Optional[int]] = [None] * n_slots
        self.lens: List[int] = [0] * n_slots
        self._prompt_len: List[int] = [0] * n_slots
        self._prompt_hashes: List[List[Tuple[BlockHash, int]]] = \
            [[] for _ in range(n_slots)]
        self._registered: List[bool] = [False] * n_slots
        # counters (surfaced through Engine metrics)
        self.hits = 0
        self.misses = 0
        self.cached_tokens = 0
        self.cow_forks = 0
        self.spec_rollback_tokens = 0
        self.spec_fork_undos = 0
        # speculative windows (spec_begin .. spec_commit): per-slot base
        # length snapshot plus the COW forks performed inside the window —
        # (chain_idx, old, new, from_spare) — so a rollback can undo forks
        # that served only rejected tokens
        self._spec_base: List[Optional[int]] = [None] * n_slots
        self._spec_forks: List[List[Tuple[int, int, int, bool]]] = \
            [[] for _ in range(n_slots)]
        # one-entry hash memo: a blocked queue head is re-matched every
        # tick and a successful admission hashes right after its match —
        # both repeat the same prompt back-to-back
        self._hash_key: Optional[bytes] = None
        self._hash_val: List[Tuple[BlockHash, int]] = []

    def _hashes_for(self, toks: np.ndarray) -> List[Tuple[BlockHash, int]]:
        key = toks.tobytes()
        if key != self._hash_key:
            self._hash_key = key
            self._hash_val = block_hashes(toks, self.block_size)
        return self._hash_val

    # -- index plumbing ------------------------------------------------------
    def _on_reclaim(self, block: int) -> None:
        if self.index is not None:
            self.index.drop_block(block)

    @property
    def cache_evictions(self) -> int:
        return self.pool.n_cache_evictions

    def publish_metrics(self, reg: "MetricsRegistry") -> None:
        """Publish prefix-cache and speculation outcomes under their
        dotted names (``serving.prefix.*`` / ``serving.spec.*``)."""
        reg.counter("serving.prefix.hits").inc(self.hits)
        reg.counter("serving.prefix.misses").inc(self.misses)
        reg.counter("serving.prefix.cached_tokens").inc(self.cached_tokens)
        reg.counter("serving.prefix.evictions").inc(self.cache_evictions)
        reg.counter("serving.prefix.cow_forks").inc(self.cow_forks)
        reg.counter("serving.spec.rollback_tokens").inc(
            self.spec_rollback_tokens)
        reg.counter("serving.spec.fork_undos").inc(self.spec_fork_undos)

    # -- matching ------------------------------------------------------------
    def match_and_lock(self, prompt: np.ndarray) -> Optional[PrefixMatch]:
        """Longest indexed prefix of ``prompt`` (full blocks, then an
        exact-content partial tail).  Matched blocks are incref'd — locked
        against reclaim — before this returns; callers either hand the match
        to :meth:`admit` (which adopts the references) or :meth:`unlock` it.

        ``covered`` is capped at ``len(prompt) - 1``: the last prompt token
        is always recomputed through the decode cell so the engine has
        logits to sample the first generated token from.

        A marginal hit is a *miss*: the uncovered tail catches up one token
        per decode tick, so a match covering less than ``min_match_ratio``
        of the prompt would trade one batched prefill for a long sequential
        tail — worse than serving cold.
        """
        if self.index is None:
            return None
        toks = np.asarray(prompt, np.int32).reshape(-1)
        hashes = self._hashes_for(toks)
        blocks: List[int] = []
        hit_hashes: List[BlockHash] = []
        covered_raw = 0
        for h, end in hashes:
            b = self.index.get(h)
            if b is None:
                break
            blocks.append(b)
            hit_hashes.append(h)
            covered_raw = end
        covered = min(covered_raw, int(toks.size) - 1)
        if covered <= 0 or covered < self.min_match_ratio * int(toks.size):
            return None
        for b in blocks:
            self.pool.incref(b)
        return PrefixMatch(blocks=blocks, hashes=hit_hashes,
                           covered=covered, covered_raw=covered_raw)

    def unlock(self, match: PrefixMatch) -> None:
        """Drop the locks of a match that will not be admitted."""
        self.pool.release(match.blocks)

    def fresh_blocks_needed(self, total_budget: int,
                            match: Optional[PrefixMatch]) -> int:
        """Admission-control charge: blocks to allocate for a request with
        ``total_budget`` tokens given an (optional) locked match — the
        uncovered chain tail plus, when the first write lands inside a
        matched block, one COW spare."""
        n_total = blocks_for_tokens(total_budget, self.block_size)
        if match is None:
            return n_total
        return n_total - len(match.blocks) + int(match.needs_cow_spare)

    # -- admit / decode / release -------------------------------------------
    def admit(self, slot: int, prompt: np.ndarray, reserve_tokens: int,
              match: Optional[PrefixMatch] = None,
              resident: Optional[int] = None) -> List[int]:
        """Build ``slot``'s block chain: matched blocks (references adopted
        from the lock) followed by freshly allocated ones, plus the COW
        spare when charged.  Returns the chain.  The caller seeds device
        block tables from it and sets the slot's decode position to
        ``match.covered`` (0-covered requests prefill the whole prompt).

        ``resident`` overrides the initial token count (chunked-prefill
        admissions start at 0: nothing is written yet — the whole prompt
        drains through chunked catch-up ticks)."""
        if self.chains[slot]:
            raise RuntimeError(f"slot {slot} is occupied")
        toks = np.asarray(prompt, np.int32).reshape(-1)
        prompt_len = int(toks.size)
        if reserve_tokens < prompt_len:
            raise ValueError("reserve_tokens must cover the prompt")
        if reserve_tokens > self.blocks_per_slot * self.block_size:
            raise ValueError(
                f"request needs {reserve_tokens} tokens; slot capacity is "
                f"{self.blocks_per_slot * self.block_size} "
                f"(blocks_per_slot x block_size)")
        matched = list(match.blocks) if match is not None else []
        # allocate exactly what admission charged (fresh_blocks_needed is
        # the single source of the charge formula)
        fresh = self.pool.allocate(
            self.fresh_blocks_needed(reserve_tokens, match))
        if match is not None and match.needs_cow_spare:
            self.spares[slot] = fresh.pop()
        self.chains[slot] = matched + fresh
        if resident is not None:
            self.lens[slot] = resident
        else:
            self.lens[slot] = match.covered if match is not None else prompt_len
        self._prompt_len[slot] = prompt_len
        self._registered[slot] = False
        if self.index is not None:
            self._prompt_hashes[slot] = list(self._hashes_for(toks))
            if match is not None:
                self.hits += 1
                self.cached_tokens += match.covered
            else:
                self.misses += 1
        return self.chains[slot]

    def needs_fork(self, slot: int) -> bool:
        """Would the next decode write for ``slot`` land in a block some
        other chain also references?  (The copy-on-write trigger.)"""
        chain = self.chains[slot]
        if not chain:
            return False
        ci = self.lens[slot] // self.block_size
        return self.pool.refcount(chain[ci]) > 1

    def fork(self, slot: int) -> Tuple[int, int, int]:
        """Copy-on-write: repoint ``slot``'s write-target chain entry at its
        pre-charged spare (or a fresh block) and drop the shared reference.
        Returns ``(chain_index, old_block, new_block)`` — the caller copies
        the device block contents and updates the block-table row."""
        ci = self.lens[slot] // self.block_size
        old = self.chains[slot][ci]
        new = self.spares[slot]
        from_spare = new is not None
        if from_spare:
            self.spares[slot] = None
        else:
            # defensive: admission charges a spare for every fork this
            # ledger can produce, but keep the fallback for direct drivers
            new = self.pool.allocate(1)[0]
        self.chains[slot][ci] = new
        self.pool.decref(old)
        self.cow_forks += 1
        if self._spec_base[slot] is not None:
            self._spec_forks[slot].append((ci, old, new, from_spare))
        return ci, old, new

    def note_write(self, slot: int, n: int = 1) -> None:
        self.lens[slot] += n

    # -- speculative windows (draft-verify-rollback) ------------------------
    def spec_begin(self, slot: int) -> None:
        """Open a speculative window on ``slot``: snapshot its committed
        length so :meth:`spec_commit` can roll back rejected writes (and
        undo COW forks that only speculative tokens needed)."""
        if self._spec_base[slot] is not None:
            raise RuntimeError(f"slot {slot} already has an open "
                               "speculative window")
        if not self.chains[slot]:
            raise RuntimeError(f"slot {slot} is empty; nothing to speculate")
        self._spec_base[slot] = self.lens[slot]
        self._spec_forks[slot] = []

    def spec_commit(self, slot: int, committed: int) -> int:
        """Close ``slot``'s speculative window, keeping the first
        ``committed`` of the tokens written inside it: the length rolls
        back to ``base + committed`` and any fork performed inside the
        window whose block ends up holding *no* committed token is undone —
        the chain is repointed back at the (still live or LRU-parked)
        shared original, and the forked copy is released, or restored as
        the slot's charged COW spare when it came from one.  This is the
        no-leak guarantee under partial acceptance.  Returns the number of
        rolled-back tokens."""
        base = self._spec_base[slot]
        if base is None:
            raise RuntimeError(f"slot {slot} has no open speculative window")
        self._spec_base[slot] = None
        written = self.lens[slot] - base
        if not 0 <= committed <= written:
            raise ValueError(
                f"slot {slot}: committed {committed} outside the window's "
                f"{written} speculative writes")
        rolled = written - committed
        self.lens[slot] = keep_end = base + committed
        for ci, old, new, from_spare in reversed(self._spec_forks[slot]):
            # the window's first write into block ci; if the commit kept
            # anything at or past it, the forked copy holds committed K/V
            # the original lacks and must stay
            first_write = max(base, ci * self.block_size)
            if keep_end > first_write:
                continue
            if self.pool.refcount(old) == 0 and old not in self.pool._lru:
                continue                 # original reclaimed: keep the fork
            self.pool.incref(old)
            self.chains[slot][ci] = old
            if from_spare and self.spares[slot] is None:
                self.spares[slot] = new  # restore the charged spare
            else:
                self.pool.decref(new)
            self.spec_fork_undos += 1
        self._spec_forks[slot] = []
        self.spec_rollback_tokens += rolled
        return rolled

    def register_prompt(self, slot: int) -> None:
        """Index ``slot``'s fully-filled prompt blocks (call once the whole
        prompt's K/V is resident: cold admits immediately after the prefill
        scatter, prefix-seeded admits when catch-up completes).  The partial
        tail block — still written by this slot's decode — is indexed later,
        at :meth:`release`."""
        if self.index is None:
            return
        self._registered[slot] = True
        n_full = self._prompt_len[slot] // self.block_size
        for i in range(n_full):
            h, _ = self._prompt_hashes[slot][i]
            if self.index.get(h) is None:
                self.index.insert(h, self.chains[slot][i])
                self.pool.mark_cached(self.chains[slot][i])

    def release(self, slot: int) -> List[int]:
        """Drop every reference ``slot`` holds (chain + unused COW spare);
        blocks the index still points at park on the LRU list, the rest go
        back to the free list.  The prompt's partial tail block is indexed
        on the way out — its owner can no longer write it, so sharing it is
        now safe.  Returns the released chain."""
        chain = self.chains[slot]
        if not chain:
            return []
        p_len = self._prompt_len[slot]
        if self.index is not None and self._registered[slot] \
                and p_len % self.block_size:
            i = p_len // self.block_size
            h, _ = self._prompt_hashes[slot][i]
            if self.index.get(h) is None:
                self.index.insert(h, chain[i])
                self.pool.mark_cached(chain[i])
        self.pool.release(chain)
        if self.spares[slot] is not None:
            self.pool.decref(self.spares[slot])
            self.spares[slot] = None
        self.chains[slot] = []
        self.lens[slot] = 0
        self._prompt_len[slot] = 0
        self._prompt_hashes[slot] = []
        self._registered[slot] = False
        self._spec_base[slot] = None
        self._spec_forks[slot] = []
        return chain

    # -- invariants ----------------------------------------------------------
    def check(self) -> None:
        """The serving-state invariants the property suite hammers on:
        pool-state conservation, refcounts == chain references, no chain or
        spare on a freed/trash block, index entries only on live-or-parked
        blocks."""
        self.pool.check_invariants()
        refs: Dict[int, int] = {}
        for chain in self.chains:
            for b in chain:
                assert b != TRASH_BLOCK, "trash block in a chain"
                refs[b] = refs.get(b, 0) + 1
        for sp in self.spares:
            if sp is not None:
                assert sp != TRASH_BLOCK, "trash block as a COW spare"
                refs[sp] = refs.get(sp, 0) + 1
        assert set(refs) == set(self.pool._ref), \
            "live blocks != blocks referenced by chains/spares"
        for b, n in refs.items():
            assert self.pool.refcount(b) == n, \
                f"block {b}: refcount {self.pool.refcount(b)} != {n} refs"
        if self.index is not None:
            for _, b in self.index.items():
                assert self.pool.refcount(b) > 0 or b in self.pool._lru, \
                    f"index entry on freed block {b}"


# ---------------------------------------------------------------------------
# paged serving state
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class _Entry:
    """One stateful op's place in the serving-state tree."""
    ukey: str
    skey: str
    op: Any                  # MicroOp
    paged: bool              # attention (non-cross) -> paged pool layout
    nlead: int               # 0, or 1 for folded units (leading reps dim)
    reps: int


def _state_entries(plan: ExecutionPlan) -> List[_Entry]:
    graph = plan.graph
    out: List[_Entry] = []
    for unit in plan.units:
        ukey = unit_key(graph, unit)
        if unit.folded:
            protos = [graph.blocks[unit.indices[j]] for j in range(unit.period)]
            nlead, reps = 1, unit.reps
        else:
            protos = [graph.blocks[unit.indices[0]]]
            nlead, reps = 0, 1
        for blk in protos:
            for op in blk.stateful_ops():
                paged = op.op == "attention" and not op.attrs.get("cross")
                out.append(_Entry(ukey, op.attrs["state_key"], op, paged,
                                  nlead, reps))
    return out


def blocks_for_tokens(tokens: int, block_size: int) -> int:
    return max(1, math.ceil(tokens / block_size))


class PagedKVCache:
    """Device state + host allocator for one compiled plan's decode cell.

    ``state`` is the pytree handed to the jitted decode stage in place of the
    rolling cache; ``slot_axes`` mirrors it with the index of each leaf's
    slot dimension (-1 for pool leaves, which are slot-agnostic) so the
    engine can slice the tree down to a batch bucket and merge the result
    back (:func:`slice_state` / :func:`merge_state`).

    With ``prefix_cache=True`` the host side runs through a refcounting
    :class:`BlockLedger` + :class:`~repro.serving.prefix.PrefixIndex`:
    :meth:`match_and_lock` finds shared prompt blocks, :meth:`admit` seeds
    from them, and :meth:`prepare_decode` performs the copy-on-write forks
    before each decode tick.
    """

    def __init__(self, plan: ExecutionPlan, n_slots: int, *,
                 block_size: int, blocks_per_slot: int,
                 num_blocks: Optional[int] = None,
                 prefix_cache: bool = False,
                 min_match_ratio: float = 0.5):
        if block_size < 1 or blocks_per_slot < 1 or n_slots < 1:
            raise ValueError("block_size, blocks_per_slot, n_slots must be >=1")
        self.plan = plan
        self.cfg = plan.cfg
        self.n_slots = n_slots
        self.block_size = block_size
        self.blocks_per_slot = blocks_per_slot    # block-table width
        # default: full provisioning (every slot can hold its whole chain)
        # plus the trash block; tighter pools exercise admission control
        self.num_blocks = num_blocks if num_blocks is not None \
            else 1 + n_slots * blocks_per_slot
        self.prefix_cache = prefix_cache
        self._entries = _state_entries(plan)
        if not any(e.paged for e in self._entries):
            raise ValueError(
                f"{plan.cfg.name} has no self-attention KV state; the paged "
                "cache applies to attention decoder models")
        if prefix_cache and any(not e.paged for e in self._entries):
            raise ValueError(
                f"{plan.cfg.name} carries non-attention per-request state "
                "(recurrences or cross-attention K/V) that a token-prefix "
                "match cannot seed; prefix_cache requires a pure attention "
                "decoder")
        self.ledger = BlockLedger(self.num_blocks, n_slots, block_size,
                                  blocks_per_slot, prefix_cache=prefix_cache,
                                  min_match_ratio=min_match_ratio)
        self.pool = self.ledger.pool
        self.state, self.slot_axes = self._build()

    # -- construction --------------------------------------------------------
    @property
    def capacity_tokens(self) -> int:
        """Per-slot token capacity (block-table width x block size)."""
        return self.blocks_per_slot * self.block_size

    @property
    def slot_blocks(self) -> List[List[int]]:
        """Per-slot block chains (host view; shared blocks included)."""
        return self.ledger.chains

    def _build(self) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        plan, cfg = self.plan, self.cfg
        dt = plan.prec.compute_dtype
        NB, bs, nblk = self.num_blocks, self.block_size, self.blocks_per_slot
        state: Dict[str, Any] = {}
        axes: Dict[str, Any] = {}
        for e in self._entries:
            lead = (e.reps,) if e.nlead else ()
            ust = state.setdefault(e.ukey, {})
            uax = axes.setdefault(e.ukey, {})
            if e.paged:
                att = cfg.attention
                KV, Dh = att.n_kv_heads, att.head_dim
                ust[e.skey] = {
                    "kp": jnp.zeros(lead + (NB, bs, KV, Dh), dt),
                    "vp": jnp.zeros(lead + (NB, bs, KV, Dh), dt),
                    "bt": jnp.zeros(lead + (self.n_slots, nblk), jnp.int32),
                    "len": jnp.zeros(lead + (self.n_slots,), jnp.int32),
                }
                uax[e.skey] = {"kp": -1, "vp": -1,
                               "bt": e.nlead, "len": e.nlead}
            else:
                shapes = _op_state_shapes(e.op, cfg, self.n_slots,
                                          plan.cache_len, dt)
                made = _mk_state(shapes, lead)
                if e.op.op == "attention":       # cross-attn nested dict
                    ust[e.skey] = made
                    uax[e.skey] = {suf: e.nlead for suf in made}
                else:
                    for suf, v in made.items():
                        ust[e.skey + suf] = v
                        uax[e.skey + suf] = e.nlead
        return state, axes

    # -- accounting ----------------------------------------------------------
    def live_tokens(self) -> int:
        """Tokens currently resident across live slots (host view)."""
        return int(sum(self.ledger.lens))

    def pool_bytes(self) -> int:
        """Device bytes held by the K/V pools (all layers)."""
        total = 0
        for e in self._entries:
            if not e.paged:
                continue
            st = self.state[e.ukey][e.skey]
            total += st["kp"].size * st["kp"].dtype.itemsize
            total += st["vp"].size * st["vp"].dtype.itemsize
        return total

    # -- prefix matching (scheduler admission hooks) -------------------------
    def match_and_lock(self, prompt: np.ndarray) -> Optional[PrefixMatch]:
        return self.ledger.match_and_lock(prompt)

    def unlock(self, match: PrefixMatch) -> None:
        self.ledger.unlock(match)

    def fresh_blocks_needed(self, total_budget: int,
                            match: Optional[PrefixMatch]) -> int:
        return self.ledger.fresh_blocks_needed(total_budget, match)

    # -- per-slot device table plumbing --------------------------------------
    def _set_tables(self, slot: int, table_row: np.ndarray,
                    length: int) -> None:
        table_row = jnp.asarray(table_row)
        for e in self._entries:
            if not e.paged:
                continue
            st = self.state[e.ukey][e.skey]
            new = dict(st)
            new["bt"] = (st["bt"].at[:, slot].set(table_row) if e.nlead
                         else st["bt"].at[slot].set(table_row))
            new["len"] = (st["len"].at[:, slot].set(length) if e.nlead
                          else st["len"].at[slot].set(length))
            self.state[e.ukey][e.skey] = new

    def _set_tables_many(self, updates: Dict[int, Tuple[np.ndarray,
                                                        int]]) -> None:
        """Batched table/len resync: one jitted donated scatter pair per
        entry for *all* dirty slots, instead of two eager scatters per slot
        (the per-slot eager path costs more than the decode cell itself on
        small models).  The slot vector is padded to ``max_batch`` by
        repeating the last slot — duplicate indices carry identical values,
        so the scatter is well-defined — keeping one compiled program
        regardless of how many slots rolled back."""
        if not updates:
            return
        n_slots = len(self.ledger.lens)
        slots = list(updates)
        slots += [slots[-1]] * (n_slots - len(slots))
        sl = jnp.asarray(np.asarray(slots, np.int32))
        rows = jnp.asarray(np.stack([updates[s][0] for s in slots]))
        lens = jnp.asarray(np.asarray([updates[s][1] for s in slots],
                                      np.int32))
        for e in self._entries:
            if not e.paged:
                continue
            st = self.state[e.ukey][e.skey]
            new = dict(st)
            setter = _set_table_rows_folded if e.nlead else _set_table_rows
            new["bt"], new["len"] = setter(st["bt"], st["len"], sl, rows,
                                           lens)
            self.state[e.ukey][e.skey] = new

    def _table_row(self, slot: int) -> np.ndarray:
        row = np.zeros(self.blocks_per_slot, np.int32)
        chain = self.ledger.chains[slot]
        row[:len(chain)] = chain
        return row

    # -- admit / evict -------------------------------------------------------
    def admit(self, slot: int, prompt_len: int, reserve_tokens: int,
              prefill_state: Dict[str, Any], row: int, pad: int,
              prompt: Optional[np.ndarray] = None) -> List[int]:
        """Move request ``row`` of a (rolling-layout) prefill state into
        ``slot``: allocate its block chain, copy the prompt K/V into pool
        blocks, point the slot's block-table row at the chain, set its
        decode position, and copy the non-attention recurrent state into the
        slot row.  ``pad`` is the request's left-padding inside the bucketed
        prefill batch; ``reserve_tokens`` (>= prompt_len) is the chain
        capacity to allocate up front (prompt + generation budget), the
        admission-control quantity.  ``prompt`` (token ids) feeds the prefix
        index when prefix caching is on — the cold path; prefix-seeded
        admissions go through :meth:`admit_cached` instead.
        """
        if self.prefix_cache and prompt is None:
            raise ValueError("prefix caching needs the prompt token ids")
        toks = np.asarray(prompt, np.int32).reshape(-1) \
            if prompt is not None else np.zeros(prompt_len, np.int32)
        if toks.size != prompt_len:
            raise ValueError(f"prompt has {toks.size} tokens, "
                             f"prompt_len says {prompt_len}")
        blocks = self.ledger.admit(slot, toks, reserve_tokens, match=None)
        bs = self.block_size
        nblk_used = blocks_for_tokens(prompt_len, bs)

        bidx = jnp.asarray(blocks[:nblk_used], jnp.int32)
        Lb = nblk_used * bs
        table_row = self._table_row(slot)

        for e in self._entries:
            ust = self.state[e.ukey]
            if e.paged:
                pst = prefill_state[e.ukey][e.skey]
                st = ust[e.skey]
                new = dict(st)
                for pool_key, cache_key in (("kp", "k"), ("vp", "v")):
                    src = pst[cache_key]               # lead+(Bp, C, KV, Dh)
                    rowv = src[:, row] if e.nlead else src[row]
                    ax = e.nlead                       # cache-length axis
                    pw = [(0, 0)] * rowv.ndim
                    pw[ax] = (0, bs)                   # room for the tail block
                    rowv = jnp.pad(rowv, pw)
                    seg = lax.slice_in_dim(rowv, pad, pad + Lb, axis=ax)
                    seg = seg.reshape(seg.shape[:ax] + (nblk_used, bs)
                                      + seg.shape[ax + 1:])
                    scatter = _scatter_blocks_folded if e.nlead \
                        else _scatter_blocks
                    new[pool_key] = scatter(st[pool_key], bidx, seg)
                ust[e.skey] = new
            elif e.op.op == "attention":               # cross-attn {k, v}
                pst = prefill_state[e.ukey][e.skey]
                st = dict(ust[e.skey])
                for suf, leaf in st.items():
                    src = pst[suf]
                    rowv = src[:, row] if e.nlead else src[row]
                    st[suf] = (leaf.at[:, slot].set(rowv) if e.nlead
                               else leaf.at[slot].set(rowv))
                ust[e.skey] = st
            else:
                made = _op_state_shapes(e.op, self.cfg, 1, 1, None)
                for suf in made:
                    key = e.skey + suf
                    src = prefill_state[e.ukey][key]
                    rowv = src[:, row] if e.nlead else src[row]
                    leaf = ust[key]
                    ust[key] = (leaf.at[:, slot].set(rowv) if e.nlead
                                else leaf.at[slot].set(rowv))
        self._set_tables(slot, table_row, prompt_len)
        # the whole prompt's K/V is resident: index its full blocks now
        self.ledger.register_prompt(slot)
        return blocks

    def admit_cached(self, slot: int, prompt: np.ndarray,
                     reserve_tokens: int, match: PrefixMatch) -> List[int]:
        """Prefix-cache hit admission: seed ``slot``'s block table from the
        matched (locked) blocks plus a fresh tail, set its decode position
        to ``match.covered``, and write *nothing* — the engine feeds the
        uncovered prompt tail through decode ticks (mid-sequence prefill;
        positions and the pool gather make it exact), sampling the first
        generated token from the last tail token's logits."""
        toks = np.asarray(prompt, np.int32).reshape(-1)
        chain = self.ledger.admit(slot, toks, reserve_tokens, match=match)
        self._set_tables(slot, self._table_row(slot), match.covered)
        return chain

    def admit_tail(self, slot: int, prompt: np.ndarray,
                   reserve_tokens: int) -> List[int]:
        """Chunked-prefill admission: allocate the slot's whole chain, point
        its block-table row at it, and write *nothing* — resident length 0.
        The engine drains the entire prompt through chunked catch-up ticks
        (``chunk_size`` tokens per tick, interleaved with ongoing decodes),
        sampling the first generated token from the last prompt token's
        logits, exactly like an uncovered prefix-cache tail with zero
        coverage."""
        toks = np.asarray(prompt, np.int32).reshape(-1)
        chain = self.ledger.admit(slot, toks, reserve_tokens, match=None,
                                  resident=0)
        self._set_tables(slot, self._table_row(slot), 0)
        return chain

    def register_prompt(self, slot: int) -> None:
        """Index the slot's fully-filled prompt blocks (the engine calls
        this when a prefix-seeded request finishes catching up)."""
        self.ledger.register_prompt(slot)

    # -- copy-on-write -------------------------------------------------------
    def prepare_decode(self, active_slots) -> int:
        """Fork every active slot whose next write would land in a shared
        block (refcount > 1): copy the block through the registry's
        ``copy_block`` kernel and repoint the slot's table row.  Returns the
        number of forks performed.  Must run before each decode tick —
        decode never writes a block with refcount > 1."""
        forks = 0
        for s in active_slots:
            if not self.ledger.chains[s]:
                continue
            if not self.ledger.needs_fork(s):
                continue
            ci, old, new = self.ledger.fork(s)
            self._device_fork(s, ci, old, new)
            forks += 1
        return forks

    def _device_fork(self, slot: int, chain_idx: int, old: int,
                     new: int) -> None:
        from repro.kernels.registry import REGISTRY, plan_kernel
        kern = plan_kernel(self.plan, "copy_block")
        if kern is not None:
            fn, interpret = kern
            copy = functools.partial(fn, interpret=interpret)
        else:
            ref = REGISTRY.get("copy_block", "ref").fn
            copy = _copy_block_ref_jit(ref)
        for e in self._entries:
            if not e.paged:
                continue
            st = self.state[e.ukey][e.skey]
            new_st = dict(st)
            new_st["kp"] = copy(st["kp"], old, new)
            new_st["vp"] = copy(st["vp"], old, new)
            new_st["bt"] = (st["bt"].at[:, slot, chain_idx].set(new)
                            if e.nlead
                            else st["bt"].at[slot, chain_idx].set(new))
            self.state[e.ukey][e.skey] = new_st

    # -- decode progress -----------------------------------------------------
    def note_decode_tick(self, active_slots, counts=None) -> None:
        """Mirror the device-side ``len`` increment for live slots (the
        device increments every row; only live slots count as live tokens).
        ``counts`` maps slot -> tokens written this tick (chunked catch-up
        rows advance by their chunk fill; plain decode rows by 1)."""
        for s in active_slots:
            self.ledger.note_write(s, 1 if counts is None else counts[s])

    # -- speculative windows -------------------------------------------------
    def spec_begin(self, slot: int) -> None:
        """Open a speculative window on ``slot`` (see
        :meth:`BlockLedger.spec_begin`).  Call *before* ``prepare_decode``
        so a COW fork triggered by the verify tick is logged inside the
        window."""
        self.ledger.spec_begin(slot)

    def spec_commit(self, slot: int, committed: int) -> int:
        """Close the window keeping ``committed`` tokens.  The ledger rolls
        back first; when anything changed — rejected writes leave the
        device-side ``len`` ahead of the committed length (the (B, k) cell
        advances it by the *fed* count), and an undone fork leaves the
        device block table pointing at the released copy — the slot's table
        row and length are rewritten from the ledger, so the next
        device-length-driven 1-token tick writes at the committed
        position.  Rejected K/V behind the new length is garbage but
        unreachable: the verify mask only admits ``kpos <= qpos`` and later
        writes land on it first."""
        undos0 = self.ledger.spec_fork_undos
        rolled = self.ledger.spec_commit(slot, committed)
        if rolled or self.ledger.spec_fork_undos != undos0:
            self._set_tables(slot, self._table_row(slot),
                             self.ledger.lens[slot])
        return rolled

    def spec_commit_many(self, commits: Dict[int, int]) -> int:
        """Close every window in ``commits`` (slot -> committed count) and
        resync all dirty slots with a *single* batched device update — the
        per-tick engine path (per-slot :meth:`spec_commit` issues one eager
        scatter pair per slot, which dominates the verify tick on small
        models).  Returns the total rolled-back token count."""
        dirty: Dict[int, Tuple[np.ndarray, int]] = {}
        total = 0
        for slot, committed in commits.items():
            undos0 = self.ledger.spec_fork_undos
            rolled = self.ledger.spec_commit(slot, committed)
            total += rolled
            if rolled or self.ledger.spec_fork_undos != undos0:
                dirty[slot] = (self._table_row(slot),
                               self.ledger.lens[slot])
        self._set_tables_many(dirty)
        return total

    def evict(self, slot: int) -> int:
        """Free ``slot``'s block chain and park it on the trash block.
        Cached (indexed) blocks stay resident on the pool's LRU list until
        allocation pressure reclaims them.  Returns the number of blocks
        the slot referenced."""
        chain = self.ledger.release(slot)
        if not chain:
            return 0
        self._set_tables(slot, np.zeros(self.blocks_per_slot, np.int32), 0)
        return len(chain)


@functools.lru_cache(maxsize=4)
def _copy_block_ref_jit(ref_fn):
    """Donated jit wrapper around the reference copy_block so the host-side
    COW fork updates the pool buffer in place."""
    return jax.jit(lambda pool, src, dst: ref_fn(pool, src, dst),
                   donate_argnums=(0,))


# ---------------------------------------------------------------------------
# batch-bucket slicing (shape-bucketed decode ticks)
# ---------------------------------------------------------------------------

def slice_state(state: Dict[str, Any], slot_axes: Dict[str, Any],
                n: int) -> Dict[str, Any]:
    """First ``n`` slot rows of every slot-indexed leaf (pool leaves pass
    through whole) — the decode tick's batch bucket."""
    def f(x, ax):
        if ax < 0 or x.shape[ax] == n:
            return x
        return lax.slice_in_dim(x, 0, n, axis=ax)
    return jax.tree.map(f, state, slot_axes)


def merge_state(full: Dict[str, Any], part: Dict[str, Any],
                slot_axes: Dict[str, Any], n: int) -> Dict[str, Any]:
    """Merge a bucketed decode tick's updated state back over the full slot
    range.  Pool leaves (slot-agnostic) are taken from ``part`` wholesale —
    they were donated into the tick; slot-indexed leaves splice the updated
    rows over the untouched tail."""
    def f(xf, xp, ax):
        if ax < 0 or xf.shape[ax] == n:
            return xp
        rest = lax.slice_in_dim(xf, n, xf.shape[ax], axis=ax)
        return jnp.concatenate([xp, rest], axis=ax)
    return jax.tree.map(f, full, part, slot_axes)
