"""Engine-level decode autotune: flow search over the serving decode cell.

The ROADMAP's "serving autotune" item: reuse the design-space explorer
(:mod:`repro.core.dse`) on the *decode* cell the Engine actually runs —
once per batch bucket of the serving profile — and pin the winning flow.
The DSE already exposes the pass knobs, the kernel backend, and (given
``devices > 1`` or a mesh) the dp/tp/pp mesh factorizations; with
``validate="measure"`` survivors are ranked by measured step time
(:meth:`CompiledModel.measure`), the serving analogue of the paper's
confirm-by-place-&-route step.  On top, a pool microbenchmark picks the
paged KV block size for the profile.

Usage::

    at = autotune_decode("llama3.2-1b", smoke=True,
                         profile=ServingProfile(batch_buckets=(1, 4),
                                                max_seq_len=64))
    eng = at.engine()                                     # or, by hand:
    cm = at.compile()                                     # pinned best flow
    eng = Engine(cm, cm.init_params(key), at.engine_config())
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import tunedb
from repro.configs.base import FlowConfig, ModelConfig, ShapeConfig
from repro.core import dse
from repro.obs import TRACER


def _timed_runs(label: str, fn: Callable[[], Any], iters: int,
                warmup: int = 1, **attrs: Any) -> List[float]:
    """Wall-clock ``fn`` ``iters`` times through the module tracer (one
    ``autotune`` span per run when tracing is on).  The first ``warmup``
    run(s) are discarded — the first call can pay jit/tick-program compile
    time, and with few iters a compile-heavy candidate would win or lose
    on compile cost rather than steady-state time.  Callers aggregate the
    returned samples by median."""
    for _ in range(max(warmup, 0)):
        fn()
    ts = []
    for _ in range(max(iters, 1)):
        sp = TRACER.timed(label, cat="autotune", **attrs)
        fn()
        sp.end()
        ts.append(sp.elapsed_s)
    return ts


# ---------------------------------------------------------------------------
# persistent microbench records (repro.tunedb, kind="serving")
# ---------------------------------------------------------------------------

def _serving_key(cfg: ModelConfig, profile: "ServingProfile", fld: str,
                 **extra: Any) -> Dict[str, Any]:
    """The structured key one ``tune_*`` microbench persists under:
    (cfg fingerprint, ServingProfile, tuned field, platform/device kind,
    plus whatever pinned context the bench depends on)."""
    key: Dict[str, Any] = {"cfg": tunedb.config_facts(cfg),
                           "profile": dataclasses.asdict(profile),
                           "field": fld,
                           "platform": tunedb.device_key()}
    key.update(extra)
    return key


def _db_served(tdb: Optional[tunedb.TuneDB],
               key: Dict[str, Any]) -> Optional[Tuple[Any, Dict]]:
    """The stored ``(best, times)`` for ``key``, or None (miss / no db)."""
    if tdb is None:
        return None
    rec = tdb.lookup(key)
    if rec is None:
        return None
    v = rec.value
    return v["best"], dict(v.get("times", []))


def _db_bank(tdb: Optional[tunedb.TuneDB], key: Dict[str, Any],
             best: Any, times: Dict) -> None:
    """Persist one microbench outcome (times as pairs: int keys and tuple
    values survive the JSON round-trip exactly)."""
    if tdb is not None:
        tdb.put(tunedb.TuneRecord.make(
            "serving", key, {"best": best, "times": list(times.items())}))


def _pinned_facts(at: "DecodeAutotune") -> Dict[str, Any]:
    """The already-pinned autotune context an engine-replay bench depends
    on — part of its key, so re-tuning one stage after an upstream stage
    changed never serves the stale replay."""
    return {"flow": tunedb.flow_facts(at.flow_for()),
            "bucket": at.best_bucket,
            "block_size": at.block_size,
            "chunk_size": at.chunk_size,
            "fori_seg": at.fori_seg,
            "prefix_cache": at.prefix_cache}


@dataclass(frozen=True)
class ServingProfile:
    """One deployment's decode envelope: what the Engine will be asked to
    serve, hence what the autotune optimizes for."""
    name: str = "default"
    batch_buckets: Tuple[int, ...] = (1, 4, 16)
    max_seq_len: int = 256
    block_sizes: Tuple[int, ...] = (8, 16, 32)
    # chunked-prefill catch-up widths to microbench (k of the (B, k) cell)
    chunk_sizes: Tuple[int, ...] = (1, 2, 4)
    # host-free decode segment lengths to A/B (0 = per-tick host loop)
    fori_segs: Tuple[int, ...] = (0, 4, 8)
    # speculative draft_k candidates to A/B with the n-gram drafter
    # (0 = speculation off)
    spec_ks: Tuple[int, ...] = (0, 2, 4)

    def __post_init__(self):
        # frozen dataclass: normalize sequence inputs via object.__setattr__
        object.__setattr__(self, "batch_buckets", tuple(self.batch_buckets))
        object.__setattr__(self, "block_sizes", tuple(self.block_sizes))
        object.__setattr__(self, "chunk_sizes", tuple(self.chunk_sizes))
        object.__setattr__(self, "fori_segs", tuple(self.fori_segs))
        object.__setattr__(self, "spec_ks", tuple(self.spec_ks))
        # candidate-set invariants live once in repro.analysis.rules (shared
        # with the static verifier); each raises with its legacy message
        from repro.analysis import rules as _rules
        msg0 = _rules.profile_batch_buckets(self.batch_buckets)
        if msg0 is not None:
            raise ValueError(msg0)
        if self.max_seq_len < 1:
            raise ValueError("max_seq_len must be >= 1")
        for msg in (_rules.profile_block_sizes(self.block_sizes,
                                               self.max_seq_len),
                    _rules.profile_chunk_sizes(self.chunk_sizes,
                                               self.max_seq_len),
                    _rules.profile_fori_segs(self.fori_segs),
                    _rules.profile_spec_ks(self.spec_ks, self.max_seq_len)):
            if msg is not None:
                raise ValueError(msg)

    def shape_for(self, bucket: int) -> ShapeConfig:
        return ShapeConfig(f"{self.name}_decode{self.max_seq_len}_b{bucket}",
                           "decode", self.max_seq_len, bucket)


@dataclass
class DecodeAutotune:
    """The autotune outcome the Engine pins: the measured-ranked flow per
    batch bucket (and overall), the chosen KV block size, and whether the
    prefix cache pays for the profile's workload."""
    cfg: ModelConfig
    profile: ServingProfile
    per_bucket: Dict[int, Any]          # bucket -> dse.ExploreResult
    block_size: int
    block_times_us: Dict[int, float] = field(default_factory=dict)
    mesh: Any = None
    prefix_cache: bool = False
    prefix_times_s: Dict[str, float] = field(default_factory=dict)
    chunk_size: int = 1
    chunk_times_us: Dict[int, float] = field(default_factory=dict)
    fori_seg: int = 0
    fori_times_s: Dict[str, float] = field(default_factory=dict)
    speculation: Optional[str] = None    # e.g. "ngram:4"; None = off
    spec_times_s: Dict[str, float] = field(default_factory=dict)
    # per-kernel Pallas tile schedules (tune_kernel_tiles): ordered
    # (tile_key, tile) pairs folded into every pinned flow, + bench times
    tile_overrides: Tuple[Tuple[str, Any], ...] = ()
    tile_times_s: Dict[str, float] = field(default_factory=dict)

    @property
    def n_measured(self) -> int:
        """Validator invocations the per-bucket flow searches actually paid
        (0 everywhere when every bucket was an exact tunedb hit) — what the
        CI warm-start gate asserts shrinks."""
        return sum(er.n_measured for er in self.per_bucket.values())

    @property
    def tunedb_statuses(self) -> Dict[int, Optional[str]]:
        """Per-bucket tunedb outcome (None without a db, else
        hit/transfer/cold)."""
        return {b: er.tunedb_status for b, er in self.per_bucket.items()}

    def _measured_per_token(self, bucket: int) -> Optional[float]:
        er = self.per_bucket[bucket]
        ts = [v["measured_step_s"] for v in er.validated
              if v["knobs"] == er.best.knob_str() and "measured_step_s" in v]
        return (ts[0] / bucket) if ts else None

    @property
    def best_bucket(self) -> int:
        """The bucket whose winner delivers the best measured *per-token*
        decode time — every bucket's search informs the pin.  Falls back to
        the largest bucket when nothing was measured (validate != measure)."""
        scored = [(b, t) for b in self.profile.batch_buckets
                  if (t := self._measured_per_token(b)) is not None]
        if not scored:
            return self.profile.batch_buckets[-1]
        return min(scored, key=lambda bt: bt[1])[0]

    def flow_for(self, bucket: Optional[int] = None) -> FlowConfig:
        b = bucket if bucket is not None else self.best_bucket
        if b not in self.per_bucket:
            raise KeyError(f"bucket {b} was not tuned "
                           f"(profile buckets: {self.profile.batch_buckets})")
        f = self.per_bucket[b].best.flow
        if self.tile_overrides:
            f = dataclasses.replace(f, tile_overrides=self.tile_overrides)
        return f

    def compile(self, bucket: Optional[int] = None):
        """CompiledModel for the winning flow of ``bucket`` (default: the
        measured-best per-token bucket) — what the Engine pins.  The decode
        shape cell always covers the profile's full envelope (largest
        bucket) so the pinned executable serves every batch bucket."""
        from repro import flow as rflow
        b = bucket if bucket is not None else self.best_bucket
        return rflow.compile(self.cfg,
                             self.profile.shape_for(
                                 self.profile.batch_buckets[-1]),
                             self.flow_for(b), mesh=self.mesh)

    def engine_config(self, **overrides) -> "EngineConfig":
        """EngineConfig matching the tuned profile (slots = largest bucket,
        tuned block size, the profile's bucket ladder)."""
        from repro.serving.engine import EngineConfig
        kw: Dict[str, Any] = dict(
            max_batch=self.profile.batch_buckets[-1],
            max_seq_len=self.profile.max_seq_len,
            batch_buckets=tuple(self.profile.batch_buckets),
            block_size=self.block_size,
            prefix_cache=self.prefix_cache,
            chunk_size=self.chunk_size,
            chunked_prefill=self.chunk_size > 1,
            fori_seg=self.fori_seg)
        if self.speculation:
            kw["speculation"] = self.speculation
            kw["fori_seg"] = 0       # S307: the host decides acceptance
        kw.update(overrides)
        return EngineConfig(**kw)

    def engine(self, params=None, rng=None, **overrides):
        """Compile the winning flow and build an Engine pinned to it."""
        from repro.serving.engine import Engine
        cm = self.compile()
        if params is None:
            params = cm.init_params(rng if rng is not None
                                    else jax.random.key(0))
        return Engine(cm, params, self.engine_config(**overrides))

    def describe(self) -> str:
        lines = [f"serving-autotune[{self.cfg.name} x {self.profile.name}] "
                 f"buckets={list(self.profile.batch_buckets)} "
                 f"pin=b{self.best_bucket} block_size={self.block_size} "
                 f"prefix_cache={'on' if self.prefix_cache else 'off'} "
                 f"chunk={self.chunk_size} fori_seg={self.fori_seg or 'off'} "
                 f"spec={self.speculation or 'off'}"]
        statuses = self.tunedb_statuses
        if any(s is not None for s in statuses.values()):
            lines.append("  tunedb: " + " ".join(
                f"b{b}={statuses[b]}" for b in self.profile.batch_buckets)
                + f" measured={self.n_measured}")
        if self.tile_overrides:
            lines.append("  tiles: " + " ".join(
                f"{k}={v}" for k, v in self.tile_overrides))
        for b in self.profile.batch_buckets:
            er = self.per_bucket[b]
            t = self._measured_per_token(b)
            meas = f" measured={t * b * 1e3:.3f}ms" \
                   f" per_tok={t * 1e3:.3f}ms" if t is not None else ""
            lines.append(f"  b{b}: [{er.best.knob_str()}]{meas}")
        if self.block_times_us:
            lines.append("  block_us: " + " ".join(
                f"{k}:{v:.0f}" for k, v in sorted(self.block_times_us.items())))
        if self.prefix_times_s:
            lines.append("  prefix_replay_s: " + " ".join(
                f"{k}:{v:.3f}" for k, v in sorted(self.prefix_times_s.items())))
        if self.chunk_times_us:
            lines.append("  chunk_us_per_tok: " + " ".join(
                f"k{k}:{v:.0f}" for k, v in sorted(self.chunk_times_us.items())))
        if self.fori_times_s:
            lines.append("  fori_replay_s: " + " ".join(
                f"{k}:{v:.3f}" for k, v in sorted(
                    self.fori_times_s.items(), key=lambda kv: int(kv[0]))))
        if self.spec_times_s:
            lines.append("  spec_replay_s: " + " ".join(
                f"{k}:{v:.3f}" for k, v in sorted(self.spec_times_s.items())))
        return "\n".join(lines)


def tune_block_size(cfg: ModelConfig, profile: ServingProfile, *,
                    iters: int = 5, seed: int = 0, db: Any = None
                    ) -> Tuple[int, Dict[int, float]]:
    """Microbenchmark the paged decode-attention lookup per candidate block
    size at the profile's largest bucket and pick the fastest (ties -> the
    larger block: fewer table entries).  Uses the registry-resolved backend
    (Pallas gather on TPU, ref fallback elsewhere).  ``db`` (TuneDB or
    path) serves a previously banked winner without re-benching."""
    from repro.kernels.registry import REGISTRY
    att = cfg.attention
    if att is None:
        raise ValueError(f"{cfg.name} has no attention; nothing to tune")
    tdb = tunedb.open_db(db)
    key = _serving_key(cfg, profile, "block_size", iters=iters, seed=seed)
    hit = _db_served(tdb, key)
    if hit is not None:
        return hit
    B = profile.batch_buckets[-1]
    H, KV, D = att.n_heads, att.n_kv_heads, att.head_dim
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(B, 1, H, D), jnp.float32)
    times: Dict[int, float] = {}
    from repro.serving.kvcache import blocks_for_tokens
    use_pallas = REGISTRY.resolve("paged_decode_attention") == "pallas"
    for bs in profile.block_sizes:
        nblk = blocks_for_tokens(profile.max_seq_len, bs)
        NB = 1 + B * nblk
        kp = jnp.asarray(rng.randn(NB, bs, KV, D), jnp.float32)
        vp = jnp.asarray(rng.randn(NB, bs, KV, D), jnp.float32)
        bt = jnp.asarray(
            1 + (np.arange(B * nblk) % (NB - 1)).reshape(B, nblk), jnp.int32)
        lens = jnp.full((B,), profile.max_seq_len - 1, jnp.int32)
        if use_pallas:
            fn = REGISTRY.get("paged_decode_attention", "pallas").fn
            run = jax.jit(lambda q, kp, vp, bt, ln: fn(q, kp, vp, bt, ln))
        else:
            ref = REGISTRY.get("paged_decode_attention", "ref").fn
            run = jax.jit(lambda q, kp, vp, bt, ln:
                          ref(q, kp, vp, bt, ln,
                              compute_dtype=jnp.float32))
        jax.block_until_ready(run(q, kp, vp, bt, lens))    # compile + warm
        ts = _timed_runs(
            "autotune.block_size",
            lambda: jax.block_until_ready(run(q, kp, vp, bt, lens)),
            iters, bs=bs)
        times[bs] = float(np.median(ts) * 1e6)
    best = min(sorted(times, reverse=True), key=lambda b: times[b])
    _db_bank(tdb, key, best, times)
    return best, times


def tune_chunk_size(cfg: ModelConfig, profile: ServingProfile, *,
                    block_size: Optional[int] = None,
                    iters: int = 5, seed: int = 0, db: Any = None
                    ) -> Tuple[int, Dict[int, float]]:
    """Microbenchmark the chunked catch-up cell — a (B, k) multi-query
    lookup against the paged pool — per candidate chunk width ``k`` and
    pick the best measured *per-token* time (ties -> the larger chunk:
    fewer engine ticks, hence fewer host syncs, per caught-up prompt).
    Mirrors :func:`tune_block_size`; uses the registry-resolved backend."""
    from repro.kernels.registry import REGISTRY
    att = cfg.attention
    if att is None:
        raise ValueError(f"{cfg.name} has no attention; nothing to tune")
    B = profile.batch_buckets[-1]
    H, KV, D = att.n_heads, att.n_kv_heads, att.head_dim
    bs = block_size if block_size is not None else profile.block_sizes[0]
    tdb = tunedb.open_db(db)
    key = _serving_key(cfg, profile, "chunk_size", block_size=bs,
                       iters=iters, seed=seed)
    hit = _db_served(tdb, key)
    if hit is not None:
        return hit
    rng = np.random.RandomState(seed)
    from repro.serving.kvcache import blocks_for_tokens
    nblk = blocks_for_tokens(profile.max_seq_len, bs)
    NB = 1 + B * nblk
    kp = jnp.asarray(rng.randn(NB, bs, KV, D), jnp.float32)
    vp = jnp.asarray(rng.randn(NB, bs, KV, D), jnp.float32)
    bt = jnp.asarray(
        1 + (np.arange(B * nblk) % (NB - 1)).reshape(B, nblk), jnp.int32)
    use_pallas = REGISTRY.resolve("paged_decode_attention") == "pallas"
    fn = REGISTRY.get("paged_decode_attention",
                      "pallas" if use_pallas else "ref").fn
    times: Dict[int, float] = {}
    for k in profile.chunk_sizes:
        resident = max(profile.max_seq_len - k, 0)
        q = jnp.asarray(rng.randn(B, k, H, D), jnp.float32)
        lens = jnp.full((B,), resident, jnp.int32)
        qpos = lens[:, None] + jnp.arange(k, dtype=jnp.int32)[None, :]
        if use_pallas:
            run = jax.jit(lambda q, kp, vp, bt, ln, qp:
                          fn(q, kp, vp, bt, ln, qpos=qp))
        else:
            run = jax.jit(lambda q, kp, vp, bt, ln, qp:
                          fn(q, kp, vp, bt, ln, qpos=qp,
                             compute_dtype=jnp.float32))
        jax.block_until_ready(run(q, kp, vp, bt, lens, qpos))
        ts = _timed_runs(
            "autotune.chunk_size",
            lambda: jax.block_until_ready(run(q, kp, vp, bt, lens, qpos)),
            iters, k=k)
        times[k] = float(np.median(ts) * 1e6 / k)      # per catch-up token
    best = min(sorted(times, reverse=True), key=lambda k: times[k])
    _db_bank(tdb, key, best, times)
    return best, times


def tune_fori_seg(at: DecodeAutotune, *, iters: int = 2, seed: int = 0,
                  db: Any = None) -> Tuple[int, Dict[str, float]]:
    """Measured A/B of the host-free decode segment length on a
    decode-heavy replay of the profile's envelope: serve the same request
    batch through a pinned Engine once per candidate ``fori_seg`` (0 = the
    per-tick host loop) and keep the fastest.  Ties break toward the
    *larger* segment — equal wall time with fewer host syncs per token is
    still a latency-variance win.  Mirrors :func:`tune_prefix_cache`."""
    from repro.serving.engine import Engine
    from repro.serving.scheduler import synthetic_requests
    prof = at.profile
    bs = at.block_size
    tdb = tunedb.open_db(db)
    key = _serving_key(at.cfg, prof, "fori_seg", pinned=_pinned_facts(at),
                       iters=iters, seed=seed)
    hit = _db_served(tdb, key)
    if hit is not None:
        return hit
    cands = sorted({0, *prof.fori_segs})
    segs = [s for s in cands if s] or [0]
    # short prompts (one block, bucket-exact: no left-padding) and long
    # generations — the segment loop's home turf
    prompt_len = bs
    max_new = min(prof.max_seq_len - prompt_len,
                  max(8, 2 * max(segs)))
    if max_new < 2:
        return 0, {}                   # envelope too small for any segment
    cands = [s for s in cands if s <= max_new]
    n = max(4, 2 * prof.batch_buckets[-1])
    cm = at.compile()
    params = cm.init_params(jax.random.key(seed))
    reqs = synthetic_requests(n, at.cfg.vocab_size, prompt_len=prompt_len,
                              max_new_tokens=max_new, seed=seed,
                              vary_lens=False)
    buckets = tuple(sorted({prompt_len, prof.max_seq_len}))
    times: Dict[str, float] = {}
    for seg in cands:
        eng = Engine(cm, params,
                     at.engine_config(fori_seg=seg, prompt_buckets=buckets))
        eng.run(reqs)                         # warm the tick programs
        ts = _timed_runs("autotune.fori_seg", lambda: eng.run(reqs),
                         iters, seg=seg)
        times[str(seg)] = float(np.median(ts))
    best = min(sorted(cands, reverse=True), key=lambda s: times[str(s)])
    _db_bank(tdb, key, best, times)
    return best, times


def tune_prefix_cache(at: DecodeAutotune, *, iters: int = 2, seed: int = 0,
                      db: Any = None) -> Tuple[bool, Dict[str, float]]:
    """Measured A/B of the prefix-cache toggle on a shared-prefix replay of
    the profile's envelope (the workload the cache is built for): serve the
    same request batch with the cache on and off through a pinned Engine and
    keep the faster setting.  Ties break toward *on* — equal wall time with
    fewer prefill tokens computed is still a resource win (the paper's
    on-chip-reuse argument).  Models the cache cannot serve exactly (extra
    recurrent state) report ``off`` with no measurement."""
    from repro.serving.engine import Engine
    from repro.serving.kvcache import _state_entries
    from repro.serving.scheduler import shared_prefix_requests
    prof = at.profile
    bs = at.block_size
    tdb = tunedb.open_db(db)
    key = _serving_key(at.cfg, prof, "prefix_cache",
                       pinned=_pinned_facts(at), iters=iters, seed=seed)
    hit = _db_served(tdb, key)
    if hit is not None:
        return hit
    max_new = max(2, min(8, prof.max_seq_len // 8))
    # shared prefix: about half the envelope, block-aligned, plus a
    # one-block tail so the whole prompt lands exactly on a prompt bucket
    # (no left-padding — the workload must serve on pad-unsafe backends)
    prefix_len = min(prof.max_seq_len // 2,
                     prof.max_seq_len - max_new - bs) // bs * bs
    if prefix_len < bs:
        return False, {}          # envelope too small for any shared block
    tail_len = bs
    prompt_len = prefix_len + tail_len
    n = max(4, 2 * prof.batch_buckets[-1])
    cm = at.compile()
    ents = _state_entries(cm.plan)
    if any(not e.paged for e in ents):
        # recurrent / cross-attention per-request state: a token-prefix
        # match cannot seed it, the cache is off by construction
        return False, {}
    params = cm.init_params(jax.random.key(seed))
    reqs = shared_prefix_requests(n, at.cfg.vocab_size,
                                  prefix_len=prefix_len, tail_len=tail_len,
                                  max_new_tokens=max_new, seed=seed)
    buckets = tuple(sorted({prompt_len, prof.max_seq_len}))
    times: Dict[str, float] = {}
    for label, toggle in (("off", False), ("on", True)):
        eng = Engine(cm, params,
                     at.engine_config(prefix_cache=toggle,
                                      prompt_buckets=buckets))
        eng.run(reqs)                         # warm the tick programs
        ts = _timed_runs("autotune.prefix_cache", lambda: eng.run(reqs),
                         iters, toggle=toggle)
        times[label] = float(np.median(ts))
    best = bool(times["on"] <= times["off"])
    _db_bank(tdb, key, best, times)
    return best, times


def tune_speculation(at: DecodeAutotune, *, iters: int = 2, seed: int = 0,
                     db: Any = None
                     ) -> Tuple[Optional[str], Dict[str, float]]:
    """Measured A/B of speculative decoding on a decode-heavy shared-prefix
    replay (the prompt-lookup drafter's home turf: generations revisit the
    shared context): serve the same batch once per candidate ``draft_k``
    (0 = off, which keeps the already-tuned fori_seg) through a pinned
    Engine and keep the fastest.  Ties break toward the *larger* k — equal
    wall time with fewer host syncs per token.  Returns the winning
    ``"ngram:<k>"`` spec (or ``None``) plus the replay times.  Models whose
    per-request state is not fully paged report off with no measurement."""
    from repro.serving.engine import Engine
    from repro.serving.kvcache import _state_entries
    from repro.serving.scheduler import shared_prefix_requests
    prof = at.profile
    bs = at.block_size
    tdb = tunedb.open_db(db)
    key = _serving_key(at.cfg, prof, "speculation",
                       pinned=_pinned_facts(at), iters=iters, seed=seed)
    hit = _db_served(tdb, key)
    if hit is not None:
        return hit
    ks = sorted({0, *prof.spec_ks})
    max_k = max(ks)
    if max_k == 0:
        return None, {}
    prefix_len = max(bs, prof.max_seq_len // 4 // bs * bs)
    tail_len = bs
    prompt_len = prefix_len + tail_len
    max_new = prof.max_seq_len - prompt_len
    if max_new < max_k + 1:
        return None, {}           # envelope too small for any verify cell
    cm = at.compile()
    if any(not e.paged for e in _state_entries(cm.plan)):
        # rollback truncates block chains; recurrent state can't express it
        return None, {}
    params = cm.init_params(jax.random.key(seed))
    n = max(4, 2 * prof.batch_buckets[-1])
    reqs = shared_prefix_requests(n, at.cfg.vocab_size,
                                  prefix_len=prefix_len, tail_len=tail_len,
                                  max_new_tokens=max_new, seed=seed)
    buckets = tuple(sorted({prompt_len, prof.max_seq_len}))

    def label(k):
        return f"ngram:{k}" if k else "off"

    times: Dict[str, float] = {}
    for k in ks:
        kw = {"speculation": f"ngram:{k}", "fori_seg": 0} if k else {}
        eng = Engine(cm, params,
                     at.engine_config(prompt_buckets=buckets, **kw))
        eng.run(reqs)                         # warm the tick programs
        ts = _timed_runs("autotune.speculation", lambda: eng.run(reqs),
                         iters, k=k)
        times[label(k)] = float(np.median(ts))
    best = min(sorted(ks, reverse=True), key=lambda k: times[label(k)])
    spec = f"ngram:{best}" if best else None
    _db_bank(tdb, key, spec, times)
    return spec, times


def tune_kernel_tiles(cfg: ModelConfig, profile: ServingProfile, *,
                      flow: Optional[FlowConfig] = None,
                      iters: int = 2, db: Any = None
                      ) -> Tuple[Tuple[Tuple[str, Any], ...],
                                 Dict[str, float]]:
    """Search *below* the plan level: per-kernel Pallas tile schedules
    (``block_q``/``block_kv`` for attention, ``block_h``/``block_c`` for
    conv) declared via :attr:`KernelContract.tile_candidates`.  Each
    candidate tile is pinned through ``FlowConfig.tile_overrides`` (the
    TilingPass applies it on top of its own selection), the cell is
    compiled and wall-clocked, and the fastest tile per ``tile_key`` wins.

    Only ops the registry resolves to the native Pallas backend are
    benched: the reference kernels are tile-invariant, so off-TPU there is
    nothing to measure and the selector's schedule stands (returns
    ``((), {})`` — deterministic on CPU CI).  Winners are recordable and
    warm-startable through ``db`` like every other microbench."""
    from repro import flow as rflow
    from repro.kernels.registry import REGISTRY
    flow0 = flow if flow is not None else FlowConfig(mode="folded")
    tdb = tunedb.open_db(db)
    key = _serving_key(cfg, profile, "kernel_tiles",
                       flow=tunedb.flow_facts(flow0), iters=iters)
    hit = _db_served(tdb, key)
    if hit is not None:
        best, times = hit
        return tuple(best), times
    B = profile.batch_buckets[-1]
    decode_shape = profile.shape_for(B)
    prefill_shape = ShapeConfig(f"{profile.name}_tiles_prefill",
                                "prefill", profile.max_seq_len, B)
    overrides: List[Tuple[str, Any]] = []
    times: Dict[str, float] = {}
    seen_keys = set()
    for op in REGISTRY.accelerated_ops():
        contract = REGISTRY.get(op, "pallas").contract
        if contract is None or contract.tile_key is None or \
                contract.tile_candidates is None:
            continue
        if contract.tile_key in seen_keys:
            continue
        if REGISTRY.resolve(op) != "pallas":
            continue           # ref path: tile-invariant, nothing to bench
        seen_keys.add(contract.tile_key)
        shape = decode_shape if "decode" in contract.tile_key \
            else prefill_shape
        cands = contract.tile_candidates(cfg, shape)
        best_tile, best_t = None, float("inf")
        for tile in cands:
            f = dataclasses.replace(
                flow0, tile_overrides=((contract.tile_key, tile),))
            sp = TRACER.timed("autotune.kernel_tiles", cat="autotune",
                              op=op, tile=str(tile))
            cm = rflow.compile(cfg, shape, f)
            t = float(cm.measure(iters=iters)["measured_step_s"])
            sp.end()
            times[f"{contract.tile_key}:{tile}"] = t
            if t < best_t:
                best_tile, best_t = tile, t
        if best_tile is not None:
            overrides.append((contract.tile_key, best_tile))
    best = tuple(overrides)
    _db_bank(tdb, key, best, times)
    return best, times


def autotune_decode(arch_or_cfg, *, profile: Optional[ServingProfile] = None,
                    base_flow: Optional[FlowConfig] = None,
                    mesh=None,
                    validate: str = "measure",
                    iters: int = 3,
                    smoke: bool = False,
                    tune_blocks: bool = True,
                    tune_prefix: Optional[bool] = None,
                    tune_chunks: bool = True,
                    tune_fori: Optional[bool] = None,
                    tune_spec: Optional[bool] = None,
                    tune_tiles: Optional[bool] = None,
                    use_cache: bool = True,
                    db: Any = None) -> DecodeAutotune:
    """Search the flow design space for each decode cell of the serving
    profile and return the pinnable result.

    ``validate``: ``"measure"`` (default) AOT-compiles and wall-clocks each
    top-k survivor, ranking by measured step time; ``"compile"`` ranks by
    the deterministic estimator order and confirms footprints only (use for
    reproducible tuning decisions in CI); ``"none"`` skips validation (the
    estimator ranking alone — cheapest).  ``mesh`` makes the dp/tp/pp
    factorization part of the search (or pins it, exactly as in
    ``repro.flow.compile``).  ``tune_prefix`` A/Bs the prefix-cache toggle
    on a measured shared-prefix replay (default: only under
    ``validate="measure"`` — it wall-clocks real engine runs).
    ``tune_chunks`` microbenchmarks the chunked-prefill catch-up width
    ``k`` (adopted only when the model's per-request state is fully paged —
    the Engine's own gate); ``tune_fori`` A/Bs the host-free decode segment
    length on a decode-heavy replay (default: only under
    ``validate="measure"``, like ``tune_prefix``); ``tune_spec`` A/Bs
    speculative decoding (n-gram drafter, the profile's ``spec_ks``) on a
    shared-prefix replay under the same default; ``tune_tiles`` benches
    per-kernel Pallas tile schedules (:func:`tune_kernel_tiles`, same
    default — a no-op off-TPU where the ref kernels are tile-invariant).

    ``db`` (a :class:`repro.tunedb.TuneDB` or a path; defaults to the base
    flow's ``tuning.tune_db``) makes the whole search persistent: each
    bucket's flow search and each microbench reads/writes the store, so a
    warm re-run with an unchanged profile measures nothing
    (``DecodeAutotune.n_measured`` reports what the flow searches paid)."""
    from repro.flow import _resolve_cfg
    if validate not in ("measure", "compile", "none"):
        raise ValueError(f"unknown validate mode {validate!r}")
    cfg = _resolve_cfg(arch_or_cfg, smoke)
    profile = profile if profile is not None else ServingProfile()
    flow0 = base_flow if base_flow is not None else FlowConfig(mode="folded")
    tdb = tunedb.open_db(db if db is not None else flow0.tuning.tune_db)

    mesh_obj = None
    devices = 1
    if mesh is not None:
        from repro.distributed.meshspec import MeshSpec
        spec = MeshSpec.of(mesh)
        mesh_obj = mesh if hasattr(mesh, "devices") else spec.build()
        devices = spec.size

    per_bucket: Dict[int, Any] = {}
    for bucket in profile.batch_buckets:
        shape = profile.shape_for(bucket)
        if validate == "measure":
            validator = dse.measure_validator(cfg, shape, mesh=mesh_obj,
                                              iters=iters)
        elif validate == "compile":
            validator = dse.compile_validator(cfg, shape)
        else:
            validator = None
        per_bucket[bucket] = dse.explore(
            cfg, shape, flow0, devices=devices, validator=validator,
            rank_measured=validate == "measure", use_cache=use_cache,
            db=tdb)

    if tune_blocks:
        block_size, block_times = tune_block_size(cfg, profile, iters=iters,
                                                  db=tdb)
    else:
        block_size, block_times = profile.block_sizes[0], {}
    at = DecodeAutotune(cfg=cfg, profile=profile, per_bucket=per_bucket,
                        block_size=block_size, block_times_us=block_times,
                        mesh=mesh_obj)
    do_tiles = tune_tiles if tune_tiles is not None \
        else validate == "measure"
    if do_tiles:
        # below-plan tunables first: the engine replays that follow pin a
        # flow carrying the winning tile schedules
        at.tile_overrides, at.tile_times_s = tune_kernel_tiles(
            cfg, profile, flow=at.per_bucket[at.best_bucket].best.flow,
            iters=iters, db=tdb)
    do_prefix = tune_prefix if tune_prefix is not None \
        else validate == "measure"
    if do_prefix:
        at.prefix_cache, at.prefix_times_s = tune_prefix_cache(at,
                                                               iters=iters,
                                                               db=tdb)
    if tune_chunks and cfg.attention is not None:
        chunk, chunk_times = tune_chunk_size(cfg, profile,
                                             block_size=at.block_size,
                                             iters=iters, db=tdb)
        at.chunk_times_us = chunk_times
        if chunk > 1:
            # the Engine's chunked paths require fully paged per-request
            # state (recurrent entries can't replay a chunk); honor its gate
            from repro.serving.kvcache import _state_entries
            if all(e.paged for e in _state_entries(at.compile().plan)):
                at.chunk_size = chunk
    do_fori = tune_fori if tune_fori is not None else validate == "measure"
    if do_fori:
        at.fori_seg, at.fori_times_s = tune_fori_seg(at, iters=iters, db=tdb)
    do_spec = tune_spec if tune_spec is not None else validate == "measure"
    if do_spec and cfg.attention is not None:
        at.speculation, at.spec_times_s = tune_speculation(at, iters=iters,
                                                           db=tdb)
    return at
