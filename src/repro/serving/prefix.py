"""Prefix-cache index: content-hashed prompt blocks over the paged KV pool.

Shared prompt prefixes (system prompts, few-shot headers, replayed chats)
recompute and re-store identical K/V across requests.  The index maps
*chained block hashes* of prompt token ids to pool blocks so a new request
can seed its block table from blocks another request already filled — the
serving-side instance of the memory-hierarchy reuse the FPGA-CNN flows
exploit (DNNVM's inter-layer reuse, the survey's on-chip caching taxonomy).

Hash scheme: block ``i`` of a prompt hashes ``blake2b(parent_digest ||
tokens[i*bs:(i+1)*bs])`` — the chain makes a digest identify *the whole
prefix up to and including this block*, so a flat dict behaves like a radix
trie keyed by block-sized edges.  Fully-filled blocks are indexed as soon as
their K/V is resident; the partially-filled tail block is indexed only when
its owner slot is evicted (its owner keeps writing generated tokens into it
while live, and an index entry must never race those writes — see
``BlockLedger`` for the copy-on-write rule on the sharing side).

The index holds no references: entries point at blocks that are either live
(refcounted by slots) or parked on the pool's LRU list, and the pool drops
entries through ``drop_block`` when allocation pressure reclaims a parked
block.
"""
from __future__ import annotations

import hashlib
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

BlockHash = bytes


def block_hashes(prompt: np.ndarray, block_size: int
                 ) -> List[Tuple[BlockHash, int]]:
    """Chained digests of ``prompt`` split into ``block_size`` runs.

    Returns one ``(digest, end)`` pair per block — ``end`` is the number of
    prompt tokens covered once this block matches (the last pair may cover a
    partial block).  Digests chain: equal digests imply equal *prefixes*,
    not merely equal blocks.
    """
    toks = np.asarray(prompt, np.int32).reshape(-1)
    out: List[Tuple[BlockHash, int]] = []
    parent = b""
    for start in range(0, toks.size, block_size):
        seg = toks[start:start + block_size]
        d = hashlib.blake2b(parent + seg.tobytes(), digest_size=16).digest()
        out.append((d, start + int(seg.size)))
        parent = d
    return out


class PrefixIndex:
    """hash -> pool block, with a reverse map so a reclaimed block can drop
    every entry pointing at it."""

    def __init__(self):
        self._map: Dict[BlockHash, int] = {}
        self._by_block: Dict[int, List[BlockHash]] = {}

    def __len__(self) -> int:
        return len(self._map)

    def get(self, h: BlockHash) -> Optional[int]:
        return self._map.get(h)

    def insert(self, h: BlockHash, block: int) -> None:
        """First writer wins: an existing entry for ``h`` is kept (its block
        already holds identical content and may be shared)."""
        if h in self._map:
            return
        self._map[h] = block
        self._by_block.setdefault(block, []).append(h)

    def drop_block(self, block: int) -> int:
        """Forget every hash pointing at ``block`` (the pool reclaimed it).
        Returns the number of entries dropped."""
        hashes = self._by_block.pop(block, [])
        for h in hashes:
            self._map.pop(h, None)
        return len(hashes)

    def blocks(self) -> Iterable[int]:
        return self._by_block.keys()

    def items(self) -> Iterable[Tuple[BlockHash, int]]:
        return self._map.items()
