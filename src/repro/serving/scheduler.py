"""Continuous-batching scheduler: request queue, admission control, slots.

The scheduler is pure bookkeeping — no jax, no model.  It owns the FIFO
request queue and the slot table; each engine tick asks it which requests to
admit (``admissions``: a free slot *and* enough free KV blocks for
prompt + generation budget), tells it which tokens were decoded (``step``),
and collects finished requests (``finished`` → evict, freeing the slot and
its blocks for the next admission).  Finished sequences are evicted and new
prompts prefilled into the freed slots *between decode ticks* — continuous
batching, not static batching.

Shape bucketing lives here too (:func:`bucket_for`): prompt lengths and
batch sizes are rounded up to a fixed ladder so every tick reuses a jitted
program instead of retracing (the serving analogue of the paper's fixed
accelerator shapes).

Prefix caching hooks in at admission: when the engine hands the scheduler a
``prefix`` object (the paged cache), each queued prompt is matched against
the block index *before* the block charge is computed — a request is charged
only for its uncovered blocks (plus one copy-on-write spare when its first
write lands inside a shared block), and the matched blocks are locked
(refcounted) the moment the admission decision is made, so an eviction
racing the same tick can never reclaim them.  A prefix-seeded slot carries
``pending`` — the uncovered prompt tail the engine feeds through decode
ticks (mid-sequence prefill) before sampling begins.
"""
from __future__ import annotations

import json
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import MetricsRegistry
from repro.serving.kvcache import BlockPool, blocks_for_tokens


# ---------------------------------------------------------------------------
# requests
# ---------------------------------------------------------------------------

@dataclass
class Request:
    rid: Any
    prompt: np.ndarray                 # 1-D int32 token ids
    max_new_tokens: int = 16
    stop_token: Optional[int] = None
    # per-request speculative-decoding toggle: None defers to the engine
    # default (on when EngineConfig.speculation is set); False pins this
    # request to plain 1-token decode rows even in a speculating engine
    speculate: Optional[bool] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens must be >=1")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.size)

    @property
    def total_budget(self) -> int:
        return self.prompt_len + self.max_new_tokens


@dataclass
class RequestResult:
    rid: Any
    prompt_len: int
    tokens: List[int] = field(default_factory=list)
    finish_reason: str = ""            # "length" | "stop"
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    # filled only under EngineConfig.capture_logits: the logits row each
    # recorded token was sampled from (parity/debug tooling)
    logits: List[Any] = field(default_factory=list)
    # speculative decoding: drafts fed through verify ticks for this
    # request, and how many were accepted (zeros when speculation is off)
    tokens_drafted: int = 0
    tokens_accepted: int = 0

    @property
    def n_generated(self) -> int:
        return len(self.tokens)

    @property
    def acceptance_rate(self) -> float:
        return self.tokens_accepted / self.tokens_drafted \
            if self.tokens_drafted else 0.0

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit

    @property
    def ttft_s(self) -> float:
        """Time to first token (submit -> first sampled token)."""
        return self.t_first_token - self.t_submit


def bucket_for(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= n (buckets ascending).  Raises when n overflows
    the ladder — admission control must have rejected such a request."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"{n} exceeds largest bucket {buckets[-1]}")


# ---------------------------------------------------------------------------
# slots
# ---------------------------------------------------------------------------

@dataclass
class Slot:
    index: int
    request: Optional[Request] = None
    result: Optional[RequestResult] = None
    pos: int = 0                       # current decode position (tokens cached)
    last_token: int = 0
    served: int = 0                    # lifetime occupants (refill counting)
    pending: List[int] = field(default_factory=list)  # uncovered prompt tail
    # submission-order serial of the occupant: the per-request rng-stream
    # index speculative sampling folds into (stable across engine configs,
    # so sampled speculative output is replay-comparable)
    serial: int = -1

    @property
    def free(self) -> bool:
        return self.request is None


@dataclass
class Admission:
    slot: int
    request: Request
    reserve_tokens: int
    covered: int = 0                   # prompt tokens seeded from the cache
    match: Any = None                  # locked PrefixMatch (engine consumes)
    chunked: bool = False              # cold prompt drains through chunk ticks


class Scheduler:
    """Slot-based continuous batching over a block-pool budget."""

    def __init__(self, n_slots: int, block_size: int, pool: BlockPool, *,
                 max_seq_len: int, clock: Callable[[], float] = time.monotonic,
                 prefix: Any = None, chunk_prefill: bool = False):
        self.n_slots = n_slots
        self.block_size = block_size
        self.pool = pool
        self.max_seq_len = max_seq_len
        self.clock = clock
        # chunked-prefill admission: cold prompts skip the monolithic
        # bucketed prefill batch and instead drain their whole prompt
        # through chunked catch-up ticks, interleaved with ongoing decodes
        # (the engine advances them chunk_size tokens per tick)
        self.chunk_prefill = chunk_prefill
        # prefix-cache hooks (duck-typed: the PagedKVCache / BlockLedger):
        # match_and_lock / unlock / fresh_blocks_needed
        self.prefix = prefix
        # queue entries carry their own submit timestamp and submission
        # serial (the same Request object may be submitted more than once)
        self.queue: Deque[Tuple[Request, float, int]] = deque()
        self.slots = [Slot(i) for i in range(n_slots)]
        self.results: List[RequestResult] = []
        # counters
        self.n_submitted = 0
        self.n_admitted = 0
        self.n_evicted = 0
        self.n_refills = 0             # admissions into a previously-used slot

    # -- queue ---------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.total_budget > self.max_seq_len:
            raise ValueError(
                f"request {req.rid}: prompt+max_new={req.total_budget} "
                f"exceeds max_seq_len={self.max_seq_len}")
        self.queue.append((req, self.clock(), self.n_submitted))
        self.n_submitted += 1

    def has_work(self) -> bool:
        return bool(self.queue) or any(not s.free for s in self.slots)

    def publish_metrics(self, reg: "MetricsRegistry") -> None:
        """Publish the scheduling counters under their dotted names."""
        reg.counter("serving.sched.submitted").inc(self.n_submitted)
        reg.counter("serving.sched.admissions").inc(self.n_admitted)
        reg.counter("serving.sched.evictions").inc(self.n_evicted)
        reg.counter("serving.sched.refills").inc(self.n_refills)

    @property
    def active_slots(self) -> List[int]:
        return [s.index for s in self.slots if not s.free]

    @property
    def high_water(self) -> int:
        """1 + highest occupied slot index (the decode batch must cover it)."""
        occ = self.active_slots
        return (occ[-1] + 1) if occ else 0

    # -- admission -----------------------------------------------------------
    def admissions(self) -> List[Admission]:
        """Pop requests into free slots while admission control passes:
        a free slot AND enough free pool blocks for the request's whole
        budget (prompt + max_new) — with prefix caching, only the blocks the
        cache doesn't already hold.  FIFO — a blocked head blocks the queue
        (no starvation of large requests).  Matched blocks are locked here,
        at decision time, so same-tick allocation pressure cannot evict
        them before the engine seeds the slot."""
        out: List[Admission] = []
        free = [s for s in self.slots if s.free]
        reserved = 0                   # blocks promised, not yet allocated
        while self.queue and free:
            req, t_submit, serial = self.queue[0]
            match = None
            if self.prefix is not None:
                match = self.prefix.match_and_lock(req.prompt)
                need = self.prefix.fresh_blocks_needed(req.total_budget,
                                                       match)
                if match is not None and \
                        need > self.pool.free_blocks - reserved:
                    # a hit must never make a request *less* admittable
                    # than cold (locking matched blocks removes them from
                    # the allocatable count and the COW spare adds a
                    # block): drop the match and retry as a cold admission
                    self.prefix.unlock(match)
                    match = None
                    need = blocks_for_tokens(req.total_budget,
                                             self.block_size)
            else:
                need = blocks_for_tokens(req.total_budget, self.block_size)
            if need > self.pool.free_blocks - reserved:
                break
            self.queue.popleft()
            reserved += need
            slot = free.pop(0)
            if slot.served > 0:
                self.n_refills += 1
            slot.served += 1
            slot.request = req
            slot.serial = serial
            covered = match.covered if match is not None else 0
            chunked = self.chunk_prefill and not covered
            if chunked:
                # cold prompt under chunked prefill: the whole prompt is the
                # pending tail, drained chunk_size tokens per decode tick
                slot.pos = 0
                slot.pending = req.prompt.tolist()
            else:
                slot.pos = covered if covered else req.prompt_len
                slot.pending = req.prompt[covered:].tolist() if covered else []
            slot.result = RequestResult(
                rid=req.rid, prompt_len=req.prompt_len,
                t_submit=t_submit, t_admit=self.clock())
            self.n_admitted += 1
            out.append(Admission(slot.index, req, req.total_budget,
                                 covered=covered, match=match,
                                 chunked=chunked))
        return out

    # -- decode progress -----------------------------------------------------
    def note_catchup(self, slot_idx: int, n: int = 1) -> None:
        """``n`` uncovered prompt-tail tokens were fed through a decode tick
        (mid-sequence prefill, chunked when n > 1): consume them and advance
        the position without recording generated tokens."""
        slot = self.slots[slot_idx]
        assert len(slot.pending) >= n, \
            f"slot {slot_idx} has {len(slot.pending)} pending, asked {n}"
        del slot.pending[:n]
        slot.pos += n

    def record_token(self, slot_idx: int, token: int, *,
                     first: bool = False) -> None:
        slot = self.slots[slot_idx]
        assert slot.request is not None and slot.result is not None
        slot.result.tokens.append(int(token))
        slot.last_token = int(token)
        if first:
            slot.result.t_first_token = self.clock()
        else:
            slot.pos += 1

    def finished(self) -> List[int]:
        """Slots whose occupant is done (budget reached or stop token)."""
        done = []
        for s in self.slots:
            if s.free:
                continue
            req, res = s.request, s.result
            if req.stop_token is not None and res.tokens and \
                    res.tokens[-1] == req.stop_token:
                res.finish_reason = "stop"
                done.append(s.index)
            elif res.n_generated >= req.max_new_tokens:
                res.finish_reason = "length"
                done.append(s.index)
        return done

    def evict(self, slot_idx: int) -> RequestResult:
        """Release the slot (the engine frees its KV blocks through the
        cache) and bank the result."""
        slot = self.slots[slot_idx]
        assert slot.result is not None
        slot.result.t_done = self.clock()
        res = slot.result
        self.results.append(res)
        slot.request = None
        slot.result = None
        slot.pos = 0
        slot.last_token = 0
        slot.pending = []
        slot.serial = -1
        self.n_evicted += 1
        return res


# ---------------------------------------------------------------------------
# request sources (CLI replay + benchmarks)
# ---------------------------------------------------------------------------

def synthetic_requests(n: int, vocab_size: int, *, prompt_len: int = 8,
                       max_new_tokens: int = 8, seed: int = 0,
                       vary_lens: bool = True) -> List[Request]:
    """Deterministic random request batch (benchmarks, tests, CI replay)."""
    rng = np.random.RandomState(seed)
    out = []
    for i in range(n):
        pl = prompt_len if not vary_lens else \
            int(rng.randint(max(1, prompt_len // 2), prompt_len + 1))
        out.append(Request(
            rid=f"req{i}",
            prompt=rng.randint(0, vocab_size, pl).astype(np.int32),
            max_new_tokens=max_new_tokens))
    return out


def shared_prefix_requests(n: int, vocab_size: int, *, prefix_len: int = 24,
                           tail_len: int = 8, max_new_tokens: int = 8,
                           seed: int = 0) -> List[Request]:
    """``n`` requests sharing one random system prompt of ``prefix_len``
    tokens, each with its own random ``tail_len``-token tail — the
    prefix-cache benchmark/test workload (every request after the first can
    seed ``prefix_len`` tokens from the block index)."""
    rng = np.random.RandomState(seed)
    system = rng.randint(0, vocab_size, prefix_len).astype(np.int32)
    out = []
    for i in range(n):
        tail = rng.randint(0, vocab_size, tail_len).astype(np.int32)
        out.append(Request(rid=f"sp{i}",
                           prompt=np.concatenate([system, tail]),
                           max_new_tokens=max_new_tokens))
    return out


def load_requests_jsonl(path: str, vocab_size: int) -> List[Request]:
    """One request per line: ``{"id": ..., "prompt": [ids...]}`` or
    ``{"prompt_len": N, "seed": S}`` (synthetic prompt), plus optional
    ``max_new_tokens`` / ``stop_token``."""
    out = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            if "prompt" in d:
                prompt = np.asarray(d["prompt"], np.int32)
                if prompt.size and (prompt.min() < 0
                                    or prompt.max() >= vocab_size):
                    raise ValueError(
                        f"{path} line {i}: prompt token ids must be in "
                        f"[0, {vocab_size}); got "
                        f"[{prompt.min()}, {prompt.max()}]")
            else:
                rng = np.random.RandomState(int(d.get("seed", i)))
                prompt = rng.randint(0, vocab_size,
                                     int(d["prompt_len"])).astype(np.int32)
            out.append(Request(
                rid=d.get("id", f"line{i}"), prompt=prompt,
                max_new_tokens=int(d.get("max_new_tokens", 16)),
                stop_token=d.get("stop_token")))
    return out
