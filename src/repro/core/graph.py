"""Layer-graph IR — the Relay analogue of the compilation flow.

Two levels:

* **Block graph** — an ordered list of :class:`Block` nodes (embedding, decoder
  layers, final head, …).  The folding pass (paper: *parameterized kernels*)
  groups isomorphic blocks here; the streaming pass assigns blocks to pipeline
  stages here.

* **Micro-op list** — each block carries a small SSA-style program of
  :class:`MicroOp` over named tensors.  The fusion pass (paper: *loop fusion*)
  and the precision pass rewrite at this level; lowering interprets it.

Blocks communicate through the reserved value name ``"h"`` (hidden states).
Encoder–decoder graphs additionally thread ``"cross"`` (encoder output).
Stateful ops (attention KV caches, recurrence states) declare state slots via
``state_specs``.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple


# Dimension roles understood by the sharding solver / estimator.
# "heads"    — projection *output* dim (H·Dh): column-parallel over tp.
# "heads_in" — projection *contraction* dim (out-proj input): NOT tp-sharded,
#              so the out-projection is row-local and the residual costs one
#              bf16 all-gather instead of an f32 psum (§Perf iteration 2).
ROLES = (
    "d_model", "d_ff", "vocab", "heads", "heads_in", "kv_heads", "head_dim",
    "layers", "expert", "seq", "batch", "conv_k", "channels", "lora", "none",
)


@dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: Tuple[int, ...]
    roles: Tuple[str, ...]           # semantic role per dim (drives sharding)
    init: str = "normal"             # normal | zeros | ones | lecun | embed
    init_scale: Optional[float] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.roles), (self.name, self.shape, self.roles)
        for r in self.roles:
            assert r in ROLES, r


@dataclass
class MicroOp:
    out: str
    op: str
    ins: Tuple[str, ...]
    params: Tuple[ParamSpec, ...] = ()
    attrs: Dict[str, Any] = field(default_factory=dict)

    def sig(self) -> str:
        p = [(ps.name, ps.shape, ps.roles, ps.init) for ps in self.params]
        a = {k: v for k, v in sorted(self.attrs.items()) if k != "state_key"}
        return json.dumps([self.out, self.op, list(self.ins), p, a], default=str)


@dataclass
class Block:
    name: str
    kind: str                        # embed | layer | head | encoder_layer | ...
    ops: List[MicroOp] = field(default_factory=list)
    attrs: Dict[str, Any] = field(default_factory=dict)

    # -- construction helpers -------------------------------------------------
    def add(self, out: str, op: str, *ins: str,
            params: Sequence[ParamSpec] = (), **attrs) -> str:
        self.ops.append(MicroOp(out, op, tuple(ins), tuple(params), dict(attrs)))
        return out

    # -- analysis -------------------------------------------------------------
    def signature(self) -> str:
        """Structural signature: blocks with equal signatures are isomorphic
        (same ops, same param shapes) and can be folded into one scan."""
        payload = json.dumps([self.kind, [op.sig() for op in self.ops]])
        return hashlib.sha1(payload.encode()).hexdigest()[:16]

    def param_specs(self) -> List[ParamSpec]:
        out: List[ParamSpec] = []
        for op in self.ops:
            out.extend(op.params)
        return out

    def param_count(self) -> int:
        n = 0
        for ps in self.param_specs():
            c = 1
            for d in ps.shape:
                c *= d
            n += c
        return n

    def stateful_ops(self) -> List[MicroOp]:
        return [op for op in self.ops if op.attrs.get("state_key")]


@dataclass
class Graph:
    name: str
    blocks: List[Block]
    meta: Dict[str, Any] = field(default_factory=dict)

    def __iter__(self):
        return iter(self.blocks)

    def block(self, name: str) -> Block:
        for b in self.blocks:
            if b.name == name:
                return b
        raise KeyError(name)

    def param_count(self) -> int:
        return sum(b.param_count() for b in self.blocks)

    def validate(self) -> None:
        names = [b.name for b in self.blocks]
        assert len(names) == len(set(names)), "duplicate block names"
        pnames = set()
        for b in self.blocks:
            defined = {"h", "cross", "positions"}
            for op in b.ops:
                for i in op.ins:
                    assert i in defined, f"{b.name}: op {op.op} reads undefined {i!r}"
                defined.add(op.out)
                for ps in op.params:
                    key = (b.name, ps.name)
                    assert key not in pnames, f"duplicate param {key}"
                    pnames.add(key)
            assert b.ops and b.ops[-1].out == "h", (
                f"block {b.name} must end by writing 'h'")


def iso_groups(blocks: List[Block]) -> List[Tuple[List[int], int]]:
    """Maximal runs of *consecutive* isomorphic blocks, as (indices, period).

    Detects repeating super-block patterns (e.g. (rec, rec, attn) × 8): a run
    whose signatures form a repeating cycle of length p is reported as one
    group with period p — the folding pass scans over the super-block.
    Returned groups partition ``range(len(blocks))``; a group of length 1 has
    period 1.  Only whole repetitions are grouped (reps × p indices).
    """
    sigs = [b.signature() for b in blocks]
    groups: List[Tuple[List[int], int]] = []
    i = 0
    n = len(blocks)
    while i < n:
        # try periods from 1 upward; prefer the period giving the longest run
        best_len, best_p = 1, 1
        for p in range(1, min(8, n - i) + 1):
            j = i + p
            while j < n and sigs[j] == sigs[j - p]:
                j += 1
            reps = (j - i) // p
            if reps >= 2 and reps * p > best_len:
                best_len, best_p = reps * p, p
        groups.append((list(range(i, i + best_len)), best_p))
        i += best_len
    return groups
