"""Design-space explorer (the paper leaves DSE to future work — built here).

The paper's factor selection ends with rule 3: *the design must not exceed
device resources*, checked by hours of place & route.  Our "place & route"
is ``.lower().compile()`` + ``memory_analysis()`` — seconds per candidate —
so the DSE sweeps candidates compile-in-the-loop and picks the first
configuration whose per-device footprint fits HBM:

* training cells: microbatch count (gradient accumulation) ∈ {1, 2, 4, 8}
  (halves activation transients per step; costs one extra round of FSDP
  weight gathers per microbatch — the measured trade is logged).
* (extensible: scan-unroll, sdpa chunk, CE chunk.)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

HBM_BYTES = 16 * 1024 ** 3     # v5e


def autotune_train_cell(arch: str, shape_name: str, mesh, base_flow,
                        candidates: Tuple[int, ...] = (1, 2, 4, 8)):
    """Returns (flow, result) for the first microbatch count that fits."""
    from repro.launch.dryrun import run_cell
    last = None
    for mb in candidates:
        flow = dataclasses.replace(base_flow, microbatches=mb)
        r = run_cell(arch, shape_name, mesh=mesh, flow=flow)
        r["autotuned_microbatches"] = mb
        last = (flow, r)
        if r["memory"]["per_device_bytes"] < HBM_BYTES:
            return flow, r
    return last
