"""Estimator-guided design-space explorer (the paper leaves DSE to future
work — built here).

The paper's §IV-J factor selection: count MACCs to predict DSP usage (cheap,
analytic), then confirm the survivors with hours of place & route.  The same
split, grown into a real explorer over the whole pass pipeline:

1. **Space** — every pass in ``PassManager.default_pipeline()`` exposes its
   tunable dimensions (fusion on/off, fold on/off, scan unroll, tile budget,
   CE chunk, microbatches, remat mode, precision, cached writes).
2. **Prune** — each candidate ``FlowConfig`` is scored with the analytic
   cost model in :mod:`repro.core.estimator`: roofline step time (rule 1,
   the bandwidth roof) and per-device HBM footprint vs the budget in
   ``FlowConfig.tuning.hbm_bytes`` (rule 3).  Tiles honour rule 2 (even
   division) by construction.
3. **Validate** — the top-k survivors compile-in-the-loop: our "place &
   route" is ``.lower().compile()`` + ``memory_analysis()`` — seconds per
   candidate instead of hours.

``explore()`` is deterministic: same (cfg, shape, base flow, devices) in,
same chosen plan out.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import warnings
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro import tunedb
from repro.configs.base import FlowConfig, ModelConfig, ShapeConfig, TuningConfig
from repro.obs import METRICS, TRACER
from repro.core import estimator

# default budget = TuningConfig's (v5e); override via FlowConfig.tuning
HBM_BYTES = TuningConfig().hbm_bytes


def per_device_bytes(mem) -> int:
    """Per-device footprint from a compiled module's ``memory_analysis()`` —
    the single definition the dry-run and the DSE validator both use."""
    return (mem.argument_size_in_bytes + mem.output_size_in_bytes +
            mem.temp_size_in_bytes - mem.alias_size_in_bytes)


# ---------------------------------------------------------------------------
# candidates
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Candidate:
    flow: FlowConfig
    knobs: Tuple[Tuple[str, Any], ...]   # the tunables this candidate sets
    footprint_bytes: float
    step_s: float
    bound: str                           # compute | memory
    fits: bool                           # rule 3: footprint < budget

    def knob_str(self) -> str:
        return " ".join(f"{k}={v}" for k, v in self.knobs)


@dataclass
class ExploreResult:
    best: Candidate
    plan: Any                            # ExecutionPlan of the chosen flow
    candidates: List[Candidate]          # estimator-ranked (pruned) list
    n_enumerated: int
    validated: List[Dict[str, Any]]      # compile-in-the-loop measurements
    budget_bytes: int
    n_rejected: int = 0                  # uneven-shard candidates screened out
    n_static_pruned: int = 0             # statically-invalid candidates the
                                         # verifier dropped before any compile
    n_measured: int = 0                  # validator invocations this search
                                         # actually paid (0 on a tunedb hit)
    tunedb_status: Optional[str] = None  # None (no db) | "hit" | "transfer"
                                         # | "cold"

    def describe(self) -> str:
        c = self.best
        lines = [
            f"dse[{self.plan.cfg.name} x {self.plan.shape.name}] "
            f"enumerated={self.n_enumerated} rejected={self.n_rejected} "
            f"static_pruned={self.n_static_pruned} "
            f"pruned_to={len(self.candidates)} "
            f"validated={len(self.validated)}"
            + (f" tunedb={self.tunedb_status} measured={self.n_measured}"
               if self.tunedb_status else ""),
            f"  budget: {self.budget_bytes / 2 ** 30:.1f} GiB/device",
            f"  best: {c.knob_str()}",
            f"  est: footprint={c.footprint_bytes / 2 ** 30:.3f} GiB "
            f"step={c.step_s * 1e3:.3f} ms ({c.bound}-bound) fits={c.fits}",
        ]
        for v in self.validated:
            extra = (f" step={v['measured_step_s'] * 1e3:.3f}ms"
                     if "measured_step_s" in v else "")
            lines.append(
                f"  measured[{v['knobs']}]: "
                f"{v['per_device_bytes'] / 2 ** 30:.3f} GiB/device "
                f"fits={v['fits']}{extra}")
        return "\n".join(lines)


def tunable_space(cfg: ModelConfig, flow: FlowConfig,
                  shape: ShapeConfig) -> Dict[str, Tuple[Any, ...]]:
    """The joint design space all passes expose for this cell."""
    from repro.core.passmanager import PassManager
    return PassManager.default_pipeline().tunable_space(cfg, flow, shape)


def enumerate_candidates(cfg: ModelConfig, shape: ShapeConfig,
                         base_flow: FlowConfig,
                         space: Optional[Dict[str, Sequence[Any]]] = None,
                         ) -> List[Tuple[FlowConfig, Tuple[Tuple[str, Any], ...]]]:
    """Cartesian product of the tunable space applied over ``base_flow``,
    in deterministic order (preferred/default value of each knob first)."""
    space = space if space is not None else tunable_space(cfg, base_flow, shape)
    keys = sorted(space)
    out = []
    cap = base_flow.tuning.max_candidates
    for combo in itertools.islice(
            itertools.product(*(space[k] for k in keys)), cap):
        knobs = tuple(zip(keys, combo))
        out.append((dataclasses.replace(base_flow, **dict(knobs)), knobs))
    return out


# ---------------------------------------------------------------------------
# compile-in-the-loop validation ("place & route" in seconds)
# ---------------------------------------------------------------------------

def abstract_inputs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one cell's batch (no allocation)."""
    import jax
    import jax.numpy as jnp
    B = shape.global_batch
    S = shape.seq_len if shape.kind != "decode" else 1
    sds = jax.ShapeDtypeStruct
    if cfg.family == "cnn":
        out = {"images": sds((B, cfg.image_size, cfg.image_size,
                              cfg.image_channels), jnp.float32)}
        if shape.kind == "train":
            out["labels"] = sds((B,), jnp.int32)
        return out
    out = {"tokens": sds((B, S), jnp.int32)}
    if shape.kind == "train":
        out["labels"] = sds((B, S), jnp.int32)
    if shape.kind != "decode":
        if cfg.n_patch_tokens:
            out["patches"] = sds((B, cfg.n_patch_tokens, cfg.d_vision),
                                 jnp.float32)
        if cfg.n_encoder_layers:
            out["frames"] = sds((B, cfg.encoder_seq, cfg.d_model),
                                jnp.float32)
    return out


def compile_candidate(cfg: ModelConfig, shape: ShapeConfig,
                      flow: FlowConfig) -> Dict[str, Any]:
    """Lower + compile one candidate on the current backend (no mesh, no
    allocation) and report its measured per-device footprint."""
    import jax
    import jax.numpy as jnp
    from repro.core import lowering
    from repro.core.plan import _build_plan
    plan = _build_plan(cfg, flow, shape)
    specs = abstract_inputs(cfg, shape)
    if shape.kind == "train":
        from repro.optim.adamw import AdamW
        from repro.train.trainer import make_train_step
        opt = AdamW()
        step = make_train_step(plan, opt, microbatches=flow.microbatches)
        pshapes = lowering.param_shapes(plan)
        ostate = jax.eval_shape(opt.init, pshapes)
        lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
            pshapes, ostate, specs)
    elif shape.kind == "decode":
        apply = lowering._make_apply(plan)
        pshapes = lowering.param_shapes(plan)
        state = lowering.init_state(plan, shape.global_batch, abstract=True)
        def fn(params, batch, state, idx):
            logits, new_state, _ = apply(params, batch, state=state,
                                         cache_index=idx, mode="decode")
            return logits, new_state
        lowered = jax.jit(fn, donate_argnums=(2,)).lower(
            pshapes, specs, state, jax.ShapeDtypeStruct((), jnp.int32))
    else:
        apply = lowering._make_apply(plan)
        pshapes = lowering.param_shapes(plan)
        fn = lambda p, b: apply(p, b, mode="prefill")[0]  # noqa: E731
        lowered = jax.jit(fn).lower(pshapes, specs)
    mem = lowered.compile().memory_analysis()
    return {"per_device_bytes": per_device_bytes(mem),
            "temp_bytes": mem.temp_size_in_bytes,
            "argument_bytes": mem.argument_size_in_bytes}


def compile_validator(cfg: ModelConfig,
                      shape: ShapeConfig) -> Callable[[FlowConfig], Dict]:
    """Validator for :func:`explore` backed by :func:`compile_candidate`."""
    return lambda flow: compile_candidate(cfg, shape, flow)


def measure_validator(cfg: ModelConfig, shape: ShapeConfig, *,
                      mesh=None, iters: int = 3
                      ) -> Callable[[FlowConfig], Dict]:
    """Measured-time validator (``repro.flow.compile(validate="measure")``):
    compiles each candidate into a CompiledModel and wall-clock-times its
    shape-appropriate stage via :meth:`CompiledModel.measure`.  The returned
    records carry ``measured_step_s``, so :func:`explore` (with
    ``rank_measured=True``) ranks the fitting survivors by real step time
    instead of compile stats alone."""
    def validate(flow: FlowConfig) -> Dict[str, Any]:
        from repro import flow as rflow
        m = mesh
        if m is None and flow.mesh_split is not None:
            # mesh-search mode: each candidate must be timed on the mesh it
            # proposes, not as an unsharded single-device executable
            from repro.distributed.meshspec import MeshSpec
            m = MeshSpec.of(flow.mesh_split).build()
        cm = rflow.compile(cfg, shape, flow, mesh=m)
        return cm.measure(iters=iters)
    return validate


# ---------------------------------------------------------------------------
# the explorer
# ---------------------------------------------------------------------------

# Completed searches keyed by (cfg, shape, flow, devices, platform, top_k,
# space) fingerprint — ``--autotune`` across serve/train/dryrun in one
# process pays for each identical search once (ROADMAP "explorer caching
# across cells").  Bounded LRU: one entry per cfg×shape×flow×mesh×space
# searched would otherwise grow without bound in a long-lived process.
_EXPLORE_CACHE: "OrderedDict[Tuple, ExploreResult]" = OrderedDict()
_EXPLORE_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}
_EXPLORE_CACHE_LIMIT = 64


def set_explore_cache_limit(n: int) -> None:
    """Bound the process-level explore cache to ``n`` results (LRU
    eviction; default 64).  ``n <= 0`` disables caching entirely."""
    global _EXPLORE_CACHE_LIMIT
    _EXPLORE_CACHE_LIMIT = int(n)
    while len(_EXPLORE_CACHE) > max(_EXPLORE_CACHE_LIMIT, 0):
        _EXPLORE_CACHE.popitem(last=False)
        _EXPLORE_CACHE_STATS["evictions"] += 1
        METRICS.counter("dse.cache.evictions").inc()


def _cache_get(fp_key: Tuple) -> Optional[ExploreResult]:
    hit = _EXPLORE_CACHE.get(fp_key)
    if hit is not None:
        _EXPLORE_CACHE.move_to_end(fp_key)
        _EXPLORE_CACHE_STATS["hits"] += 1
        METRICS.counter("dse.cache.hits").inc()
    else:
        _EXPLORE_CACHE_STATS["misses"] += 1
        METRICS.counter("dse.cache.misses").inc()
    return hit


def _cache_put(fp_key: Tuple, result: ExploreResult) -> None:
    if _EXPLORE_CACHE_LIMIT <= 0:
        return
    _EXPLORE_CACHE[fp_key] = result
    _EXPLORE_CACHE.move_to_end(fp_key)
    while len(_EXPLORE_CACHE) > _EXPLORE_CACHE_LIMIT:
        _EXPLORE_CACHE.popitem(last=False)
        _EXPLORE_CACHE_STATS["evictions"] += 1
        METRICS.counter("dse.cache.evictions").inc()


def _platform_key() -> str:
    """``"<backend>:<device kind>"`` of the default jax device.  Part of
    every fingerprint (in-process cache AND persisted tunedb records):
    flipping ``JAX_PLATFORMS`` (or CPU↔TPU in one process) must never serve
    a result measured on the other platform."""
    return tunedb.device_key()


def _explore_fingerprint(cfg: ModelConfig, shape: ShapeConfig,
                         flow: FlowConfig, devices: int,
                         top_k: Optional[int],
                         space: Optional[Dict[str, Sequence[Any]]],
                         validate_tag: str,
                         platform: Optional[str] = None) -> Tuple:
    space_key = None if space is None else tuple(
        sorted((k, tuple(v)) for k, v in space.items()))
    # cfg/shape/flow are frozen dataclasses (hashable); kernel_backend AND
    # the mesh topology (flow.mesh_split + tuning.mesh_devices, normalized
    # by explore() before fingerprinting) are part of flow, so a backend or
    # mesh change in-process misses the cache as required.  ``platform``
    # carries the jax backend *and* device kind — the device count alone
    # used to be keyed, so a JAX_PLATFORMS flip served stale results.
    # ``validate_tag`` ("none" | "compile" | "measure") keeps
    # estimator-only results from answering for validated searches and
    # compile-validated ones from answering for measured-time searches.
    platform = platform if platform is not None else _platform_key()
    return (cfg, shape, flow, devices, platform, top_k, space_key,
            validate_tag)


def explore_cache_stats() -> Dict[str, int]:
    return dict(_EXPLORE_CACHE_STATS)


def clear_explore_cache() -> None:
    _EXPLORE_CACHE.clear()
    _EXPLORE_CACHE_STATS.update(hits=0, misses=0, evictions=0)


# ---------------------------------------------------------------------------
# persistent tunedb integration (repro.tunedb)
# ---------------------------------------------------------------------------

def _explore_db_key(cfg: ModelConfig, shape: ShapeConfig, flow: FlowConfig,
                    devices: int, top_k: Optional[int],
                    space: Optional[Dict[str, Sequence[Any]]],
                    validate_tag: str, platform: str) -> Dict[str, Any]:
    """The structured (JSON-safe) twin of :func:`_explore_fingerprint` for
    persisted records — same facts, same poisoning fixes (platform/device
    kind included)."""
    space_enc = None if space is None else {
        k: tuple(v) for k, v in sorted(space.items())}
    return {"cfg": tunedb.config_facts(cfg),
            "shape": tunedb.shape_facts(shape),
            "flow": tunedb.flow_facts(flow),
            "devices": devices, "platform": platform, "top_k": top_k,
            "space": space_enc, "validate": validate_tag}


def _stale_record_warning(reason: str) -> None:
    """Surface a persisted record that no longer verifies against the
    current plan as a T601 diagnostic (warning severity: the search simply
    falls back to measuring) — the analysis-layer vocabulary for it."""
    from repro.analysis import Diagnostic, WARNING
    diag = Diagnostic("T601", WARNING, reason, where="tunedb")
    warnings.warn(diag.format(), stacklevel=3)


def _serve_exact_hit(rec, cfg: ModelConfig, shape: ShapeConfig,
                     flow0: FlowConfig, pool: List[Candidate]
                     ) -> Optional[Tuple[Candidate, List[Dict[str, Any]]]]:
    """Reconstruct (winner, validated) from an exact-fingerprint record
    without measuring anything.  Returns None — after a T601 warning — when
    the stored winner no longer verifies against the current plan space
    (knob vanished, plan now statically invalid, candidate no longer
    enumerated), in which case the caller re-measures."""
    try:
        knobs = tuple((k, v) for k, v in
                      tunedb.decode_value(rec.value["best_knobs"]))
        best_flow = dataclasses.replace(flow0, **dict(knobs))
    except (KeyError, TypeError, ValueError) as e:
        _stale_record_warning(
            f"record {rec.fingerprint[:12]} winner knobs no longer apply "
            f"to FlowConfig ({e}); re-measuring")
        return None
    best = next((c for c in pool if c.flow == best_flow), None)
    if best is None:
        _stale_record_warning(
            f"record {rec.fingerprint[:12]} winner "
            f"[{' '.join(f'{k}={v}' for k, v in knobs)}] is no longer an "
            "enumerated candidate of the current search space; re-measuring")
        return None
    from repro.analysis import verify_plan as _verify_plan
    from repro.core.plan import _build_plan as _bp
    result = _verify_plan(_bp(cfg, best.flow, shape))
    if not result.ok:
        _stale_record_warning(
            f"record {rec.fingerprint[:12]} winner plan fails static "
            f"verification under the current code "
            f"({result.summary_line()}); re-measuring")
        return None
    validated = [dict(v) for v in tunedb.decode_value(
        rec.value.get("validated", []))]
    return best, validated


def _transfer_anchor(pool: List[Candidate], neighbor) -> Dict[str, float]:
    """Per-knob anchor ratios from a neighboring record: the neighbor's
    *measured* step time over its *estimated* step time, keyed by knob
    string.  Multiplying this cell's estimates by the ratio re-anchors the
    estimator ranking with transferred measurements — before any compile."""
    est_nb = tunedb.decode_value(neighbor.value.get("est_by_knobs", {}))
    ratios: Dict[str, float] = {}
    for v in tunedb.decode_value(neighbor.value.get("validated", [])):
        ks = v.get("knobs")
        t = v.get("measured_step_s")
        e = est_nb.get(ks)
        if ks and t and e:
            ratios[ks] = float(t) / float(e)
    return ratios


def explore(cfg: ModelConfig, shape: ShapeConfig,
            base_flow: Optional[FlowConfig] = None, *,
            devices: int = 1,
            mesh: Optional[Any] = None,
            validator: Optional[Callable[[FlowConfig], Dict]] = None,
            space: Optional[Dict[str, Sequence[Any]]] = None,
            top_k: Optional[int] = None,
            rank_measured: bool = False,
            use_cache: bool = True,
            db: Any = None) -> ExploreResult:
    """Search the joint pass design space for the fastest candidate that
    fits the device budget.

    The mesh is a search dimension: with ``devices > 1`` (or ``mesh=``) the
    ShardingPass exposes every dp/tp/pp factorization of the device count as
    ``mesh_split`` candidates, and candidates whose splits would produce
    uneven shards are rejected before scoring (the paper's even-division
    rule, across devices).  An explicit ``mesh`` (MeshSpec / axis-size dict /
    jax Mesh) pins the factorization instead, like a pinned kernel backend.

    Before any scoring, the static verifier screens the space: candidates
    whose flow knobs hold values no pass or registry accepts (F501) are
    dropped, and each top-k survivor's *plan* is verified
    (:func:`repro.analysis.verify_plan`) before the validator pays a compile
    for it — both counted in ``ExploreResult.n_static_pruned``.

    Estimator scoring (roofline + footprint + the mesh's communication cost)
    prunes the full space; the top-k survivors are validated when a
    ``validator`` is given (see :func:`compile_validator` and
    :func:`measure_validator`; the multi-pod dry-run path passes a
    ``run_cell``-backed one).  With ``rank_measured=True`` every top-k
    survivor is validated and the fitting one with the smallest
    ``measured_step_s`` wins (measured-time ranking); otherwise the first
    fitting survivor wins.  Without a validator the estimator ranking
    decides alone.

    Identical searches (same cfg/shape/base-flow/devices/platform/
    mesh-topology fingerprint) are served from a bounded process-level LRU
    cache — including their recorded validations — so repeated
    ``--autotune`` invocations in one process don't redo the sweep.
    ``use_cache=False`` forces a fresh search.

    ``db`` (a :class:`repro.tunedb.TuneDB` or a path; defaults to
    ``flow0.tuning.tune_db``) adds the *persistent* layer: an
    exact-fingerprint record serves the winner with **zero** measurements,
    and when only a neighboring cell was tuned (same model/flow/device,
    different batch bucket or seq rung) its measurements re-anchor the
    estimator ranking so at most half the usual top-k survivors are
    compiled (``ExploreResult.tunedb_status`` / ``n_measured`` report the
    outcome).  Every validated search is written back to the store.
    """
    flow0 = base_flow if base_flow is not None else FlowConfig(mode="folded")
    if mesh is not None:
        from repro.distributed.meshspec import MeshSpec
        spec = MeshSpec.of(mesh)
        devices = spec.size
        if flow0.mesh_split is None:
            flow0 = dataclasses.replace(flow0, mesh_split=spec.axes)
    if devices > 1 and flow0.tuning.mesh_devices != devices:
        # the ShardingPass reads the device count off the tuning config to
        # enumerate mesh factorizations; folding it into the flow also folds
        # the topology into the cache fingerprint
        flow0 = dataclasses.replace(
            flow0, tuning=dataclasses.replace(flow0.tuning,
                                              mesh_devices=devices))
    validate_tag = "none" if validator is None else \
        ("measure" if rank_measured else "compile")
    platform = _platform_key()
    fp_key = _explore_fingerprint(cfg, shape, flow0, devices, top_k, space,
                                  validate_tag, platform)
    if use_cache:
        hit = _cache_get(fp_key)
        if hit is not None:
            return hit
    tdb = tunedb.open_db(db if db is not None else flow0.tuning.tune_db)
    db_key = db_fp = None
    if tdb is not None:
        db_key = _explore_db_key(cfg, shape, flow0, devices, top_k, space,
                                 validate_tag, platform)
        db_fp = tunedb.fingerprint(db_key)
    tuning = flow0.tuning
    budget = tuning.hbm_bytes
    k = top_k if top_k is not None else tuning.top_k

    from repro.analysis.rules import flow_knob_rejection
    from repro.core.passes.sharding import split_rejection_reason
    sp_enum = TRACER.timed("dse.enumerate", cat="dse", arch=cfg.name,
                           devices=devices)
    enumerated = enumerate_candidates(cfg, shape, flow0, space=space)
    sp_enum.end(n=len(enumerated))
    # static knob screen (F501): a flow holding a value no pass or registry
    # accepts would crash the builder or the compiler — drop it before any
    # plan is built.  Unlike the mesh screen this is never readmitted.
    n_static_pruned = 0
    statically_valid = []
    for flow, knobs in enumerated:
        if flow_knob_rejection(flow) is not None:
            n_static_pruned += 1
            continue
        statically_valid.append((flow, knobs))
    if not statically_valid and enumerated:
        reasons = sorted({r for r in (flow_knob_rejection(f)
                                      for f, _ in enumerated) if r})
        raise ValueError("explore: every candidate failed the static flow "
                         "screen: " + "; ".join(reasons))
    # the divisibility screen applies to *searched* splits only: a pinned
    # mesh (compile(mesh=...)) is a given — the solver simply leaves axes it
    # cannot use unsharded, exactly as the launch wiring always did
    searching = flow0.mesh_split is None
    survivors = []
    n_rejected = 0
    for flow, knobs in statically_valid:
        if searching and flow.mesh_split is not None and \
                split_rejection_reason(cfg, shape, flow, flow.mesh_split):
            n_rejected += 1            # uneven shards never survive pruning
            continue
        survivors.append((flow, knobs))
    if not survivors and statically_valid:
        # every split was screened out (e.g. a CNN whose batch doesn't cover
        # the device count).  The screen is advisory, not fatal: the solver
        # leaves axes it cannot use unsharded, so any split still compiles —
        # readmit everything and let the estimator ranking decide.
        survivors, n_rejected = statically_valid, 0
    cands: List[Candidate] = []
    sp_est = TRACER.timed("dse.estimate", cat="dse", n=len(survivors))
    for flow, knobs in survivors:
        fp = estimator.estimate_footprint(cfg, shape, flow, devices)
        st = estimator.estimate_step_seconds(cfg, shape, flow, devices)
        cands.append(Candidate(flow, knobs, fp["total"], st["step_s"],
                               st["bound"], fp["total"] < budget))
    sp_est.end()
    fitting = [c for c in cands if c.fits]
    # stable sorts: enumeration order (defaults first) breaks ties.  When
    # nothing fits analytically, footprint (closest to fitting) leads.
    if fitting:
        pool = sorted(fitting, key=lambda c: (c.step_s, c.footprint_bytes))
    else:
        pool = sorted(cands, key=lambda c: (c.footprint_bytes, c.step_s))
    top = pool[:max(k, 1)]

    validated: List[Dict[str, Any]] = []
    best = top[0]
    n_measured = 0
    tunedb_status: Optional[str] = None if tdb is None else "cold"
    served = None
    if tdb is not None:
        sp_db = TRACER.timed("tunedb.lookup", cat="tunedb", kind="explore")
        rec = tdb.get(db_fp)
        if rec is not None:
            served = _serve_exact_hit(rec, cfg, shape, flow0, pool)
        sp_db.end(hit=served is not None)
        if served is not None:
            # exact-fingerprint hit: the persisted winner and its recorded
            # measurements stand in for the whole validation phase — zero
            # candidates measured
            best, validated = served
            tunedb_status = "hit"
            METRICS.counter("tunedb.hits").inc()
        else:
            METRICS.counter("tunedb.misses").inc()
    if served is None and validator is not None:
        top_v = top
        if tdb is not None:
            # warm start: the nearest record that agrees on everything but
            # the shape cell (same op shapes via cfg, different batch
            # bucket / seq rung) re-anchors the estimator ranking with its
            # measured/estimated ratios; only the anchored best half of the
            # usual top-k then pays a compile
            match = {kk: vv for kk, vv in db_key.items() if kk != "shape"}

            def _dist(r) -> float:
                s = r.key.get("shape", {})
                return (abs(math.log2(max(int(s.get("global_batch", 1)), 1))
                            - math.log2(max(shape.global_batch, 1)))
                        + abs(math.log2(max(int(s.get("seq_len", 1)), 1))
                              - math.log2(max(shape.seq_len, 1))))

            nbs = tdb.neighbors("explore", match, exclude=db_fp,
                                distance=_dist)
            if nbs:
                ratios = _transfer_anchor(pool, nbs[0])
                anchored = [c for c in top if c.knob_str() in ratios]
                if anchored:
                    anchored.sort(key=lambda c:
                                  (c.step_s * ratios[c.knob_str()],
                                   c.footprint_bytes))
                    top_v = anchored[:max(1, len(top) // 2)]
                    tunedb_status = "transfer"
                    METRICS.counter("tunedb.transfers").inc()
        from repro.analysis import verify_plan as _verify_plan
        from repro.core.plan import _build_plan as _bp
        chosen = None
        chosen_t = float("inf")
        for c in top_v:
            # plan-level static gate: build (cheap, milliseconds) and verify
            # before paying a compile — an invalid plan never reaches the
            # validator
            if not _verify_plan(_bp(cfg, c.flow, shape)).ok:
                n_static_pruned += 1
                continue
            sp_val = TRACER.timed("dse.validate", cat="dse",
                                  knobs=c.knob_str())
            r = dict(validator(c.flow))
            sp_val.end()
            n_measured += 1
            r["knobs"] = c.knob_str()
            r["fits"] = bool(r["per_device_bytes"] < budget)
            validated.append(r)
            if not r["fits"]:
                continue
            if rank_measured:
                t = float(r.get("measured_step_s", float("inf")))
                if t < chosen_t:
                    chosen, chosen_t = c, t
                continue           # measured ranking needs every survivor
            chosen = c
            break                  # first fitting candidate wins; don't pay
                                   # further compiles for report decoration
        best = chosen if chosen is not None else top_v[0] if top_v else top[0]
    if served is None and tdb is not None:
        # bank this search: the winner's knobs, every recorded measurement,
        # and the estimator's predictions for the validated set (the anchor
        # a neighboring bucket's warm start divides by)
        tdb.put(tunedb.TuneRecord.make(
            "explore", db_key,
            {"best_knobs": best.knobs,
             "validated": validated,
             "est_by_knobs": {c.knob_str(): c.step_s for c in top},
             "n_enumerated": len(enumerated),
             "winner_step_s": best.step_s},
            device=platform))

    from repro.core.plan import _build_plan
    plan = _build_plan(cfg, best.flow, shape)
    result = ExploreResult(best=best, plan=plan, candidates=pool,
                           n_enumerated=len(enumerated), validated=validated,
                           budget_bytes=budget, n_rejected=n_rejected,
                           n_static_pruned=n_static_pruned,
                           n_measured=n_measured, tunedb_status=tunedb_status)
    if use_cache:
        _cache_put(fp_key, result)
    return result


# ---------------------------------------------------------------------------
# mesh-level train-cell autotune (the original DSE entry point, kept for the
# dry-run driver; now budget-aware via FlowConfig.tuning)
# ---------------------------------------------------------------------------

def autotune_train_cell(arch: str, shape_name: str, mesh, base_flow,
                        candidates: Optional[Tuple[int, ...]] = None,
                        hbm_bytes: Optional[int] = None):
    """Returns (flow, result) for the first microbatch count whose measured
    per-device footprint fits the configured HBM budget."""
    from repro.launch.dryrun import run_cell
    budget = hbm_bytes if hbm_bytes is not None else base_flow.tuning.hbm_bytes
    cands = candidates if candidates is not None else \
        base_flow.tuning.microbatch_candidates
    last = None
    for mb in cands:
        flow = dataclasses.replace(base_flow, microbatches=mb)
        r = run_cell(arch, shape_name, mesh=mesh, flow=flow)
        r["autotuned_microbatches"] = mb
        last = (flow, r)
        if r["memory"]["per_device_bytes"] < budget:
            return flow, r
    return last
