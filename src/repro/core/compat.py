"""Version-compatibility shims for the JAX APIs this repo spans."""
from __future__ import annotations

import jax


def shard_map(body, mesh, in_specs, out_specs, *, axis_names):
    """jax.shard_map with a fallback for the pre-0.6 experimental API
    (manual axes are the complement of ``auto`` there; replication checking
    is ``check_rep`` instead of ``check_vma``).

    Fallback caveats (pre-0.6): the region runs with every mesh axis manual
    and unchecked replication, and its transpose mis-tracks *scalar*
    residuals/outputs — keep values crossing the region boundary rank >= 1
    (see distributed/pipeline_parallel.py).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=axis_names,
                             check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)
