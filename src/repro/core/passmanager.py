"""PassManager — the compilation flow's pass pipeline as a first-class,
pluggable subsystem.

The paper's flow applies a fixed sequence of optimizations (LF fusion, PK
folding, LU/LT tiling, OF precision, CW caching, CH/CE streaming); here each
one is a :class:`Pass` with a uniform protocol:

* ``name`` / ``paper``   — identity and the paper-section tag,
* ``applies_to``         — whether the pass participates for this
  (cfg, flow, shape) cell (a skipped pass is recorded in the trace),
* ``run(ctx)``           — reads/writes the shared :class:`PlanContext`,
  reporting its stats into ``ctx.stats[name]``,
* ``tunable_space``      — the pass's contribution to the design space the
  explorer (:mod:`repro.core.dse`) searches: a dict mapping ``FlowConfig``
  field names to candidate values.

:class:`PassManager` threads a :class:`PlanContext` through the registered
passes with per-pass wall-clock timing and a trace, then assembles the
:class:`~repro.core.plan.ExecutionPlan`.  ``build_plan`` is a thin wrapper
over :meth:`PassManager.default_pipeline`; custom pipelines (extra passes,
replaced passes, reordered passes) are built by constructing a manager with
any sequence of passes.
"""
from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.configs.base import FlowConfig, ModelConfig, ShapeConfig
from repro.core.graph import Graph
from repro.obs import TRACER


@dataclass
class PlanContext:
    """Mutable state threaded between passes: the graph under rewrite plus
    the artifacts each pass deposits for the final ExecutionPlan."""
    cfg: ModelConfig
    flow: FlowConfig
    shape: ShapeConfig
    mesh_axes: Tuple[str, ...] = ()
    rules: Any = None
    graph: Optional[Graph] = None          # set by GraphBuildPass
    input_graph: Optional[Graph] = None    # caller-provided graph (optional)
    artifacts: Dict[str, Any] = field(default_factory=dict)
    stats: Dict[str, Dict[str, Any]] = field(default_factory=dict)
    timings_ms: Dict[str, float] = field(default_factory=dict)
    trace: List[str] = field(default_factory=list)


class Pass:
    """Base class of all compilation passes (the uniform pass protocol)."""

    name: str = "?"
    paper: str = ""                        # paper-section tag, e.g. "LF §IV-C"
    # dataflow contract over PlanContext artifacts ("graph" stands for
    # ctx.graph): which keys run() consumes and which it deposits.  The
    # static verifier (repro.analysis.verify_pipeline) orders-checks these
    # (P101 reader-before-writer, P102 required-artifact-never-written).
    reads: Tuple[str, ...] = ()
    writes: Tuple[str, ...] = ()

    def applies_to(self, cfg: ModelConfig, flow: FlowConfig,
                   shape: ShapeConfig) -> bool:
        return True

    def run(self, ctx: PlanContext) -> None:
        raise NotImplementedError

    def tunable_space(self, cfg: ModelConfig, flow: FlowConfig,
                      shape: ShapeConfig) -> Dict[str, Tuple[Any, ...]]:
        """FlowConfig field -> candidate values this pass exposes to the DSE."""
        return {}

    def __repr__(self) -> str:
        return f"<{type(self).__name__} {self.name!r}>"


class GraphBuildPass(Pass):
    """Materialize the layer-graph IR the rest of the pipeline rewrites.

    A caller-provided graph is deep-copied (fusion mutates in place); without
    one the graph builder runs on the model config."""

    name = "graph"
    paper = "IR build (Relay analogue)"
    writes = ("graph",)

    def run(self, ctx: PlanContext) -> None:
        if ctx.input_graph is not None:
            ctx.graph = copy.deepcopy(ctx.input_graph)
        else:
            from repro.models.lm import build_graph
            ctx.graph = build_graph(ctx.cfg)
        ctx.stats[self.name] = {
            "applied": True,
            "blocks": len(ctx.graph.blocks),
            "ops": sum(len(b.ops) for b in ctx.graph.blocks),
            "params": ctx.graph.param_count(),
        }


class PassManager:
    """Runs a sequence of passes over a PlanContext and assembles the plan."""

    def __init__(self, passes: Sequence[Pass]):
        names = [p.name for p in passes]
        if len(names) != len(set(names)):
            raise ValueError(f"duplicate pass names: {names}")
        self.passes: List[Pass] = list(passes)

    # -- construction -------------------------------------------------------
    @classmethod
    def default_pipeline(cls) -> "PassManager":
        """The paper's pipeline: graph -> LF fusion -> CH/CE streaming ->
        PK folding -> LU/LT tiling -> OF precision -> CW caching."""
        from repro.core.passes import default_passes
        return cls(default_passes())

    def replaced(self, pass_: Pass) -> "PassManager":
        """A new manager with the same-named pass swapped out."""
        return PassManager([pass_ if p.name == pass_.name else p
                            for p in self.passes])

    # -- execution ----------------------------------------------------------
    def run_context(self, cfg: ModelConfig, flow: FlowConfig,
                    shape: ShapeConfig, mesh_axes: Tuple[str, ...] = (),
                    rules=None, graph: Optional[Graph] = None) -> PlanContext:
        ctx = PlanContext(cfg=cfg, flow=flow, shape=shape,
                          mesh_axes=tuple(mesh_axes), rules=rules,
                          input_graph=graph)
        for p in self.passes:
            if not p.applies_to(cfg, flow, shape):
                ctx.stats[p.name] = {"applied": False}
                ctx.trace.append(f"skip {p.name}")
                continue
            sp = TRACER.timed(f"pass.{p.name}", cat="pass", paper=p.paper)
            p.run(ctx)
            sp.end()
            dt_ms = sp.elapsed_ms
            ctx.timings_ms[p.name] = round(dt_ms, 3)
            ctx.stats.setdefault(p.name, {}).setdefault("applied", True)
            ctx.trace.append(f"run {p.name} [{p.paper}] {dt_ms:.2f}ms")
        return ctx

    def run(self, cfg: ModelConfig, flow: FlowConfig, shape: ShapeConfig,
            mesh_axes: Tuple[str, ...] = (), rules=None,
            graph: Optional[Graph] = None):
        """Run the pipeline and assemble an ExecutionPlan."""
        from repro.core.plan import ExecutionPlan
        ctx = self.run_context(cfg, flow, shape, mesh_axes, rules, graph)
        missing = [k for k in ("units", "tiles", "stream", "prec", "cache")
                   if k not in ctx.artifacts]
        if missing:
            raise ValueError(
                f"pipeline {[p.name for p in self.passes]} did not produce "
                f"required artifacts: {missing}")
        return ExecutionPlan(
            cfg, flow, shape, ctx.graph, ctx.artifacts["units"],
            ctx.artifacts["tiles"], ctx.artifacts["stream"],
            ctx.artifacts["prec"], ctx.artifacts["cache"], rules,
            sharding=ctx.artifacts.get("sharding"),
            kernels=ctx.artifacts.get("kernels", {}),
            pass_stats=ctx.stats, pass_timings_ms=ctx.timings_ms,
            trace=ctx.trace)

    # -- design space --------------------------------------------------------
    def tunable_space(self, cfg: ModelConfig, flow: FlowConfig,
                      shape: ShapeConfig) -> Dict[str, Tuple[Any, ...]]:
        """Union of the passes' tunable spaces (explorer input).  Every pass
        contributes regardless of ``applies_to`` — the explorer must be able
        to turn a currently-off pass *on* (each pass gates its own dims on
        cfg/shape applicability instead)."""
        space: Dict[str, Tuple[Any, ...]] = {}
        for p in self.passes:
            for key, vals in p.tunable_space(cfg, flow, shape).items():
                if key in space:
                    raise ValueError(
                        f"pass {p.name!r} re-declares tunable {key!r}")
                space[key] = tuple(vals)
        return space
