"""CW — cached-writes pass (paper §IV-D).

On the FPGA, accumulations were moved from DDR read-modify-write into local
registers with a final copy-out stage.  On the TPU the kernel analogue is the
fp32 VMEM scratch accumulator in the fused matmul/conv kernels: partial sums
live in VMEM across the K grid dimension and HBM is written exactly once at
the last K step.  This pass records that policy for the kernel layer and for
the estimator's HBM-byte model; with ``cached_writes`` off the kernels use
the naive read-modify-write schedule (one HBM round-trip per K step) — the
paper's base behaviour.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class CachingPlan:
    vmem_accumulate: bool      # accumulate in VMEM scratch (True = CW on)
    donate_state: bool = True  # donate KV/optimizer buffers (in-place update)


def run(flow) -> CachingPlan:
    return CachingPlan(vmem_accumulate=flow.cached_writes)
