"""CW — cached-writes pass (paper §IV-D).

On the FPGA, accumulations were moved from DDR read-modify-write into local
registers with a final copy-out stage.  On the TPU the kernel analogue is the
fp32 VMEM scratch accumulator in the fused matmul/conv kernels: partial sums
live in VMEM across the K grid dimension and HBM is written exactly once at
the last K step.  This pass records that policy for the kernel layer and for
the estimator's HBM-byte model; with ``cached_writes`` off the kernels use
the naive read-modify-write schedule (one HBM round-trip per K step) — the
paper's base behaviour.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.passmanager import Pass, PlanContext


@dataclass(frozen=True)
class CachingPlan:
    vmem_accumulate: bool      # accumulate in VMEM scratch (True = CW on)
    donate_state: bool = True  # donate KV/optimizer buffers (in-place update)


def run(flow) -> CachingPlan:
    return CachingPlan(vmem_accumulate=flow.cached_writes)


class CachingPass(Pass):
    name = "caching"
    paper = "CW §IV-D"
    reads = ("graph",)
    writes = ("cache",)

    def run(self, ctx: PlanContext) -> None:
        cp = run(ctx.flow)
        ctx.artifacts["cache"] = cp
        ctx.stats[self.name] = {"applied": True,
                                "vmem_accumulate": cp.vmem_accumulate,
                                "donate_state": cp.donate_state,
                                "remat": ctx.flow.remat}

    def tunable_space(self, cfg, flow, shape):
        space = {"cached_writes": (True, False)}
        if shape.kind == "train":
            # remat is the training-side memory-for-compute cache policy
            space["remat"] = ("block", "nested", "none")
        return space
