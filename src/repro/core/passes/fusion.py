"""LF — loop-fusion pass (paper §IV-C) + CW epilogue placement.

Peephole-rewrites each block's micro-op list:

* ``matmul → bias_add``            ⇒ matmul(bias=True)
* ``matmul → act``                 ⇒ matmul(act=k)
* ``act(matmul_a(x)) * matmul_b(x)`` ⇒ ``glu_matmul`` (gated-linear pair)
* ``add(resid, matmul(...))``      ⇒ matmul(residual=True)
* ``conv2d → batchnorm [→ act]``   ⇒ conv2d(bn=True, act=k)   (inference only)

On the FPGA these fusions removed the temporary array between the convolution
and the activation loop (and its LSUs); here they decide the *epilogue* of the
fused Pallas kernel so activations never round-trip HBM, and shrink the HLO
the reference path emits.
"""
from __future__ import annotations

from typing import List

from repro.core.graph import Block, Graph, MicroOp
from repro.core.passmanager import Pass, PlanContext


def _fuse_block(b: Block, fold_bn: bool) -> None:
    changed = True
    while changed:
        changed = False
        ops = b.ops
        for i, op in enumerate(ops):
            nxt = ops[i + 1] if i + 1 < len(ops) else None
            # matmul/glu_matmul + bias_add
            if (nxt and op.op in ("matmul", "conv2d", "depthwise_conv2d")
                    and nxt.op == "bias_add" and nxt.ins == (op.out,)
                    and not op.attrs.get("bias")):
                if op.op == "matmul":
                    op.params = op.params + nxt.params
                    op.attrs["bias"] = True
                    op.out = nxt.out
                    del ops[i + 1]
                    changed = True
                    break
            # (fused-)matmul/conv + act
            if (nxt and op.op in ("matmul", "glu_matmul", "conv2d",
                                  "depthwise_conv2d")
                    and nxt.op == "act" and nxt.ins == (op.out,)
                    and not op.attrs.get("act")
                    and not op.attrs.get("residual")):
                op.attrs["act"] = nxt.attrs["kind"]
                op.out = nxt.out
                del ops[i + 1]
                changed = True
                break
            # GLU pair:  g=mm_a(x); ga=act(g) folded above; u=mm_b(x); mul(ga,u)
            if op.op == "mul" and i >= 2:
                a, bop = ops[i - 2], ops[i - 1]
                if (a.op == "matmul" and bop.op == "matmul"
                        and a.attrs.get("act") and not bop.attrs.get("act")
                        and a.ins == bop.ins
                        and set(op.ins) == {a.out, bop.out}
                        and not a.attrs.get("bias") and not bop.attrs.get("bias")):
                    fused = MicroOp(op.out, "glu_matmul", a.ins,
                                    a.params + bop.params,
                                    {"act": a.attrs["act"]})
                    ops[i - 2:i + 1] = [fused]
                    changed = True
                    break
            # residual add into the producing matmul
            if (op.op == "add" and i >= 1 and ops[i - 1].op in
                    ("matmul", "glu_matmul")
                    and ops[i - 1].out in op.ins
                    and not ops[i - 1].attrs.get("residual")):
                prod = ops[i - 1]
                other = op.ins[0] if op.ins[1] == prod.out else op.ins[1]
                prod.attrs["residual"] = True
                prod.ins = prod.ins + (other,)
                prod.out = op.out
                del ops[i]
                changed = True
                break
            # conv2d + batchnorm (+act): inference-time BN folding
            if (fold_bn and nxt and op.op in ("conv2d", "depthwise_conv2d")
                    and nxt.op == "batchnorm" and nxt.ins == (op.out,)
                    and not op.attrs.get("bn")):
                op.params = op.params + nxt.params
                op.attrs["bn"] = True
                op.out = nxt.out
                del ops[i + 1]
                changed = True
                break


def run(graph: Graph, *, fold_bn: bool) -> Graph:
    for b in graph.blocks:
        _fuse_block(b, fold_bn)
    return graph


class FusionPass(Pass):
    name = "fusion"
    paper = "LF §IV-C"
    reads = ("graph",)
    writes = ("graph",)

    def applies_to(self, cfg, flow, shape) -> bool:
        return flow.fuse_epilogues

    def run(self, ctx: PlanContext) -> None:
        before = sum(len(b.ops) for b in ctx.graph.blocks)
        run(ctx.graph, fold_bn=ctx.shape.kind != "train")
        after = sum(len(b.ops) for b in ctx.graph.blocks)
        epilogues = {"act": 0, "bias": 0, "residual": 0, "bn": 0, "glu": 0}
        for b in ctx.graph.blocks:
            for op in b.ops:
                for k in ("act", "bias", "residual", "bn"):
                    if op.attrs.get(k):
                        epilogues[k] += 1
                if op.op == "glu_matmul":
                    epilogues["glu"] += 1
        ctx.stats[self.name] = {"applied": True, "ops_before": before,
                                "ops_after": after,
                                "ops_removed": before - after,
                                "epilogues": epilogues}

    def tunable_space(self, cfg, flow, shape):
        return {"fuse_epilogues": (True, False)}
