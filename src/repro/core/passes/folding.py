"""PK — parameterized-kernel / folding pass (paper §IV-H).

Groups consecutive isomorphic blocks (equal structural signatures, including
repeating super-block patterns such as RecurrentGemma's (rec, rec, attn))
into scan units: one compiled body re-used across layers — the TPU analogue
of one parameterized OpenCL kernel executing many layers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

from repro.core.graph import Graph, iso_groups
from repro.core.passmanager import Pass, PlanContext


@dataclass(frozen=True)
class Unit:
    """One execution unit: either a single block or a folded scan group."""
    indices: tuple            # block indices in graph order
    period: int = 1           # super-block size (blocks per scan step)

    @property
    def folded(self) -> bool:
        return len(self.indices) > self.period

    @property
    def reps(self) -> int:
        return len(self.indices) // self.period


def run(graph: Graph, *, enabled: bool, min_reps: int = 2) -> List[Unit]:
    foldable = [i for i, b in enumerate(graph.blocks)
                if b.kind in ("layer", "encoder_layer", "decoder_layer",
                              "cnn_block")]
    units: List[Unit] = []
    i = 0
    n = len(graph.blocks)
    while i < n:
        if not enabled or i not in foldable:
            units.append(Unit((i,)))
            i += 1
            continue
        # find the contiguous foldable run starting here
        j = i
        while j < n and j in foldable:
            j += 1
        run_blocks = graph.blocks[i:j]
        for g, period in iso_groups(run_blocks):
            idxs = tuple(i + k for k in g)
            if len(idxs) // period >= min_reps and len(idxs) % period == 0:
                units.append(Unit(idxs, period))
            else:
                for k in idxs:
                    units.append(Unit((k,)))
        i = j
    return units


class FoldingPass(Pass):
    name = "folding"
    paper = "PK §IV-H"
    reads = ("graph", "stream")
    writes = ("units",)

    def run(self, ctx: PlanContext) -> None:
        stream = ctx.artifacts["stream"]      # runs after StreamingPass
        enabled = ctx.flow.fold_layers and stream.mode == "folded"
        units = run(ctx.graph, enabled=enabled)
        ctx.artifacts["units"] = units
        folded = [u for u in units if u.folded]
        ctx.stats[self.name] = {
            "applied": True, "enabled": enabled, "n_units": len(units),
            "n_folded": len(folded),
            "folded_blocks": sum(len(u.indices) for u in folded),
            "groups": [(u.reps, u.period) for u in folded],
        }

    def tunable_space(self, cfg, flow, shape):
        space = {"fold_layers": (True, False)}
        if shape.kind == "train":
            space["scan_unroll"] = flow.tuning.scan_unroll_candidates
        return space
