"""OF — optimized float operations (paper §IV-I).

``-fp-relaxed``/``-fpc`` let the AOC compiler reassociate float ops and fuse
multiply-accumulates.  The TPU analogue: bf16 storage/compute feeding the MXU
with fp32 accumulation (``preferred_element_type``), and bf16 parameters for
serving.  The base configuration is straight fp32 — the unfused, unrelaxed
float pipeline of the base kernels.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.core.passmanager import Pass, PlanContext


@dataclass(frozen=True)
class PrecisionPlan:
    compute_dtype: object
    param_dtype: object
    accum_dtype: object = jnp.float32


def run(flow, shape) -> PrecisionPlan:
    if flow.precision == "bf16":
        # serving keeps bf16 weights; training keeps fp32 masters, bf16 compute
        pdt = jnp.bfloat16 if shape.kind != "train" else jnp.float32
        return PrecisionPlan(jnp.bfloat16, pdt)
    return PrecisionPlan(jnp.float32, jnp.float32)


class PrecisionPass(Pass):
    name = "precision"
    paper = "OF §IV-I"
    reads = ("graph",)
    writes = ("prec",)

    def run(self, ctx: PlanContext) -> None:
        prec = run(ctx.flow, ctx.shape)
        ctx.artifacts["prec"] = prec
        ctx.stats[self.name] = {
            "applied": True,
            "compute": jnp.dtype(prec.compute_dtype).name,
            "param": jnp.dtype(prec.param_dtype).name,
            "accum": jnp.dtype(prec.accum_dtype).name,
        }

    def tunable_space(self, cfg, flow, shape):
        return {"precision": ("bf16", "fp32")}
