"""OF — optimized float operations (paper §IV-I).

``-fp-relaxed``/``-fpc`` let the AOC compiler reassociate float ops and fuse
multiply-accumulates.  The TPU analogue: bf16 storage/compute feeding the MXU
with fp32 accumulation (``preferred_element_type``), and bf16 parameters for
serving.  The base configuration is straight fp32 — the unfused, unrelaxed
float pipeline of the base kernels.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class PrecisionPlan:
    compute_dtype: object
    param_dtype: object
    accum_dtype: object = jnp.float32


def run(flow, shape) -> PrecisionPlan:
    if flow.precision == "bf16":
        # serving keeps bf16 weights; training keeps fp32 masters, bf16 compute
        pdt = jnp.bfloat16 if shape.kind != "train" else jnp.float32
        return PrecisionPlan(jnp.bfloat16, pdt)
    return PrecisionPlan(jnp.float32, jnp.float32)
