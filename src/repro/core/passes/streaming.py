"""CH/AR/CE — streaming / execution-mode pass (paper §IV-E/F/G).

Decides between the paper's two execution modes and the host-side (here:
launcher-side) concurrency knobs:

* **pipelined** — every layer materialized as its own program section
  (unrolled), activations streamed between them; on a multi-pod mesh the
  layers are additionally assigned to pipeline *stages* over the ``pp_axis``
  with microbatched ``ppermute`` streaming (OpenCL channels ↔ ICI links;
  channel depth ↔ in-flight microbatches).  Viable for small networks, just
  as on the FPGA.
* **folded** — isomorphic groups are scanned (PK), the default for deep nets.

AR (autorun) has no separate artifact: every step is a single jitted,
donated-state program, host-free by construction; the decode loop runs
on-device.  CE (concurrent execution) corresponds to compute/collective
overlap, which the launcher enables via XLA latency-hiding flags recorded
here.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.core.passmanager import Pass, PlanContext


PIPELINE_PARAM_LIMIT = 100_000_000   # "fits on chip unrolled" heuristic


@dataclass(frozen=True)
class StreamPlan:
    mode: str                        # folded | pipelined
    pp_axis: Optional[str]
    n_stages: int
    microbatches: int
    stage_boundaries: Tuple[int, ...]   # block index where each stage starts
    xla_overlap_flags: Tuple[str, ...] = (
        "--xla_tpu_enable_data_parallel_all_reduce_opt=true",
        "--xla_tpu_overlap_compute_collective_tc=true",
        "--xla_enable_async_all_gather=true",
        "--xla_enable_async_collective_permute=true",
    )


def run(graph, cfg, flow, mesh_axes: Tuple[str, ...] = ()) -> StreamPlan:
    if flow.mode == "auto":
        small = graph.param_count() < PIPELINE_PARAM_LIMIT or cfg.n_layers <= 8
        mode = "pipelined" if small else "folded"
    else:
        mode = flow.mode
    split = dict(flow.mesh_split) if flow.mesh_split else {}
    known_axes = set(mesh_axes) | set(split)
    pp = flow.pp_axis if flow.pp_axis in known_axes else None
    n_stages = 1
    boundaries: Tuple[int, ...] = (0,)
    if pp is not None:
        # split layer blocks evenly over the pp axis (stage per pod); the
        # stage count comes from the flow's mesh factorization when known
        n_stages = split.get(pp, 2)
        layer_idx = [i for i, b in enumerate(graph.blocks)
                     if b.kind.endswith("layer") or b.kind == "cnn_block"]
        per = max(1, len(layer_idx) // n_stages)
        boundaries = tuple(layer_idx[min(i * per, len(layer_idx) - 1)]
                           for i in range(n_stages)) if layer_idx else (0,)
    mb = max(flow.microbatches, n_stages if pp else flow.microbatches)
    return StreamPlan(mode, pp, n_stages, mb, boundaries)


class StreamingPass(Pass):
    name = "streaming"
    paper = "CH/AR/CE §IV-E–G"
    reads = ("graph",)
    writes = ("stream",)

    def run(self, ctx: PlanContext) -> None:
        sp = run(ctx.graph, ctx.cfg, ctx.flow, ctx.mesh_axes)
        ctx.artifacts["stream"] = sp
        ctx.stats[self.name] = {"applied": True, "mode": sp.mode,
                                "n_stages": sp.n_stages,
                                "microbatches": sp.microbatches,
                                "pp_axis": sp.pp_axis}

    def tunable_space(self, cfg, flow, shape):
        if shape.kind != "train":
            return {}
        # gradient-accumulation microbatches trade activation transients
        # against one extra round of weight gathers per microbatch
        return {"microbatches": flow.tuning.microbatch_candidates}
