"""Kernel-backend selection pass (multi-backend lowering, DNNVM-style).

Resolves the flow's ``kernel_backend`` policy (``auto`` | ``reference`` |
``pallas`` | ``pallas_interpret``) against the :class:`KernelRegistry` into a
per-op backend table, recorded on the plan (``plan.kernels``) so lowering
dispatches through it, ``plan.describe()`` reports it, and the DSE can
search over it as a tunable dimension.
"""
from __future__ import annotations

from repro.core.passmanager import Pass, PlanContext


class KernelSelectPass(Pass):
    name = "kernels"
    paper = "backend selection (multi-backend lowering)"
    writes = ("kernels",)

    def run(self, ctx: PlanContext) -> None:
        from repro.kernels.registry import REGISTRY
        table = REGISTRY.resolve_all(ctx.flow.kernel_backend)
        ctx.artifacts["kernels"] = table
        accel = sorted(op for op, b in table.items() if b != "ref")
        ctx.stats[self.name] = {
            "applied": True,
            "backend": ctx.flow.kernel_backend,
            "pallas_ops": accel,
            "ref_ops": sum(1 for b in table.values() if b == "ref"),
        }

    def tunable_space(self, cfg, flow, shape):
        # an explicitly pinned backend is a user constraint, not a search
        # dimension — only the default "auto" policy is explorable (so e.g.
        # compile(backend="reference", autotune=True) keeps the pin)
        if flow.kernel_backend != "auto":
            return {"kernel_backend": (flow.kernel_backend,)}
        return {"kernel_backend": flow.tuning.backend_candidates}
