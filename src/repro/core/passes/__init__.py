from repro.core.passes import (  # noqa: F401
    backends, caching, folding, fusion, precision, sharding, streaming,
    tiling)


def default_passes():
    """The default pipeline's pass instances, in execution order."""
    from repro.core.passmanager import GraphBuildPass
    return [GraphBuildPass(), fusion.FusionPass(), streaming.StreamingPass(),
            folding.FoldingPass(), sharding.ShardingPass(),
            tiling.TilingPass(), precision.PrecisionPass(),
            caching.CachingPass(), backends.KernelSelectPass()]
