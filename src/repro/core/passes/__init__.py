from repro.core.passes import caching, folding, fusion, precision, streaming, tiling  # noqa: F401
