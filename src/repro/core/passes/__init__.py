from repro.core.passes import (  # noqa: F401
    backends, caching, folding, fusion, precision, streaming, tiling)


def default_passes():
    """The default pipeline's pass instances, in execution order."""
    from repro.core.passmanager import GraphBuildPass
    return [GraphBuildPass(), fusion.FusionPass(), streaming.StreamingPass(),
            folding.FoldingPass(), tiling.TilingPass(),
            precision.PrecisionPass(), caching.CachingPass(),
            backends.KernelSelectPass()]
