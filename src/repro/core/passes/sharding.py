"""Sharding pass — partitioning as a compilation decision (paper §IV-J,
grown across devices).

The paper's factor selection chooses hardware parallelism factors per layer;
on a multi-device system the dominant factors are the mesh axes the model is
split over (dp / tp / pp).  This pass makes that a *plan* decision instead of
launch wiring: it consumes the flow's mesh factorization
(``FlowConfig.mesh_split``, normally set by ``repro.flow.compile(mesh=...)``
or by the DSE), runs the divisibility-aware solver over every parameter of
the (post-folding) plan, assigns pipeline stages when a pp axis is present,
and records the result as a :class:`ShardingPlan` on the ``ExecutionPlan``
(``plan.sharding``, shown in ``plan.describe()``).

The runtime (:mod:`repro.distributed.sharding`'s ``ShardingRules``) binds
these recorded decisions to a live ``jax.Mesh``; the solver itself lives
here so the explorer can search mesh factorizations without touching a
device.

Solver policy (moved from ``distributed/sharding.py``):

* **tp ("model")** — d_ff (Megatron column/row FFN), vocab (embedding/head),
  expert (EP, when num_experts divides the axis), heads (storage sharding of
  attention projections; compute-level attention parallelism is context
  parallelism over the sequence, which works for every head count).
* **fsdp (dp axes)** — the largest remaining divisible dim (d_model first):
  ZeRO-3-style parameter + optimizer-state sharding; XLA inserts the
  all-gathers at use and reduce-scatters the gradients.

Every assignment checks divisibility — jit rejects uneven shards — and never
uses a mesh axis twice in one spec.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from jax.sharding import PartitionSpec as P

from repro.core.passmanager import Pass, PlanContext
from repro.distributed.meshspec import MeshSpec

# role -> priority order for the tp axis (first divisible wins).
# "heads_in" is deliberately absent: the attention out-projection stays
# row-local (its input is already sequence-sharded by context parallelism).
TP_ROLES = ("expert", "d_ff", "vocab", "heads")
# role -> priority for fsdp
FSDP_ROLES = ("d_model", "heads", "heads_in", "d_ff", "vocab", "expert",
              "layers")

ACT_ROLE_AXES = {
    "batch": "__dp__",
    "seq_cp": "__tp__",      # context-parallel sequence sharding
    "kv_len": "__tp__",      # decode: KV cache length over tp
    "vocab": "__tp__",
    "d_ff": "__tp__",
    "expert": "__tp__",
    "heads": "__tp__",
    "gather": None,          # force replication (KV all-gather)
    "none": None,
    "seq": None,
}


# ---------------------------------------------------------------------------
# pure solver (no jax.Mesh, no devices)
# ---------------------------------------------------------------------------

def _entry_size(entry, axis_sizes: Dict[str, int]) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for a in entry:
            n *= axis_sizes.get(a, 1)
        return n
    return axis_sizes.get(entry, 1)


def solve_param_pspec(roles: Tuple[str, ...], shape: Tuple[int, ...],
                      dp_axes: Tuple[str, ...], tp_axis: Optional[str],
                      axis_sizes: Dict[str, int]) -> P:
    """The divisibility-aware role -> mesh-axis assignment for one param."""
    assert len(roles) == len(shape), (roles, shape)
    entries: list = [None] * len(roles)
    tp_size = axis_sizes.get(tp_axis, 1) if tp_axis else 1
    dp_size = 1
    for a in dp_axes:
        dp_size *= axis_sizes.get(a, 1)
    used_tp = tp_axis is None
    for want in TP_ROLES:
        if used_tp:
            break
        for i, r in enumerate(roles):
            if r == want and shape[i] % tp_size == 0:
                entries[i] = tp_axis
                used_tp = True
                break
    dp_ent = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    if dp_ent is not None:
        for want in FSDP_ROLES:
            done = False
            for i, r in enumerate(roles):
                if (r == want and entries[i] is None
                        and shape[i] % dp_size == 0):
                    entries[i] = dp_ent
                    done = True
                    break
            if done:
                break
    return P(*entries)


def solve_act_pspec(roles: Tuple[str, ...], shape: Tuple[int, ...],
                    dp_axes: Tuple[str, ...], tp_axis: Optional[str],
                    axis_sizes: Dict[str, int]) -> P:
    """Role -> mesh-axis assignment for one activation/state tensor."""
    entries = []
    used: set = set()
    dp_ent = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    for i, r in enumerate(roles):
        ax = ACT_ROLE_AXES.get(r)
        if ax == "__dp__":
            ent, flat = dp_ent, dp_axes
        elif ax == "__tp__":
            ent, flat = tp_axis, (tp_axis,) if tp_axis else ()
        else:
            ent, flat = None, ()
        if ent is not None and (set(flat) & used
                                or shape[i] % _entry_size(ent, axis_sizes)
                                != 0):
            ent, flat = None, ()
        used |= set(flat)
        entries.append(ent)
    return P(*entries)


# ---------------------------------------------------------------------------
# the recorded decision
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShardingPlan:
    """Partitioning decisions recorded on the ExecutionPlan: the mesh
    factorization, the axis roles, every parameter's PartitionSpec, and the
    pipeline-stage assignment.  ``distributed.sharding.ShardingRules`` binds
    these to a live mesh; ``plan.describe()`` reports them."""
    mesh: MeshSpec
    dp_axes: Tuple[str, ...]
    tp_axis: Optional[str]
    pp_axis: Optional[str]
    # flat "<unit key>/<param key>" -> PartitionSpec for every param leaf
    param_specs: Dict[str, P] = field(default_factory=dict)
    n_stages: int = 1
    stage_of_layer: Tuple[int, ...] = ()   # stage per folded-unit layer (rep)

    @property
    def axis_sizes(self) -> Dict[str, int]:
        return self.mesh.shape

    @property
    def dp_size(self) -> int:
        n = 1
        for a in self.dp_axes:
            n *= self.mesh.axis_size(a)
        return n

    @property
    def tp_size(self) -> int:
        return self.mesh.axis_size(self.tp_axis) if self.tp_axis else 1

    @property
    def pp_size(self) -> int:
        return self.mesh.axis_size(self.pp_axis) if self.pp_axis else 1

    def param_pspec(self, key: str) -> Optional[P]:
        return self.param_specs.get(key)

    def act_pspec(self, roles: Tuple[str, ...], shape: Tuple[int, ...]) -> P:
        return solve_act_pspec(roles, shape, self.dp_axes, self.tp_axis,
                               self.axis_sizes)

    def spec_counts(self) -> Dict[str, int]:
        """tp- / fsdp- / replicated param-tensor counts (describe line)."""
        tp = fsdp = repl = 0
        dp_flat = set(self.dp_axes)
        for ps in self.param_specs.values():
            axes: set = set()
            for e in ps:
                if e is None:
                    continue
                axes |= set(e) if isinstance(e, tuple) else {e}
            if self.tp_axis in axes:
                tp += 1
            elif axes & dp_flat:
                fsdp += 1
            else:
                repl += 1
        return {"tp": tp, "fsdp": fsdp, "repl": repl}

    def describe_line(self) -> str:
        c = self.spec_counts()
        tp = f"{self.tp_axis}:{self.tp_size}" if self.tp_axis else "-"
        pp = (f"{self.pp_axis}:{self.n_stages}" if self.pp_axis
              and self.n_stages > 1 else "-")
        dp = "+".join(self.dp_axes) + f":{self.dp_size}" if self.dp_axes \
            else "-"
        line = (f"  sharding: mesh={{{self.mesh.describe()}}} dp={dp} "
                f"tp={tp} pp={pp} "
                f"params[tp={c['tp']} fsdp={c['fsdp']} repl={c['repl']}]")
        if self.n_stages > 1:
            per = len(self.stage_of_layer) // self.n_stages
            line += f" stages={self.n_stages}x{per}L"
        return line


# ---------------------------------------------------------------------------
# DSE dimensions: mesh factorizations + viability (uneven-shard rejection)
# ---------------------------------------------------------------------------

def enumerate_mesh_splits(devices: int, *, dp_axis: str = "data",
                          tp_axis: Optional[str] = "model",
                          pp_axis: Optional[str] = None,
                          ) -> Tuple[Tuple[Tuple[str, int], ...], ...]:
    """All dp/tp(/pp) factorizations of ``devices`` over the flow's own axis
    names, deterministic order: pure data parallelism (the default) first,
    then decreasing dp.  The tp/pp dimensions are enumerated only when the
    flow names those axes."""
    out: List[Tuple[Tuple[str, int], ...]] = []
    pps = [p for p in range(1, devices + 1) if devices % p == 0] \
        if pp_axis else [1]
    for pp in pps:
        rest = devices // pp
        dps = sorted((d for d in range(1, rest + 1) if rest % d == 0),
                     reverse=True) if tp_axis else [rest]
        for dp in dps:
            split: Tuple[Tuple[str, int], ...] = ()
            if pp > 1:
                split += ((pp_axis, pp),)
            split += ((dp_axis, dp),)
            if tp_axis:
                split += ((tp_axis, rest // dp),)
            out.append(split)
    return tuple(out)


def split_roles(flow, split: Tuple[Tuple[str, int], ...]
                ) -> Tuple[Tuple[str, ...], Optional[str], Optional[str]]:
    """(dp_axes, tp_axis, pp_axis) of a mesh split under the flow's axis-role
    convention.  A size-1 tp/pp axis degenerates to None; every other axis
    carries data parallelism (matching the launcher's historical wiring)."""
    sizes = dict(split)
    tp = flow.tp_axis if sizes.get(flow.tp_axis, 0) > 1 else None
    pp = flow.pp_axis if sizes.get(flow.pp_axis, 0) > 1 else None
    dp = tuple(a for a, _ in split if a not in (tp, pp))
    return dp, tp, pp


def split_rejection_reason(cfg, shape, flow,
                           split: Tuple[Tuple[str, int], ...]
                           ) -> Optional[str]:
    """Divisibility screen (the paper's rule 2, across devices): returns the
    rejection reason (truthy => reject), or None when the split yields even
    shards.  Used by the explorer to prune *searched* candidates before
    estimator scoring; pinned meshes bypass it.

    The rule itself lives in :mod:`repro.analysis.rules` (shared with the
    static verifier's M401/M402/M403 diagnostics); this is the
    string-returning legacy surface."""
    from repro.analysis.rules import mesh_split_rejection
    hit = mesh_split_rejection(cfg, shape, flow, split)
    return hit[1] if hit is not None else None


# ---------------------------------------------------------------------------
# the pass
# ---------------------------------------------------------------------------

class ShardingPass(Pass):
    name = "sharding"
    paper = "partitioning (§IV-J factors across the mesh)"
    reads = ("graph", "units")
    writes = ("sharding",)

    def _split_for(self, ctx: PlanContext
                   ) -> Optional[Tuple[Tuple[str, int], ...]]:
        if ctx.flow.mesh_split is not None:
            return ctx.flow.mesh_split
        if ctx.rules is not None:           # legacy path: rules built first
            m = ctx.rules.mesh
            return tuple((a, int(m.shape[a])) for a in m.axis_names)
        return None

    def run(self, ctx: PlanContext) -> None:
        split = self._split_for(ctx)
        if split is None:
            ctx.stats[self.name] = {"applied": False}
            return
        spec = MeshSpec.of(split)
        dp_axes, tp_axis, pp_axis = split_roles(ctx.flow, split)
        axis_sizes = spec.shape
        graph, units = ctx.graph, ctx.artifacts["units"]

        # key format is lowering's param-pytree layout: "<unit key>/<leaf>"
        # with folded leaves "<j>:<name>" (see lowering.param_shapes)
        from repro.core.lowering import unit_key
        param_specs: Dict[str, P] = {}
        for unit in units:
            ukey = unit_key(graph, unit)
            if not unit.folded:
                b = graph.blocks[unit.indices[0]]
                for s in b.param_specs():
                    param_specs[f"{ukey}/{s.name}"] = solve_param_pspec(
                        s.roles, s.shape, dp_axes, tp_axis, axis_sizes)
            else:
                for j in range(unit.period):
                    proto = graph.blocks[unit.indices[j]]
                    for s in proto.param_specs():
                        param_specs[f"{ukey}/{j}:{s.name}"] = \
                            solve_param_pspec(
                                ("layers",) + s.roles,
                                (unit.reps,) + s.shape,
                                dp_axes, tp_axis, axis_sizes)

        # pipeline-stage assignment: contiguous equal runs of the single
        # folded layer group over the pp axis (the GPipe layout
        # distributed/pipeline_parallel.py executes)
        n_stages, stage_of_layer = 1, ()
        note = None
        if pp_axis is not None:
            folded = [u for u in units if u.folded]
            pp = axis_sizes[pp_axis]
            if len(folded) == 1 and folded[0].reps % pp == 0:
                reps = folded[0].reps
                n_stages = pp
                per = reps // pp
                stage_of_layer = tuple(r // per for r in range(reps))
            else:
                note = "pp_unassigned: needs one folded group with reps % pp == 0"
                pp_axis = None

        sp = ShardingPlan(mesh=spec, dp_axes=dp_axes, tp_axis=tp_axis,
                          pp_axis=pp_axis, param_specs=param_specs,
                          n_stages=n_stages, stage_of_layer=stage_of_layer)
        ctx.artifacts["sharding"] = sp
        counts = sp.spec_counts()
        st: Dict[str, Any] = {
            "applied": True,
            "mesh": spec.describe(),
            "dp": sp.dp_size, "tp": sp.tp_size, "pp": sp.n_stages,
            "params_tp": counts["tp"], "params_fsdp": counts["fsdp"],
            "params_repl": counts["repl"],
        }
        if note:
            st["note"] = note
        ctx.stats[self.name] = st

    def tunable_space(self, cfg, flow, shape):
        # an explicit mesh (compile(mesh=...)) is a user constraint — pinned,
        # like a pinned kernel backend.  Otherwise the pass exposes every
        # dp/tp/pp factorization of the explorer's device count.
        if flow.mesh_split is not None:
            return {"mesh_split": (flow.mesh_split,)}
        n = flow.tuning.mesh_devices
        if n and n > 1:
            return {"mesh_split": enumerate_mesh_splits(
                n, dp_axis=flow.dp_axes[0] if flow.dp_axes else "data",
                tp_axis=flow.tp_axis, pp_axis=flow.pp_axis)}
        return {}
