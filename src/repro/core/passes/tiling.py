"""LU/LT — unroll / strip-mine / tile selection (paper §IV-A/B/J).

On the FPGA the unroll factor widened LSUs and replicated DSPs, bounded by
(1) the memory-bandwidth roof, (2) even division of loop counts, and (3) the
resource budget.  On the TPU the same three rules pick Pallas ``BlockSpec``
block shapes:

1. *MXU alignment* — matmul tile dims are multiples of 128 (the systolic
   array edge), elementwise tiles multiples of (8, 128) (VPU lanes).
2. *even division* — block dims divide the (padded) problem dims, so no
   prologue/epilogue grid steps are generated.
3. *VMEM budget* — the working set (x-tile + w-tile + fp32 accumulator +
   epilogue operands) must fit the per-core VMEM allowance.

The selector maximizes arithmetic intensity (prefer large N,M tiles; deep K
streaming) subject to those constraints — the analogue of "unroll as wide as
the bandwidth roof allows".
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

from repro.core.passmanager import Pass, PlanContext


def _fit(n: int, target: int, align: int) -> int:
    """Largest multiple of ``align`` that divides n and is <= target; when no
    aligned divisor exists, the largest divisor of n <= target (rule 2: even
    division — no prologue/epilogue grid steps).  n itself is returned when
    n < align (kernel pads internally)."""
    if n <= align:
        return n
    t = min(target, n)
    for cand in range(t - t % align, 0, -align):
        if n % cand == 0:
            return cand
    for cand in range(t, 0, -1):
        if n % cand == 0:
            return cand
    return n


def select_matmul_tile(m: int, k: int, n: int, *, vmem: int,
                       bytes_in: int = 2) -> Tuple[int, int, int]:
    """(bm, bk, bn) for the fused-matmul kernel."""
    bm = _fit(m, 512, 128) if m >= 128 else m
    bn = _fit(n, 512, 128)
    bk = _fit(k, 2048, 128)
    # shrink until x(bm,bk) + w(bk,bn) + acc(bm,bn)*4 + out fits
    def ws(bm, bk, bn):
        return (bm * bk + bk * bn) * bytes_in + bm * bn * (4 + bytes_in)
    order = ["bk", "bn", "bm"]
    vals = {"bm": bm, "bk": bk, "bn": bn}
    oi = 0
    while ws(vals["bm"], vals["bk"], vals["bn"]) > vmem and oi < 64:
        dim = order[oi % 3]
        if vals[dim] > 128:
            vals[dim] = _fit(vals[dim] // 2 * 2, vals[dim] // 2, 128)
        oi += 1
    return vals["bm"], vals["bk"], vals["bn"]


def select_attention_tile(seq_q: int, seq_k: int, head_dim: int, *,
                          vmem: int) -> Tuple[int, int]:
    """(block_q, block_k) for the flash-attention kernel."""
    bq = _fit(seq_q, 512, 128) if seq_q >= 128 else seq_q
    bk = _fit(seq_k, 1024, 128) if seq_k >= 128 else seq_k
    def ws(bq, bk):
        # q, k, v tiles + fp32 scores + fp32 acc
        return (bq + 2 * bk) * head_dim * 2 + bq * bk * 4 + bq * head_dim * 4
    while ws(bq, bk) > vmem and (bq > 128 or bk > 128):
        if bk >= bq and bk > 128:
            bk = _fit(seq_k, bk // 2, 128)
        elif bq > 128:
            bq = _fit(seq_q, bq // 2, 128)
        else:
            break
    return bq, bk


#: Every key the tile table may carry — the valid targets of
#: ``FlowConfig.tile_overrides`` (the flow-knob screen rejects others).
TILE_KEYS = ("matmul", "attention", "decode_attention", "conv2d",
             "wkv_chunk", "ce_chunk")


def apply_overrides(tiles: Dict[str, object], flow) -> Dict[str, str]:
    """Apply ``flow.tile_overrides`` on top of the selector's tile table
    (in place).  Overrides are the per-kernel tunables the tunedb records
    and the serving autotune's tile microbench pins; an override for a key
    this cell does not produce (e.g. ``attention`` on a pure CNN) is
    ignored rather than invented — the kernel it targets never runs here.
    Returns the applied subset for the pass stats."""
    applied: Dict[str, str] = {}
    for key, tile in (flow.tile_overrides or ()):
        if key in tiles:
            tiles[key] = tuple(tile) if isinstance(tile, (list, tuple)) \
                else tile
            applied[key] = str(tiles[key])
    return applied


def run(cfg, shape, flow) -> Dict[str, object]:
    """Produce the plan's tile table.  With ``tile_select`` off (the paper's
    base configuration) everything falls back to minimal 128 tiles — the
    analogue of the unparallelized base kernels.  ``flow.tile_overrides``
    (tuned per-kernel schedules) are applied on top in both modes."""
    vmem = flow.vmem_budget_bytes // 4   # conservative per-kernel allowance
    tiles: Dict[str, object] = {}
    if not flow.tile_select:
        tiles["matmul"] = (128, 128, 128)
        tiles["attention"] = (128, 128)
        tiles["decode_attention"] = 512
        tiles["conv2d"] = (8, 128)
        tiles["wkv_chunk"] = 16
        tiles["ce_chunk"] = flow.ce_chunk
        apply_overrides(tiles, flow)
        return tiles
    d, f = cfg.d_model, cfg.d_ff
    seq = shape.seq_len if shape.kind != "decode" else 1
    m = max(seq, 8)
    tiles["matmul"] = select_matmul_tile(m, d, f, vmem=vmem)
    if cfg.attention is not None:
        skv = shape.seq_len
        tiles["attention"] = select_attention_tile(
            max(seq, 8), skv, cfg.attention.head_dim, vmem=vmem)
        tiles["decode_attention"] = max(512, _fit(skv, 2048, 512))
    tiles["conv2d"] = (8, 128)
    tiles["wkv_chunk"] = 32
    tiles["ce_chunk"] = flow.ce_chunk
    apply_overrides(tiles, flow)
    return tiles


class TilingPass(Pass):
    name = "tiling"
    paper = "LU/LT §IV-A/B/J"
    reads = ("graph",)
    writes = ("tiles",)

    def run(self, ctx: PlanContext) -> None:
        tiles = run(ctx.cfg, ctx.shape, ctx.flow)
        ctx.artifacts["tiles"] = tiles
        stats = {"applied": True, "selected": ctx.flow.tile_select,
                 "tiles": dict(tiles)}
        bm, bk, bn = tiles["matmul"]
        stats["matmul_workingset_bytes"] = (bm * bk + bk * bn) * 2 + bm * bn * 6
        ctx.stats[self.name] = stats

    def tunable_space(self, cfg, flow, shape):
        space = {"tile_select": (True, False),
                 "vmem_budget_bytes": flow.tuning.vmem_candidates}
        if shape.kind == "train":
            space["ce_chunk"] = flow.tuning.ce_chunk_candidates
        return space
