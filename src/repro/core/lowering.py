"""Lowering: ExecutionPlan × Graph → executable JAX functions.

* ``init_params``  — parameter pytree (folded groups pre-stacked for scan)
* ``init_state``   — serving state (KV caches / recurrence states), stacked
* ``make_apply``   — apply(params, batch, state, cache_index, mode)
* ``make_loss_fn`` — training loss with sequence-chunked cross-entropy (the
  LM-head analogue of the paper's loop fusion: logits never materialize)

Folded units (the paper's parameterized kernels) lower to ``lax.scan`` over
stacked per-layer parameters and state; unfolded units lower to straight-line
code (the pipelined mode's one-section-per-layer).
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.graph import Block, Graph, MicroOp, ParamSpec
from repro.core.ops_impl import OPS, Ctx
from repro.core.plan import ExecutionPlan
from repro.core.passes.folding import Unit

AUX_KEYS = ("moe_aux",)


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------

def _init_one(key, spec: ParamSpec, dtype):
    shape = spec.shape
    if spec.init == "zeros":
        return jnp.zeros(shape, dtype)
    if spec.init == "ones":
        return jnp.ones(shape, dtype)
    if spec.init == "lru_lambda":
        u = jax.random.uniform(key, shape, jnp.float32, 0.9, 0.999)
        r = u ** (1.0 / 8.0)
        return jnp.log(r / (1 - r)).astype(dtype)
    if spec.init == "rwkv_mix":
        return jax.random.uniform(key, shape, jnp.float32).astype(dtype)
    if spec.init == "rwkv_decay":
        n = shape[-1]
        base = -6.0 + 5.0 * (jnp.arange(n) / max(n - 1, 1)) ** 0.9
        return jnp.broadcast_to(base, shape).astype(dtype)
    if spec.init == "embed":
        scale = spec.init_scale or shape[-1] ** -0.5
        return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
    # default: normal with 1/sqrt(fan_in)
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = spec.init_scale or fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _fold_key(graph: Graph, unit: Unit) -> str:
    return f"fold_{graph.blocks[unit.indices[0]].name}"


def unit_key(graph: Graph, unit: Unit) -> str:
    if unit.folded:
        return _fold_key(graph, unit)
    return graph.blocks[unit.indices[0]].name


def init_params(plan: ExecutionPlan, rng) -> Dict[str, Any]:
    graph, dtype = plan.graph, plan.prec.param_dtype
    params: Dict[str, Any] = {}
    for unit in plan.units:
        if not unit.folded:
            b = graph.blocks[unit.indices[0]]
            bp = {}
            for spec in b.param_specs():
                k = jax.random.fold_in(rng, _stable_hash(b.name + spec.name))
                bp[spec.name] = _init_one(k, spec, dtype)
            if bp:
                params[b.name] = bp
        else:
            period, reps = unit.period, unit.reps
            gp: Dict[str, Any] = {}
            for j in range(period):
                proto = graph.blocks[unit.indices[j]]
                for spec in proto.param_specs():
                    slices = []
                    for r in range(reps):
                        blk = graph.blocks[unit.indices[r * period + j]]
                        k = jax.random.fold_in(
                            rng, _stable_hash(blk.name + spec.name))
                        slices.append(_init_one(k, spec, dtype))
                    gp[f"{j}:{spec.name}"] = jnp.stack(slices)
            params[_fold_key(graph, unit)] = gp
    return params


def _stable_hash(s: str) -> int:
    h = 2166136261
    for ch in s.encode():
        h = (h ^ ch) * 16777619 % (1 << 31)
    return h


def param_shapes(plan: ExecutionPlan) -> Dict[str, Any]:
    """ShapeDtypeStructs of the parameter pytree (no allocation) — used by
    the dry-run and the sharding solver."""
    graph, dtype = plan.graph, plan.prec.param_dtype
    out: Dict[str, Any] = {}
    for unit in plan.units:
        if not unit.folded:
            b = graph.blocks[unit.indices[0]]
            bp = {s.name: jax.ShapeDtypeStruct(s.shape, dtype)
                  for s in b.param_specs()}
            if bp:
                out[b.name] = bp
        else:
            gp = {}
            for j in range(unit.period):
                proto = graph.blocks[unit.indices[j]]
                for s in proto.param_specs():
                    gp[f"{j}:{s.name}"] = jax.ShapeDtypeStruct(
                        (unit.reps,) + s.shape, dtype)
            out[_fold_key(graph, unit)] = gp
    return out


def param_specs_tree(plan: ExecutionPlan) -> Dict[str, Any]:
    """Same structure as params, holding (ParamSpec, stacked: bool)."""
    graph = plan.graph
    out: Dict[str, Any] = {}
    for unit in plan.units:
        if not unit.folded:
            b = graph.blocks[unit.indices[0]]
            bp = {s.name: (s, False) for s in b.param_specs()}
            if bp:
                out[b.name] = bp
        else:
            gp = {}
            for j in range(unit.period):
                proto = graph.blocks[unit.indices[j]]
                for s in proto.param_specs():
                    gp[f"{j}:{s.name}"] = (s, True)
            out[_fold_key(graph, unit)] = gp
    return out


# ---------------------------------------------------------------------------
# Serving state
# ---------------------------------------------------------------------------

def _op_state_shapes(op: MicroOp, cfg, B: int, C: int, dtype):
    """Returns {suffix: (shape, dtype, roles)} for one stateful op.  The
    roles drive the sharding solver (KV length over tp; batch over dp;
    recurrence heads/width over tp)."""
    a = op.attrs
    if op.op == "attention":
        att = cfg.attention
        KV, Dh = att.n_kv_heads, att.head_dim
        if a.get("cross"):
            S = cfg.encoder_seq
            r = ("batch", "none", "none", "none")
            return {"k": ((B, S, KV, Dh), dtype, r),
                    "v": ((B, S, KV, Dh), dtype, r)}
        r = ("batch", "kv_len", "none", "none")
        return {"k": ((B, C, KV, Dh), dtype, r),
                "v": ((B, C, KV, Dh), dtype, r),
                "pos": ((B, C), jnp.int32, ("batch", "kv_len"))}
    if op.op == "conv1d_causal":
        kw, w = op.params[0].shape
        return {"": ((B, kw - 1, w), dtype, ("batch", "none", "d_ff"))}
    if op.op == "rg_lru":
        w = op.params[0].shape[0]
        return {"": ((B, w), dtype, ("batch", "d_ff"))}
    if op.op == "rwkv6_timemix":
        d = [s for s in op.params if s.name.endswith("w_r")][0].shape[0]
        H, dh = a["n_heads"], a["head_dim"]
        return {"_shift": ((B, d), dtype, ("batch", "none")),
                "_s": ((B, H, dh, dh), dtype,
                       ("batch", "heads", "none", "none"))}
    if op.op == "rwkv6_channelmix":
        d = [s for s in op.params if s.name.endswith("cw_r")][0].shape[0]
        return {"_shift": ((B, d), dtype, ("batch", "none"))}
    return {}


def _mk_state(shapes: Dict[str, tuple], lead: Tuple[int, ...] = (),
              abstract: bool = False, roles: bool = False):
    out = {}
    for suf, (shp, dt, rl) in shapes.items():
        full = lead + shp
        if roles:
            out[suf] = ("layers",) * len(lead) + rl
        elif abstract:
            out[suf] = jax.ShapeDtypeStruct(full, dt)
        elif dt == jnp.int32:
            out[suf] = jnp.full(full, -1, dt)
        else:
            out[suf] = jnp.zeros(full, dt)
    return out


def init_state(plan: ExecutionPlan, batch_size: int, abstract: bool = False,
               roles: bool = False):
    """Serving state pytree, stacked to match the folded units.  With
    ``roles=True`` returns the matching tree of per-dim role tuples (for the
    sharding solver)."""
    graph, cfg = plan.graph, plan.cfg
    dtype = plan.prec.compute_dtype
    C = plan.cache_len
    state: Dict[str, Any] = {}
    for unit in plan.units:
        ukey = unit_key(graph, unit)
        ust: Dict[str, Any] = {}
        def add(op, lead):
            shapes = _op_state_shapes(op, cfg, batch_size, C, dtype)
            made = _mk_state(shapes, lead, abstract, roles)
            key = op.attrs["state_key"]
            if op.op == "attention":      # attention state is a nested dict
                ust[key] = made
            else:
                for suf, v in made.items():
                    ust[key + suf] = v

        if not unit.folded:
            for op in graph.blocks[unit.indices[0]].stateful_ops():
                add(op, ())
        else:
            for j in range(unit.period):
                for op in graph.blocks[unit.indices[j]].stateful_ops():
                    add(op, (unit.reps,))
        if ust:
            state[ukey] = ust
    return state


def state_shardings(plan: ExecutionPlan, batch_size: int, rules):
    """NamedSharding tree for the serving state (role-driven)."""
    import jax.sharding as js
    abs_tree = init_state(plan, batch_size, abstract=True)
    role_tree = init_state(plan, batch_size, roles=True)
    def one(a, r):
        return js.NamedSharding(rules.mesh, rules.act_pspec(r, a.shape))
    return jax.tree.map(one, abs_tree, role_tree,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


# ---------------------------------------------------------------------------
# Block interpretation (with per-mode dead-code elimination)
# ---------------------------------------------------------------------------

def _used_ins(op: MicroOp, mode: str) -> Tuple[str, ...]:
    if op.op == "attention" and op.attrs.get("cross") and mode == "decode":
        return (op.ins[0], op.ins[3])       # q, positions (K/V come from cache)
    return op.ins


def live_ops(block: Block, mode: str) -> List[MicroOp]:
    keep = [False] * len(block.ops)
    live = {"h"}
    for i in range(len(block.ops) - 1, -1, -1):
        op = block.ops[i]
        needed = op.out in live
        if op.attrs.get("state_key") and mode in ("prefill", "decode"):
            needed = True
        if needed:
            keep[i] = True
            live.discard(op.out)
            live.update(_used_ins(op, mode))
    return [op for i, op in enumerate(block.ops) if keep[i]]


def _param_slice(op: MicroOp, bparams: Dict[str, Any], j: Optional[int]):
    """Dict param-name → array for one op (handles folded 'j:' prefixes)."""
    out = {}
    for spec in op.params:
        key = spec.name if j is None else f"{j}:{spec.name}"
        out[spec.name] = bparams[key]
    return out


def _run_block(ctx: Ctx, block: Block, bparams, env: Dict[str, Any],
               mode: str, j: Optional[int] = None,
               tied_tables: Optional[Dict[str, Any]] = None):
    for op in live_ops(block, mode):
        args = [env[i] for i in _used_ins(op, mode)]
        if op.op == "attention" and len(args) == 2:    # decode cross-attn
            q, pos = args
            args = [q, q, q, pos]                       # K/V placeholders
        p = _param_slice(op, bparams, j)
        if op.op == "unembed" and op.attrs.get("tied"):
            args.append(tied_tables[op.attrs["tied"]])
        env[op.out] = OPS[op.op](ctx, op, p, *args)
    return env["h"]


# ---------------------------------------------------------------------------
# apply()
# ---------------------------------------------------------------------------

def make_apply(plan: ExecutionPlan, head: bool = True):
    """Deprecated shim over :func:`_make_apply` — reach the apply function
    through :func:`repro.flow.compile` (``CompiledModel.apply``) instead."""
    from repro.core.plan import _warn_deprecated
    _warn_deprecated("repro.core.lowering.make_apply")
    return _make_apply(plan, head=head)


def _make_apply(plan: ExecutionPlan, head: bool = True):
    """Returns apply(params, batch, state, cache_index, mode) ->
    (out, new_state, aux).  ``head=False`` stops before the unembed (training
    uses the chunked-CE loss instead)."""
    graph, cfg = plan.graph, plan.cfg
    units = plan.units
    rules = plan.rules

    def constrain(x, roles):
        if rules is None:
            return x
        return rules.constrain_act(x, roles)

    def apply(params, batch, state=None, cache_index=None, mode="train"):
        ctx = Ctx(mode=mode, plan=plan, cache_index=cache_index)
        ctx.constrain = constrain
        ctx.aux["__inputs__"] = batch
        new_state: Dict[str, Any] = {}

        if "tokens" in batch:
            h = batch["tokens"]
        else:
            h = batch["images"]
        B = h.shape[0]

        def pos_for(x, encoder=False):
            # positions for the *current* chain (encoder/decoder lengths differ)
            if x.ndim == 4:                    # images
                return None
            S = x.shape[1]
            # explicit per-row positions (serving: left-padded bucketed
            # prefill, heterogeneous decode positions with the paged cache).
            # Decoder chains only — encoder chains always keep their arange.
            p = None if encoder else batch.get("positions")
            if p is not None and p.ndim == 2 and p.shape[1] == S:
                return p.astype(jnp.int32)
            if mode == "decode":
                return jnp.broadcast_to(cache_index, (B, S)).astype(jnp.int32)
            return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        tied_tables = {}
        for unit in units:
            b0 = graph.blocks[unit.indices[0]]
            for spec in b0.param_specs():
                if spec.name == "table":
                    tied_tables[f"{b0.name}/table"] = params[b0.name]["table"]

        def cst_h(x):
            return ctx.cst(x, ("batch",) + ("none",) * (x.ndim - 1))

        cross = None
        h = cst_h(h)
        for unit in units:
            ukey = unit_key(graph, unit)
            b0 = graph.blocks[unit.indices[0]]
            if mode == "decode" and (b0.kind.startswith("enc")
                                     or b0.kind == "mm"):
                continue   # prompt-only blocks: patches/frames live in caches
            if b0.kind == "dec_embed":
                h = batch["tokens"]
            if b0.kind == "head":
                if not head:
                    break
                if mode == "prefill":
                    h = h[:, -1:]
            env = {"h": h, "cross": cross,
                   "positions": pos_for(
                       h, encoder=b0.kind.startswith("enc"))}
            if not unit.folded:
                ctx.state_in = (state or {}).get(ukey, {})
                ctx.state_out = {}
                h = _run_block(ctx, b0, params.get(ukey, {}), env, mode,
                               tied_tables=tied_tables)
                if ctx.state_out:
                    new_state[ukey] = ctx.state_out
            else:
                h, st = _run_folded(ctx, plan, unit, params[ukey],
                                    (state or {}).get(ukey), env, mode)
                if st:
                    new_state[ukey] = st
            if b0.attrs.get("captures_cross"):
                cross = h
            h = cst_h(h)
        aux = {k: v for k, v in ctx.aux.items() if k != "__inputs__"}
        return h, new_state, aux

    return apply


def _run_folded(ctx: Ctx, plan: ExecutionPlan, unit: Unit, gparams,
                gstate, env, mode: str):
    graph = plan.graph
    period = unit.period
    protos = [graph.blocks[unit.indices[j]] for j in range(period)]
    positions, cross = env["positions"], env["cross"]
    outer = ctx

    def body(carry, xs):
        h, aux = carry
        step_params, step_state = xs
        c = Ctx(mode=mode, plan=plan, cache_index=outer.cache_index)
        c.constrain = outer.constrain
        c.aux = dict(outer.aux)
        c.aux.update(aux)
        c.state_in = step_state or {}
        c.state_out = {}
        e = {"h": h, "positions": positions, "cross": cross}
        for j, blk in enumerate(protos):
            e["h"] = _run_block(c, blk, step_params, e, mode, j=j)
            e["h"] = c.cst(e["h"], ("batch",) + ("none",) * (e["h"].ndim - 1))
        aux2 = {k: jnp.asarray(c.aux.get(k, 0.0), jnp.float32)
                for k in AUX_KEYS}
        return (e["h"], aux2), c.state_out

    aux0 = {k: jnp.asarray(outer.aux.get(k, 0.0), jnp.float32)
            for k in AUX_KEYS}
    reps = unit.reps

    if mode == "train" and plan.flow.remat == "nested" and reps >= 4:
        # two-level activation checkpointing (paper-CW analogue for HBM):
        # save the layer-boundary h only every k layers; the backward pass
        # recomputes within a group.  Peak saved activations:
        # O(reps/k + k) layer inputs instead of O(reps).
        k = max(int(reps ** 0.5), 1)
        while reps % k:
            k -= 1
        inner_body = jax.checkpoint(body, prevent_cse=False)
        def group(carry, xs_g):
            return lax.scan(inner_body, carry, xs_g)
        group = jax.checkpoint(group, prevent_cse=False)
        xs_resh = jax.tree.map(
            lambda a: a.reshape((reps // k, k) + a.shape[1:]),
            (gparams, gstate))
        (h, aux), ys = lax.scan(group, (env["h"], aux0), xs_resh,
                                length=reps // k)
        ys = jax.tree.map(
            lambda a: a.reshape((reps,) + a.shape[2:]), ys)
    else:
        if mode == "train" and plan.flow.remat in ("block", "nested"):
            body = jax.checkpoint(body, prevent_cse=False)
        (h, aux), ys = lax.scan(body, (env["h"], aux0),
                                (gparams, gstate),
                                length=reps,
                                unroll=plan.flow.scan_unroll)
    for k2 in AUX_KEYS:
        outer.aux[k2] = aux[k2]
    return h, ys


# ---------------------------------------------------------------------------
# Loss (sequence-chunked cross-entropy — logits never fully materialize)
# ---------------------------------------------------------------------------

def make_loss_fn(plan: ExecutionPlan):
    cfg = plan.cfg
    apply = _make_apply(plan, head=cfg.family == "cnn")
    graph = plan.graph
    head_block = graph.blocks[-1]
    assert head_block.kind in ("head", "cnn_head")

    def loss_fn(params, batch):
        if cfg.family == "cnn":
            logits, _, aux = apply(params, batch, mode="train")
            labels = batch["labels"]
            lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            ll = jnp.take_along_axis(lp, labels[:, None], -1)[:, 0]
            loss = -jnp.mean(ll)
            return loss, {"loss": loss}

        h, _, aux = apply(params, batch, mode="train")
        # run the final norm from the head block
        ctx = Ctx(mode="train", plan=plan)
        if plan.rules is not None:
            ctx.constrain = plan.rules.constrain_act
        env = {"h": h}
        hp = params.get("head", {})
        ops = head_block.ops
        for op in ops:
            if op.op == "unembed":
                break
            args = [env[i] for i in op.ins]
            env[op.out] = OPS[op.op](ctx, op,
                                     _param_slice(op, hp, None), *args)
        hn = env[ops[-1].ins[0]] if ops[-1].op == "unembed" else env["h"]
        un = ops[-1]
        table = (params[un.attrs["tied"].split("/")[0]]["table"]
                 if un.attrs.get("tied") else hp["lm_head"])
        labels = batch["labels"]
        loss, acc = _chunked_ce(ctx, hn, table, labels, cfg.vocab_size,
                                plan.tiles.get("ce_chunk", 256))
        total = loss + sum(aux.get(k, 0.0) for k in AUX_KEYS)
        return total, {"loss": loss, "acc": acc,
                       **{k: aux[k] for k in aux}}

    return loss_fn


def _chunked_ce(ctx, h, table, labels, true_vocab, chunk):
    B, S, d = h.shape
    Vp = table.shape[0]
    dt = ctx.compute_dtype
    while S % chunk:
        chunk //= 2
    chunk = max(chunk, 1)
    nc = S // chunk
    hs = h.reshape(B, nc, chunk, d).swapaxes(0, 1)
    ls = labels.reshape(B, nc, chunk).swapaxes(0, 1)
    vmask = (jnp.arange(Vp) < true_vocab)

    def one(args):
        hc, lc = args
        logits = jnp.einsum("bcd,vd->bcv", hc.astype(dt), table.astype(dt),
                            preferred_element_type=jnp.float32)
        logits = jnp.where(vmask, logits, -1e9)
        logits = ctx.cst(logits, ("batch", "none", "vocab"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        oh = jax.nn.one_hot(lc, Vp, dtype=logits.dtype)
        ll = jnp.einsum("bcv,bcv->bc", logits, oh)
        valid = (lc >= 0).astype(jnp.float32)
        nll = (lse - ll) * valid
        correct = (jnp.argmax(logits, -1) == lc).astype(jnp.float32) * valid
        return (jnp.sum(nll), jnp.sum(valid), jnp.sum(correct))

    # remat per chunk: the (B, chunk, V) logits block is recomputed in the
    # backward pass instead of being saved — full logits never exist in HBM.
    nll, cnt, cor = lax.map(jax.checkpoint(one, prevent_cse=False), (hs, ls))
    denom = jnp.maximum(jnp.sum(cnt), 1.0)
    return jnp.sum(nll) / denom, jnp.sum(cor) / denom
