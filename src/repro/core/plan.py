"""ExecutionPlan — the product of the compilation flow's pass pipeline."""
from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.configs.base import FlowConfig, ModelConfig, ShapeConfig
from repro.core.graph import Graph
from repro.core.passes import caching, folding, fusion, precision, streaming, tiling
from repro.core.passes.folding import Unit


@dataclass
class ExecutionPlan:
    cfg: ModelConfig
    flow: FlowConfig
    shape: ShapeConfig
    graph: Graph                     # post-fusion graph
    units: List[Unit]                # folding result (scan groups)
    tiles: Dict[str, Any]
    stream: streaming.StreamPlan
    prec: precision.PrecisionPlan
    cache: caching.CachingPlan
    rules: Optional[Any] = None      # ShardingRules (distributed runtime)

    @property
    def cache_len(self) -> int:
        """KV-cache length for serving: bounded by the attention window."""
        w = self.cfg.attention.window if self.cfg.attention else None
        c = self.shape.seq_len
        if w:
            c = min(c, w)
        return c

    def describe(self) -> str:
        folded = [u for u in self.units if u.folded]
        lines = [
            f"plan[{self.cfg.name} x {self.shape.name}] mode={self.stream.mode}",
            f"  passes: fuse={self.flow.fuse_epilogues} fold={self.flow.fold_layers}"
            f" tiles={self.flow.tile_select} cw={self.flow.cached_writes}"
            f" prec={self.flow.precision}",
            f"  units: {len(self.units)} ({len(folded)} folded: " +
            ", ".join(f"{u.reps}x{u.period}" for u in folded) + ")",
            f"  tiles: {self.tiles}",
        ]
        return "\n".join(lines)


def build_plan(cfg: ModelConfig, flow: FlowConfig, shape: ShapeConfig,
               mesh_axes: Tuple[str, ...] = (), rules=None,
               graph: Optional[Graph] = None) -> ExecutionPlan:
    """Run the full pass pipeline: build graph -> LF fusion -> PK folding ->
    LU/LT tiling -> OF precision -> CW caching -> CH/CE streaming."""
    from repro.models.lm import build_graph
    g = copy.deepcopy(graph) if graph is not None else build_graph(cfg)
    if flow.fuse_epilogues:
        g = fusion.run(g, fold_bn=shape.kind != "train")
    stream = streaming.run(g, cfg, flow, mesh_axes)
    fold_on = flow.fold_layers and stream.mode == "folded"
    units = folding.run(g, enabled=fold_on)
    tiles = tiling.run(cfg, shape, flow)
    prec = precision.run(flow, shape)
    cach = caching.run(flow)
    return ExecutionPlan(cfg, flow, shape, g, units, tiles, stream, prec,
                         cach, rules)
