"""ExecutionPlan — the product of the compilation flow's pass pipeline.

The pipeline itself lives in :mod:`repro.core.passmanager`; ``build_plan`` is
a thin wrapper over ``PassManager.default_pipeline()`` kept as the stable
entry point every launcher/test uses.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.configs.base import FlowConfig, ModelConfig, ShapeConfig
from repro.core.graph import Graph
from repro.core.passes import caching, precision, streaming
from repro.core.passes.folding import Unit


@dataclass
class ExecutionPlan:
    cfg: ModelConfig
    flow: FlowConfig
    shape: ShapeConfig
    graph: Graph                     # post-fusion graph
    units: List[Unit]                # folding result (scan groups)
    tiles: Dict[str, Any]
    stream: streaming.StreamPlan
    prec: precision.PrecisionPlan
    cache: caching.CachingPlan
    rules: Optional[Any] = None      # ShardingRules (distributed runtime)
    # partitioning decisions (ShardingPass): mesh factorization, per-param
    # PartitionSpecs, pipeline-stage assignment
    sharding: Optional[Any] = None   # passes.sharding.ShardingPlan
    # per-op kernel-backend resolution (KernelSelectPass / KernelRegistry)
    kernels: Dict[str, str] = field(default_factory=dict)
    # pass-pipeline instrumentation (PassManager)
    pass_stats: Dict[str, Dict[str, Any]] = field(default_factory=dict)
    pass_timings_ms: Dict[str, float] = field(default_factory=dict)
    trace: List[str] = field(default_factory=list)
    # set by repro.analysis.verify_plan / flow.compile(verify=True);
    # an analysis.diagnostics.VerificationResult when the plan was verified
    verification: Optional[Any] = None

    @property
    def cache_len(self) -> int:
        """KV-cache length for serving: bounded by the attention window."""
        w = self.cfg.attention.window if self.cfg.attention else None
        c = self.shape.seq_len
        if w:
            c = min(c, w)
        return c

    def _stat_line(self, name: str) -> Optional[str]:
        st = self.pass_stats.get(name)
        if st is None:
            return None
        if not st.get("applied"):
            return f"    {name}: skipped"
        parts = []
        for k, v in st.items():
            if k == "applied":
                continue
            parts.append(f"{k}={v}")
        return f"    {name}: " + " ".join(parts)

    def describe(self, stats: bool = False) -> str:
        """Human-readable plan summary.  Deterministic for fixed inputs (no
        timings), so it doubles as the golden-snapshot format; ``stats=True``
        appends each pass's reported stats."""
        folded = [u for u in self.units if u.folded]
        lines = [
            f"plan[{self.cfg.name} x {self.shape.name}] mode={self.stream.mode}",
            f"  passes: fuse={self.flow.fuse_epilogues} fold={self.flow.fold_layers}"
            f" tiles={self.flow.tile_select} cw={self.flow.cached_writes}"
            f" prec={self.flow.precision}",
            f"  units: {len(self.units)} ({len(folded)} folded: " +
            ", ".join(f"{u.reps}x{u.period}" for u in folded) + ")",
            f"  tiles: {self.tiles}",
        ]
        if self.sharding is not None:
            lines.append(self.sharding.describe_line())
        if self.kernels:
            from repro.kernels.registry import REGISTRY
            accel = [op for op in REGISTRY.accelerated_ops()
                     if op in self.kernels]
            lines.append(
                f"  kernels: backend={self.flow.kernel_backend} " +
                " ".join(f"{op}={self.kernels[op]}" for op in accel))
        if self.verification is not None:
            lines.append(f"  verify: {self.verification.summary_line()}")
        if stats:
            lines.append("  pass stats:")
            for name in self.pass_stats:
                line = self._stat_line(name)
                if line:
                    lines.append(line)
        return "\n".join(lines)


def _build_plan(cfg: ModelConfig, flow: FlowConfig, shape: ShapeConfig,
                mesh_axes: Tuple[str, ...] = (), rules=None,
                graph: Optional[Graph] = None) -> ExecutionPlan:
    """Run the default pass pipeline: build graph -> LF fusion -> CH/CE
    streaming -> PK folding -> LU/LT tiling -> OF precision -> CW caching ->
    kernel-backend selection.  Internal entry point — the public facade is
    :func:`repro.flow.compile`."""
    from repro.core.passmanager import PassManager
    return PassManager.default_pipeline().run(
        cfg, flow, shape, mesh_axes=mesh_axes, rules=rules, graph=graph)


_DEPRECATION_WARNED = False


def _warn_deprecated(name: str) -> None:
    """One DeprecationWarning per process for the whole legacy surface."""
    global _DEPRECATION_WARNED
    if _DEPRECATION_WARNED:
        return
    _DEPRECATION_WARNED = True
    warnings.warn(
        f"{name} is a deprecated entry point; use repro.flow.compile(...) "
        "(returns a CompiledModel owning the plan and the jitted "
        "train/prefill/decode/generate callables)",
        DeprecationWarning, stacklevel=3)


def build_plan(cfg: ModelConfig, flow: FlowConfig, shape: ShapeConfig,
               mesh_axes: Tuple[str, ...] = (), rules=None,
               graph: Optional[Graph] = None) -> ExecutionPlan:
    """Deprecated shim over the default pipeline — use
    :func:`repro.flow.compile`.  Produces byte-identical plans."""
    _warn_deprecated("repro.core.plan.build_plan")
    return _build_plan(cfg, flow, shape, mesh_axes=mesh_axes, rules=rules,
                       graph=graph)
