"""Analytic cost model — the flow's resource estimator (paper §IV-J).

On the FPGA, DSP usage was predicted by counting MACCs × unroll factors while
logic/BRAM needed place-and-route.  Here the analytic layer predicts params,
MODEL_FLOPS, per-op FLOPs/HBM-bytes (for tile selection and for the
kernel-path roofline cross-check), while the "place-and-route" ground truth
is the dry-run's ``compiled.cost_analysis()`` / ``memory_analysis()``.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig


@lru_cache(maxsize=64)
def _graph_for(cfg: ModelConfig):
    from repro.models.lm import build_graph
    return build_graph(cfg)


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact parameter count from the graph (padded vocab included).  With
    ``active_only`` routed-expert params are scaled by top_k / num_experts
    (MoE active-parameter count for MODEL_FLOPS)."""
    g = _graph_for(cfg)
    total = 0
    for b in g.blocks:
        for spec in b.param_specs():
            n = 1
            for d in spec.shape:
                n *= d
            if active_only and spec.name.startswith("we_"):
                n = n * cfg.moe.top_k // cfg.moe.num_experts
            total += n
    return total


def non_embedding_params(cfg: ModelConfig, active_only: bool = False) -> int:
    g = _graph_for(cfg)
    total = 0
    for b in g.blocks:
        if b.kind in ("embed", "dec_embed", "head"):
            continue
        for spec in b.param_specs():
            n = 1
            for d in spec.shape:
                n *= d
            if active_only and spec.name.startswith("we_"):
                n = n * cfg.moe.top_k // cfg.moe.num_experts
            total += n
    return total


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS per step: 6·N·D (train), 2·N·D (prefill forward),
    2·N·B (decode, one token per sequence).  N = active params for MoE."""
    n = count_params(cfg, active_only=cfg.moe is not None)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch


def attention_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Quadratic attention term (excluded from 6·N·D), for the estimator's
    FLOPs cross-check."""
    a = cfg.attention
    if a is None:
        return 0.0
    n_attn = sum(1 for k in cfg.layer_kinds if k == "attn")
    S = shape.seq_len
    w = a.window or S
    if shape.kind == "decode":
        per = 2 * 2 * a.n_heads * a.head_dim * min(S, w)
        return per * n_attn * shape.global_batch
    # sum over query positions of visible window
    kv_per_q = min(w, S) if not a.causal else min(w, S) / 2
    per_tok = 2 * 2 * a.n_heads * a.head_dim * kv_per_q
    mult = 3 if shape.kind == "train" else 1
    return per_tok * S * shape.global_batch * n_attn * mult


def hbm_bytes_kernel_path(cfg: ModelConfig, shape: ShapeConfig,
                          dtype_bytes: int = 2) -> float:
    """Analytic HBM traffic of the *kernel* path (fused epilogues, flash
    attention: no S² intermediate, VMEM accumulation): params read once +
    activations once per layer boundary + KV cache traffic."""
    n = count_params(cfg, active_only=cfg.moe is not None)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    act = tokens * cfg.d_model * dtype_bytes
    per_layer_acts = 4 * act                     # in/out of the two sub-blocks
    total = n * dtype_bytes + cfg.n_layers * per_layer_acts
    if shape.kind == "decode" and cfg.attention:
        C = min(shape.seq_len, cfg.attention.window or shape.seq_len)
        kv = (2 * C * cfg.attention.n_kv_heads * cfg.attention.head_dim *
              dtype_bytes * shape.global_batch)
        n_attn = sum(1 for k in cfg.layer_kinds if k == "attn")
        total += kv * n_attn
    if shape.kind == "train":
        total *= 3                               # fwd + bwd re-read/write
    return total
