"""Analytic cost model — the flow's resource estimator (paper §IV-J).

On the FPGA, DSP usage was predicted by counting MACCs × unroll factors while
logic/BRAM needed place-and-route.  Here the analytic layer predicts params,
MODEL_FLOPS, per-op FLOPs/HBM-bytes (for tile selection and for the
kernel-path roofline cross-check), while the "place-and-route" ground truth
is the dry-run's ``compiled.cost_analysis()`` / ``memory_analysis()``.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Dict

from repro.configs.base import FlowConfig, ModelConfig, ShapeConfig

# Device presets for the DSE's roofline / budget rules.  The budget itself
# (rule 3) comes from FlowConfig.tuning.hbm_bytes so non-v5e devices are a
# config change, not a code change.
DEVICE_PRESETS: Dict[str, Dict[str, float]] = {
    "v5e": {"hbm_bytes": 16 * 1024 ** 3, "hbm_bw": 819e9,
            "bf16_flops": 197e12, "ici_bw": 200e9},
    "v5p": {"hbm_bytes": 95 * 1024 ** 3, "hbm_bw": 2765e9,
            "bf16_flops": 459e12, "ici_bw": 600e9},
}


@lru_cache(maxsize=64)
def _graph_for(cfg: ModelConfig):
    from repro.models.lm import build_graph
    return build_graph(cfg)


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact parameter count from the graph (padded vocab included).  With
    ``active_only`` routed-expert params are scaled by top_k / num_experts
    (MoE active-parameter count for MODEL_FLOPS)."""
    g = _graph_for(cfg)
    total = 0
    for b in g.blocks:
        for spec in b.param_specs():
            n = 1
            for d in spec.shape:
                n *= d
            if active_only and spec.name.startswith("we_"):
                n = n * cfg.moe.top_k // cfg.moe.num_experts
            total += n
    return total


def non_embedding_params(cfg: ModelConfig, active_only: bool = False) -> int:
    g = _graph_for(cfg)
    total = 0
    for b in g.blocks:
        if b.kind in ("embed", "dec_embed", "head"):
            continue
        for spec in b.param_specs():
            n = 1
            for d in spec.shape:
                n *= d
            if active_only and spec.name.startswith("we_"):
                n = n * cfg.moe.top_k // cfg.moe.num_experts
            total += n
    return total


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS per step: 6·N·D (train), 2·N·D (prefill forward),
    2·N·B (decode, one token per sequence).  N = active params for MoE."""
    n = count_params(cfg, active_only=cfg.moe is not None)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch


def attention_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Quadratic attention term (excluded from 6·N·D), for the estimator's
    FLOPs cross-check."""
    a = cfg.attention
    if a is None:
        return 0.0
    n_attn = sum(1 for k in cfg.layer_kinds if k == "attn")
    S = shape.seq_len
    w = a.window or S
    if shape.kind == "decode":
        per = 2 * 2 * a.n_heads * a.head_dim * min(S, w)
        return per * n_attn * shape.global_batch
    # sum over query positions of visible window
    kv_per_q = min(w, S) if not a.causal else min(w, S) / 2
    per_tok = 2 * 2 * a.n_heads * a.head_dim * kv_per_q
    mult = 3 if shape.kind == "train" else 1
    return per_tok * S * shape.global_batch * n_attn * mult


def hbm_bytes_kernel_path(cfg: ModelConfig, shape: ShapeConfig,
                          dtype_bytes: int = 2) -> float:
    """Analytic HBM traffic of the *kernel* path (fused epilogues, flash
    attention: no S² intermediate, VMEM accumulation): params read once +
    activations once per layer boundary + KV cache traffic."""
    n = count_params(cfg, active_only=cfg.moe is not None)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    act = tokens * cfg.d_model * dtype_bytes
    per_layer_acts = 4 * act                     # in/out of the two sub-blocks
    total = n * dtype_bytes + cfg.n_layers * per_layer_acts
    if shape.kind == "decode" and cfg.attention:
        C = min(shape.seq_len, cfg.attention.window or shape.seq_len)
        kv = (2 * C * cfg.attention.n_kv_heads * cfg.attention.head_dim *
              dtype_bytes * shape.global_batch)
        n_attn = sum(1 for k in cfg.layer_kinds if k == "attn")
        total += kv * n_attn
    if shape.kind == "train":
        total *= 3                               # fwd + bwd re-read/write
    return total


# ---------------------------------------------------------------------------
# DSE scoring: the paper's three factor rules, applied analytically
# ---------------------------------------------------------------------------

def _act_dtype_bytes(flow: FlowConfig) -> int:
    return 2 if flow.precision == "bf16" else 4


def mesh_parallel_sizes(flow: FlowConfig) -> Dict[str, int]:
    """(dp, tp, pp) sizes implied by ``flow.mesh_split`` under the flow's
    axis-role convention (size-1 tp/pp degenerate; every other axis is data
    parallelism).  All 1 without a mesh split."""
    if not flow.mesh_split:
        return {"dp": 1, "tp": 1, "pp": 1}
    from repro.core.passes.sharding import split_roles
    sizes = dict(flow.mesh_split)
    dp_axes, tp_axis, pp_axis = split_roles(flow, flow.mesh_split)
    dp = 1
    for a in dp_axes:
        dp *= sizes.get(a, 1)
    return {"dp": dp,
            "tp": sizes.get(tp_axis, 1) if tp_axis else 1,
            "pp": sizes.get(pp_axis, 1) if pp_axis else 1}


def _effective_devices(cfg: ModelConfig, flow: FlowConfig,
                       devices: int) -> int:
    """Sharding denominator for a mesh split: only the axes the model can
    actually use count (a CNN leaves the tp axis idle — its params replicate
    over it, so dividing by the raw axis product would understate the
    footprint and overstate the compute parallelism)."""
    if not flow.mesh_split:
        return devices
    par = mesh_parallel_sizes(flow)
    tp = par["tp"] if cfg.family != "cnn" else 1
    return max(1, par["dp"] * tp * par["pp"])


def estimate_comm_bytes(cfg: ModelConfig, shape: ShapeConfig,
                        flow: FlowConfig) -> Dict[str, float]:
    """Per-device collective traffic per step, from the partition decisions
    the mesh split implies — the communication analogue of the MACC count.

    * **dp (FSDP/ZeRO-3)** — every microbatch re-all-gathers the sharded
      weights at use; training reduce-scatters fp32 gradients once per step.
    * **tp (Megatron)** — two activation all-reduce rounds per layer
      (attention out + FFN out), with the backward re-reductions in train.
    * **pp (GPipe)** — per-microbatch boundary activations ppermuted
      stage -> stage (fwd, plus bwd in train).
    """
    out = {"all_gather": 0.0, "reduce_scatter": 0.0, "all_reduce": 0.0,
           "p2p": 0.0, "total": 0.0}
    if not flow.mesh_split:
        return out                       # unmeshed: skip the graph walk
    par = mesh_parallel_sizes(flow)
    dp, tp, pp = par["dp"], par["tp"], par["pp"]
    adt = _act_dtype_bytes(flow)
    n = count_params(cfg, active_only=cfg.moe is not None)
    tokens = shape.global_batch * (
        shape.seq_len if shape.kind != "decode" else 1)
    train = shape.kind == "train"
    if dp > 1:
        gathers = max(flow.microbatches, 1) if train else 1
        out["all_gather"] = n * adt * (dp - 1) / dp * gathers
        if train:
            out["reduce_scatter"] = 4.0 * n * (dp - 1) / dp
    if tp > 1 and cfg.family != "cnn":     # CNNs leave the tp axis unused
        act = tokens / dp * cfg.d_model * adt      # per-device activations
        rounds = 2 * cfg.n_layers * (3 if train else 1)
        out["all_reduce"] = 2.0 * act * (tp - 1) / tp * rounds
    if pp > 1:
        act = tokens / dp * cfg.d_model * adt
        out["p2p"] = act * (pp - 1) / pp * (3 if train else 1)
    out["total"] = sum(out.values())
    return out

_REMAT_FACTOR = {"none": 10.0, "block": 2.0, "nested": 1.0}


def estimate_footprint(cfg: ModelConfig, shape: ShapeConfig, flow: FlowConfig,
                       devices: int = 1) -> Dict[str, float]:
    """Per-device HBM footprint prediction (rule 3 — the resource budget).

    The MACC-count-predicts-DSP analogue: an analytic byte count good enough
    to *prune* candidates; the dry-run's ``memory_analysis()`` is the
    place-and-route ground truth for the survivors.  Weights/optimizer are
    FSDP-sharded over ``devices``; activation transients shrink with
    microbatching, remat strength, and bf16 storage.
    """
    devices = _effective_devices(cfg, flow, devices)
    n = count_params(cfg)
    adt = _act_dtype_bytes(flow)
    if cfg.family == "cnn":
        # early conv activations dominate: B x H x W x C at full resolution
        act_units = shape.global_batch * cfg.image_size ** 2 * max(
            cfg.image_channels, 8)
        width = 1.0
    else:
        act_units = shape.global_batch * (
            shape.seq_len if shape.kind != "decode" else 1) * cfg.d_model
        width = max(1.0, cfg.d_ff / max(cfg.d_model, 1) / 4)
    out: Dict[str, float] = {}
    if shape.kind == "train":
        # fp32 master params + grads + AdamW m,v — FSDP-sharded
        out["params"] = 4.0 * n / devices
        out["grads"] = 4.0 * n / devices
        out["optimizer"] = 8.0 * n / devices
        mb = max(flow.microbatches, 1)
        per_mb = act_units / devices / mb
        remat = _REMAT_FACTOR.get(flow.remat, 2.0)
        out["activations"] = per_mb * adt * cfg.n_layers * remat * width
        # chunked-CE logits block (fp32), rematerialized per chunk
        b_loc = max(shape.global_batch // devices // mb, 1)
        chunk = min(flow.ce_chunk, shape.seq_len)
        out["logits"] = 4.0 * b_loc * chunk * cfg.padded_vocab
    else:
        out["params"] = float(adt) * n / devices
        out["activations"] = act_units / devices * adt * 4
        b_loc = max(shape.global_batch // devices, 1)
        out["logits"] = 4.0 * b_loc * cfg.padded_vocab
        if shape.kind == "decode" and cfg.attention is not None:
            a = cfg.attention
            C = min(shape.seq_len, a.window or shape.seq_len)
            n_attn = sum(1 for k in cfg.layer_kinds if k == "attn")
            out["kv_cache"] = (2.0 * C * a.n_kv_heads * a.head_dim * adt *
                               b_loc * n_attn)
    out["total"] = sum(out.values())
    return out


def estimate_step_seconds(cfg: ModelConfig, shape: ShapeConfig,
                          flow: FlowConfig, devices: int = 1,
                          device: str = "v5e") -> Dict[str, float]:
    """Roofline step-time prediction (rules 1–2 — the bandwidth roof).

    Candidates are ranked by ``max(compute, memory, comm)`` time; passes that
    are off inflate the byte side the way their FPGA counterparts did (no
    cached writes -> read-modify-write per K step; no fusion -> intermediate
    arrays round-trip HBM; fp32 -> half MXU rate, double bytes).  A mesh
    split adds the ICI roof: the all-gather/reduce-scatter/all-reduce bytes
    its partition decisions imply (:func:`estimate_comm_bytes`).
    """
    if device not in DEVICE_PRESETS:
        raise ValueError(f"unknown device {device!r}; "
                         f"known: {sorted(DEVICE_PRESETS)}")
    dev = DEVICE_PRESETS[device]
    devices = _effective_devices(cfg, flow, devices)
    flops = model_flops(cfg, shape) + attention_flops(cfg, shape)
    peak = dev["bf16_flops"] * (1.0 if flow.precision == "bf16" else 0.5)
    adt = _act_dtype_bytes(flow)
    bytes_ = hbm_bytes_kernel_path(cfg, shape, dtype_bytes=adt)
    if not flow.cached_writes:
        bytes_ *= 3.0
    if not flow.fuse_epilogues:
        bytes_ *= 1.5
    if not flow.tile_select:
        # minimal 128-tiles re-stream weights once per output tile row
        bytes_ *= 2.0
    if shape.kind == "train":
        # memory savers are not free: each extra microbatch re-gathers the
        # sharded weights; remat recomputes (part of) the forward in backward
        n = count_params(cfg, active_only=cfg.moe is not None)
        bytes_ += (max(flow.microbatches, 1) - 1) * n * adt
        flops *= {"none": 1.0, "block": 4.0 / 3.0,
                  "nested": 1.5}.get(flow.remat, 4.0 / 3.0)
    compute_s = flops / (peak * devices)
    memory_s = bytes_ / (dev["hbm_bw"] * devices)
    comm_s = estimate_comm_bytes(cfg, shape, flow)["total"] / dev["ici_bw"]
    step_s = max(compute_s, memory_s, comm_s)
    bound = ("compute" if step_s == compute_s
             else "memory" if step_s == memory_s else "comm")
    return {"compute_s": compute_s, "memory_s": memory_s, "comm_s": comm_s,
            "step_s": step_s, "bound": bound}
