"""Reference (pure-XLA) implementations of every micro-op.

Each op is a function ``fn(ctx, op, p, *args)`` where ``p`` maps param name →
array (names are the *last path component* of the ParamSpec name).  ``ctx``
carries execution mode, decode state, sharding-constraint hooks and the
compilation plan.  The fused ops produced by the fusion pass (``glu_matmul``,
epilogue attrs on ``matmul``/``conv2d``) are implemented here too; the
matmul/attention/conv/recurrence entry points dispatch through the
:mod:`repro.kernels.registry` using the per-op backend table the
``kernels`` pass recorded on the plan (``plan.kernels``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.registry import plan_kernel


# ---------------------------------------------------------------------------
# Execution context
# ---------------------------------------------------------------------------

@dataclass
class Ctx:
    mode: str                        # train | prefill | decode
    plan: Any                        # ExecutionPlan
    state_in: Dict[str, Any] = field(default_factory=dict)
    state_out: Dict[str, Any] = field(default_factory=dict)
    cache_index: Optional[jax.Array] = None   # decode position (scalar int32)
    aux: Dict[str, Any] = field(default_factory=dict)

    # sharding-constraint hook, set by the lowering when a mesh is active.
    constrain: Callable[[jax.Array, tuple], jax.Array] = lambda x, roles: x

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.plan.flow.precision == "bf16" else jnp.float32

    def cst(self, x, *roles):
        if len(roles) == 1 and isinstance(roles[0], (tuple, list)):
            roles = tuple(roles[0])
        return self.constrain(x, roles)

    def add_aux(self, name: str, value):
        self.aux[name] = self.aux.get(name, 0.0) + value


_CPU_SAFE_DOTS: Optional[bool] = None


def set_cpu_safe_dots(v: Optional[bool]):
    """The CPU interpreter backend lacks a few fused bf16xbf16->f32 dot
    layouts (hit by the MoE expert einsums under grad).  When executing on
    CPU we upcast those operands to f32; the dry-run disables this so the
    compiled TPU-target program keeps bf16 MXU dots."""
    global _CPU_SAFE_DOTS
    _CPU_SAFE_DOTS = v


def _cpu_safe_dots() -> bool:
    global _CPU_SAFE_DOTS
    if _CPU_SAFE_DOTS is None:
        _CPU_SAFE_DOTS = jax.default_backend() == "cpu"
    return _CPU_SAFE_DOTS


def _moe_dot(spec, a, b, dt):
    if _cpu_safe_dots():
        return jnp.einsum(spec, a.astype(jnp.float32), b.astype(jnp.float32))
    return jnp.einsum(spec, a.astype(dt), b.astype(dt),
                      preferred_element_type=jnp.float32)


def _act(x, kind: str):
    return {
        "gelu": lambda v: jax.nn.gelu(v, approximate=True),
        "silu": jax.nn.silu,
        "relu": jax.nn.relu,
        "relu2": lambda v: jnp.square(jax.nn.relu(v)),
        "sigmoid": jax.nn.sigmoid,
        "tanh": jnp.tanh,
        "identity": lambda v: v,
    }[kind](x)


# ---------------------------------------------------------------------------
# Dense / elementwise ops
# ---------------------------------------------------------------------------

def _matmul_backend(ctx: Ctx, x, w, *, bias=None, act=None, w2=None):
    """Single entry point for all (possibly fused) matmuls; routes to the
    Pallas kernel when the plan's backend table selects it."""
    kern = plan_kernel(ctx.plan, "glu_matmul" if w2 is not None else "matmul",
                       x=x, w=w)
    if kern is not None:
        fn, interpret = kern
        return fn(x, w, bias=bias, act=act, w2=w2, interpret=interpret,
                  tile=ctx.plan.tiles.get("matmul"),
                  out_dtype=ctx.compute_dtype)
    dt = ctx.compute_dtype
    y = jnp.matmul(x.astype(dt), w.astype(dt),
                   preferred_element_type=jnp.float32)
    if w2 is not None:  # fused GLU pair: act(x@w) * (x@w2)
        y2 = jnp.matmul(x.astype(dt), w2.astype(dt),
                        preferred_element_type=jnp.float32)
        y = _act(y, act or "silu") * y2
        act = None
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if act is not None:
        y = _act(y, act)
    return y.astype(dt)


def op_matmul(ctx: Ctx, op, p, x, *extra):
    vals = list(p.values())
    w = vals[0]
    bias = vals[1] if op.attrs.get("bias") else None
    y = _matmul_backend(ctx, x, w, bias=bias, act=op.attrs.get("act"))
    if op.attrs.get("residual"):
        y = (y.astype(jnp.float32) + extra[0].astype(jnp.float32)).astype(y.dtype)
    return y


def op_glu_matmul(ctx: Ctx, op, p, x):
    vals = list(p.values())
    return _matmul_backend(ctx, x, vals[0], w2=vals[1],
                           act=op.attrs.get("act", "silu"))


def op_bias_add(ctx: Ctx, op, p, x):
    (b,) = p.values()
    return (x.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def op_act(ctx: Ctx, op, p, x):
    return _act(x, op.attrs["kind"])


def op_mul(ctx: Ctx, op, p, a, b):
    return a * b


def op_add(ctx: Ctx, op, p, a, b):
    return (a.astype(jnp.float32) + b.astype(jnp.float32)).astype(
        ctx.compute_dtype)


def op_identity(ctx: Ctx, op, p, x):
    return x


def op_norm(ctx: Ctx, op, p, x):
    eps = op.attrs.get("eps", 1e-6)
    xf = x.astype(jnp.float32)
    scale = next(v for k, v in p.items() if k.endswith("scale")).astype(jnp.float32)
    if op.attrs["kind"] == "rmsnorm":
        y = xf * lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
        y = y * scale
    else:
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
        y = (xf - mu) * lax.rsqrt(var + eps) * scale
        b = next((v for k, v in p.items() if k.endswith("bias")), None)
        if b is not None:
            y = y + b.astype(jnp.float32)
    return y.astype(ctx.compute_dtype)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def op_embed(ctx: Ctx, op, p, tokens):
    table = p["table"]
    y = jnp.take(table, tokens, axis=0).astype(ctx.compute_dtype)
    if op.attrs.get("scale_by_sqrt_d"):
        y = y * jnp.asarray(math.sqrt(table.shape[1]), y.dtype)
    if op.attrs.get("sinusoid_pos"):
        B, S, d = y.shape
        if ctx.mode == "decode" and ctx.cache_index is not None:
            pos = jnp.full((B, S), 0, jnp.int32) + ctx.cache_index
        else:
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        y = y + _sinusoid(pos, d).astype(y.dtype)
    return ctx.cst(y, ("batch", "seq", "none"))


def _sinusoid(pos, d):
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                    / max(half - 1, 1))
    ang = pos.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def op_unembed(ctx: Ctx, op, p, x, *tied):
    table = tied[0] if tied else p["lm_head"]
    dt = ctx.compute_dtype
    logits = jnp.matmul(x.astype(dt), table.astype(dt).T,
                        preferred_element_type=jnp.float32)
    vocab = op.attrs.get("true_vocab")
    if vocab is not None and vocab < table.shape[0]:
        mask = (jnp.arange(table.shape[0]) < vocab)
        logits = jnp.where(mask, logits, -1e9)
    return ctx.cst(logits, ("batch", "seq", "vocab"))


# ---------------------------------------------------------------------------
# Rotary embedding
# ---------------------------------------------------------------------------

def op_rope(ctx: Ctx, op, p, x, positions):
    # x: (B, S, H, Dh); positions: (B, S) absolute token positions.
    rd = op.attrs["rot_dim"]
    base = op.attrs.get("base", 10000.0)
    half = rd // 2
    inv = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, :, None, None] * inv  # (B,S,1,half)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:rd].astype(jnp.float32)
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return jnp.concatenate([rot.astype(x.dtype), x[..., rd:]], -1)


def op_split_heads(ctx: Ctx, op, p, x):
    B, S, _ = x.shape
    return x.reshape(B, S, op.attrs["n"], op.attrs["dh"])


def op_merge_heads(ctx: Ctx, op, p, x):
    B, S, H, Dh = x.shape
    return x.reshape(B, S, H * Dh)


# ---------------------------------------------------------------------------
# Attention (full / causal / sliding-window / cross), GQA, with KV cache
# ---------------------------------------------------------------------------

def _sdpa(ctx: Ctx, q, k, v, qpos, kpos, *, causal, window, softcap,
          chunk=512):
    """Masked scaled-dot-product attention, query-chunked to bound the score
    intermediate (reference analogue of the flash kernel's tiling)."""
    B, Sq, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = Dh ** -0.5
    qf = (q * scale).astype(ctx.compute_dtype)
    kf = k.astype(ctx.compute_dtype)
    vf = v.astype(ctx.compute_dtype)

    def block(qc, qpc):
        # qc: (B, C, H, Dh) -> scores (B, KV, G, C, Skv) in fp32
        qg = qc.reshape(B, qc.shape[1], KV, G, Dh)
        s = jnp.einsum("bckgd,bskd->bkgcs", qg, kf,
                       preferred_element_type=jnp.float32)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        valid = kpos[:, None, None, None, :] >= 0
        if causal:
            valid &= kpos[:, None, None, None, :] <= qpc[:, None, None, :, None]
        if window:
            valid &= kpos[:, None, None, None, :] > (
                qpc[:, None, None, :, None] - window)
        s = jnp.where(valid, s, -1e30)
        pr = jax.nn.softmax(s, axis=-1).astype(ctx.compute_dtype)
        o = jnp.einsum("bkgcs,bskd->bckgd", pr, vf,
                       preferred_element_type=jnp.float32)
        return o.reshape(B, qc.shape[1], H, Dh).astype(ctx.compute_dtype)

    if Sq <= chunk:
        return block(qf, qpos)
    while Sq % chunk:
        chunk -= 1                       # largest divisor of Sq (whisper 1500)
    nc = Sq // chunk
    qs = qf.reshape(B, nc, chunk, H, Dh).swapaxes(0, 1)
    ps = qpos.reshape(B, nc, chunk).swapaxes(0, 1)
    # remat per chunk: the fp32 score block is recomputed in backward, never
    # saved — the reference-path analogue of the flash kernel's tiling.
    fn = jax.checkpoint(lambda t: block(*t), prevent_cse=False) \
        if ctx.mode == "train" else (lambda t: block(*t))
    out = lax.map(fn, (qs, ps))
    return out.swapaxes(0, 1).reshape(B, Sq, H, Dh)


def op_attention(ctx: Ctx, op, p, q, k, v, positions):
    attrs = op.attrs
    cross = attrs.get("cross", False)
    skey = attrs["state_key"]
    causal = attrs.get("causal", True)
    window = attrs.get("window")
    softcap = attrs.get("softcap")
    B, Sq, H, Dh = q.shape

    if ctx.mode in ("train", "prefill") and not cross:
        q = ctx.cst(q, ("batch", "seq_cp", "none", "none"))
        k = ctx.cst(k, ("batch", "gather", "none", "none"))
        v = ctx.cst(v, ("batch", "gather", "none", "none"))
        kern = plan_kernel(ctx.plan, "attention", window=window, cross=cross)
        if kern is not None:
            fn, interpret = kern
            out = fn(q, k, v, positions, causal=causal, window=window,
                     softcap=softcap, interpret=interpret,
                     tile=ctx.plan.tiles.get("attention"))
        else:
            out = _sdpa(ctx, q, k, v, positions, positions, causal=causal,
                        window=window, softcap=softcap)
        out = ctx.cst(out, ("batch", "seq_cp", "none", "none"))
        if ctx.mode == "prefill" and skey is not None:
            C = ctx.plan.cache_len
            if Sq >= C:
                kc, vc = k[:, Sq - C:], v[:, Sq - C:]
                pc = positions[:, Sq - C:]
            else:
                pad = C - Sq
                kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                pc = jnp.pad(positions, ((0, 0), (0, pad)),
                             constant_values=-1)
            ctx.state_out[skey] = {"k": ctx.cst(kc, ("batch", "kv_len", "none", "none")),
                                   "v": ctx.cst(vc, ("batch", "kv_len", "none", "none")),
                                   "pos": pc}
        return out

    if cross:
        if ctx.mode == "decode":
            st = ctx.state_in[skey]
            kc, vc = st["k"], st["v"]
            ctx.state_out[skey] = st
        else:
            kc, vc = k, v
            if ctx.mode == "prefill":   # cache encoder K/V once
                ctx.state_out[skey] = {"k": k, "v": v}
        Skv = kc.shape[1]
        kpos = jnp.broadcast_to(jnp.arange(Skv, dtype=jnp.int32),
                                (B, Skv))
        return _sdpa(ctx, q, kc, vc, positions, kpos, causal=False,
                     window=None, softcap=softcap)

    # -- decode: paged (block-table) or rolling cache --------------------
    st = ctx.state_in[skey]
    if "kp" in st:
        # paged KV pool (serving subsystem): per-row block tables + lengths
        # instead of a dense per-request cache.  ``len[b]`` is the position
        # of the token being decoded; the new K/V land at logical offset
        # ``len[b]`` of row b's block chain, then attention runs over the
        # pool through the block table (Pallas gather on TPU, the registered
        # ref fallback elsewhere).  Free slots park on trash block 0: their
        # writes are garbage into a block no live request owns.
        kp, vp, bt, ln = st["kp"], st["vp"], st["bt"], st["len"]
        bs = kp.shape[1]
        nblk = bt.shape[1]
        if Sq > 1:
            # paged multi-query (chunked catch-up): row b scores a chunk of
            # Sq = k freshly written tokens at absolute positions
            # ``positions[b]`` against its pool blocks.  Entries < 0 are
            # padding (decode rows advancing one token, drained tails):
            # their K/V writes are aimed at pool block 0 — the trash block
            # no live request owns — and their attention rows are masked to
            # zero and discarded by the engine.
            pos = positions.astype(jnp.int32)            # (B, Sq)
            act = pos >= 0
            safe = jnp.where(act, pos, 0)
            rows = jnp.arange(B)
            blk = jnp.where(act, bt[rows[:, None], (safe // bs) % nblk], 0)
            off = jnp.where(act, safe % bs, 0)
            kp = kp.at[blk, off].set(k.astype(kp.dtype))
            vp = vp.at[blk, off].set(v.astype(vp.dtype))
            ctx.state_out[skey] = {"kp": kp, "vp": vp, "bt": bt,
                                   "len": ln + act.sum(1).astype(jnp.int32)}
            kern = plan_kernel(ctx.plan, "paged_decode_attention")
            if kern is not None:
                fn, interpret = kern
                return fn(q, kp, vp, bt, ln, qpos=pos, window=window,
                          softcap=softcap, interpret=interpret)
            from repro.kernels.registry import REGISTRY
            ref = REGISTRY.get("paged_decode_attention", "ref").fn
            return ref(q, kp, vp, bt, ln, qpos=pos, window=window,
                       softcap=softcap, compute_dtype=ctx.compute_dtype)
        rows = jnp.arange(B)
        blk = bt[rows, (ln // bs) % nblk]            # (B,) pool block ids
        off = ln % bs
        kp = kp.at[blk, off].set(k[:, 0].astype(kp.dtype))
        vp = vp.at[blk, off].set(v[:, 0].astype(vp.dtype))
        ctx.state_out[skey] = {"kp": kp, "vp": vp, "bt": bt,
                               "len": ln + jnp.int32(1)}
        kern = plan_kernel(ctx.plan, "paged_decode_attention")
        if kern is not None:
            fn, interpret = kern
            return fn(q, kp, vp, bt, ln, window=window, softcap=softcap,
                      interpret=interpret)
        from repro.kernels.registry import REGISTRY
        ref = REGISTRY.get("paged_decode_attention", "ref").fn
        return ref(q, kp, vp, bt, ln, window=window, softcap=softcap,
                   compute_dtype=ctx.compute_dtype)

    # rolling cache path
    kc, vc, pc = st["k"], st["v"], st["pos"]
    C = kc.shape[1]
    idx = ctx.cache_index % C
    kc = lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, idx, 0, 0))
    vc = lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, idx, 0, 0))
    pc = lax.dynamic_update_slice(
        pc, jnp.broadcast_to(ctx.cache_index, (B, 1)).astype(pc.dtype),
        (0, idx))
    kc = ctx.cst(kc, ("batch", "kv_len", "none", "none"))
    vc = ctx.cst(vc, ("batch", "kv_len", "none", "none"))
    ctx.state_out[skey] = {"k": kc, "v": vc, "pos": pc}
    qpos = jnp.broadcast_to(ctx.cache_index, (B, 1)).astype(jnp.int32)
    kern = plan_kernel(ctx.plan, "decode_attention")
    if kern is not None:
        fn, interpret = kern
        return fn(q, kc, vc, pc, qpos, window=window, softcap=softcap,
                  interpret=interpret,
                  tile=ctx.plan.tiles.get("decode_attention"))
    return _sdpa(ctx, q, kc, vc, qpos, pc, causal=True, window=window,
                 softcap=softcap)


# ---------------------------------------------------------------------------
# Temporal conv + RG-LRU (Griffin)
# ---------------------------------------------------------------------------

def op_conv1d_causal(ctx: Ctx, op, p, x):
    W = p[[k for k in p if k.endswith("_w")][0]].astype(jnp.float32)
    b = p[[k for k in p if k.endswith("_b")][0]].astype(jnp.float32)
    kw = op.attrs["width"]
    skey = op.attrs["state_key"]
    xf = x.astype(jnp.float32)
    if ctx.mode == "decode":
        st = ctx.state_in[skey]          # (B, kw-1, w) previous inputs
        seq = jnp.concatenate([st.astype(jnp.float32), xf], axis=1)
        y = jnp.einsum("bkw,kw->bw", seq, W)[:, None, :] + b
        ctx.state_out[skey] = seq[:, 1:].astype(x.dtype)
        return y.astype(ctx.compute_dtype)
    pad = jnp.pad(xf, ((0, 0), (kw - 1, 0), (0, 0)))
    y = sum(pad[:, i:i + x.shape[1], :] * W[i] for i in range(kw)) + b
    if ctx.mode == "prefill":
        S = x.shape[1]
        tail = xf[:, max(0, S - (kw - 1)):, :]
        if S < kw - 1:
            tail = jnp.pad(tail, ((0, 0), (kw - 1 - S, 0), (0, 0)))
        ctx.state_out[skey] = tail.astype(x.dtype)
    return y.astype(ctx.compute_dtype)


def _block_diag_linear(x, W, b):
    # x: (B, S, w); W: (nb, w/nb, w/nb)
    B, S, w = x.shape
    nb = W.shape[0]
    xr = x.reshape(B, S, nb, w // nb)
    y = jnp.einsum("bsnk,nkj->bsnj", xr.astype(jnp.float32),
                   W.astype(jnp.float32))
    return y.reshape(B, S, w) + b.astype(jnp.float32)


def op_rg_lru(ctx: Ctx, op, p, x):
    c = op.attrs.get("c", 8.0)
    skey = op.attrs["state_key"]
    nb = op.attrs["n_blocks"]
    lam = p["lru_lambda"].astype(jnp.float32)
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(_block_diag_linear(x, p["lru_wa"], p["lru_ba"]))
    i = jax.nn.sigmoid(_block_diag_linear(x, p["lru_wx"], p["lru_bx"]))
    log_a = -c * r * jax.nn.softplus(-lam)          # log of recurrence gate
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)
    if ctx.mode == "decode":
        h0 = ctx.state_in[skey].astype(jnp.float32)
        h = a[:, 0] * h0 + gated[:, 0]
        ctx.state_out[skey] = h.astype(x.dtype)
        return h[:, None, :].astype(ctx.compute_dtype)
    # linear recurrence over the sequence: Pallas scan kernel (state resident
    # in VMEM) on the kernel backends, associative scan on the reference path
    kern = plan_kernel(ctx.plan, "rg_lru")
    if kern is not None:
        fn, interpret = kern
        h = fn(a, gated, interpret=interpret).astype(jnp.float32)
    else:
        def comb(u, w_):
            (a1, b1), (a2, b2) = u, w_
            return a2 * a1, a2 * b1 + b2
        _, h = lax.associative_scan(comb, (a, gated), axis=1)  # h_0 = 0
    if ctx.mode == "prefill":
        ctx.state_out[skey] = h[:, -1].astype(x.dtype)
    return h.astype(ctx.compute_dtype)


# ---------------------------------------------------------------------------
# RWKV6 time-mix / channel-mix
# ---------------------------------------------------------------------------

def _token_shift(ctx, x, skey):
    """Returns x_{t-1} (zeros / cached state at t=0) and stores new state."""
    if ctx.mode == "decode":
        prev = ctx.state_in[skey].astype(x.dtype)[:, None, :]
        ctx.state_out[skey] = x[:, -1]
    else:
        prev = jnp.pad(x[:, :-1], ((0, 0), (1, 0), (0, 0)))
        if ctx.mode == "prefill":
            ctx.state_out[skey] = x[:, -1]
    return prev


def _wkv_chunked(r, k, v, w, u, chunk, parallel: bool = True,
                 boundary_dt=jnp.float32):
    """RWKV6 linear recurrence, chunked.

    ``parallel=True`` (inference): inter-chunk associative scan over chunk
    summaries + one intra-chunk scan vectorized across all chunks — maximal
    parallelism, but its backward would store every per-step state
    (O(B·S·H·dk·dv), probed at 59 GiB/device for rwkv6-7b train_4k).

    ``parallel=False`` (training): nested scans — outer over chunks (carries
    only the (B,H,dk,dv) boundary state), inner over the chunk's steps, with
    the chunk body rematerialized.  Backward stores nc boundary states plus
    one chunk's steps: O(B·(S/C + C)·H·dk·dv).  This is the fla-style
    chunk-recompute schedule; a fused Pallas linear-scan kernel is the
    hardware answer on TPU.

    Shapes: r,k,w (B,S,H,dk); v (B,S,H,dv); u (H,dk). Returns (B,S,H,dv)."""
    B, S, Hh, dk = r.shape
    dv = v.shape[-1]
    C = min(chunk, S)
    while S % C:
        C //= 2
    nc = S // C

    if not parallel:
        xs = tuple(t.reshape(B, nc, C, Hh, -1).transpose(1, 2, 0, 3, 4)
                   for t in (r, k, v, jnp.exp(w)))   # (nc, C, B, H, d)

        def step(Sst, inp):
            rt, kt, vt, wt = inp
            bonus = jnp.einsum("bhk,bhk,bhv->bhv", rt, u * kt, vt)
            yt = jnp.einsum("bhk,bhkv->bhv", rt, Sst) + bonus
            Sst = wt[..., None] * Sst + kt[..., None] * vt[..., None, :]
            return Sst, yt

        @jax.checkpoint
        def chunk_body(S0, data):
            # boundary state crosses chunks in `boundary_dt` (bf16 in bf16
            # training: the saved (nc,B,H,dk,dv) stack halves — §Perf); the
            # in-chunk recurrence recomputes in f32.
            S1, ys = lax.scan(step, S0.astype(jnp.float32), data)
            return S1.astype(boundary_dt), ys

        S0 = jnp.zeros((B, Hh, dk, dv), boundary_dt)
        Sfin, ys = lax.scan(chunk_body, S0, xs)       # ys: (nc, C, B, H, dv)
        y = ys.transpose(2, 0, 1, 3, 4).reshape(B, S, Hh, dv)
        return y, Sfin.astype(jnp.float32)

    rs, ks, vs, logw = (t.reshape(B, nc, C, Hh, -1) for t in (r, k, v, w))
    Lc = jnp.cumsum(logw, axis=2)                       # (B,nc,C,H,dk)
    chunk_decay = jnp.exp(Lc[:, :, -1])                 # (B,nc,H,dk)
    # sum_s exp(L_C - L_s) k_s v_s^T  (safe: exponent <= 0)
    kd = ks * jnp.exp(Lc[:, :, -1:, :, :] - Lc)
    chunk_kv = jnp.einsum("bnchk,bnchv->bnhkv", kd, vs)
    # associative scan over chunks: S_{c} = D_c * S_{c-1} + M_c
    def comb(p1, p2):
        (d1, m1), (d2, m2) = p1, p2
        return d1 * d2, d2[..., None] * m1 + m2
    Dacc, Macc = lax.associative_scan(comb, (chunk_decay, chunk_kv), axis=1)
    # state entering chunk n (exclusive): shift right
    S_in = jnp.pad(Macc[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    # intra-chunk: sequential over C, vectorized over (B, nc, H)
    xs = tuple(t.transpose(2, 0, 1, 3, 4)
               for t in (rs, ks, vs, jnp.exp(logw)))
    def step(Sst, inp):
        rt, kt, vt, wt = inp
        bonus = jnp.einsum("bnhk,bnhk,bnhv->bnhv", rt, u * kt, vt)
        yt = jnp.einsum("bnhk,bnhkv->bnhv", rt, Sst) + bonus
        Sst = wt[..., None] * Sst + kt[..., None] * vt[..., None, :]
        return Sst, yt
    _, ys = lax.scan(step, S_in, xs)
    y = jnp.moveaxis(ys, 0, 2)                          # (B,nc,C,H,dv)
    final = Macc[:, -1]            # state after the full sequence (S_0 = 0)
    return y.reshape(B, S, Hh, dv), final


def op_rwkv6_timemix(ctx: Ctx, op, p, x):
    Hh, dh = op.attrs["n_heads"], op.attrs["head_dim"]
    rank = op.attrs["lora_rank"]
    skey = op.attrs["state_key"]
    B, S, d = x.shape
    dt = ctx.compute_dtype
    # token-shift lerps in compute dtype (fp32 copies of (B,S,d) x5 were the
    # rwkv6 train memory hog — §Perf iteration); LoRA math stays fp32.
    xf = x.astype(dt)
    prev = _token_shift(ctx, xf, skey + "_shift")
    dx = prev - xf
    # data-dependent token-shift mixes (5 targets: r,k,v,w,g)
    lo = jnp.tanh(jnp.einsum("bsd,dr->bsr", xf.astype(jnp.float32),
                             p["mu_lora_a"].astype(jnp.float32)))
    lo = lo.reshape(B, S, 5, rank)
    delta = jnp.einsum("bsnr,nrd->nbsd", lo, p["mu_lora_b"].astype(jnp.float32))
    mix = p["mu_base"].astype(jnp.float32)[:, None, None, :] + delta  # (5,B,S,d)
    xr, xk, xv, xw, xg = (xf + dx * mix[j].astype(dt) for j in range(5))
    proj = lambda z, w_: jnp.einsum("bsd,de->bse", z.astype(dt), w_.astype(dt),
                                    preferred_element_type=jnp.float32)
    r = proj(xr, p["w_r"]).reshape(B, S, Hh, dh)
    k = proj(xk, p["w_k"]).reshape(B, S, Hh, dh)
    v = proj(xv, p["w_v"]).reshape(B, S, Hh, dh)
    g = proj(xg, p["w_g"])
    wraw = (p["decay_base"].astype(jnp.float32) +
            jnp.tanh(jnp.einsum("bsd,dr->bsr", xw.astype(jnp.float32),
                                p["decay_lora_a"].astype(jnp.float32)))
            @ p["decay_lora_b"].astype(jnp.float32))
    logw = -jnp.exp(jnp.clip(wraw, -20.0, 4.0)).reshape(B, S, Hh, dh)
    u = p["bonus"].astype(jnp.float32).reshape(Hh, dh)
    if ctx.mode == "decode":
        St = ctx.state_in[skey + "_s"].astype(jnp.float32)  # (B,H,dk,dv)
        rt, kt, vt = r[:, 0], k[:, 0], v[:, 0]
        bonus = jnp.einsum("bhk,bhk,bhv->bhv", rt, u * kt, vt)
        yt = jnp.einsum("bhk,bhkv->bhv", rt, St) + bonus
        St = jnp.exp(logw[:, 0])[..., None] * St + \
            kt[..., None] * vt[..., None, :]
        ctx.state_out[skey + "_s"] = St.astype(x.dtype)
        y = yt[:, None]
    else:
        y, Sfin = _wkv_chunked(r, k, v, logw, u,
                               ctx.plan.tiles.get("wkv_chunk", 32),
                               parallel=ctx.mode != "train",
                               boundary_dt=dt)
        if ctx.mode == "prefill":
            ctx.state_out[skey + "_s"] = Sfin.astype(x.dtype)
    # per-head group norm, gate, output proj
    y = y.reshape(B, S, Hh, dh)
    mu = jnp.mean(y, -1, keepdims=True)
    var = jnp.var(y, -1, keepdims=True)
    y = (y - mu) * lax.rsqrt(var + 64e-5)
    y = y.reshape(B, S, Hh * dh) * p["ln_x_scale"].astype(jnp.float32) + \
        p["ln_x_bias"].astype(jnp.float32)
    y = y * jax.nn.silu(g)
    out = jnp.einsum("bse,ed->bsd", y.astype(dt), p["w_o"].astype(dt),
                     preferred_element_type=jnp.float32)
    return out.astype(dt)


def op_rwkv6_channelmix(ctx: Ctx, op, p, x):
    skey = op.attrs["state_key"]
    dt = ctx.compute_dtype
    xf = x.astype(dt)
    prev = _token_shift(ctx, xf, skey + "_shift")
    dx = prev - xf
    mu = p["cm_mu"].astype(dt)
    xr = xf + dx * mu[0]
    xk = xf + dx * mu[1]
    mm = lambda z, w_: jnp.matmul(z.astype(dt), w_.astype(dt),
                                  preferred_element_type=jnp.float32)
    r = jax.nn.sigmoid(mm(xr, p["cw_r"]))
    k = jnp.square(jax.nn.relu(mm(xk, p["cw_k"]))).astype(dt)
    return (r * mm(k, p["cw_v"])).astype(dt)


# ---------------------------------------------------------------------------
# Mixture-of-Experts FFN (capacity-based dispatch; EP- or TP-sharded)
# ---------------------------------------------------------------------------

def _moe_core(ctx: Ctx, attrs, x, router, wg, wu, wd, shared,
              eid0=0, e_local=None, tp_shards=1):
    """Dispatch → expert FFN → combine on one model shard.

    ``eid0``/``e_local``: the expert range owned by this shard (EP); with
    expert-TP every shard owns all experts on a d_ff slice.  Routing and
    dispatch bookkeeping are replicated across model shards (cheap, integer
    work); only this shard's experts contribute to the returned *partial*
    output, which the caller psums.
    """
    E, topk = attrs["num_experts"], attrs["top_k"]
    cf = attrs.get("capacity_factor", 1.25)
    B, S, d = x.shape
    dt = ctx.compute_dtype
    E_loc = e_local or E
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, -1)
    gate, idx = lax.top_k(probs, topk)                      # (B,S,k)
    gate = gate / jnp.clip(jnp.sum(gate, -1, keepdims=True), 1e-9)
    aux = jnp.zeros((), jnp.float32)
    if ctx.mode == "train":
        me = jnp.mean(probs, axis=(0, 1))
        ce = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32),
                      axis=(0, 1))
        aux = attrs.get("aux_weight", 0.01) * E * jnp.sum(me * ce)

    cap = max(math.ceil(S * topk / E * cf), 1)
    fe = idx.reshape(B, S * topk)
    fg = gate.reshape(B, S * topk).astype(jnp.float32)

    def pos_in_expert(e_row):
        Tk = e_row.shape[0]
        order = jnp.argsort(e_row, stable=True)
        sorted_e = e_row[order]
        starts = jnp.searchsorted(sorted_e, jnp.arange(E))
        pos_sorted = jnp.arange(Tk) - starts[sorted_e]
        return jnp.zeros((Tk,), jnp.int32).at[order].set(
            pos_sorted.astype(jnp.int32))
    mypos = jax.vmap(pos_in_expert)(fe)
    keep = (mypos < cap).astype(jnp.float32)
    mypos = jnp.minimum(mypos, cap - 1)
    # restrict to this shard's experts (EP); no-op for expert-TP
    fe_loc = fe - eid0
    mine = ((fe_loc >= 0) & (fe_loc < E_loc)).astype(jnp.float32)
    keep_l = keep * mine
    fe_loc = jnp.clip(fe_loc, 0, E_loc - 1)

    xr = jnp.repeat(x, topk, axis=1) if topk > 1 else x
    contrib = (xr.astype(jnp.float32) * keep_l[..., None]).astype(dt)
    scatter = jax.vmap(
        lambda e_, p_, c_: jnp.zeros((E_loc, cap, d), dt).at[e_, p_].add(c_))
    buf = scatter(fe_loc, mypos, contrib)                   # (B,E_loc,cap,d)
    hg = _moe_dot("becd,edf->becf", buf, wg, dt)
    hu = _moe_dot("becd,edf->becf", buf, wu, dt)
    hmid = (_act(hg, attrs.get("act", "silu")) * hu).astype(dt)
    out_buf = _moe_dot("becf,efd->becd", hmid, wd, dt).astype(dt)
    gather = jax.vmap(lambda ob, e_, p_: ob[e_, p_])
    y = gather(out_buf, fe_loc, mypos) * (fg * keep_l)[..., None].astype(dt)
    y = y.reshape(B, S, topk, d).sum(2) if topk > 1 else y.reshape(B, S, d)
    if shared is not None:
        ws_g, ws_u, ws_d = shared
        sg = _moe_dot("bsd,df->bsf", x, ws_g, dt)
        su = _moe_dot("bsd,df->bsf", x, ws_u, dt)
        sh = (_act(sg, "silu") * su).astype(dt)
        y = y + _moe_dot("bsf,fd->bsd", sh, ws_d, dt).astype(dt)
    return y.astype(dt), aux


def _moe_shard_map(ctx: Ctx, op, p, x):
    """Fully-manual MoE region: every collective explicit.

    Layout inside the region: batch local per dp shard; expert weights
    sharded over the model axis (EP when E divides it, expert-TP on d_ff
    otherwise) and *gathered over the dp axes at the region boundary* (the
    FSDP gather, inserted as boundary resharding); one explicit psum of the
    combined (B_loc, S, d) output over the model axis.  This replaces
    GSPMD's choice of fp32 buffer-granularity all-reduces (measured
    710 GiB/device/step on mixtral train_4k — EXPERIMENTS.md §Perf it.1).

    NB: a bf16 psum inside shard_map hits an XLA partitioner CHECK
    ("Invalid binary instruction opcode copy") on this CPU build — the
    activation crosses the boundary and reduces in f32.  On a TPU toolchain
    the psum would be bf16 (half the ICI bytes; noted in the roofline).
    """
    from jax.sharding import PartitionSpec as P
    rules = ctx.plan.rules
    attrs = op.attrs
    E = attrs["num_experts"]
    tp, tpn = rules.tp_size, rules.tp
    ep = E % tp == 0
    E_loc = E // tp if ep else E
    dp_ent = rules.dp if len(rules.dp) > 1 else rules.dp[0]
    B = x.shape[0]
    if B % rules.dp_size:
        dp_ent = None                      # long_500k: batch unshardable

    def wspec(ndim: int, ffn_dim: int):
        ent = [None] * ndim
        if ep:
            ent[0] = tpn
        else:
            ent[ffn_dim] = tpn
        return P(*ent)

    has_shared = attrs.get("num_shared")

    def body(x_, router, wg, wu, wd, *shared_w):
        x_ = x_.astype(ctx.compute_dtype)
        ax = jax.lax.axis_index(tpn)
        eid0 = ax * E_loc if ep else 0
        y, aux = _moe_core(ctx, attrs, x_, router, wg, wu, wd,
                           tuple(shared_w) if shared_w else None,
                           eid0=eid0, e_local=E_loc, tp_shards=tp)
        y = jax.lax.psum(y.astype(jnp.float32), tpn)
        if ctx.mode == "train" and dp_ent is not None:
            aux = jax.lax.pmean(aux, rules.dp if len(rules.dp) > 1
                                else rules.dp[0])
        return y, aux

    operands = [x.astype(jnp.float32), p["router"], p["we_gate"],
                p["we_up"], p["we_down"]]
    in_specs = [P(dp_ent, None, None), P(), wspec(3, 2), wspec(3, 2),
                wspec(3, 1)]
    if has_shared:
        operands += [p["ws_gate"], p["ws_up"], p["ws_down"]]
        in_specs += [P(None, tpn), P(None, tpn), P(tpn, None)]
    from repro.core.compat import shard_map
    f = shard_map(body, rules.mesh, tuple(in_specs),
                  (P(dp_ent, None, None), P()),
                  axis_names=set(rules.mesh.axis_names))
    y, aux = f(*operands)
    if ctx.mode == "train":
        ctx.add_aux("moe_aux", aux)
    return y.astype(ctx.compute_dtype)


def op_moe_ffn(ctx: Ctx, op, p, x):
    """Per-sequence, causal capacity dispatch:

    Token positions within an expert are assigned by a cumsum *within each
    sequence*, so (a) dispatch shards cleanly over the batch (no cross-shard
    cumsum), (b) a sequence's routing is independent of the rest of the batch
    (a serving invariant), and (c) prefill→decode is consistent (appending a
    token never changes earlier tokens' slots).  Decode steps (S=1, ≤1 token
    per expert per sequence) are dropless by construction.

    With an active mesh the expert compute runs in a manual shard_map over
    the model axis (EP or expert-TP) with one explicit psum — see
    :func:`_moe_shard_map`.
    """
    if ctx.plan.rules is not None and ctx.plan.rules.tp:
        return _moe_shard_map(ctx, op, p, x)
    shared = ((p["ws_gate"], p["ws_up"], p["ws_down"])
              if op.attrs.get("num_shared") else None)
    y, aux = _moe_core(ctx, op.attrs, x, p["router"], p["we_gate"],
                       p["we_up"], p["we_down"], shared)
    if ctx.mode == "train":
        ctx.add_aux("moe_aux", aux)
    return y

# ---------------------------------------------------------------------------
# Multimodal / audio stubs
# ---------------------------------------------------------------------------

def op_patch_proj(ctx: Ctx, op, p, h):
    """Replace the first n_patches positions of the token-embedded sequence
    with projected (precomputed, stubbed) vision-patch embeddings."""
    patches = ctx.aux["__inputs__"]["patches"]          # (B, P, d_vision)
    dt = ctx.compute_dtype
    z = jnp.matmul(patches.astype(dt), p["mm_w1"].astype(dt),
                   preferred_element_type=jnp.float32) + p["mm_b1"]
    z = jax.nn.gelu(z, approximate=True)
    z = jnp.matmul(z.astype(dt), p["mm_w2"].astype(dt),
                   preferred_element_type=jnp.float32) + p["mm_b2"]
    z = z.astype(dt)
    P_ = op.attrs["n_patches"]
    return jnp.concatenate([z, h[:, P_:, :]], axis=1)


def op_frames_in(ctx: Ctx, op, p, h):
    """Whisper frontend stub: input already contains frame embeddings."""
    frames = ctx.aux["__inputs__"]["frames"]            # (B, enc_seq, d)
    B, S, d = frames.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return (frames.astype(jnp.float32) +
            _sinusoid(pos, d)).astype(ctx.compute_dtype)


def op_image_in(ctx: Ctx, op, p, h):
    return h.astype(ctx.compute_dtype)


# ---------------------------------------------------------------------------
# CNN ops
# ---------------------------------------------------------------------------

def _conv_backend(ctx: Ctx, x, w, *, stride, padding, groups=1,
                  bn=None, act=None):
    kern = plan_kernel(ctx.plan, "conv2d", groups=groups)
    if kern is not None:
        fn, interpret = kern
        return fn(x, w, stride=stride, padding=padding, bn=bn, act=act,
                  interpret=interpret, tile=ctx.plan.tiles.get("conv2d"))
    dt = ctx.compute_dtype
    # mixed-precision conv transpose rules reject bf16 operands with an f32
    # preferred type; the reference path upcasts instead (the Pallas kernel
    # is the optimized path and accumulates fp32 natively).
    cdt = jnp.float32 if dt == jnp.bfloat16 else dt
    y = lax.conv_general_dilated(
        x.astype(cdt), w.astype(cdt), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
        preferred_element_type=jnp.float32)
    if bn is not None:
        scale, bias, mean, var = bn
        inv = lax.rsqrt(var.astype(jnp.float32) + 1e-5)
        y = (y - mean) * (inv * scale) + bias
    if act:
        y = _act(y, act)
    return y.astype(dt)


def _bn_params(p, prefix=""):
    g = lambda suf: next(v for k, v in p.items() if k.endswith(suf))
    return (g("_scale"), g("_bias"), g("_mean"), g("_var"))


def op_conv2d(ctx: Ctx, op, p, x):
    w = next(v for k, v in p.items() if k.endswith("_w"))
    bn = _bn_params(p) if op.attrs.get("bn") else None
    return _conv_backend(ctx, x, w, stride=op.attrs.get("stride", 1),
                         padding=op.attrs.get("padding", "SAME"),
                         bn=bn, act=op.attrs.get("act"))


def op_depthwise_conv2d(ctx: Ctx, op, p, x):
    w = next(v for k, v in p.items() if k.endswith("_w"))
    C = x.shape[-1]
    kh, kw, _, _ = w.shape
    wg = w.reshape(kh, kw, 1, C)
    bn = _bn_params(p) if op.attrs.get("bn") else None
    return _conv_backend(ctx, x, wg, stride=op.attrs.get("stride", 1),
                         padding=op.attrs.get("padding", "SAME"), groups=C,
                         bn=bn, act=op.attrs.get("act"))


def op_batchnorm(ctx: Ctx, op, p, x):
    scale, bias, mean, var = _bn_params(p)
    if ctx.mode == "train":
        xf = x.astype(jnp.float32)
        mean = jnp.mean(xf, axis=(0, 1, 2))
        var = jnp.var(xf, axis=(0, 1, 2))
    inv = lax.rsqrt(var.astype(jnp.float32) + op.attrs.get("eps", 1e-5))
    y = (x.astype(jnp.float32) - mean) * (inv * scale.astype(jnp.float32)) \
        + bias.astype(jnp.float32)
    return y.astype(ctx.compute_dtype)


def _pool(x, window, stride, kind):
    init = -jnp.inf if kind == "max" else 0.0
    op_ = lax.max if kind == "max" else lax.add
    y = lax.reduce_window(x.astype(jnp.float32), init, op_,
                          (1, window, window, 1), (1, stride, stride, 1),
                          "SAME")
    if kind == "avg":
        y = y / (window * window)
    return y


def op_maxpool2d(ctx: Ctx, op, p, x):
    return _pool(x, op.attrs["window"], op.attrs["stride"], "max").astype(x.dtype)


def op_avgpool2d(ctx: Ctx, op, p, x):
    return _pool(x, op.attrs["window"], op.attrs["stride"], "avg").astype(x.dtype)


def op_global_avgpool(ctx: Ctx, op, p, x):
    return jnp.mean(x.astype(jnp.float32), axis=(1, 2)).astype(x.dtype)


def op_flatten(ctx: Ctx, op, p, x):
    return x.reshape(x.shape[0], -1)


OPS: Dict[str, Callable] = {
    "matmul": op_matmul, "glu_matmul": op_glu_matmul, "bias_add": op_bias_add,
    "act": op_act, "mul": op_mul, "add": op_add, "identity": op_identity,
    "norm": op_norm, "embed": op_embed, "unembed": op_unembed,
    "rope": op_rope, "split_heads": op_split_heads,
    "merge_heads": op_merge_heads, "attention": op_attention,
    "conv1d_causal": op_conv1d_causal, "rg_lru": op_rg_lru,
    "rwkv6_timemix": op_rwkv6_timemix, "rwkv6_channelmix": op_rwkv6_channelmix,
    "moe_ffn": op_moe_ffn, "patch_proj": op_patch_proj,
    "frames_in": op_frames_in, "image_in": op_image_in,
    "conv2d": op_conv2d, "depthwise_conv2d": op_depthwise_conv2d,
    "batchnorm": op_batchnorm, "maxpool2d": op_maxpool2d,
    "avgpool2d": op_avgpool2d, "global_avgpool": op_global_avgpool,
    "flatten": op_flatten,
}
