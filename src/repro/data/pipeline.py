"""Deterministic synthetic data pipeline with host sharding + straggler hooks.

Each host materializes only its shard of the global batch, derived from a
counter-based PRNG keyed on (seed, step, host) — restart-safe (resuming at
step k regenerates identical batches; no data-state checkpoint needed) and
elastic (a different host count re-partitions the same global stream).

The straggler hook models large-cluster input stalls: if a host's shard
misses its deadline the loader substitutes the previous step's shard
(bounded staleness) instead of stalling the step — mitigation is tested by
injecting artificial delays.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, Optional

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    deadline_s: Optional[float] = None       # straggler deadline
    # test hook: artificial per-step delay in seconds (callable of step)
    delay_fn: Optional[Callable[[int], float]] = None


class SyntheticLM:
    """Markov-ish synthetic token stream: next token depends on the previous
    one (so the loss has learnable structure for convergence tests)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        assert cfg.global_batch % cfg.n_hosts == 0
        self.local_batch = cfg.global_batch // cfg.n_hosts
        self._last: Optional[Dict[str, np.ndarray]] = None
        self.stale_steps = 0

    def _gen(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, step, c.host_id]))
        B, S, V = self.local_batch, c.seq_len, c.vocab_size
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.integers(0, V, B)
        noise = rng.integers(0, V, (B, S))
        keep = rng.random((B, S)) < 0.75
        for t in range(S):
            nxt = (toks[:, t] * 31 + 7) % V       # deterministic transition
            toks[:, t + 1] = np.where(keep[:, t], nxt, noise[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def get(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        t0 = time.monotonic()
        if c.delay_fn:
            time.sleep(c.delay_fn(step))
        batch = self._gen(step)
        if (c.deadline_s is not None and self._last is not None
                and time.monotonic() - t0 > c.deadline_s):
            # straggler: bounded-staleness substitution
            self.stale_steps += 1
            batch = self._last
        self._last = batch
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.get(step)
            step += 1


class SyntheticImages:
    def __init__(self, cfg: DataConfig, image_size: int, channels: int,
                 n_classes: int):
        self.cfg = cfg
        self.image_size, self.channels, self.n_classes = (
            image_size, channels, n_classes)
        self.local_batch = cfg.global_batch // cfg.n_hosts

    def get(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, step, c.host_id]))
        B = self.local_batch
        labels = rng.integers(0, self.n_classes, B).astype(np.int32)
        # class-dependent mean so the task is learnable
        base = (labels[:, None, None, None] / self.n_classes - 0.5)
        imgs = (rng.standard_normal(
            (B, self.image_size, self.image_size, self.channels)) * 0.5
            + base).astype(np.float32)
        return {"images": imgs, "labels": labels}
