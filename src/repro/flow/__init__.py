"""repro.flow — the single public entry point of the compilation flow.

The paper's contract is *frozen model in, optimized accelerator out*; this
package is that front door for the repro stack::

    from repro import flow

    cm = flow.compile("llama3.2-1b", "decode_32k", smoke=True)
    params = cm.init_params(jax.random.key(0))
    tokens, state = cm.generate(params, {"tokens": prompt}, steps=16)
    print(cm.describe())

``compile()`` runs the pass pipeline (optionally the design-space explorer)
and returns a :class:`CompiledModel` that owns the :class:`ExecutionPlan`,
the jitted ``train_step`` / ``prefill`` / ``decode`` / ``generate``
callables, ``init_params`` / ``init_state``, per-stage compile stats, and a
``describe()`` mirroring the paper's flow report.  Kernel-backend selection
happens behind it through the :class:`~repro.kernels.registry.KernelRegistry`
(``backend="auto"`` resolves per op: Pallas where the platform compiles it
natively, the reference path elsewhere).

Everything downstream (``launch/*``, ``serving.engine.Engine``,
``examples/*``) consumes a ``CompiledModel``; ``build_plan`` / ``make_apply``
remain as deprecated shims.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, get_smoke
from repro.configs.base import FlowConfig, ModelConfig, ShapeConfig
from repro.core import lowering
from repro.core.plan import ExecutionPlan, _build_plan
from repro.distributed.meshspec import MeshSpec
from repro.obs import TRACER

__all__ = ["compile", "CompiledModel", "MeshSpec"]


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


class CompiledModel:
    """The product of :func:`compile`: an ExecutionPlan plus the executable
    surface lowered from it.

    Jitted stages (``prefill``/``decode``/``train_step``/``generate_fori``)
    are built lazily and cached; the wall-clock of each stage's first
    invocation (trace + XLA compile) is recorded in ``stats["stages"]`` —
    the per-stage analogue of the paper's per-optimization build report.
    """

    def __init__(self, plan: ExecutionPlan, *, mesh=None,
                 explore_result=None, build_s: float = 0.0):
        self.plan = plan
        self.cfg: ModelConfig = plan.cfg
        self.flow: FlowConfig = plan.flow
        self.shape: ShapeConfig = plan.shape
        self.mesh = mesh
        self.rules = plan.rules
        self.explore_result = explore_result
        self.stats: Dict[str, Any] = {
            "plan_build_s": round(build_s, 4),
            "pass_timings_ms": dict(plan.pass_timings_ms),
            "stages": {},
        }
        self._apply = None
        self._loss_fn = None
        self._stages: Dict[str, Callable] = {}
        self._train_steps: Dict[Tuple[int, int], Callable] = {}

    @classmethod
    def from_plan(cls, plan: ExecutionPlan, mesh=None) -> "CompiledModel":
        """Wrap an already-built plan (legacy-path interop)."""
        return cls(plan, mesh=mesh)

    # -- lowering primitives -------------------------------------------------
    @property
    def apply(self) -> Callable:
        """apply(params, batch, state=None, cache_index=None, mode=...) ->
        (out, new_state, aux) — the un-jitted lowered program."""
        if self._apply is None:
            self._apply = lowering._make_apply(self.plan)
        return self._apply

    @property
    def loss_fn(self) -> Callable:
        if self._loss_fn is None:
            self._loss_fn = lowering.make_loss_fn(self.plan)
        return self._loss_fn

    def init_params(self, rng):
        return lowering.init_params(self.plan, rng)

    def init_state(self, batch_size: int, **kw):
        return lowering.init_state(self.plan, batch_size, **kw)

    def param_shapes(self):
        return lowering.param_shapes(self.plan)

    # -- jitted stages -------------------------------------------------------
    def _mesh_ctx(self):
        return self.mesh if self.mesh is not None else _nullcontext()

    def _wrap_timed(self, name: str, jfn: Callable) -> Callable:
        """Record the wall-clock of the stage's first call (trace + XLA
        compile) into ``stats['stages']``."""
        def fn(*args, **kw):
            st = self.stats["stages"]
            if name not in st:
                sp = TRACER.timed(f"stage.{name}", cat="stage")
                out = jfn(*args, **kw)
                jax.block_until_ready(out)
                sp.end()
                st[name] = {"first_call_s": round(sp.elapsed_s, 4)}
                return out
            return jfn(*args, **kw)
        return fn

    def _stage(self, name: str, build: Callable[[], Callable]) -> Callable:
        fn = self._stages.get(name)
        if fn is None:
            fn = self._wrap_timed(name, build())
            self._stages[name] = fn
        return fn

    @property
    def prefill(self) -> Callable:
        """Jitted prefill(params, batch) -> (logits, state, aux)."""
        def build():
            apply = self.apply
            with self._mesh_ctx():
                return jax.jit(lambda p, b: apply(p, b, mode="prefill"))
        return self._stage("prefill", build)

    @property
    def decode(self) -> Callable:
        """Jitted decode(params, batch, state, cache_index) ->
        (logits, new_state, aux); the state argument is donated."""
        def build():
            apply = self.apply
            with self._mesh_ctx():
                return jax.jit(
                    lambda p, b, st, i: apply(p, b, state=st, cache_index=i,
                                              mode="decode"),
                    donate_argnums=(2,))
        return self._stage("decode", build)

    def train_step(self, opt, microbatches: Optional[int] = None) -> Callable:
        """Jitted, donated train step for ``opt``:
        step(params, opt_state, batch) -> (params, opt_state, metrics)."""
        mb = microbatches if microbatches is not None \
            else max(self.flow.microbatches, 1)
        key = (id(opt), mb)
        fn = self._train_steps.get(key)
        if fn is None:
            from repro.train.trainer import make_train_step
            raw = make_train_step(self.plan, opt, microbatches=mb)
            with self._mesh_ctx():
                jfn = jax.jit(raw, donate_argnums=(0, 1))
            fn = self._wrap_timed(f"train_step[mb={mb}]", jfn)
            self._train_steps[key] = fn
        return fn

    # -- generation ----------------------------------------------------------
    def _sample(self, logits, rng, temperature: float):
        if temperature == 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            rng, logits / temperature, axis=-1).astype(jnp.int32)

    def generate(self, params, batch: Dict[str, Any], steps: int, *,
                 temperature: float = 0.0, seed: int = 0
                 ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        """Prefill the prompt batch, then decode ``steps`` tokens through the
        jitted donated decode stage (host-side sampling loop)."""
        S = batch["tokens"].shape[1]
        logits, state, _ = self.prefill(params, batch)
        rng = jax.random.key(seed)
        tok = self._sample(logits[:, -1], rng, temperature)
        out = [tok]
        for t in range(steps - 1):
            rng, k = jax.random.split(rng)
            lg, state, _ = self.decode(params, {"tokens": tok[:, None]},
                                       state, jnp.int32(S + t))
            tok = self._sample(lg[:, -1], k, temperature)
            out.append(tok)
        return jnp.stack(out, axis=1), state

    def generate_fori(self, params, batch: Dict[str, Any],
                      steps: int) -> jnp.ndarray:
        """Fully on-device greedy generation: prefill plus the whole decode
        loop as one jitted program (the paper's autorun analogue)."""
        S = batch["tokens"].shape[1]
        apply = self.apply

        def build():
            def run(params, batch):
                logits, state, _ = apply(params, batch, mode="prefill")
                tok0 = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
                B = tok0.shape[0]
                toks = jnp.zeros((B, steps), jnp.int32)
                toks = toks.at[:, 0].set(tok0)

                def body(t, carry):
                    toks, state = carry
                    cur = jax.lax.dynamic_slice_in_dim(toks, t, 1, axis=1)
                    lg, state, _ = apply(params, {"tokens": cur}, state=state,
                                         cache_index=(S + t).astype(jnp.int32),
                                         mode="decode")
                    nxt = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)
                    toks = jax.lax.dynamic_update_slice_in_dim(
                        toks, nxt[:, None], t + 1, axis=1)
                    return toks, state

                toks, _ = jax.lax.fori_loop(0, steps - 1, body, (toks, state))
                return toks

            with self._mesh_ctx():
                return jax.jit(run)

        return self._stage(f"generate_fori[{S}+{steps}]", build)(params, batch)

    def decode_segment(self, steps: int, *,
                       temperature: float = 0.0) -> Callable:
        """Jitted host-free multi-tick decode over externally managed state
        (the serving engine's paged KV pool):

            run(params, state, tok0, pos0, rng) -> (tokens, new_state, rng)

        ``tok0``/``pos0`` are (B,) int32 — each row's last sampled token and
        its absolute position; ``tokens`` is (B, steps).  The body replays
        the engine's per-tick host loop exactly — decode cell, then one
        ``jax.random.split`` per tick, then sample — so the produced tokens
        are byte-identical to ``steps`` host ticks (including the rng stream
        at temperature > 0), with a single device round-trip for the whole
        segment instead of one per token.  ``state`` is donated."""
        apply = self.apply
        sample = self._sample

        def build():
            def run(params, state, tok0, pos0, rng):
                B = tok0.shape[0]
                toks = jnp.zeros((B, steps), jnp.int32)

                def body(t, carry):
                    toks, state, rng, cur, pos = carry
                    lg, state, _ = apply(
                        params, {"tokens": cur[:, None],
                                 "positions": pos[:, None]},
                        state=state, cache_index=jnp.int32(0), mode="decode")
                    rng, k = jax.random.split(rng)
                    nxt = sample(lg[:, -1], k, temperature)
                    toks = jax.lax.dynamic_update_slice_in_dim(
                        toks, nxt[:, None], t, axis=1)
                    return toks, state, rng, nxt, pos + 1

                toks, state, rng, _, _ = jax.lax.fori_loop(
                    0, steps, body, (toks, state, rng, tok0, pos0))
                return toks, state, rng

            with self._mesh_ctx():
                return jax.jit(run, donate_argnums=(1,))

        return self._stage(
            f"decode_segment[T={steps},temp={temperature}]", build)

    # -- measured-time validation --------------------------------------------
    def _measure_inputs(self, seed: int = 0) -> Dict[str, Any]:
        """Concrete random inputs matching the cell's abstract shapes."""
        import numpy as np
        from repro.core.dse import abstract_inputs
        rng = np.random.RandomState(seed)
        out = {}
        for k, sds in abstract_inputs(self.cfg, self.shape).items():
            if sds.dtype == jnp.int32:
                out[k] = jnp.asarray(
                    rng.randint(0, self.cfg.vocab_size, sds.shape), jnp.int32)
            else:
                out[k] = jnp.asarray(rng.randn(*sds.shape), sds.dtype)
        return out

    def measure(self, stage: Optional[str] = None, iters: int = 3, *,
                seed: int = 0, trace_dir: Optional[str] = None
                ) -> Dict[str, Any]:
        """Wall-clock one stage of this compiled cell: AOT-compile it
        (recording ``per_device_bytes`` from ``memory_analysis()``), run it
        once to warm up, then time ``iters`` steps and report the best and
        mean.  ``stage`` defaults to the shape cell's kind (train -> the
        donated train step, prefill/decode -> the serving stages).  This is
        the DSE's measured-time validator (``validate="measure"``) — the
        on-device confirmation the paper got from hours of place & route.

        ``trace_dir`` brackets the timed loop in ``jax.profiler.trace`` so
        a device profile lines up with the host-side ``measure.step``
        spans the module tracer records (``repro.obs``).
        """
        stage = stage if stage is not None else self.shape.kind
        B = self.shape.global_batch
        batch = self._measure_inputs(seed)
        if stage == "train":
            from repro.optim.adamw import AdamW
            from repro.train.trainer import make_train_step
            opt = AdamW()
            raw = make_train_step(self.plan, opt,
                                  microbatches=max(self.flow.microbatches, 1))
            params = self.init_params(jax.random.key(seed))
            args = [params, opt.init(params), batch]
            fn, donate = raw, (0, 1)
            def carry(out, args):      # re-feed donated params/opt state
                return [out[0], out[1], args[2]]
        elif stage == "decode":
            apply = self.apply
            params = self.init_params(jax.random.key(seed))
            state = self.init_state(B)
            tok = batch["tokens"].reshape(B, 1)

            def fn(p, b, st, i):
                logits, new_state, _ = apply(p, b, state=st, cache_index=i,
                                             mode="decode")
                return logits, new_state
            args = [params, {"tokens": tok}, state, jnp.int32(0)]
            donate = (2,)
            def carry(out, args):
                return [args[0], args[1], out[1], args[3] + 1]
        elif stage == "prefill":
            apply = self.apply
            params = self.init_params(jax.random.key(seed))
            fn = lambda p, b: apply(p, b, mode="prefill")[0]  # noqa: E731
            args = [params, batch]
            donate = ()
            def carry(out, args):
                return args
        else:
            raise ValueError(f"unknown stage {stage!r}; "
                             "expected train | prefill | decode")

        from repro.core.dse import per_device_bytes
        sp_compile = TRACER.timed("measure.compile", cat="measure",
                                  stage=stage)
        with self._mesh_ctx():
            compiled = jax.jit(fn, donate_argnums=donate).lower(
                *args).compile()
        sp_compile.end()
        compile_s = sp_compile.elapsed_s
        mem = compiled.memory_analysis()
        args = carry(compiled(*args), args)          # warm-up (not timed)
        jax.block_until_ready(args)
        times = []
        prof_ctx = jax.profiler.trace(trace_dir) if trace_dir \
            else _nullcontext()
        with prof_ctx:
            for _ in range(max(iters, 1)):
                sp = TRACER.timed("measure.step", cat="measure", stage=stage)
                out = compiled(*args)
                jax.block_until_ready(out)
                sp.end()
                times.append(sp.elapsed_s)
                args = carry(out, args)
        rec = {"stage": stage, "iters": len(times),
               "compile_s": round(compile_s, 4),
               "measured_step_s": min(times),
               "mean_step_s": sum(times) / len(times),
               "per_device_bytes": per_device_bytes(mem),
               "temp_bytes": mem.temp_size_in_bytes,
               "argument_bytes": mem.argument_size_in_bytes}
        self.stats.setdefault("measure", {})[stage] = rec
        return rec

    # -- reporting -----------------------------------------------------------
    def describe(self, stats: bool = False) -> str:
        """The flow report: plan summary (passes, units, tiles, kernel
        backends), DSE outcome when autotuned, and per-stage compile stats."""
        lines = [self.plan.describe(stats=stats)]
        if self.explore_result is not None:
            er = self.explore_result
            lines.append(f"  dse: best=[{er.best.knob_str()}] "
                         f"enumerated={er.n_enumerated} "
                         f"validated={len(er.validated)}")
        if stats and self.stats["stages"]:
            parts = [f"{k}={v['first_call_s']}s"
                     for k, v in self.stats["stages"].items()]
            lines.append("  stages: " + " ".join(parts))
        return "\n".join(lines)

    def __repr__(self) -> str:
        return (f"<CompiledModel {self.cfg.name} x {self.shape.name} "
                f"backend={self.flow.kernel_backend}>")


def _resolve_cfg(arch_or_cfg: Union[str, ModelConfig],
                 smoke: bool) -> ModelConfig:
    if isinstance(arch_or_cfg, str):
        return get_smoke(arch_or_cfg) if smoke else get_config(arch_or_cfg)
    return arch_or_cfg


def _resolve_shape(shape: Union[str, ShapeConfig]) -> ShapeConfig:
    if isinstance(shape, str):
        try:
            return SHAPES[shape]
        except KeyError:
            raise KeyError(f"unknown shape {shape!r}; known: "
                           f"{list(SHAPES)}") from None
    return shape


def _rules_for(mesh, flow: FlowConfig):
    from repro.core.passes.sharding import split_roles
    from repro.distributed.sharding import ShardingRules
    split = tuple((a, int(mesh.shape[a])) for a in mesh.axis_names)
    dp, tp, _pp = split_roles(flow, split)
    return ShardingRules(mesh, dp=dp or ("data",), tp=tp)


def _resolve_mesh(mesh) -> Tuple[Optional[Any], Optional[MeshSpec]]:
    """(runtime jax Mesh, MeshSpec) from any accepted mesh spelling.  A
    MeshSpec / axis-size dict is bound to the local devices; a live Mesh is
    passed through."""
    if mesh is None:
        return None, None
    spec = MeshSpec.of(mesh)
    if hasattr(mesh, "devices"):            # already a live jax Mesh
        return mesh, spec
    return spec.build(), spec


def compile(arch_or_cfg: Union[str, ModelConfig],
            shape: Union[str, ShapeConfig],
            flow: Optional[FlowConfig] = None, *,
            backend: str = "auto",
            autotune: bool = False,
            mesh=None,
            validate: str = "compile",
            verify: bool = False,
            smoke: bool = False) -> CompiledModel:
    """Compile one (model, shape) cell through the whole flow.

    Args:
      arch_or_cfg: registry arch name (``"llama3.2-1b"``) or a ModelConfig.
      shape: shape-cell name from ``repro.configs.SHAPES`` or a ShapeConfig.
      flow: FlowConfig knobs; defaults to ``FlowConfig(mode="folded")``.
      backend: kernel-backend policy (``auto`` | ``reference`` | ``pallas`` |
        ``pallas_interpret``).  A non-``auto`` value overrides the flow's
        ``kernel_backend``; the default keeps the flow's own setting.
      autotune: run the design-space explorer (estimator-pruned,
        compile-validated; results are cached per (cfg, shape, flow, mesh)
        fingerprint) and compile the winning flow.
      mesh: the device mesh — a :class:`MeshSpec`, an axis-size dict
        (``{"data": 2, "model": 2}``), or a live jax Mesh.  The factorization
        is recorded on the flow (``mesh_split``), the ShardingPass writes the
        partitioning decisions onto the plan, and the runtime binds them via
        ShardingRules (``model`` TP, other axes DP, ``flow.pp_axis`` PP).
      validate: with ``autotune``, how the top-k survivors are confirmed:
        ``"compile"`` (lower+compile+memory_analysis, the default) or
        ``"measure"`` (AOT-compile *and* wall-clock the stage via
        :meth:`CompiledModel.measure`, ranking survivors by measured step
        time).
      verify: run the static plan verifier (:func:`repro.analysis.verify_plan`)
        over the built plan *before any jit*.  The result is recorded on
        ``plan.verification`` (one ``verify:`` line in ``describe()``); any
        error-severity diagnostic raises
        :class:`~repro.analysis.PlanVerificationError` carrying the full
        diagnostic list.
      smoke: with a string arch, select the reduced (CPU-runnable) config.
    """
    cfg = _resolve_cfg(arch_or_cfg, smoke)
    shape = _resolve_shape(shape)
    flow = flow if flow is not None else FlowConfig(mode="folded")
    if backend != "auto" and backend != flow.kernel_backend:
        flow = dataclasses.replace(flow, kernel_backend=backend)
    if validate not in ("compile", "measure"):
        raise ValueError(f"unknown validate mode {validate!r}; "
                         "expected 'compile' | 'measure'")

    mesh_obj, mesh_spec = _resolve_mesh(mesh)
    if mesh_spec is not None and flow.mesh_split != mesh_spec.axes:
        flow = dataclasses.replace(flow, mesh_split=mesh_spec.axes)

    explore_result = None
    sp_build = TRACER.timed("flow.build", cat="compile", arch=cfg.name,
                            autotune=autotune)
    if autotune:
        from repro.core import dse
        n_dev = mesh_spec.size if mesh_spec is not None else 1
        if validate == "measure":
            validator = dse.measure_validator(cfg, shape, mesh=mesh_obj)
        else:
            validator = dse.compile_validator(cfg, shape)
        explore_result = dse.explore(
            cfg, shape, flow, devices=n_dev, validator=validator,
            rank_measured=validate == "measure")
        flow = explore_result.best.flow

    rules = None
    mesh_axes: Tuple[str, ...] = ()
    if mesh_obj is not None:
        rules = _rules_for(mesh_obj, flow)
        mesh_axes = tuple(mesh_obj.axis_names)

    if explore_result is not None and mesh_obj is None:
        plan = explore_result.plan          # already built for the best flow
    else:
        plan = _build_plan(cfg, flow, shape, mesh_axes=mesh_axes, rules=rules)
    if verify:
        from repro.analysis import PlanVerificationError, verify_plan
        result = verify_plan(plan)
        plan.verification = result
        if not result.ok:                   # gate: no jit for a bad plan
            raise PlanVerificationError(result)
    sp_build.end()
    return CompiledModel(plan, mesh=mesh_obj, explore_result=explore_result,
                         build_s=sp_build.elapsed_s)
