"""repro.flow — the single public entry point of the compilation flow.

The paper's contract is *frozen model in, optimized accelerator out*; this
package is that front door for the repro stack::

    from repro import flow

    cm = flow.compile("llama3.2-1b", "decode_32k", smoke=True)
    params = cm.init_params(jax.random.key(0))
    tokens, state = cm.generate(params, {"tokens": prompt}, steps=16)
    print(cm.describe())

``compile()`` runs the pass pipeline (optionally the design-space explorer)
and returns a :class:`CompiledModel` that owns the :class:`ExecutionPlan`,
the jitted ``train_step`` / ``prefill`` / ``decode`` / ``generate``
callables, ``init_params`` / ``init_state``, per-stage compile stats, and a
``describe()`` mirroring the paper's flow report.  Kernel-backend selection
happens behind it through the :class:`~repro.kernels.registry.KernelRegistry`
(``backend="auto"`` resolves per op: Pallas where the platform compiles it
natively, the reference path elsewhere).

Everything downstream (``launch/*``, ``serving.engine.Engine``,
``examples/*``) consumes a ``CompiledModel``; ``build_plan`` / ``make_apply``
remain as deprecated shims.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, get_smoke
from repro.configs.base import FlowConfig, ModelConfig, ShapeConfig
from repro.core import lowering
from repro.core.plan import ExecutionPlan, _build_plan

__all__ = ["compile", "CompiledModel"]


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


class CompiledModel:
    """The product of :func:`compile`: an ExecutionPlan plus the executable
    surface lowered from it.

    Jitted stages (``prefill``/``decode``/``train_step``/``generate_fori``)
    are built lazily and cached; the wall-clock of each stage's first
    invocation (trace + XLA compile) is recorded in ``stats["stages"]`` —
    the per-stage analogue of the paper's per-optimization build report.
    """

    def __init__(self, plan: ExecutionPlan, *, mesh=None,
                 explore_result=None, build_s: float = 0.0):
        self.plan = plan
        self.cfg: ModelConfig = plan.cfg
        self.flow: FlowConfig = plan.flow
        self.shape: ShapeConfig = plan.shape
        self.mesh = mesh
        self.rules = plan.rules
        self.explore_result = explore_result
        self.stats: Dict[str, Any] = {
            "plan_build_s": round(build_s, 4),
            "pass_timings_ms": dict(plan.pass_timings_ms),
            "stages": {},
        }
        self._apply = None
        self._loss_fn = None
        self._stages: Dict[str, Callable] = {}
        self._train_steps: Dict[Tuple[int, int], Callable] = {}

    @classmethod
    def from_plan(cls, plan: ExecutionPlan, mesh=None) -> "CompiledModel":
        """Wrap an already-built plan (legacy-path interop)."""
        return cls(plan, mesh=mesh)

    # -- lowering primitives -------------------------------------------------
    @property
    def apply(self) -> Callable:
        """apply(params, batch, state=None, cache_index=None, mode=...) ->
        (out, new_state, aux) — the un-jitted lowered program."""
        if self._apply is None:
            self._apply = lowering._make_apply(self.plan)
        return self._apply

    @property
    def loss_fn(self) -> Callable:
        if self._loss_fn is None:
            self._loss_fn = lowering.make_loss_fn(self.plan)
        return self._loss_fn

    def init_params(self, rng):
        return lowering.init_params(self.plan, rng)

    def init_state(self, batch_size: int, **kw):
        return lowering.init_state(self.plan, batch_size, **kw)

    def param_shapes(self):
        return lowering.param_shapes(self.plan)

    # -- jitted stages -------------------------------------------------------
    def _mesh_ctx(self):
        return self.mesh if self.mesh is not None else _nullcontext()

    def _wrap_timed(self, name: str, jfn: Callable) -> Callable:
        """Record the wall-clock of the stage's first call (trace + XLA
        compile) into ``stats['stages']``."""
        def fn(*args, **kw):
            st = self.stats["stages"]
            if name not in st:
                t0 = time.perf_counter()
                out = jfn(*args, **kw)
                jax.block_until_ready(out)
                st[name] = {"first_call_s":
                            round(time.perf_counter() - t0, 4)}
                return out
            return jfn(*args, **kw)
        return fn

    def _stage(self, name: str, build: Callable[[], Callable]) -> Callable:
        fn = self._stages.get(name)
        if fn is None:
            fn = self._wrap_timed(name, build())
            self._stages[name] = fn
        return fn

    @property
    def prefill(self) -> Callable:
        """Jitted prefill(params, batch) -> (logits, state, aux)."""
        def build():
            apply = self.apply
            with self._mesh_ctx():
                return jax.jit(lambda p, b: apply(p, b, mode="prefill"))
        return self._stage("prefill", build)

    @property
    def decode(self) -> Callable:
        """Jitted decode(params, batch, state, cache_index) ->
        (logits, new_state, aux); the state argument is donated."""
        def build():
            apply = self.apply
            with self._mesh_ctx():
                return jax.jit(
                    lambda p, b, st, i: apply(p, b, state=st, cache_index=i,
                                              mode="decode"),
                    donate_argnums=(2,))
        return self._stage("decode", build)

    def train_step(self, opt, microbatches: Optional[int] = None) -> Callable:
        """Jitted, donated train step for ``opt``:
        step(params, opt_state, batch) -> (params, opt_state, metrics)."""
        mb = microbatches if microbatches is not None \
            else max(self.flow.microbatches, 1)
        key = (id(opt), mb)
        fn = self._train_steps.get(key)
        if fn is None:
            from repro.train.trainer import make_train_step
            raw = make_train_step(self.plan, opt, microbatches=mb)
            with self._mesh_ctx():
                jfn = jax.jit(raw, donate_argnums=(0, 1))
            fn = self._wrap_timed(f"train_step[mb={mb}]", jfn)
            self._train_steps[key] = fn
        return fn

    # -- generation ----------------------------------------------------------
    def _sample(self, logits, rng, temperature: float):
        if temperature == 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            rng, logits / temperature, axis=-1).astype(jnp.int32)

    def generate(self, params, batch: Dict[str, Any], steps: int, *,
                 temperature: float = 0.0, seed: int = 0
                 ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        """Prefill the prompt batch, then decode ``steps`` tokens through the
        jitted donated decode stage (host-side sampling loop)."""
        S = batch["tokens"].shape[1]
        logits, state, _ = self.prefill(params, batch)
        rng = jax.random.key(seed)
        tok = self._sample(logits[:, -1], rng, temperature)
        out = [tok]
        for t in range(steps - 1):
            rng, k = jax.random.split(rng)
            lg, state, _ = self.decode(params, {"tokens": tok[:, None]},
                                       state, jnp.int32(S + t))
            tok = self._sample(lg[:, -1], k, temperature)
            out.append(tok)
        return jnp.stack(out, axis=1), state

    def generate_fori(self, params, batch: Dict[str, Any],
                      steps: int) -> jnp.ndarray:
        """Fully on-device greedy generation: prefill plus the whole decode
        loop as one jitted program (the paper's autorun analogue)."""
        S = batch["tokens"].shape[1]
        apply = self.apply

        def build():
            def run(params, batch):
                logits, state, _ = apply(params, batch, mode="prefill")
                tok0 = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
                B = tok0.shape[0]
                toks = jnp.zeros((B, steps), jnp.int32)
                toks = toks.at[:, 0].set(tok0)

                def body(t, carry):
                    toks, state = carry
                    cur = jax.lax.dynamic_slice_in_dim(toks, t, 1, axis=1)
                    lg, state, _ = apply(params, {"tokens": cur}, state=state,
                                         cache_index=(S + t).astype(jnp.int32),
                                         mode="decode")
                    nxt = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)
                    toks = jax.lax.dynamic_update_slice_in_dim(
                        toks, nxt[:, None], t + 1, axis=1)
                    return toks, state

                toks, _ = jax.lax.fori_loop(0, steps - 1, body, (toks, state))
                return toks

            with self._mesh_ctx():
                return jax.jit(run)

        return self._stage(f"generate_fori[{S}+{steps}]", build)(params, batch)

    # -- reporting -----------------------------------------------------------
    def describe(self, stats: bool = False) -> str:
        """The flow report: plan summary (passes, units, tiles, kernel
        backends), DSE outcome when autotuned, and per-stage compile stats."""
        lines = [self.plan.describe(stats=stats)]
        if self.explore_result is not None:
            er = self.explore_result
            lines.append(f"  dse: best=[{er.best.knob_str()}] "
                         f"enumerated={er.n_enumerated} "
                         f"validated={len(er.validated)}")
        if stats and self.stats["stages"]:
            parts = [f"{k}={v['first_call_s']}s"
                     for k, v in self.stats["stages"].items()]
            lines.append("  stages: " + " ".join(parts))
        return "\n".join(lines)

    def __repr__(self) -> str:
        return (f"<CompiledModel {self.cfg.name} x {self.shape.name} "
                f"backend={self.flow.kernel_backend}>")


def _resolve_cfg(arch_or_cfg: Union[str, ModelConfig],
                 smoke: bool) -> ModelConfig:
    if isinstance(arch_or_cfg, str):
        return get_smoke(arch_or_cfg) if smoke else get_config(arch_or_cfg)
    return arch_or_cfg


def _resolve_shape(shape: Union[str, ShapeConfig]) -> ShapeConfig:
    if isinstance(shape, str):
        try:
            return SHAPES[shape]
        except KeyError:
            raise KeyError(f"unknown shape {shape!r}; known: "
                           f"{list(SHAPES)}") from None
    return shape


def _rules_for(mesh):
    from repro.distributed.sharding import ShardingRules
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return ShardingRules(mesh, dp=dp, tp="model")


def compile(arch_or_cfg: Union[str, ModelConfig],
            shape: Union[str, ShapeConfig],
            flow: Optional[FlowConfig] = None, *,
            backend: str = "auto",
            autotune: bool = False,
            mesh=None,
            smoke: bool = False) -> CompiledModel:
    """Compile one (model, shape) cell through the whole flow.

    Args:
      arch_or_cfg: registry arch name (``"llama3.2-1b"``) or a ModelConfig.
      shape: shape-cell name from ``repro.configs.SHAPES`` or a ShapeConfig.
      flow: FlowConfig knobs; defaults to ``FlowConfig(mode="folded")``.
      backend: kernel-backend policy (``auto`` | ``reference`` | ``pallas`` |
        ``pallas_interpret``).  A non-``auto`` value overrides the flow's
        ``kernel_backend``; the default keeps the flow's own setting.
      autotune: run the design-space explorer (estimator-pruned,
        compile-validated; results are cached per (cfg, shape, flow)
        fingerprint) and compile the winning flow.
      mesh: a jax Mesh for the distributed runtime; sharding rules are
        derived from its axis names (``model`` TP, ``data``/``pod`` DP).
      smoke: with a string arch, select the reduced (CPU-runnable) config.
    """
    cfg = _resolve_cfg(arch_or_cfg, smoke)
    shape = _resolve_shape(shape)
    flow = flow if flow is not None else FlowConfig(mode="folded")
    if backend != "auto" and backend != flow.kernel_backend:
        flow = dataclasses.replace(flow, kernel_backend=backend)

    explore_result = None
    t0 = time.perf_counter()
    if autotune:
        from repro.core import dse
        n_dev = int(mesh.devices.size) if mesh is not None else 1
        explore_result = dse.explore(
            cfg, shape, flow, devices=n_dev,
            validator=dse.compile_validator(cfg, shape))
        flow = explore_result.best.flow

    rules = None
    mesh_axes: Tuple[str, ...] = ()
    if mesh is not None:
        rules = _rules_for(mesh)
        mesh_axes = tuple(mesh.axis_names)

    if explore_result is not None and mesh is None:
        plan = explore_result.plan          # already built for the best flow
    else:
        plan = _build_plan(cfg, flow, shape, mesh_axes=mesh_axes, rules=rules)
    build_s = time.perf_counter() - t0
    return CompiledModel(plan, mesh=mesh, explore_result=explore_result,
                         build_s=build_s)
