"""repro.tunedb — the persistent autotune database.

The paper's flow pays an expensive per-model optimization search once and
banks the outcome; AutoTVM-style stacks (Canopy's logged conv2d schedules)
make the same move explicit: *measured tuning records persist and
transfer*, so tuning cost is paid per (workload, device) — not per process.
This module is that store for the repro stack:

* :class:`TuneRecord` — one measured result: a structured JSON-safe key
  (model/shape/flow/device facts), its :func:`fingerprint`, the record
  ``kind`` (``"explore"`` for DSE searches, ``"serving"`` for the engine
  autotune's microbenches, ``"kernel"`` for per-kernel Pallas tile
  schedules), the measured ``value`` payload, the device key, and the
  code version the measurement was taken under.
* :class:`TuneDB` — an append-only JSONL file plus an in-memory index
  (last record per fingerprint wins).  Appends are single ``O_APPEND``
  writes, so concurrent writers interleave whole lines (never torn
  records); a truncated or corrupt trailing line from a killed writer is
  skipped with a warning on load, never a crash.  ``gc()`` compacts the
  log atomically (temp file + ``os.replace``).

Consumers: ``repro.core.dse.explore(db=...)`` serves exact-fingerprint
hits without re-measuring and warm-starts new searches from
nearest-neighbor records (:meth:`TuneDB.neighbors`);
``repro.serving.autotune`` banks its five microbench winners; the
``python -m repro.launch.tune`` CLI shows/compacts/exports a store.
Lookup outcomes are published as ``tunedb.{hits,misses,transfers}``
through :data:`repro.obs.METRICS` and bracketed by ``tunedb.*`` spans.

The module is jax-free: fingerprints hash canonical JSON, and the device
key is supplied by callers (``device_key()`` imports jax lazily).
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

from repro.obs import METRICS, TRACER

#: Bump when the meaning of a stored measurement changes (not merely when
#: new fields are added): records from another code version never serve
#: exact hits — they are reported stale (diagnostic T601) and re-measured.
CODE_VERSION = "pr10.1"

SCHEMA_VERSION = 1

KINDS = ("explore", "serving", "kernel")


# ---------------------------------------------------------------------------
# JSON-safe value encoding (tuples must round-trip: flow knobs carry them)
# ---------------------------------------------------------------------------

def encode_value(v: Any) -> Any:
    """Recursively encode ``v`` into JSON-safe structures.  Tuples become
    ``{"__tuple__": [...]}`` so :func:`decode_value` restores them exactly
    (flow knobs like ``mesh_split`` and tile shapes are tuples, and the
    winner must round-trip byte-identical)."""
    if isinstance(v, tuple):
        return {"__tuple__": [encode_value(x) for x in v]}
    if isinstance(v, list):
        return [encode_value(x) for x in v]
    if isinstance(v, dict):
        return {str(k): encode_value(x) for k, x in v.items()}
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    raise TypeError(f"tunedb cannot encode {type(v).__name__!r}: {v!r}")


def decode_value(v: Any) -> Any:
    """Inverse of :func:`encode_value`."""
    if isinstance(v, dict):
        if set(v) == {"__tuple__"}:
            return tuple(decode_value(x) for x in v["__tuple__"])
        return {k: decode_value(x) for k, x in v.items()}
    if isinstance(v, list):
        return [decode_value(x) for x in v]
    return v


def canonical_json(obj: Any) -> str:
    """Deterministic JSON: sorted keys, no whitespace variance."""
    return json.dumps(encode_value(obj), sort_keys=True,
                      separators=(",", ":"))


def fingerprint(key: Dict[str, Any]) -> str:
    """Stable hex fingerprint of a structured key dict."""
    import hashlib
    return hashlib.blake2b(canonical_json(key).encode(),
                           digest_size=16).hexdigest()


def device_key() -> str:
    """``"<backend>:<device kind>"`` of the default jax device — part of
    every fingerprint, so a record measured on one platform never serves
    another (the backend/device-kind cache-poisoning fix)."""
    try:
        import jax
        backend = jax.default_backend()
        kind = jax.devices()[0].device_kind
    except Exception:                           # pragma: no cover - no jax
        return "unknown:unknown"
    return f"{backend}:{kind}"


# ---------------------------------------------------------------------------
# records
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TuneRecord:
    """One persisted measurement."""
    kind: str                       # "explore" | "serving" | "kernel"
    fingerprint: str                # fingerprint(key)
    key: Dict[str, Any]             # the structured facts that were keyed
    value: Dict[str, Any]           # winner + measurements
    device: str                     # device_key() at measurement time
    code_version: str = CODE_VERSION
    schema: int = SCHEMA_VERSION
    created_s: float = 0.0          # wall time of the measurement

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"unknown record kind {self.kind!r}; "
                             f"expected one of {KINDS}")

    def to_json(self) -> str:
        return canonical_json(dataclasses.asdict(self))

    @classmethod
    def from_json(cls, line: str) -> "TuneRecord":
        d = decode_value(json.loads(line))
        if not isinstance(d, dict):
            raise ValueError("tunedb record line is not an object")
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})

    @classmethod
    def make(cls, kind: str, key: Dict[str, Any], value: Dict[str, Any], *,
             device: Optional[str] = None) -> "TuneRecord":
        return cls(kind=kind, fingerprint=fingerprint(key), key=key,
                   value=value,
                   device=device if device is not None else device_key(),
                   created_s=time.time())


# ---------------------------------------------------------------------------
# the store
# ---------------------------------------------------------------------------

class TuneDB:
    """Append-only JSONL store of :class:`TuneRecord` with an in-memory
    index (last record per fingerprint wins — re-tuning supersedes)."""

    def __init__(self, path: str) -> None:
        self.path = os.fspath(path)
        self._lock = threading.Lock()
        self._index: Dict[str, TuneRecord] = {}
        self.n_skipped = 0              # corrupt/truncated lines on load
        self._load()

    # -- loading -------------------------------------------------------------
    def _load(self) -> None:
        if not os.path.exists(self.path):
            return
        sp = TRACER.timed("tunedb.load", cat="tunedb", path=self.path)
        n_bad = 0
        with open(self.path, "r", encoding="utf-8") as f:
            for lineno, line in enumerate(f, 1):
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = TuneRecord.from_json(line)
                except (ValueError, TypeError, KeyError) as e:
                    n_bad += 1
                    warnings.warn(
                        f"tunedb: skipping corrupt record at "
                        f"{self.path}:{lineno} ({e})", stacklevel=2)
                    continue
                self._index[rec.fingerprint] = rec
        self.n_skipped = n_bad
        sp.end(n=len(self._index), skipped=n_bad)

    def reload(self) -> None:
        """Re-read the file (another process may have appended)."""
        with self._lock:
            self._index.clear()
            self._load()

    # -- writes --------------------------------------------------------------
    def put(self, rec: TuneRecord) -> TuneRecord:
        """Append one record.  The write is a single ``O_APPEND`` ``write()``
        of one full line, so concurrent writers (threads or processes)
        interleave whole records — a reader never sees a torn line from a
        completed write."""
        line = (rec.to_json() + "\n").encode("utf-8")
        sp = TRACER.timed("tunedb.store", cat="tunedb", kind=rec.kind)
        with self._lock:
            d = os.path.dirname(os.path.abspath(self.path))
            os.makedirs(d, exist_ok=True)
            fd = os.open(self.path,
                         os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
            try:
                os.write(fd, line)
            finally:
                os.close(fd)
            self._index[rec.fingerprint] = rec
        sp.end()
        return rec

    def record(self, kind: str, key: Dict[str, Any],
               value: Dict[str, Any]) -> TuneRecord:
        """Build (fingerprinting ``key``) and append one record."""
        return self.put(TuneRecord.make(kind, key, value))

    # -- lookup --------------------------------------------------------------
    def get(self, fp: str, *, code_version: Optional[str] = CODE_VERSION
            ) -> Optional[TuneRecord]:
        """The exact-fingerprint record, or None.  Records from a different
        code version are *not* served (pass ``code_version=None`` to see
        them anyway, e.g. for the CLI / gc)."""
        rec = self._index.get(fp)
        if rec is None:
            return None
        if code_version is not None and rec.code_version != code_version:
            return None
        return rec

    def lookup(self, key: Dict[str, Any], **kw) -> Optional[TuneRecord]:
        rec = self.get(fingerprint(key), **kw)
        if rec is not None:
            METRICS.counter("tunedb.hits").inc()
        else:
            METRICS.counter("tunedb.misses").inc()
        return rec

    def records(self, kind: Optional[str] = None) -> List[TuneRecord]:
        out = [r for r in self._index.values()
               if kind is None or r.kind == kind]
        return sorted(out, key=lambda r: (r.kind, r.fingerprint))

    def neighbors(self, kind: str, match: Dict[str, Any], *,
                  exclude: Optional[str] = None,
                  distance: Optional[Callable[[TuneRecord], float]] = None,
                  code_version: Optional[str] = CODE_VERSION
                  ) -> List[TuneRecord]:
        """Records of ``kind`` whose key agrees with every entry of
        ``match`` (the transfer axes are simply left out of ``match``),
        excluding fingerprint ``exclude``, nearest first when ``distance``
        is given.  This is the cross-config transfer query: e.g. match on
        (cfg, flow, device, validate mode) but not on the batch bucket, and
        the same workload tuned at a neighboring bucket comes back."""
        want = {k: encode_value(v) for k, v in match.items()}
        out = []
        for rec in self._index.values():
            if rec.kind != kind or rec.fingerprint == exclude:
                continue
            if code_version is not None and rec.code_version != code_version:
                continue
            enc = {k: encode_value(v) for k, v in rec.key.items()}
            if all(enc.get(k) == v for k, v in want.items()):
                out.append(rec)
        if distance is not None:
            out.sort(key=distance)
        else:
            out.sort(key=lambda r: r.fingerprint)
        return out

    # -- maintenance ---------------------------------------------------------
    def gc(self, *, drop_stale: bool = True) -> Dict[str, int]:
        """Compact the log: keep the indexed (latest) record per
        fingerprint, optionally dropping records from other code versions,
        and rewrite atomically (temp file + ``os.replace``)."""
        with self._lock:
            kept, dropped = [], 0
            for fp in sorted(self._index):
                rec = self._index[fp]
                if drop_stale and rec.code_version != CODE_VERSION:
                    dropped += 1
                    continue
                kept.append(rec)
            tmp = self.path + ".tmp"
            d = os.path.dirname(os.path.abspath(self.path))
            os.makedirs(d, exist_ok=True)
            with open(tmp, "w", encoding="utf-8") as f:
                for rec in kept:
                    f.write(rec.to_json() + "\n")
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.path)
            self._index = {r.fingerprint: r for r in kept}
            return {"kept": len(kept), "dropped_stale": dropped}

    def stats(self) -> Dict[str, Any]:
        by_kind: Dict[str, int] = {}
        stale = 0
        for rec in self._index.values():
            by_kind[rec.kind] = by_kind.get(rec.kind, 0) + 1
            if rec.code_version != CODE_VERSION:
                stale += 1
        return {"path": self.path, "records": len(self._index),
                "by_kind": dict(sorted(by_kind.items())), "stale": stale,
                "skipped_on_load": self.n_skipped}

    def __len__(self) -> int:
        return len(self._index)

    def __repr__(self) -> str:
        return f"<TuneDB {self.path!r} records={len(self._index)}>"


# ---------------------------------------------------------------------------
# process-level open-db cache (one index per path per process)
# ---------------------------------------------------------------------------

_OPEN: Dict[str, TuneDB] = {}
_OPEN_LOCK = threading.Lock()


def open_db(db: Any) -> Optional[TuneDB]:
    """Coerce ``db`` (TuneDB | path | None) into a TuneDB.  Paths are
    cached per process so every explore/autotune call against the same
    store shares one loaded index."""
    if db is None:
        return None
    if isinstance(db, TuneDB):
        return db
    path = os.path.abspath(os.fspath(db))
    with _OPEN_LOCK:
        inst = _OPEN.get(path)
        if inst is None:
            inst = TuneDB(path)
            _OPEN[path] = inst
        return inst


def close_all() -> None:
    """Drop the process-level path cache (tests)."""
    with _OPEN_LOCK:
        _OPEN.clear()


# ---------------------------------------------------------------------------
# structured-key helpers shared by the DSE and serving autotune
# ---------------------------------------------------------------------------

def config_facts(cfg: Any) -> Dict[str, Any]:
    """The model-config part of a key: name plus a content hash, so a
    same-named config with edited dimensions never serves stale records."""
    d = dataclasses.asdict(cfg)
    return {"name": cfg.name, "hash": fingerprint(d)}


def flow_facts(flow: Any) -> Dict[str, Any]:
    """The flow-knob part of a key: full FlowConfig content minus where the
    store itself lives (moving the db file must not orphan its records)."""
    d = dataclasses.asdict(flow)
    d.get("tuning", {}).pop("tune_db", None)
    return d


def shape_facts(shape: Any) -> Dict[str, Any]:
    return {"kind": shape.kind, "seq_len": shape.seq_len,
            "global_batch": shape.global_batch}
