"""llava-next-mistral-7b [vlm] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000; mistral-7b backbone; vision frontend is a STUB (input_specs
provides precomputed patch embeddings; anyres tiling = 576 base patches).
[hf:llava-hf/llava-v1.6-mistral-7b-hf]"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, d_ff=14336, vocab_size=32000,
    attention=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                              causal=True, rope="default", rope_base=1e6),
    ffn_kind="swiglu", norm_kind="rmsnorm",
    n_patch_tokens=576, d_vision=1024,
)

SMOKE = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=3, d_model=64, d_ff=192, vocab_size=256,
    attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16,
                              causal=True, rope="default"),
    ffn_kind="swiglu", norm_kind="rmsnorm",
    n_patch_tokens=4, d_vision=32,
)
