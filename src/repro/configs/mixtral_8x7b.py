"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000; 8 experts top-2; sliding-window attention 4096.
[arXiv:2401.04088]"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, d_ff=14336, vocab_size=32000,
    attention=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                              causal=True, window=4096, rope="default",
                              rope_base=1e6),
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=14336),
    ffn_kind="moe", norm_kind="rmsnorm",
)

SMOKE = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=3, d_model=64, d_ff=128, vocab_size=256,
    attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16,
                              causal=True, window=16, rope="default"),
    moe=MoEConfig(num_experts=4, top_k=2, d_expert=128, capacity_factor=4.0),
    ffn_kind="moe", norm_kind="rmsnorm",
)
