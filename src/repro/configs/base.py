"""Configuration dataclasses for the repro compilation flow.

A ``ModelConfig`` fully describes an architecture (the graph builder consumes
it); a ``ShapeConfig`` describes one input-shape cell (train / prefill /
decode / long-context-decode).  ``FlowConfig`` holds the knobs of the
compilation flow itself (which passes run, execution mode, precision,
distribution) — the analogue of the paper's optimization-application table.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                  # per-expert FFN hidden size
    num_shared: int = 0            # shared (always-on) experts
    d_shared: Optional[int] = None # hidden size of shared experts (default d_expert)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    first_dense_layers: int = 0    # leading layers that use a dense FFN instead
    first_dense_d_ff: int = 0      # hidden size of those dense FFNs

    @property
    def d_shared_eff(self) -> int:
        return self.d_shared if self.d_shared is not None else self.d_expert


@dataclass(frozen=True)
class AttentionConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    causal: bool = True
    window: Optional[int] = None          # sliding-window size (None = full)
    rope: Optional[str] = "default"       # None | default | partial
    rope_base: float = 10000.0
    rope_pct: float = 1.0                 # fraction of head_dim rotated
    qkv_bias: bool = False
    out_bias: bool = False
    logits_softcap: Optional[float] = None


@dataclass(frozen=True)
class RecurrenceConfig:
    """Config for linear-recurrence temporal mixing (RG-LRU / RWKV6)."""
    kind: str                      # "rg_lru" | "rwkv6"
    width: int                     # recurrence width (d for rg_lru)
    n_heads: int = 0               # rwkv6 heads (width // head size)
    head_dim: int = 64
    conv_width: int = 4            # temporal conv in front of RG-LRU
    lora_rank: int = 64            # rwkv6 data-dependent decay LoRA rank


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | vlm | audio | cnn
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    recurrence: Optional[RecurrenceConfig] = None
    # layer pattern: e.g. ("rec", "rec", "attn") repeated for recurrentgemma.
    # None => all layers identical ("attn" or "rec" depending on configs).
    layer_pattern: Optional[Tuple[str, ...]] = None
    ffn_kind: str = "swiglu"       # swiglu | geglu | gelu_mlp | rwkv_cm | moe
    norm_kind: str = "rmsnorm"     # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # encoder-decoder (whisper):
    n_encoder_layers: int = 0
    encoder_seq: int = 0           # frames produced by the (stubbed) frontend
    cross_attention: bool = False
    # multimodal stub (llava): number of prepended patch embeddings
    n_patch_tokens: int = 0
    d_vision: int = 1024           # vision-tower output dim (stub input)
    vocab_pad_multiple: int = 32   # Megatron-style vocab padding for TP
    max_seq_len: int = 1 << 20
    # CNN-family fields (paper's own networks); vocab_size doubles as n_classes
    image_size: int = 0
    image_channels: int = 3

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return (self.vocab_size + m - 1) // m * m

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer temporal-mixing kind, length n_layers."""
        if self.layer_pattern is None:
            kind = "rec" if (self.recurrence and self.attention is None) else "attn"
            return tuple([kind] * self.n_layers)
        pat = self.layer_pattern
        reps = (self.n_layers + len(pat) - 1) // len(pat)
        return tuple((pat * reps)[: self.n_layers])

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6·N·D)."""
        from repro.core.estimator import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.core.estimator import count_params
        return count_params(self, active_only=True)


# ---------------------------------------------------------------------------
# Input-shape cells
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str                      # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                      # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


# ---------------------------------------------------------------------------
# Flow (compilation) configuration — the paper's optimization knobs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TuningConfig:
    """Design-space-explorer settings (paper §IV-J factor selection, grown
    into the 'future work' DSE).  ``hbm_bytes`` is the paper's rule 3 — the
    device resource budget a candidate must fit (v5e HBM by default, but a
    first-class knob: other device generations/backends set it here)."""
    hbm_bytes: int = 16 * 1024 ** 3        # per-device budget (v5e default)
    vmem_candidates: Tuple[int, ...] = (96 * 2 ** 20, 48 * 2 ** 20)
    microbatch_candidates: Tuple[int, ...] = (1, 2, 4, 8)
    scan_unroll_candidates: Tuple[int, ...] = (1, 2, 4)
    ce_chunk_candidates: Tuple[int, ...] = (128, 256, 512)
    # kernel-backend dimension the KernelSelectPass exposes to the explorer:
    # "auto" resolves per-op through the KernelRegistry (Pallas on TPU),
    # "reference" pins the pure-XLA path everywhere.
    backend_candidates: Tuple[str, ...] = ("auto", "reference")
    top_k: int = 3                         # candidates validated compile-in-loop
    max_candidates: int = 16384            # enumeration safety cap
    # device count the ShardingPass enumerates dp/tp/pp mesh factorizations
    # for (0 => mesh is not a search dimension).  ``dse.explore`` sets it
    # from its ``devices`` argument; an explicit ``FlowConfig.mesh_split``
    # pins the factorization instead.
    mesh_devices: int = 0
    # path of the persistent autotune database (repro.tunedb): measured DSE
    # and serving-autotune results are written there and served back across
    # processes (exact-fingerprint hits measure nothing; neighboring batch
    # buckets warm-start).  None disables persistence; ``dse.explore(db=)``
    # and ``autotune_decode(db=)`` override per call.
    tune_db: Optional[str] = None


@dataclass(frozen=True)
class FlowConfig:
    # passes (paper Table I)
    fuse_epilogues: bool = True        # LF
    fold_layers: bool = True           # PK: scan over isomorphic groups
    cached_writes: bool = True         # CW: VMEM accumulation in kernels
    tile_select: bool = True           # LU/LT: BlockSpec tile selection
    precision: str = "bf16"            # OF: "fp32" (base) | "bf16" (optimized)
    streaming: bool = True             # CH/CE analogue: pipeline+overlap enabled
    # execution mode: "auto" picks folded for deep nets, pipelined for small
    mode: str = "auto"                 # auto | folded | pipelined
    # distribution
    dp_axes: Tuple[str, ...] = ("data",)
    tp_axis: Optional[str] = "model"
    pp_axis: Optional[str] = None      # set to "pod" for cross-pod pipelining
    # the chosen mesh factorization as ordered (axis, size) pairs, e.g.
    # (("data", 2), ("model", 2)).  Set by repro.flow.compile(mesh=...) from
    # the MeshSpec, or by the DSE when it searches dp/tp/pp splits; consumed
    # by the ShardingPass, which records the partitioning on the plan.
    mesh_split: Optional[Tuple[Tuple[str, int], ...]] = None
    microbatches: int = 1              # grad-accum / pipeline microbatches
    # training
    remat: str = "block"               # none | block | nested (two-level)
    grad_compression: Optional[str] = None  # None | "int8_ef"
    # kernels: "auto" resolves per op via the KernelRegistry (Pallas where an
    # implementation exists and the platform compiles it natively, reference
    # elsewhere); the explicit values pin one backend for every op.
    kernel_backend: str = "auto"       # auto | reference | pallas | pallas_interpret
    vmem_budget_bytes: int = 96 * 1024 * 1024  # v5e ~128MiB VMEM, leave headroom
    scan_unroll: int = 1
    ce_chunk: int = 256                # sequence-chunked CE logits block
    # per-kernel Pallas tile-schedule overrides as ordered (tile_key, tile)
    # pairs, e.g. (("attention", (128, 256)), ("conv2d", (16, 128))) — the
    # sub-plan-level tunables the tunedb records and the serving autotune's
    # tile microbench pins (KernelContract.tile_key names the join point).
    # Applied by the TilingPass on top of its own selection; None keeps the
    # selector's choices.
    tile_overrides: Optional[Tuple[Tuple[str, Any], ...]] = None
    # design-space exploration (repro.core.dse)
    tuning: TuningConfig = TuningConfig()

    def base(self) -> "FlowConfig":
        """The paper's *base* (unoptimized) configuration — every pass off."""
        return dataclasses.replace(
            self, fuse_epilogues=False, fold_layers=False, cached_writes=False,
            tile_select=False, precision="fp32", streaming=False, mode="folded",
            remat="none",
        )


def replace(cfg, **kw):
    return dataclasses.replace(cfg, **kw)
