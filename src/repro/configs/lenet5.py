"""LeNet-5 — the paper's pipelined-mode network (Keras/MNIST definition)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="lenet5", family="cnn", n_layers=5, d_model=120, d_ff=84,
    vocab_size=10, image_size=32, image_channels=1,
)

SMOKE = CONFIG  # already tiny
