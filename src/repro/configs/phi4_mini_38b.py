"""phi4-mini-3.8b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064, RoPE (partial 0.75) SwiGLU GQA.  [arXiv:2412.08905]"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, d_ff=8192, vocab_size=200064,
    attention=AttentionConfig(n_heads=24, n_kv_heads=8, head_dim=128,
                              causal=True, rope="partial", rope_base=10000.0,
                              rope_pct=0.75),
    ffn_kind="swiglu", norm_kind="rmsnorm", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=3, d_model=48, d_ff=128, vocab_size=256,
    attention=AttentionConfig(n_heads=3, n_kv_heads=1, head_dim=16,
                              causal=True, rope="partial", rope_pct=0.75),
    ffn_kind="swiglu", norm_kind="rmsnorm", tie_embeddings=True,
)
