"""whisper-small [audio] — enc-dec, 12L+12L d_model=768 12H (MHA kv=12)
d_ff=3072 vocab=51865 (padded to 51872 for TP); conv frontend is a STUB
(input_specs provides precomputed frame embeddings, encoder_seq=1500).
Decoder positions use sinusoids (deviation: HF uses learned embeddings, but
the assigned decode shapes exceed the trained 448-position table).
[arXiv:2212.04356]"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, d_ff=3072, vocab_size=51865,
    attention=AttentionConfig(n_heads=12, n_kv_heads=12, head_dim=64,
                              causal=True, rope=None),
    ffn_kind="gelu_mlp", norm_kind="layernorm", norm_eps=1e-5,
    n_encoder_layers=12, encoder_seq=1500, cross_attention=True,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=2, d_model=64, d_ff=128, vocab_size=256,
    attention=AttentionConfig(n_heads=4, n_kv_heads=4, head_dim=16,
                              causal=True, rope=None),
    ffn_kind="gelu_mlp", norm_kind="layernorm", norm_eps=1e-5,
    n_encoder_layers=2, encoder_seq=12, cross_attention=True,
    tie_embeddings=True,
)
