"""llama3.2-1b [dense] — 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256.  [hf:meta-llama/Llama-3.2-1B]"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, d_ff=8192, vocab_size=128256,
    attention=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=64,
                              causal=True, rope="default", rope_base=500000.0),
    ffn_kind="swiglu", norm_kind="rmsnorm", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="llama3.2-1b", family="dense",
    n_layers=3, d_model=64, d_ff=192, vocab_size=256,
    attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16,
                              causal=True, rope="default"),
    ffn_kind="swiglu", norm_kind="rmsnorm", tie_embeddings=True,
)
