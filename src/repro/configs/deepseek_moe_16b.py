"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (MHA kv=16) d_ff=1408
vocab=102400; 2 shared + 64 routed experts top-6, fine-grained; first layer
dense (d_ff 10944).  [arXiv:2401.06066]"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, d_ff=1408, vocab_size=102400,
    attention=AttentionConfig(n_heads=16, n_kv_heads=16, head_dim=128,
                              causal=True, rope="default", rope_base=10000.0),
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408, num_shared=2,
                  first_dense_layers=1, first_dense_d_ff=10944),
    ffn_kind="moe", norm_kind="rmsnorm",
)

SMOKE = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=3, d_model=64, d_ff=48, vocab_size=256,
    attention=AttentionConfig(n_heads=4, n_kv_heads=4, head_dim=16,
                              causal=True, rope="default"),
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=48, num_shared=2,
                  first_dense_layers=1, first_dense_d_ff=128,
                  capacity_factor=4.0),
    ffn_kind="moe", norm_kind="rmsnorm",
)
