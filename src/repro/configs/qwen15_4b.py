"""qwen1.5-4b [dense] — 40L d_model=2560 20H (MHA kv=20) d_ff=6912
vocab=151936, QKV bias.  [hf:Qwen/Qwen1.5-4B]"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, d_ff=6912, vocab_size=151936,
    attention=AttentionConfig(n_heads=20, n_kv_heads=20, head_dim=128,
                              causal=True, rope="default", rope_base=1e6,
                              qkv_bias=True),
    ffn_kind="swiglu", norm_kind="rmsnorm",
)

SMOKE = ModelConfig(
    name="qwen1.5-4b", family="dense",
    n_layers=3, d_model=64, d_ff=160, vocab_size=256,
    attention=AttentionConfig(n_heads=4, n_kv_heads=4, head_dim=16,
                              causal=True, rope="default", qkv_bias=True),
    ffn_kind="swiglu", norm_kind="rmsnorm",
)
