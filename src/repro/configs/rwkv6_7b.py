"""rwkv6-7b (Finch) [ssm] — 32L d_model=4096 (attention-free) d_ff=14336
vocab=65536; data-dependent decay time-mix + squared-relu channel-mix.
[arXiv:2404.05892]"""
from repro.configs.base import ModelConfig, RecurrenceConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, d_ff=14336, vocab_size=65536,
    recurrence=RecurrenceConfig(kind="rwkv6", width=4096, n_heads=64,
                                head_dim=64, lora_rank=64),
    layer_pattern=("rec",),
    ffn_kind="rwkv_cm", norm_kind="layernorm", norm_eps=1e-5,
)

SMOKE = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=3, d_model=64, d_ff=224, vocab_size=256,
    recurrence=RecurrenceConfig(kind="rwkv6", width=64, n_heads=4,
                                head_dim=16, lora_rank=8),
    layer_pattern=("rec",),
    ffn_kind="rwkv_cm", norm_kind="layernorm", norm_eps=1e-5,
)
