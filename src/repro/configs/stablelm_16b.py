"""stablelm-1.6b [dense] — 24L d_model=2048 32H (MHA kv=32) d_ff=5632
vocab=100352, LayerNorm, partial rotary 0.25, qkv bias.
[hf:stabilityai/stablelm-2-1_6b]"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, d_ff=5632, vocab_size=100352,
    attention=AttentionConfig(n_heads=32, n_kv_heads=32, head_dim=64,
                              causal=True, rope="partial", rope_base=10000.0,
                              rope_pct=0.25, qkv_bias=True),
    ffn_kind="swiglu", norm_kind="layernorm", norm_eps=1e-5,
)

SMOKE = ModelConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=3, d_model=64, d_ff=176, vocab_size=256,
    attention=AttentionConfig(n_heads=4, n_kv_heads=4, head_dim=16,
                              causal=True, rope="partial", rope_pct=0.25,
                              qkv_bias=True),
    ffn_kind="swiglu", norm_kind="layernorm", norm_eps=1e-5,
)
