"""MobileNetV1 — the paper's folded-mode network (1x1 convs are 94.9% of
multiply-adds: the parameterized-kernel workhorse).  [arXiv:1704.04861]"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mobilenetv1", family="cnn", n_layers=14, d_model=1024, d_ff=1024,
    vocab_size=1000, image_size=224, image_channels=3,
)

SMOKE = dataclasses.replace(CONFIG, image_size=64, vocab_size=16)
