"""Architecture registry: ``get_config(arch)`` / ``get_smoke(arch)`` and the
40-cell (arch × shape) table with long-context applicability."""
from __future__ import annotations

import importlib
from typing import Dict, List, Tuple

from repro.configs.base import (  # noqa: F401
    AttentionConfig, FlowConfig, ModelConfig, MoEConfig, RecurrenceConfig,
    ShapeConfig, SHAPES,
)

_MODULES: Dict[str, str] = {
    "recurrentgemma-2b": "recurrentgemma_2b",
    "qwen1.5-4b": "qwen15_4b",
    "phi4-mini-3.8b": "phi4_mini_38b",
    "stablelm-1.6b": "stablelm_16b",
    "llama3.2-1b": "llama32_1b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "mixtral-8x7b": "mixtral_8x7b",
    "rwkv6-7b": "rwkv6_7b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "whisper-small": "whisper_small",
    "lenet5": "lenet5",
    "mobilenetv1": "mobilenetv1",
    "resnet34": "resnet34",
}

ARCHS: List[str] = list(_MODULES)[:10]          # the ten assigned archs
CNNS: List[str] = list(_MODULES)[10:]           # the paper's own networks

# archs with sub-quadratic decode state: run long_500k; pure full-attention
# archs skip it (see DESIGN.md §Arch-applicability).
LONG_CONTEXT_OK = ("recurrentgemma-2b", "mixtral-8x7b", "rwkv6-7b")


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _mod(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _mod(name).SMOKE


def cells(include_skipped: bool = False) -> List[Tuple[str, str, bool]]:
    """The 40 (arch, shape, runnable) cells of the assignment."""
    out = []
    for a in ARCHS:
        for s in SHAPES:
            runnable = not (s == "long_500k" and a not in LONG_CONTEXT_OK)
            if runnable or include_skipped:
                out.append((a, s, runnable))
    return out
