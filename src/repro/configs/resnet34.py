"""ResNet-34 — the paper's largest network (846x base->optimized speedup)."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="resnet34", family="cnn", n_layers=34, d_model=512, d_ff=512,
    vocab_size=1000, image_size=224, image_channels=3,
)

SMOKE = dataclasses.replace(CONFIG, image_size=64, vocab_size=16)
