"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 ratio.

26L d_model=2560 10H (MQA kv=1, head_dim 256) d_ff=7680 vocab=256000.
Layer pattern (rec, rec, attn) repeating — 26 = 8x(R,R,A) + (R,R).
[arXiv:2402.19427]
"""
from repro.configs.base import AttentionConfig, ModelConfig, RecurrenceConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, d_ff=7680, vocab_size=256000,
    attention=AttentionConfig(n_heads=10, n_kv_heads=1, head_dim=256,
                              causal=True, window=2048, rope="default",
                              rope_base=10000.0),
    recurrence=RecurrenceConfig(kind="rg_lru", width=2560, conv_width=4),
    layer_pattern=("rec", "rec", "attn"),
    ffn_kind="geglu", norm_kind="rmsnorm", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=6, d_model=64, d_ff=192, vocab_size=256,
    attention=AttentionConfig(n_heads=2, n_kv_heads=1, head_dim=32,
                              causal=True, window=16, rope="default"),
    recurrence=RecurrenceConfig(kind="rg_lru", width=64, conv_width=4),
    layer_pattern=("rec", "rec", "attn"),
    ffn_kind="geglu", norm_kind="rmsnorm", tie_embeddings=True,
)
